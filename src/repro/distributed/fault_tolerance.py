"""Fault tolerance + elasticity for 1000+ node posture.

On a real multi-pod deployment every component below is driven by the
cluster controller; here each mechanism is implemented against jax device
lists so the logic is fully unit-testable on CPU:

  * HeartbeatMonitor — per-host liveness with EWMA step-time tracking;
    flags dead hosts (missed deadline) and stragglers (step time > k x
    fleet median, the paper's "slowest UPI path" analog at fleet scale).
  * ElasticMeshPlanner — given surviving hosts, picks the largest
    (data, model)-factorable mesh <= survivors, preferring to shrink the
    *data* axis (pure-DP slices are stateless beyond the data shard; the
    model axis is rebuilt only when a model-shard host dies).
  * recover() — the restart recipe: new mesh -> reshard checkpoint ->
    resume pipeline from the checkpointed step (deterministic pipeline:
    no data loss/duplication).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class HostState:
    last_seen: float
    step_time_ewma: float = 0.0


class HeartbeatMonitor:
    def __init__(self, hosts: Sequence[str], *, deadline_s: float = 60.0,
                 straggler_factor: float = 2.0, ewma: float = 0.9):
        self.deadline_s = deadline_s
        self.straggler_factor = straggler_factor
        self.ewma = ewma
        now = time.monotonic()
        self.hosts: dict[str, HostState] = {h: HostState(last_seen=now) for h in hosts}

    def beat(self, host: str, step_time_s: float | None = None, *, now: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        st = self.hosts.setdefault(host, HostState(last_seen=now))
        st.last_seen = now
        if step_time_s is not None:
            st.step_time_ewma = (
                step_time_s if st.step_time_ewma == 0.0
                else self.ewma * st.step_time_ewma + (1 - self.ewma) * step_time_s
            )

    def dead(self, *, now: float | None = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return [h for h, s in self.hosts.items() if now - s.last_seen > self.deadline_s]

    def stragglers(self) -> list[str]:
        times = [s.step_time_ewma for s in self.hosts.values() if s.step_time_ewma > 0]
        if len(times) < 2:
            return []
        med = float(np.median(times))
        return [
            h for h, s in self.hosts.items()
            if s.step_time_ewma > self.straggler_factor * med
        ]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    data: int
    model: int
    dropped_hosts: tuple[str, ...]

    @property
    def n_devices(self) -> int:
        return self.data * self.model


class ElasticMeshPlanner:
    """Choose the next mesh after failures.

    Invariants: model axis preserved if possible (model-sharded state is
    expensive to reshard); data axis shrinks to the largest count that
    divides the global batch (so per-shard batch stays integral).
    """

    def __init__(self, *, devices_per_host: int, model_axis: int, global_batch: int):
        self.devices_per_host = devices_per_host
        self.model_axis = model_axis
        self.global_batch = global_batch

    def plan(self, alive_hosts: Sequence[str], dead_hosts: Sequence[str]) -> MeshPlan:
        n_devices = len(alive_hosts) * self.devices_per_host
        model = self.model_axis
        while model > 1 and n_devices % model:
            model //= 2
        data = n_devices // model
        # shrink data until it divides the global batch
        while data > 1 and self.global_batch % data:
            data -= 1
        return MeshPlan(data=data, model=model, dropped_hosts=tuple(dead_hosts))


def straggler_safe_step_budget(step_times_s: Sequence[float], factor: float = 2.0) -> float:
    """Deadline for collective participation before a host is suspected."""
    if not step_times_s:
        return float("inf")
    return factor * float(np.median(np.asarray(step_times_s)))

"""Pipeline parallelism (GPipe schedule) via shard_map + collective_permute.

For the deep-narrow archs (granite-34b: 88 layers) a 'pipe' mesh axis can
replace part of the model axis. Implementation: layers are stacked and
sharded over 'pipe' (each rank holds n_layers/S contiguous stages);
microbatches stream through a lax.scan over M + S - 1 ticks; activations
hop stages with lax.ppermute. Reverse-mode autodiff of the scanned
schedule yields the standard GPipe backward (reverse hops) for free.

This is the forward/loss building block: `pipeline_forward` is exact —
tested equal to the sequential stack (value AND gradients) on a host mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat


def pipeline_forward(
    stage_params: Any,
    x_microbatches: jax.Array,  # (M, mb, ...) microbatched inputs
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    *,
    mesh: Mesh,
    axis: str = "pipe",
) -> jax.Array:
    """Run x through S pipeline stages; returns (M, mb, ...) outputs.

    stage_params: pytree whose leaves have a leading dim == S (sharded over
    ``axis``); stage_fn(params_slice, x) -> y applies ONE stage.
    """
    s_stages = mesh.shape[axis]
    m = x_microbatches.shape[0]
    ticks = m + s_stages - 1

    def per_rank(params_local, x_local):
        # params_local: leaves (1, ...) — this rank's stage
        params_one = jax.tree.map(lambda p: p[0], params_local)
        rank = jax.lax.axis_index(axis)
        mb_shape = x_local.shape[1:]
        buf_out = jnp.zeros((m,) + mb_shape, x_local.dtype)

        def tick(carry, t):
            held, buf = carry
            # stage 0 injects microbatch t (if in range); others use held
            inject = jnp.where(t < m, t, 0)
            x_in = jnp.where(rank == 0, x_local[inject], held)
            y = stage_fn(params_one, x_in)
            # pass to next stage; last stage's output is collected
            fwd = [(i, (i + 1) % s_stages) for i in range(s_stages)]
            passed = jax.lax.ppermute(y, axis, fwd)
            out_t = t - (s_stages - 1)
            write = jnp.where(out_t >= 0, out_t, 0)
            is_out = jnp.logical_and(rank == s_stages - 1, out_t >= 0)
            buf = jax.lax.cond(
                is_out,
                lambda b: jax.lax.dynamic_update_index_in_dim(b, y, write, 0),
                lambda b: b,
                buf,
            )
            return (passed, buf), None

        held0 = jnp.zeros(mb_shape, x_local.dtype)
        (_, buf_out), _ = jax.lax.scan(tick, (held0, buf_out), jnp.arange(ticks))
        # buf_out is zeros on every rank but the last (is_out guard), so a
        # psum over 'pipe' broadcasts the result to all ranks.
        return jax.lax.psum(buf_out, axis)

    in_specs = (
        jax.tree.map(lambda _: P(axis), stage_params),
        P(),  # microbatches replicated into every rank (stage 0 reads them)
    )
    fn = compat.shard_map(
        per_rank, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False
    )
    return fn(stage_params, x_microbatches)


def sequential_reference(
    stage_params: Any,
    x_microbatches: jax.Array,
    stage_fn: Callable[[Any, jax.Array], jax.Array],
) -> jax.Array:
    """Oracle: apply all stages in order to each microbatch."""
    s_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def one_mb(x):
        for i in range(s_stages):
            p_i = jax.tree.map(lambda p: p[i], stage_params)
            x = stage_fn(p_i, x)
        return x

    return jax.vmap(one_mb)(x_microbatches)

"""Logical-axis sharding resolver: DP / FSDP / TP / EP / SP as rules — plus
the SU3 lattice's site/halo sharding rules.

Every param carries logical axis names (models.common.ParamSpec); this module
maps them onto mesh axes with divisibility fallbacks — a dim that does not
divide its mesh axes is replicated instead (e.g. granite-34b's single KV
head under 16-way TP), and a mesh axis is never used twice in one spec.

This is the paper's placement lesson at datacenter scale: *every* array in
the system (params, optimizer moments, activations, KV caches, SSM states)
has an explicit placement decided here — nothing is ever "first-touched"
onto the wrong device and silently redistributed.

The lattice section at the bottom (``lattice_site_axes`` /
``lattice_site_spec`` / ``host_site_ranges`` / ``halo_spec``) is the same
lesson for the SU3 mesh: the site dimension shards host-major over the
(host, device) mesh so each host owns one contiguous site block, and the
halo model quantifies what a *stencil* kernel (Dslash-style neighbor access,
arXiv:1411.2087) would have to exchange across those block boundaries.  The
su3_bench multiply itself is site-local — no halo traffic moves today — but
the boundary geometry is what makes routing-by-locality and the (future)
stencil kernels priceable, so it is a first-class rule here rather than
folklore.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """logical axis name -> tuple of mesh axis names (in sharding order)."""

    data_axes: tuple[str, ...] = ("data",)  # batch / DP
    fsdp_axes: tuple[str, ...] = ("data",)  # param 'embed' dim / ZeRO
    model_axes: tuple[str, ...] = ("model",)  # TP / EP
    seq_axes: tuple[str, ...] = ()  # SP (long-context)

    def logical(self) -> dict[str, tuple[str, ...]]:
        return {
            "batch": self.data_axes,
            "embed": self.fsdp_axes,
            "vocab": self.model_axes,
            "heads": self.model_axes,
            "kv_heads": self.model_axes,
            "mlp": self.model_axes,
            "experts": self.model_axes,
            # 'latent' replicated: sharding MLA latent dims over model was
            # tried and REFUTED (§Perf it.2: resharding between the latent-
            # sharded down-projection outputs and the head-sharded
            # up-projections cost more than the saved param-grad reductions:
            # 148.4s -> 154.6s collective on the 671B train cell).
            "latent": (),
            "seq": self.seq_axes,
            "layers": (),
        }


def default_rules(mesh: Mesh, *, fsdp: bool = True) -> MeshRules:
    """Production defaults for the assignment meshes.

    single-pod (data, model):   DP over data, FSDP over data, TP/EP over model
    multi-pod (pod, data, model): DP over (pod, data), FSDP over (pod, data)
    """
    names = mesh.axis_names
    if "pod" in names:
        dp = ("pod", "data")
    else:
        dp = ("data",)
    return MeshRules(
        data_axes=dp,
        fsdp_axes=dp if fsdp else (),
        model_axes=("model",),
    )


def _axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def resolve_spec(
    axes: tuple[str | None, ...], shape: tuple[int, ...], mesh: Mesh, rules: MeshRules
) -> P:
    """Logical axes + concrete shape -> PartitionSpec with fallbacks."""
    table = rules.logical()
    used: set[str] = set()
    out: list[Any] = []
    for dim, name in zip(shape, axes):
        assignment: Any = None
        if name is not None:
            mesh_axes = tuple(a for a in table.get(name, ()) if a not in used)
            if mesh_axes and dim % _axis_size(mesh, mesh_axes) == 0:
                assignment = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
                used.update(mesh_axes)
        out.append(assignment)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_shardings(
    spec_tree: common.SpecTree, mesh: Mesh, rules: MeshRules
) -> Any:
    """ParamSpec tree -> NamedSharding tree (params, grads and adam moments)."""

    def one(s: common.ParamSpec) -> NamedSharding:
        return NamedSharding(mesh, resolve_spec(s.axes, s.shape, mesh, rules))

    return jax.tree.map(one, spec_tree, is_leaf=lambda x: isinstance(x, common.ParamSpec))


def opt_state_shardings(param_sh: Any, mesh: Mesh) -> dict[str, Any]:
    return {
        "m": param_sh,
        "v": param_sh,
        "count": NamedSharding(mesh, P()),
    }


def batch_shardings(
    specs: dict[str, jax.ShapeDtypeStruct], mesh: Mesh, rules: MeshRules
) -> dict[str, NamedSharding]:
    """Input batches shard on the leading (batch) dim over the DP axes."""
    out = {}
    for name, sds in specs.items():
        dp = tuple(a for a in rules.data_axes)
        if sds.shape and sds.shape[0] % _axis_size(mesh, dp) == 0:
            spec = P(dp if len(dp) > 1 else dp[0])
        else:
            spec = P()
        out[name] = NamedSharding(mesh, spec)
    return out


# -- decode/prefill state (KV caches, SSM states) ---------------------------
#
# State leaves are identified by key name + rank. Layout contracts:
#   k/v            (L, B, S, H_kv, D)   batch->dp, kv heads->model if divisible
#   self_k/self_v  (L, B, S, H, D)      same
#   cross_k/cross_v(L, B, F, H, D)      same
#   ckv/k_rope     (L, B, S, R)         batch->dp (latent: replicated model)
#   ssm            (L, B, H, P, N)      batch->dp, ssm heads->model
#   conv           (L, B, K, C)         batch->dp, channels->model
#   c (mlstm)      (B, H, P, P) | slstm (B, E)
#   n              (B, H, P) | (B, E);  m (B, H) | (B, E);  h (B, E)


def _state_spec_for(
    key: str, shape: tuple[int, ...], mesh: Mesh, rules: MeshRules,
    *, kv_seq_shard: bool = False,
) -> P:
    """State-leaf PartitionSpec by key name + rank.

    ``kv_seq_shard``: when KV heads cannot shard over the model axis (GQA
    with kv_heads < model size), shard the cache *sequence* dim over the
    model axis instead (flash-decoding style) — the §Perf fix for the
    decode cells whose replicated caches exceed HBM.
    """
    model = rules.model_axes
    msize = _axis_size(mesh, model)
    mx = model if len(model) > 1 else (model[0] if model else None)
    dsize = _axis_size(mesh, rules.data_axes)

    def d_if(dim: int):
        if rules.data_axes and dim % dsize == 0:
            return rules.data_axes if len(rules.data_axes) > 1 else rules.data_axes[0]
        return None

    def m_if(dim: int):
        return mx if mx is not None and dim % msize == 0 else None

    name = key.split("/")[-1]
    r = len(shape)
    if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v") and r == 5:
        # (L, B, S, H, D)
        h_ax = m_if(shape[3])
        s_ax = m_if(shape[2]) if (kv_seq_shard and h_ax is None) else None
        return P(None, d_if(shape[1]), s_ax, h_ax, None)
    if name in ("k", "v") and r == 4:  # unstacked (B, S, H, D)
        h_ax = m_if(shape[2])
        s_ax = m_if(shape[1]) if (kv_seq_shard and h_ax is None) else None
        return P(d_if(shape[0]), s_ax, h_ax, None)
    if name in ("ckv", "k_rope") and r == 4:  # (L, B, S, R) MLA latent
        s_ax = m_if(shape[2]) if kv_seq_shard else None
        return P(None, d_if(shape[1]), s_ax, None)
    if name in ("ckv", "k_rope") and r == 3:
        s_ax = m_if(shape[1]) if kv_seq_shard else None
        return P(d_if(shape[0]), s_ax, None)
    if name == "ssm" and r == 5:  # (L, B, H, P, N)
        return P(None, d_if(shape[1]), m_if(shape[2]), None, None)
    if name == "ssm" and r == 4:
        return P(d_if(shape[0]), m_if(shape[1]), None, None)
    if name == "conv" and r == 4:  # (L, B, K, C)
        return P(None, d_if(shape[1]), None, m_if(shape[3]))
    if name == "conv" and r == 3:
        return P(d_if(shape[0]), None, m_if(shape[2]))
    if r >= 2:  # xlstm scalar states etc: (B, ...) batch-sharded
        return P(*((d_if(shape[0]),) + (None,) * (r - 1)))
    return P()


def state_shardings(
    state_spec_tree: Any, mesh: Mesh, rules: MeshRules, *, kv_seq_shard: bool = False
) -> Any:
    """ShapeDtypeStruct state tree -> NamedSharding tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_spec_tree)
    out = []
    for path, sds in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", ""))) for p in path
        )
        out.append(
            NamedSharding(
                mesh,
                _state_spec_for(key, sds.shape, mesh, rules, kv_seq_shard=kv_seq_shard),
            )
        )
    return jax.tree_util.tree_unflatten(jax.tree.structure(state_spec_tree), out)


# ---------------------------------------------------------------------------
# SU3 lattice: site sharding over (host, device) meshes + halo/boundary rules
# ---------------------------------------------------------------------------

# Imported lazily-by-name to keep this module importable without the SU3
# stack; the constants are small and stable.
LATTICE_SITE_AXIS = "sites"  # legacy 1-D mesh axis
LATTICE_HOST_AXIS = "hosts"
LATTICE_DEVICE_AXIS = "devices"

_GAUGE_WORDS_PER_SITE = 72  # 4 links x 3x3 complex = 36 c64 entries = 72 words
VECTOR_WORDS_PER_SITE = 6  # one color 3-vector, planar re+im — stencil halo

# storage word widths, duplicated from core.su3.layouts.WORD_BYTES so this
# module stays importable without the SU3 stack (see note above).
_WORD_BYTES = {"float32": 4, "bfloat16": 2, "float64": 8}


def lattice_site_axes(mesh: Mesh) -> tuple[str, ...]:
    """The mesh axes the lattice site dimension shards over, in major order.

    * legacy 1-D mesh      -> ``("sites",)``
    * (host, device) mesh  -> ``("hosts", "devices")`` — host-major, so one
      host's sites are contiguous (the invariant first-touch init and the
      halo model below rely on)
    * anything else        -> every mesh axis, in mesh order (an explicit
      choice: an SU3 plan handed a foreign mesh flattens it).
    """
    names = tuple(mesh.axis_names)
    if LATTICE_SITE_AXIS in names:
        return (LATTICE_SITE_AXIS,)
    if LATTICE_HOST_AXIS in names and LATTICE_DEVICE_AXIS in names:
        return (LATTICE_HOST_AXIS, LATTICE_DEVICE_AXIS)
    return names


def lattice_site_spec(codec: Any, mesh: Mesh) -> P:
    """PartitionSpec sharding ``codec``'s physical site axis over ``mesh``.

    Args:
        codec: a ``repro.core.su3.layouts.LayoutCodec`` (anything with a
            ``site_spec(site_axes)`` method).
        mesh: 1-D site mesh or (host, device) mesh.

    Returns:
        The codec's physical-layout PartitionSpec with the site dimension
        assigned to :func:`lattice_site_axes`.
    """
    return codec.site_spec(lattice_site_axes(mesh))


def lattice_is_multi_host(mesh: Mesh) -> bool:
    """True when ``mesh`` carries a host axis of size > 1."""
    return (
        LATTICE_HOST_AXIS in mesh.axis_names
        and int(mesh.shape[LATTICE_HOST_AXIS]) > 1
    )


def host_site_ranges(n_sites: int, mesh: Mesh) -> list[tuple[int, int]]:
    """Per-host contiguous site ranges ``[(lo, hi), ...]`` under the lattice
    sharding.

    ``n_sites`` must divide evenly over the host axis (plans pad the lattice
    to a whole number of per-device tiles, which guarantees it).  On a 1-D /
    single-host mesh this is one range covering everything.
    """
    hosts = (
        int(mesh.shape[LATTICE_HOST_AXIS])
        if LATTICE_HOST_AXIS in mesh.axis_names
        else 1
    )
    if n_sites % hosts:
        raise ValueError(
            f"{n_sites} sites do not divide over {hosts} hosts; pad the "
            f"lattice (plans do this) before asking for host ranges"
        )
    per = n_sites // hosts
    return [(h * per, (h + 1) * per) for h in range(hosts)]


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Boundary geometry of one host's lattice shard.

    The L^4 lattice is sharded along the outermost (t) dimension, so a host
    shard of ``sites_per_host`` sites is a slab of ``sites_per_host / L**3``
    t-slices; its boundary toward each neighbor is one L^3 face.  A stencil
    kernel with nearest-neighbor access (Dslash-like) exchanges both faces
    per application — that is the halo traffic priced here.  The su3_bench
    multiply is site-local and moves none of it; the spec exists so routing
    and future stencil plans can reason about the boundary *before* any
    kernel is written (the paper's measure-the-napkin-first method).

    Attributes:
        L: lattice extent (the lattice is L^4 sites).
        n_shards: how many contiguous site slabs the lattice splits into
            (the mesh's host-axis size).
        word_bytes: storage word width (4 = f32, 2 = bf16 storage plans).
        words_per_site: planar words of the *exchanged* field per site.
            Default 72 (the full gauge field — what a link-field stencil
            would move); a vector-field stencil (Dslash-style, the
            ``ExecutionPlan.stencil_step`` workload) exchanges color
            3-vectors and prices 6 (:data:`VECTOR_WORDS_PER_SITE`).
        depth: ghost-zone thickness in faces.  depth=1 is the classic
            nearest-neighbor halo; depth=2 prices the communication-avoiding
            exchange that feeds TWO stencil applications per transfer (the
            ``ExecutionPlan.stencil_step(depth=2)`` schedule): twice the
            payload per exchange, half as many exchanges per application.
            The interior/boundary split (``boundary_ranges`` /
            ``interior_ranges``) stays depth-1 — it describes one
            application's recompute schedule — while ``ghost_ranges`` and
            the exchange pricing widen with the depth.
    """

    L: int
    n_shards: int
    word_bytes: int = 4
    words_per_site: int = _GAUGE_WORDS_PER_SITE
    depth: int = 1

    @property
    def sites_per_shard(self) -> int:
        return self.L**4 // self.n_shards

    @property
    def face_sites(self) -> int:
        """Sites in one boundary face of a slab (an L^3 time-slice)."""
        return self.L**3

    @property
    def boundary_sites(self) -> int:
        """Sites on a shard's surface: two faces (periodic lattice), zero
        when the lattice is unsharded — capped at the slab size when the
        slab is thinner than two faces (``n_shards > L/2`` degeneracy,
        where every site of the shard is surface)."""
        if self.n_shards == 1:
            return 0
        return min(2 * self.face_sites, self.sites_per_shard)

    @property
    def halo_sites(self) -> int:
        """Sites one shard sends per exchange at this spec's ``depth``: two
        faces of thickness ``depth``, capped at the slab size (a shard can
        never ship more than it owns).  Equals :attr:`boundary_sites` at
        depth 1."""
        if self.n_shards == 1:
            return 0
        return min(2 * self.depth * self.face_sites, self.sites_per_shard)

    @property
    def interior_fraction(self) -> float:
        """Fraction of a shard's sites that touch no boundary — the locality
        argument for routing work to the host that holds the shard."""
        if self.sites_per_shard == 0:
            return 0.0
        return max(0.0, 1.0 - self.boundary_sites / self.sites_per_shard)

    @property
    def halo_bytes_per_exchange(self) -> int:
        """Bytes one shard sends per EXCHANGE: the exchanged field's words
        on both depth-thick faces, at storage width (metadata never
        travels).  ``words_per_site`` picks the payload: 72 (gauge field,
        the default) or 6 (the Dslash vector field).  At ``depth > 1`` an
        exchange costs proportionally more but amortizes over ``depth``
        stencil applications — per-application bytes are
        ``halo_bytes_per_exchange / depth``."""
        return self.halo_sites * self.words_per_site * self.word_bytes

    # -- interior/boundary/ghost site decomposition ---------------------------
    #
    # The ranges below are what the overlap-scheduled stencil dispatches on:
    # interior sites (no remote neighbor) compute while the ghost transfer is
    # in flight; boundary sites wait for it.  All ranges are GLOBAL site-id
    # half-open intervals; for every shard, interior_ranges(shard) +
    # boundary_ranges(shard) partition [lo, hi) exactly (disjoint, covering).

    def shard_range(self, shard: int) -> tuple[int, int]:
        """Global ``[lo, hi)`` site range of ``shard``'s contiguous slab."""
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range [0, {self.n_shards})")
        per = self.sites_per_shard
        return shard * per, (shard + 1) * per

    def boundary_ranges(self, shard: int) -> list[tuple[int, int]]:
        """Ranges of ``shard``'s sites whose +-t neighbors are remote: the
        slab's first and last L^3 faces.  Degenerate slabs (thinner than two
        faces) are all boundary — ONE range covering the slab, never
        double-counted.  Empty when the lattice is unsharded."""
        lo, hi = self.shard_range(shard)
        if self.n_shards == 1:
            return []
        per, face = self.sites_per_shard, self.face_sites
        b_lo = min(face, per)
        b_hi = min(face, per - b_lo)
        out = [(lo, lo + b_lo)]
        if b_hi:
            out.append((hi - b_hi, hi))
        return out

    def interior_ranges(self, shard: int) -> list[tuple[int, int]]:
        """Ranges of ``shard``'s sites with every neighbor shard-local —
        the whole slab when unsharded, empty when the slab is all surface."""
        lo, hi = self.shard_range(shard)
        if self.n_shards == 1:
            return [(lo, hi)]
        per, face = self.sites_per_shard, self.face_sites
        b_lo = min(face, per)
        b_hi = min(face, per - b_lo)
        if lo + b_lo >= hi - b_hi:
            return []
        return [(lo + b_lo, hi - b_hi)]

    def ghost_ranges(self, shard: int) -> list[tuple[int, int]]:
        """REMOTE global site ranges ``shard`` must receive per exchange:
        the sites within ``depth`` +-t faces of its boundary (the facing
        faces of the neighboring slabs, wrap-split at the periodic seam).
        Empty when the lattice is unsharded.

        depth=1 reproduces the classic nearest-neighbor ghost faces exactly
        (same shift-based derivation, including the degenerate sub-face-slab
        cuts); depth>1 unions the faces at distance 1..depth and merges
        overlapping segments (thin lattices wrap the two sides into each
        other before the cap does).
        """
        if self.n_shards == 1:
            return []
        S = self.L**4
        face = self.face_sites
        out: list[tuple[int, int]] = []
        for b_lo, b_hi in self.boundary_ranges(shard):
            for k in range(1, self.depth + 1):
                for shift in (k * face, -k * face):  # +t then -t neighbors
                    g_lo = (b_lo + shift) % S
                    g_hi = g_lo + (b_hi - b_lo)
                    if g_hi <= S:
                        segs = [(g_lo, g_hi)]
                    else:  # periodic wrap: split at the seam
                        segs = [(g_lo, S), (0, g_hi - S)]
                    lo_s, hi_s = self.shard_range(shard)
                    for lo, hi in segs:
                        # a degenerate slab's shifted face can land (partly)
                        # inside the shard itself; only remote sites are ghosts
                        cut_lo = max(lo, min(hi, lo_s))
                        cut_hi = max(lo, min(hi, hi_s))
                        if lo < cut_lo:
                            out.append((lo, cut_lo))
                        if cut_hi < hi:
                            out.append((cut_hi, hi))
        ranges = sorted(set(out))
        if self.depth == 1:
            return ranges  # byte-identical to the pre-depth behavior
        merged: list[tuple[int, int]] = []
        for lo, hi in ranges:
            if merged and lo <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], hi))
            else:
                merged.append((lo, hi))
        return merged

    def as_dict(self) -> dict[str, Any]:
        d = {
            "L": self.L,
            "n_shards": self.n_shards,
            "sites_per_shard": self.sites_per_shard,
            "boundary_sites": self.boundary_sites,
            "interior_fraction": round(self.interior_fraction, 4),
            "halo_bytes_per_exchange": self.halo_bytes_per_exchange,
        }
        if self.depth != 1:  # depth-1 dicts stay byte-identical to pre-depth rows
            d["depth"] = self.depth
        return d


def halo_spec(
    L: int,
    mesh: Mesh,
    word_bytes: int | None = None,
    *,
    dtype: str | None = None,
    words_per_site: int = _GAUGE_WORDS_PER_SITE,
    depth: int = 1,
) -> HaloSpec:
    """The halo/boundary spec of an L^4 lattice sharded over ``mesh``'s host
    axis (n_shards=1 on single-host meshes: no boundary, no halo).

    Args:
        L: lattice extent.
        mesh: the lattice mesh; only its host-axis size matters here.
        word_bytes: explicit storage word width.  Prefer ``dtype``; when both
            are given they must agree (an explicit 4 with dtype="bfloat16"
            was exactly the silent mispricing this signature fixes).
        dtype: storage dtype name (``"float32"``/``"bfloat16"``/...) — the
            plan-consistent way to price bf16-storage lattices at 2 B/word,
            matching how ``TrafficModel.for_dtype`` charges them.
        words_per_site: exchanged-field payload (72 = gauge links, the
            default; 6 = the stencil's color vectors).
        depth: ghost-zone thickness in faces (2 = the communication-avoiding
            two-applications-per-exchange schedule).
    """
    hosts = (
        int(mesh.shape[LATTICE_HOST_AXIS])
        if LATTICE_HOST_AXIS in mesh.axis_names
        else 1
    )
    if L**4 % hosts:
        raise ValueError(f"L={L} lattice does not shard over {hosts} hosts")
    if dtype is not None:
        from_dtype = _WORD_BYTES[dtype]
        if word_bytes is not None and word_bytes != from_dtype:
            raise ValueError(
                f"word_bytes={word_bytes} contradicts dtype={dtype!r} "
                f"({from_dtype} B/word); pass one or the other"
            )
        word_bytes = from_dtype
    return HaloSpec(
        L=L,
        n_shards=hosts,
        word_bytes=4 if word_bytes is None else word_bytes,
        words_per_site=words_per_site,
        depth=depth,
    )

"""Logical-axis sharding resolver: DP / FSDP / TP / EP / SP as rules.

Every param carries logical axis names (models.common.ParamSpec); this module
maps them onto mesh axes with divisibility fallbacks — a dim that does not
divide its mesh axes is replicated instead (e.g. granite-34b's single KV
head under 16-way TP), and a mesh axis is never used twice in one spec.

This is the paper's placement lesson at datacenter scale: *every* array in
the system (params, optimizer moments, activations, KV caches, SSM states)
has an explicit placement decided here — nothing is ever "first-touched"
onto the wrong device and silently redistributed.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """logical axis name -> tuple of mesh axis names (in sharding order)."""

    data_axes: tuple[str, ...] = ("data",)  # batch / DP
    fsdp_axes: tuple[str, ...] = ("data",)  # param 'embed' dim / ZeRO
    model_axes: tuple[str, ...] = ("model",)  # TP / EP
    seq_axes: tuple[str, ...] = ()  # SP (long-context)

    def logical(self) -> dict[str, tuple[str, ...]]:
        return {
            "batch": self.data_axes,
            "embed": self.fsdp_axes,
            "vocab": self.model_axes,
            "heads": self.model_axes,
            "kv_heads": self.model_axes,
            "mlp": self.model_axes,
            "experts": self.model_axes,
            # 'latent' replicated: sharding MLA latent dims over model was
            # tried and REFUTED (§Perf it.2: resharding between the latent-
            # sharded down-projection outputs and the head-sharded
            # up-projections cost more than the saved param-grad reductions:
            # 148.4s -> 154.6s collective on the 671B train cell).
            "latent": (),
            "seq": self.seq_axes,
            "layers": (),
        }


def default_rules(mesh: Mesh, *, fsdp: bool = True) -> MeshRules:
    """Production defaults for the assignment meshes.

    single-pod (data, model):   DP over data, FSDP over data, TP/EP over model
    multi-pod (pod, data, model): DP over (pod, data), FSDP over (pod, data)
    """
    names = mesh.axis_names
    if "pod" in names:
        dp = ("pod", "data")
    else:
        dp = ("data",)
    return MeshRules(
        data_axes=dp,
        fsdp_axes=dp if fsdp else (),
        model_axes=("model",),
    )


def _axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def resolve_spec(
    axes: tuple[str | None, ...], shape: tuple[int, ...], mesh: Mesh, rules: MeshRules
) -> P:
    """Logical axes + concrete shape -> PartitionSpec with fallbacks."""
    table = rules.logical()
    used: set[str] = set()
    out: list[Any] = []
    for dim, name in zip(shape, axes):
        assignment: Any = None
        if name is not None:
            mesh_axes = tuple(a for a in table.get(name, ()) if a not in used)
            if mesh_axes and dim % _axis_size(mesh, mesh_axes) == 0:
                assignment = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
                used.update(mesh_axes)
        out.append(assignment)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_shardings(
    spec_tree: common.SpecTree, mesh: Mesh, rules: MeshRules
) -> Any:
    """ParamSpec tree -> NamedSharding tree (params, grads and adam moments)."""

    def one(s: common.ParamSpec) -> NamedSharding:
        return NamedSharding(mesh, resolve_spec(s.axes, s.shape, mesh, rules))

    return jax.tree.map(one, spec_tree, is_leaf=lambda x: isinstance(x, common.ParamSpec))


def opt_state_shardings(param_sh: Any, mesh: Mesh) -> dict[str, Any]:
    return {
        "m": param_sh,
        "v": param_sh,
        "count": NamedSharding(mesh, P()),
    }


def batch_shardings(
    specs: dict[str, jax.ShapeDtypeStruct], mesh: Mesh, rules: MeshRules
) -> dict[str, NamedSharding]:
    """Input batches shard on the leading (batch) dim over the DP axes."""
    out = {}
    for name, sds in specs.items():
        dp = tuple(a for a in rules.data_axes)
        if sds.shape and sds.shape[0] % _axis_size(mesh, dp) == 0:
            spec = P(dp if len(dp) > 1 else dp[0])
        else:
            spec = P()
        out[name] = NamedSharding(mesh, spec)
    return out


# -- decode/prefill state (KV caches, SSM states) ---------------------------
#
# State leaves are identified by key name + rank. Layout contracts:
#   k/v            (L, B, S, H_kv, D)   batch->dp, kv heads->model if divisible
#   self_k/self_v  (L, B, S, H, D)      same
#   cross_k/cross_v(L, B, F, H, D)      same
#   ckv/k_rope     (L, B, S, R)         batch->dp (latent: replicated model)
#   ssm            (L, B, H, P, N)      batch->dp, ssm heads->model
#   conv           (L, B, K, C)         batch->dp, channels->model
#   c (mlstm)      (B, H, P, P) | slstm (B, E)
#   n              (B, H, P) | (B, E);  m (B, H) | (B, E);  h (B, E)


def _state_spec_for(
    key: str, shape: tuple[int, ...], mesh: Mesh, rules: MeshRules,
    *, kv_seq_shard: bool = False,
) -> P:
    """State-leaf PartitionSpec by key name + rank.

    ``kv_seq_shard``: when KV heads cannot shard over the model axis (GQA
    with kv_heads < model size), shard the cache *sequence* dim over the
    model axis instead (flash-decoding style) — the §Perf fix for the
    decode cells whose replicated caches exceed HBM.
    """
    model = rules.model_axes
    msize = _axis_size(mesh, model)
    mx = model if len(model) > 1 else (model[0] if model else None)
    dsize = _axis_size(mesh, rules.data_axes)

    def d_if(dim: int):
        if rules.data_axes and dim % dsize == 0:
            return rules.data_axes if len(rules.data_axes) > 1 else rules.data_axes[0]
        return None

    def m_if(dim: int):
        return mx if mx is not None and dim % msize == 0 else None

    name = key.split("/")[-1]
    r = len(shape)
    if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v") and r == 5:
        # (L, B, S, H, D)
        h_ax = m_if(shape[3])
        s_ax = m_if(shape[2]) if (kv_seq_shard and h_ax is None) else None
        return P(None, d_if(shape[1]), s_ax, h_ax, None)
    if name in ("k", "v") and r == 4:  # unstacked (B, S, H, D)
        h_ax = m_if(shape[2])
        s_ax = m_if(shape[1]) if (kv_seq_shard and h_ax is None) else None
        return P(d_if(shape[0]), s_ax, h_ax, None)
    if name in ("ckv", "k_rope") and r == 4:  # (L, B, S, R) MLA latent
        s_ax = m_if(shape[2]) if kv_seq_shard else None
        return P(None, d_if(shape[1]), s_ax, None)
    if name in ("ckv", "k_rope") and r == 3:
        s_ax = m_if(shape[1]) if kv_seq_shard else None
        return P(d_if(shape[0]), s_ax, None)
    if name == "ssm" and r == 5:  # (L, B, H, P, N)
        return P(None, d_if(shape[1]), m_if(shape[2]), None, None)
    if name == "ssm" and r == 4:
        return P(d_if(shape[0]), m_if(shape[1]), None, None)
    if name == "conv" and r == 4:  # (L, B, K, C)
        return P(None, d_if(shape[1]), None, m_if(shape[3]))
    if name == "conv" and r == 3:
        return P(d_if(shape[0]), None, m_if(shape[2]))
    if r >= 2:  # xlstm scalar states etc: (B, ...) batch-sharded
        return P(*((d_if(shape[0]),) + (None,) * (r - 1)))
    return P()


def state_shardings(
    state_spec_tree: Any, mesh: Mesh, rules: MeshRules, *, kv_seq_shard: bool = False
) -> Any:
    """ShapeDtypeStruct state tree -> NamedSharding tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_spec_tree)
    out = []
    for path, sds in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", ""))) for p in path
        )
        out.append(
            NamedSharding(
                mesh,
                _state_spec_for(key, sds.shape, mesh, rules, kv_seq_shard=kv_seq_shard),
            )
        )
    return jax.tree_util.tree_unflatten(jax.tree.structure(state_spec_tree), out)

"""Activation sharding constraints — placement for every major intermediate.

XLA's SPMD propagation through nested while loops (layer scan x microbatch
scan x attention chunk scan) can drop the batch sharding of loop carries and
remat-saved residuals: observed on the qwen3 train cell as unsharded
(36, 64, 4096, d) fp32 stacks = 22 GiB/device of dead weight. Pinning the
canonical activations at block boundaries keeps every saved buffer sharded
— the paper's "data must live where compute happens" applied to activations.

Models call ``shard(x, kind)``; a no-op unless a launcher has installed
rules via ``use_rules`` (smoke tests on one device run unconstrained).
"""
from __future__ import annotations

import contextlib
from typing import Iterator

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import MeshRules, _axis_size

_STATE: dict[str, object] = {"mesh": None, "rules": None}

# kind -> logical axis per dim (None = replicated). 'model' entries fall
# back to replicated when the dim does not divide the model axis.
KINDS: dict[str, tuple[str | None, ...]] = {
    "btd": ("data", None, None),  # (batch, seq, d_model)
    "btf": ("data", None, "model"),  # (batch, seq, d_ff/d_inner)
    "bthd": ("data", None, "model", None),  # (batch, seq, heads, head_dim)
    "btv": ("data", None, "model"),  # logits (batch, seq, vocab)
    "bt": ("data", None),  # per-token scalars
    "gecd": ("data", "model", None, None),  # MoE capacity buffer (G,E,C,d)
    "becf": ("data", "model", None, "model2"),  # unused placeholder
    "bhpn": ("data", "model", None, None),  # SSM state (b, heads, p, n)
    "bshp": ("data", None, "model", None),  # SSD activations (b, s, heads, p)
    "bqhgd": ("data", None, "model", None, None),  # flash out (b,cq,hkv,g,dv)
    "bhgqd": ("data", "model", None, None, None),  # flash acc (b,hkv,g,cq,dv)
    "bhgq": ("data", "model", None, None),  # flash stats (b,hkv,g,cq)
}


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: MeshRules) -> Iterator[None]:
    prev = dict(_STATE)
    _STATE["mesh"] = mesh
    _STATE["rules"] = rules
    try:
        yield
    finally:
        _STATE.update(prev)


def shard(x: jax.Array, kind: str) -> jax.Array:
    mesh: Mesh | None = _STATE["mesh"]  # type: ignore[assignment]
    rules: MeshRules | None = _STATE["rules"]  # type: ignore[assignment]
    if mesh is None or rules is None:
        return x
    axes = KINDS[kind]
    assert len(axes) == x.ndim, (kind, x.shape)
    spec: list = []
    used: set[str] = set()
    for dim, name in zip(x.shape, axes):
        assignment = None
        if name == "data":
            mesh_axes = tuple(a for a in rules.data_axes if a not in used)
            if mesh_axes and dim % _axis_size(mesh, mesh_axes) == 0:
                assignment = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
                used.update(mesh_axes)
        elif name == "model":
            mesh_axes = tuple(a for a in rules.model_axes if a not in used)
            if mesh_axes and dim % _axis_size(mesh, mesh_axes) == 0:
                assignment = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
                used.update(mesh_axes)
        spec.append(assignment)
    while spec and spec[-1] is None:
        spec.pop()
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))

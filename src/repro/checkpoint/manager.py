"""Fault-tolerant checkpointing: atomic, async, restart-friendly.

Design (tensorstore-free, works on any shared filesystem):

  * one ``step_<N>/`` directory per checkpoint; arrays stored as a single
    .npz per host plus a JSON manifest (tree structure, dtypes, pipeline
    state, step, config fingerprint);
  * ATOMIC: written to ``step_<N>.tmp`` then ``os.rename``d — a crashed
    writer can never leave a half checkpoint that restore would pick up;
  * ASYNC: ``save()`` snapshots device arrays to host (blocking only for
    the device->host copy) and hands serialization to a worker thread —
    the train loop overlaps the next step with checkpoint IO;
  * retention: ``keep`` newest checkpoints are kept, older ones pruned;
  * restore picks the newest complete manifest; corrupt/partial dirs are
    skipped — this is the node-failure restart path.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np


@dataclasses.dataclass
class CheckpointConfig:
    directory: str
    keep: int = 3
    async_save: bool = True


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self.dir = pathlib.Path(cfg.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._worker: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: dict[str, Any] | None = None) -> None:
        """Snapshot + async write. ``tree`` is any pytree of arrays."""
        self.wait()  # one outstanding save at a time
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        payload_extra = dict(extra or {})

        def work() -> None:
            try:
                self._write(step, host_leaves, str(treedef), payload_extra)
            except BaseException as e:  # surfaced on next save/wait
                self._error = e

        if self.cfg.async_save:
            self._worker = threading.Thread(target=work, daemon=True)
            self._worker.start()
        else:
            work()
            self._raise_if_failed()

    def _write(self, step: int, leaves: list[np.ndarray], treedef: str, extra: dict) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **{f"leaf_{i}": a for i, a in enumerate(leaves)})
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": treedef,
            "dtypes": [str(a.dtype) for a in leaves],
            "shapes": [list(a.shape) for a in leaves],
            "extra": extra,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomicity boundary
        self._prune()

    def _prune(self) -> None:
        ckpts = sorted(self.all_steps())
        for s in ckpts[: -self.cfg.keep] if self.cfg.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        self._raise_if_failed()

    def _raise_if_failed(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err

    # -- restore ----------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None) -> tuple[Any, dict[str, Any], int]:
        """-> (tree matching ``template`` structure, extra, step).

        Restores into the template's structure; array shardings are applied
        by the caller (device_put with the training shardings).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        with np.load(d / "arrays.npz") as z:
            leaves = [z[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
        treedef = jax.tree_util.tree_structure(template)
        if treedef.num_leaves != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves; template expects {treedef.num_leaves}"
            )
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest.get("extra", {}), step

"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth that tests/test_su3_kernels.py sweeps shapes and
dtypes against. They use complex arithmetic directly (which XLA supports on
CPU) — the Pallas kernels use planar re/im because TPU vector units do not.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def su3_mult_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """SU3_Bench core kernel, canonical complex form.

    C[i, j] = A[i, j] @ B[j]  for every site i and link j (paper Fig. 1).

    a: (n_sites, 4, 3, 3) complex; b: (4, 3, 3) complex -> (n_sites, 4, 3, 3).
    """
    return jnp.einsum("sjkl,jlm->sjkm", a, b)


def su3_mult_planar_ref(a_p: jax.Array, b_p: jax.Array) -> jax.Array:
    """Planar oracle: SoA layout (2, 4, 3, 3, n_sites) x (2, 4, 3, 3).

    (ar + i*ai)(br + i*bi) = (ar*br - ai*bi) + i*(ar*bi + ai*br)
    """
    ar, ai = a_p[0], a_p[1]
    br, bi = b_p[0], b_p[1]
    cr = jnp.einsum("jkls,jlm->jkms", ar, br) - jnp.einsum("jkls,jlm->jkms", ai, bi)
    ci = jnp.einsum("jkls,jlm->jkms", ar, bi) + jnp.einsum("jkls,jlm->jkms", ai, br)
    return jnp.stack([cr, ci], axis=0)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Naive full-materialization attention oracle.

    q: (batch, q_len, n_q_heads, d_head); k/v: (batch, kv_len, n_kv_heads, d_head).
    GQA handled by repeating kv heads. Computes in fp32 regardless of input dtype.
    """
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    assert hq % hkv == 0
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else d**-0.5
    qf = q.astype(jnp.float32) * scale
    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, k.astype(jnp.float32))
    if causal:
        # Align last query with last key (supports sq < sk for chunked decode).
        qpos = jnp.arange(sq)[:, None] + (sk - sq)
        kpos = jnp.arange(sk)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w

"""jit'd public wrappers around the Pallas kernels, with backend dispatch.

On TPU the Pallas path compiles natively; on CPU (this container) it runs in
``interpret=True`` mode, which executes the kernel body with standard JAX ops
— bit-identical control flow, no Mosaic. The dry-run/compile paths of the LM
stack use the pure-jnp reference implementations instead (Pallas does not
lower through the CPU AOT pipeline), selected in models/ by backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.su3 import layouts, registry
from repro.core.su3.layouts import Layout
from repro.kernels import ref as kref
from repro.kernels import su3_matmul, su3_stencil

DEFAULT_TILE = 512


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@registry.register_kernel(
    "pallas",
    layouts=(Layout.SOA, Layout.AOSOA),
    backends=("pallas",),
    form=registry.PLANAR,
    supports_fused=True,
    supports_accum=True,
    supports_compressed=True,
)
def su3_mult_planar(
    a_p: jax.Array,
    b_p: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    k_iters: int = 1,
    interpret: bool | None = None,
    alias: bool = False,
    accum_dtype: str | None = None,
    compressed: bool = False,
) -> jax.Array:
    """Planar flattened SoA entry point: a_p (2, 36, S), b_p (2, 36).

    ``k_iters`` chains K multiplies in one dispatch (fused iteration stepping);
    ``alias`` requests in-place C-into-A writes via input_output_aliases;
    ``accum_dtype`` accumulates the FMA chain at a wider precision than the
    streamed storage words (bf16-storage / f32-accumulate serving plans);
    ``compressed`` streams two-row gauge blocks a_p (2, 24, S) with
    in-register third-row reconstruction.
    """
    if interpret is None:
        interpret = _use_interpret()
    return su3_matmul.su3_mult_planar(
        a_p, b_p, tile=tile, k_iters=k_iters, interpret=interpret, alias=alias,
        accum_dtype=accum_dtype, compressed=compressed,
    )


@registry.register_kernel(
    "pallas_megakernel",
    layouts=(Layout.SOA, Layout.AOSOA),
    backends=("pallas",),
    form=registry.BATCHED,
    supports_fused=True,
    supports_accum=True,
    supports_compressed=True,
)
def su3_mult_planar_batched(
    a_p: jax.Array,
    b_p: jax.Array,
    slot_k: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    max_k: int = su3_matmul._UNROLL_MAX,
    interpret: bool | None = None,
    alias: bool = False,
    accum_dtype: str | None = None,
    compressed: bool = False,
) -> jax.Array:
    """Slot-batched megakernel entry: a_p (slots, 2, 36, S), b_p (slots, 2, 36),
    slot_k (slots,) per-slot chain depths — one dispatch for the whole table.
    """
    if interpret is None:
        interpret = _use_interpret()
    return su3_matmul.su3_mult_planar_batched(
        a_p, b_p, slot_k, tile=tile, max_k=max_k, interpret=interpret,
        alias=alias, accum_dtype=accum_dtype, compressed=compressed,
    )


@registry.register_kernel(
    "pallas_stencil",
    layouts=(Layout.SOA, Layout.AOSOA),
    backends=("pallas",),
    form=registry.STENCIL,
    supports_accum=True,
    supports_compressed=True,
)
def su3_stencil_planar(
    u_p: jax.Array,
    v_nbr: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool | None = None,
    accum_dtype: str | None = None,
    compressed: bool = False,
) -> jax.Array:
    """Planar nearest-neighbor stencil entry: u_p (2, 36, S) links — or
    (2, 24, S) two-row compressed, reconstructed in-register —
    v_nbr (8, 2, 3, S) direction-major shifted neighbor vectors -> (2, 3, S).
    """
    if interpret is None:
        interpret = _use_interpret()
    return su3_stencil.su3_stencil_planar(
        u_p, v_nbr, tile=tile, interpret=interpret, accum_dtype=accum_dtype,
        compressed=compressed,
    )


@registry.register_kernel(
    "pallas_cg",
    layouts=(Layout.SOA, Layout.AOSOA),
    backends=("pallas",),
    form=registry.STENCIL_AXPY,
    supports_accum=True,
    supports_compressed=True,
)
def su3_cg_fused_planar(
    u_p: jax.Array,
    r_nbr: jax.Array,
    p_nbr: jax.Array,
    r_p: jax.Array,
    p_p: jax.Array,
    coefs: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool | None = None,
    accum_dtype: str | None = None,
    compressed: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused CG iteration entry: u_p (2, 36 | 24, S) links, (r_nbr, p_nbr)
    (8, 2, 3, S) gathered neighbors, (r_p, p_p) (2, 3, S) planar vectors,
    coefs (1, 2) [beta, sigma] -> (p_new, S(p_new)); the sigma shift runs
    in the plan's shared epilogue, not in-kernel."""
    if interpret is None:
        interpret = _use_interpret()
    return su3_stencil.su3_cg_fused_planar(
        u_p, r_nbr, p_nbr, r_p, p_p, coefs, tile=tile, interpret=interpret,
        accum_dtype=accum_dtype, compressed=compressed,
    )


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def su3_mult(
    a: jax.Array, b: jax.Array, *, tile: int = DEFAULT_TILE, interpret: bool | None = None
) -> jax.Array:
    """Canonical complex entry point matching kernels.ref.su3_mult_ref.

    a: (n_sites, 4, 3, 3) complex, b: (4, 3, 3) complex.
    Packs to planar SoA, pads sites to the tile, runs the kernel, unpacks.
    """
    if interpret is None:
        interpret = _use_interpret()
    n_sites = a.shape[0]
    pad = (-n_sites) % tile
    a_p = layouts.pack_soa(a).reshape(2, su3_matmul.ROWS, n_sites)
    if pad:
        a_p = jnp.pad(a_p, ((0, 0), (0, 0), (0, pad)))
    b_p = layouts.to_planar(b).reshape(2, su3_matmul.ROWS)
    c_p = su3_matmul.su3_mult_planar(a_p, b_p, tile=tile, interpret=interpret)
    c_p = c_p[:, :, :n_sites].reshape(2, layouts.LINKS, layouts.SU3, layouts.SU3, n_sites)
    return layouts.unpack_soa(c_p, a.dtype)


# Re-exported oracles so call sites can do `from repro.kernels import ops` and
# flip between kernel and reference with one name change.
su3_mult_ref = kref.su3_mult_ref
su3_mult_planar_ref = kref.su3_mult_planar_ref

"""Pallas TPU kernels for the SU3 hot-spot, with jnp oracles (ref.py)."""

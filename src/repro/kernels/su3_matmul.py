"""Pallas TPU kernel for the SU3_Bench core loop.

TPU-native formulation of the paper's explicit/blocked GEMM (§4, §5.4):

  * A 3x3 complex matrix cannot profitably use the 128x128 MXU (K=3 wastes
    >97% of the systolic array) — and the kernel is bandwidth-bound anyway
    (AI = 1.35 fp32). So *sites* map to VPU lanes and the 3x3x3 complex
    product is fully unrolled into real FMA chains over (tile,) vectors:
    the paper's "explicit GEMM with FMA" in lane-vector form.
  * The paper's PIUMA blocking (2x3 + 1x3 to fit the register file) becomes
    site-tile blocking to fit VMEM: one grid step streams an
    (2, 36, tile) A-block HBM->VMEM, produces the C-block, and streams it
    back. tile is the tunable (kernels.ops.DEFAULT_TILE; swept by the
    autotuner and by tests).
  * B (2, 36) is tiny (288 B fp32); it rides in VMEM across all grid steps —
    the paper's "B stays in cache" plus its "copy B transposed" fix: the
    packing step lays B out so the kernel reads it row-major.

Layout contract (planar SoA, packed by kernels.ops / core.su3.layouts):
  a: (2, 36, S)  — [re|im, link*row*col, site], S % tile == 0
  b: (2, 36)     — [re|im, link*row*col]
  -> c: (2, 36, S)

``su3_mult_planar_batched`` is the serving megakernel: the same body over a
(slots x site-tiles) grid with a scalar-prefetched per-slot chain depth, so
a whole slot table of in-flight chains advances in ONE dispatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LINKS, SU3 = 4, 3
ROWS = LINKS * SU3 * SU3  # 36 complex entries per site
COMP_ROWS = LINKS * 2 * SU3  # 24: two-row compressed gauge (12 reals/link)
_UNROLL_MAX = 8  # fused chains up to this K are fully unrolled in-kernel


def _flat(j: int, k: int, l: int) -> int:
    return (j * SU3 + k) * SU3 + l


def _cflat(j: int, k: int, l: int) -> int:
    """Row index in the two-row compressed planar form (k in {0, 1})."""
    return (j * 2 + k) * SU3 + l


# full-form row ids of the stored rows, in compressed row order — the
# store-side "drop row 2" map (mirrors layouts.COMP_ROW_INDICES).
_COMP_TO_FULL = tuple(
    _flat(j, k, l) for j in range(LINKS) for k in range(2) for l in range(SU3)
)


def _expand_tile(a: jax.Array) -> jax.Array:
    """(2, 24, T) two-row tile -> (2, 36, T): reconstruct-on-load.

    Per link, row 2 is the unitarity cross product of the two resident rows,
    ``row2 = conj(row0 x row1)``.  The cross product always runs at f32 —
    even for bf16 storage — then narrows back to the tile's working dtype,
    so narrow-storage plans lose no reconstruction precision beyond the one
    storage rounding they already paid.  The expanded tile feeds the same
    fixed-order FMA bodies as full storage.

    Identity contract: the formula and operand grouping match the codec's
    :func:`repro.core.su3.layouts.reconstruct_third_row` exactly, but LLVM
    may contract mul+add pairs into FMAs differently across compiled
    programs, so reconstructed values agree with the out-of-kernel reference
    to ~1 ulp rather than bitwise.  What IS exact: (a) the multiply's stored
    output — rows 0/1 of C depend only on rows 0/1 of A, so reconstruction
    rounding never reaches them — and (b) any site-set decomposition of the
    SAME compressed kernel (interior/boundary/overlap/depth-2 schedules),
    which is where the repo's bit-identity contracts are load-bearing.
    """
    ar, ai = a[0], a[1]
    rows_r: list = [None] * ROWS
    rows_i: list = [None] * ROWS
    for j in range(LINKS):
        for k in range(2):
            for l in range(SU3):
                rows_r[_flat(j, k, l)] = ar[_cflat(j, k, l)]
                rows_i[_flat(j, k, l)] = ai[_cflat(j, k, l)]
        # row2[l] = conj(r0[l+1]*r1[l+2] - r0[l+2]*r1[l+1])  (indices mod 3)
        for l in range(SU3):
            l1, l2 = (l + 1) % SU3, (l + 2) % SU3
            pr, pi = rows_r, rows_i
            f32 = jnp.float32
            a_r, a_i = pr[_flat(j, 0, l1)].astype(f32), pi[_flat(j, 0, l1)].astype(f32)
            b_r, b_i = pr[_flat(j, 1, l2)].astype(f32), pi[_flat(j, 1, l2)].astype(f32)
            c_r, c_i = pr[_flat(j, 0, l2)].astype(f32), pi[_flat(j, 0, l2)].astype(f32)
            d_r, d_i = pr[_flat(j, 1, l1)].astype(f32), pi[_flat(j, 1, l1)].astype(f32)
            xr = (a_r * b_r - a_i * b_i) - (c_r * d_r - c_i * d_i)
            xi = (a_r * b_i + a_i * b_r) - (c_r * d_i + c_i * d_r)
            rows_r[_flat(j, 2, l)] = xr.astype(ar.dtype)
            rows_i[_flat(j, 2, l)] = (-xi).astype(ar.dtype)  # conjugate
    return jnp.stack(
        [jnp.stack(rows_r, axis=0), jnp.stack(rows_i, axis=0)], axis=0
    )


def _compress_tile(c: jax.Array) -> jax.Array:
    """(2, 36, T) full tile -> (2, 24, T): drop each link's third row.

    The output of a chain of SU(3) multiplies on SU(3) inputs is SU(3), so
    its rows 0/1 determine it; rows 0/1 of C also depend only on rows 0/1 of
    A, so the stored result is exact for ANY input — compression error never
    compounds across chained steps.
    """
    return jnp.stack(
        [jnp.stack([c[p, r] for r in _COMP_TO_FULL], axis=0) for p in range(2)],
        axis=0,
    )


def _mult_tile(a: jax.Array, b: jax.Array) -> jax.Array:
    """C-tile = A-tile (x) B, fully unrolled complex FMAs.

    a: (2, 36, T) planar tile, b: (2, 36) planar B. The shared body of the
    single-step and fused multi-iteration kernels.
    """
    ar, ai = a[0], a[1]
    out_r = [None] * ROWS
    out_i = [None] * ROWS
    for j in range(LINKS):
        for k in range(SU3):
            for m in range(SU3):
                # c[j,k,m] = sum_l a[j,k,l] * b[j,l,m]   (complex)
                cr = None
                ci = None
                for l in range(SU3):
                    arow, brow = _flat(j, k, l), _flat(j, l, m)
                    br = b[0, brow]
                    bi = b[1, brow]
                    if cr is None:
                        cr = ar[arow] * br - ai[arow] * bi
                        ci = ar[arow] * bi + ai[arow] * br
                    else:
                        cr = cr + ar[arow] * br - ai[arow] * bi
                        ci = ci + ar[arow] * bi + ai[arow] * br
                out_r[_flat(j, k, m)] = cr
                out_i[_flat(j, k, m)] = ci
    return jnp.stack([jnp.stack(out_r, axis=0), jnp.stack(out_i, axis=0)], axis=0)


def _su3_kernel(
    a_ref,
    b_ref,
    c_ref,
    *,
    k_iters: int = 1,
    accum_dtype: str | None = None,
    compressed: bool = False,
):
    """One grid step: chain ``k_iters`` multiplies on the resident VMEM tile.

    k_iters=1 is the classic single step C = A (x) B.  k_iters>1 feeds C back
    as the next A *without leaving VMEM*: one HBM read of the A-tile and one
    HBM write of the final C-tile amortize over K multiplies — the per-
    iteration dispatch + HBM roundtrip that dominates at small L disappears.
    The chaining (rather than recomputing the identical product) keeps the
    loop un-DCE-able and matches K sequential engine steps fed back C->A.

    ``accum_dtype`` widens the VREG working precision: the A/B tiles are
    upcast once on VMEM load, every FMA in the chain accumulates at that
    width, and the final C-tile narrows back to the storage dtype on the way
    out.  HBM traffic stays at storage width (the MILC-on-KNL reduced-
    precision-storage scheme: stream bf16, accumulate f32).
    """
    a = a_ref[...]  # (2, 36 | 24, tile) in VMEM
    b = b_ref[...]  # (2, 36)            in VMEM (resident across grid steps)
    if accum_dtype is not None:
        a = a.astype(accum_dtype)
        b = b.astype(accum_dtype)
    if compressed:
        a = _expand_tile(a)  # reconstruct-on-load, f32 cross product
    if k_iters <= _UNROLL_MAX:
        # unrolled chain: one straight-line FMA stream, no loop-carry
        # overhead — the compiler sees the whole K-multiply dataflow
        c = a
        for _ in range(k_iters):
            c = _mult_tile(c, b)
    else:
        c = jax.lax.fori_loop(0, k_iters, lambda _, x: _mult_tile(x, b), a)
    if compressed:
        c = _compress_tile(c)  # store two rows; HBM write stays at 48 words
    c_ref[...] = c.astype(c_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "tile", "k_iters", "interpret", "alias", "accum_dtype", "compressed"
    ),
)
def su3_mult_planar(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: int = 512,
    k_iters: int = 1,
    interpret: bool = False,
    alias: bool = False,
    accum_dtype: str | None = None,
    compressed: bool = False,
) -> jax.Array:
    """Planar-SoA SU3 multiply via pallas_call. See module docstring for layout.

    ``k_iters`` chains K multiplies inside one grid step (fused iteration).
    ``alias`` writes the C-tile into A's buffer (``input_output_aliases``) so
    the fused step is a true in-place update; callers that donate A (the
    engine's fused loop rebinds ``a = step(a, b)``) avoid the defensive copy.
    ``accum_dtype`` upcasts the resident tiles for the FMA chain (e.g. bf16
    storage with float32 accumulation) while streaming storage-width bytes.
    ``compressed`` streams two-row gauge blocks (2, 24, tile): row 2 is
    reconstructed in-register on load and dropped again on store, cutting
    the dominant A/C HBM traffic from 72 to 48 words per site.
    """
    rows = COMP_ROWS if compressed else ROWS
    assert a.ndim == 3 and a.shape[:2] == (2, rows), (a.shape, compressed)
    assert b.shape == (2, ROWS), b.shape
    assert k_iters >= 1, k_iters
    n_sites = a.shape[2]
    assert n_sites % tile == 0, (n_sites, tile)
    grid = (n_sites // tile,)
    return pl.pallas_call(
        functools.partial(
            _su3_kernel, k_iters=k_iters, accum_dtype=accum_dtype,
            compressed=compressed,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2, rows, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((2, ROWS), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((2, rows, tile), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        input_output_aliases={0: 0} if alias else {},
        interpret=interpret,
    )(a, b)


def _su3_megakernel(
    k_ref,
    a_ref,
    b_ref,
    c_ref,
    *,
    max_k: int,
    accum_dtype: str | None = None,
    compressed: bool = False,
):
    """One (slot, tile) grid step of the batched K-chain megakernel.

    ``k_ref`` is the scalar-prefetched per-slot chain-depth table (SMEM, the
    whole ``(slots,)`` array — available before the body runs, so Mosaic can
    schedule the DMAs); the grid walks ``slot`` major, ``site-tile`` minor, and
    the BlockSpec pipeline double-buffers the A-tile HBM->VMEM staging across
    grid steps exactly as in the single-lattice kernel.  Each step chains
    ``k = clamp(k_ref[slot], 0, max_k)`` multiplies on the resident tile: a
    dead slot (k=0) copies A through untouched, a live slot runs its own
    chain depth — mixed-depth batches share ONE dispatch, which is the whole
    point (the per-(L, chain) dispatch tax is the pipeline-throughput ceiling
    the paper measures on PIUMA).
    """
    slot = pl.program_id(0)
    k = jnp.clip(k_ref[slot], 0, max_k)
    a = a_ref[0]  # (2, 36 | 24, tile) in VMEM
    b = b_ref[0]  # (2, 36)            per-slot B, VMEM-resident across tiles
    if accum_dtype is not None:
        a = a.astype(accum_dtype)
        b = b.astype(accum_dtype)
    if compressed:
        a = _expand_tile(a)
    # dynamic trip count: the chain body is identical to the fused kernel's,
    # so a slot's k-chain is bit-identical to k sequential single steps
    c = jax.lax.fori_loop(0, k, lambda _, x: _mult_tile(x, b), a)
    if compressed:
        c = _compress_tile(c)
    c_ref[0] = c.astype(c_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "tile", "max_k", "interpret", "alias", "accum_dtype", "compressed"
    ),
)
def su3_mult_planar_batched(
    a: jax.Array,
    b: jax.Array,
    slot_k: jax.Array,
    *,
    tile: int = 512,
    max_k: int = _UNROLL_MAX,
    interpret: bool = False,
    alias: bool = False,
    accum_dtype: str | None = None,
    compressed: bool = False,
) -> jax.Array:
    """Batched K-chain megakernel: ONE pallas_call over (slots x site tiles).

    The serving dispatch amortizer: where the single-lattice kernel pays one
    dispatch per (lattice, chain) per iteration, this kernel walks a grid of
    ``slots * (S // tile)`` steps in one dispatch, chaining ``slot_k[s]``
    multiplies in-kernel for slot ``s`` (scalar-prefetched, so per-slot chain
    depths are data, not compiled shapes).

    Layout contract (planar, batched over the leading slot axis):
      a:      (slots, 2, 36, S) — per-slot planar lattice, S % tile == 0
      b:      (slots, 2, 36)    — per-slot planar B
      slot_k: (slots,) int32    — chain depth per slot; 0 = pass-through
      -> c:   (slots, 2, 36, S)

    ``alias`` writes C into A's buffer (``input_output_aliases``; index 1 —
    the scalar-prefetch operand occupies index 0) so donated in-flight slot
    tables update in place with zero copies.  ``max_k`` is the static chain
    bound the dynamic per-slot depth is clamped to (one compiled program
    serves every depth up to it).
    """
    rows = COMP_ROWS if compressed else ROWS
    assert a.ndim == 4 and a.shape[1:3] == (2, rows), (a.shape, compressed)
    slots, n_sites = a.shape[0], a.shape[3]
    assert b.shape == (slots, 2, ROWS), (b.shape, slots)
    assert slot_k.shape == (slots,), (slot_k.shape, slots)
    assert n_sites % tile == 0, (n_sites, tile)
    assert max_k >= 1, max_k
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(slots, n_sites // tile),
        in_specs=[
            pl.BlockSpec((1, 2, rows, tile), lambda s, i, k_ref: (s, 0, 0, i)),
            pl.BlockSpec((1, 2, ROWS), lambda s, i, k_ref: (s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2, rows, tile), lambda s, i, k_ref: (s, 0, 0, i)),
    )
    return pl.pallas_call(
        functools.partial(
            _su3_megakernel, max_k=max_k, accum_dtype=accum_dtype,
            compressed=compressed,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        input_output_aliases={1: 0} if alias else {},
        interpret=interpret,
    )(slot_k.astype(jnp.int32), a, b)


def vmem_bytes(tile: int, word_bytes: int = 4, accum_word_bytes: int | None = None) -> int:
    """Working-set estimate for one grid step (A, C tiles + B) — the quantity
    the paper bounded by the register file and we bound by VMEM (~16 MiB).

    With mixed-precision accumulation the resident tiles live at the *wider*
    of storage and accumulation width once upcast, so that bounds the set.
    Compressed (two-row) plans stream smaller blocks but expand to the full
    36-row tile in registers, so this full-width figure bounds them too —
    the autotuner's VMEM gate stays conservative without a compression knob.
    """
    w = max(word_bytes, accum_word_bytes or word_bytes)
    return (2 * 2 * ROWS * tile + 2 * ROWS) * w

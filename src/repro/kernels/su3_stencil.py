"""Pallas nearest-neighbor SU(3) stencil kernel (staggered-Dslash style).

The paper's lesson is that SU3_Bench's ceiling is set by how data moves; the
stencil is the first workload in this repo where data moves *between shards*.
Per site ``x`` the kernel applies the 8-point nearest-neighbor operator

    out(x) = sum_mu [ U_mu(x) . v(x + mu_hat)  +  U_mu(x)^dagger . v(x - mu_hat) ]

over the 4 lattice directions (mu = x, y, z, t), where ``U`` is the site's
gauge-link field (the same planar (2, 36, S) array the multiply kernels
stream) and ``v`` is a color 3-vector field in planar (2, 3, S) form.  This
is the staggered-Dslash access pattern of arXiv:1411.2087 with one
simplification: the backward term uses the *site-local* adjoint link
``U_mu(x)^dagger`` rather than the neighbor's ``U_mu(x - mu_hat)^dagger``,
which keeps gauge-field traffic at ONE streamed read of U per application
(the neighbor-gather cost all lands on the small vector field — exactly the
halo traffic ``distributed.sharding.HaloSpec`` prices).

Kernel formulation (same philosophy as ``su3_matmul``):

  * sites map to VPU lanes; the 8 matrix-vector products per site are fully
    unrolled into real FMA chains over (tile,) vectors — no MXU (K=3 wastes
    the systolic array, and the stencil is bandwidth-bound anyway);
  * the *neighbor gathering* happens OUTSIDE the kernel (the plan layer
    materializes 8 shifted views of v); the kernel streams one
    (8, 2, 3, tile) neighbor block plus one (2, 36, tile) link block
    HBM->VMEM per grid step and keeps them resident while the unrolled
    FMA chain runs — "shifted-neighbor loads kept in VMEM";
  * ``accum_dtype`` upcasts the resident tiles so bf16-storage plans
    accumulate at f32 while streaming 2-byte words (same scheme as the
    multiply kernel).

Layout contract:
  u:     (2, 36, S)    — planar gauge links, [re|im, link*row*col, site]
  v_nbr: (8, 2, 3, S)  — planar neighbor vectors, direction-major
                         (+x, +y, +z, +t, -x, -y, -z, -t)
  -> out: (2, 3, S)    — planar result vector field

The per-site accumulation order is FIXED (mu-major, then l, forward before
backward), so any site-set decomposition that feeds the same per-site inputs
— full lattice, interior-only, boundary-only — produces bit-identical
outputs.  The overlap-scheduled ``ExecutionPlan.stencil_step`` relies on
this to stay bit-identical to the non-overlapped reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.su3_matmul import COMP_ROWS, _expand_tile

LINKS, SU3 = 4, 3
ROWS = LINKS * SU3 * SU3  # 36 complex link entries per site
NBR_DIRS = 2 * LINKS  # +x +y +z +t -x -y -z -t

# 8 matrix-vector products x 9 complex MACs x 8 flops (4 mul + 4 add): the
# useful-flop figure benchmarks report (the combine adds are the MAC adds).
STENCIL_FLOPS_PER_SITE = NBR_DIRS * SU3 * SU3 * 8

# words streamed per site: U (72) + 8 neighbor vectors (8 x 6) + out (6).
# The halo payload constant (6 words per exchanged vector) lives with the
# pricing rules in distributed.sharding.VECTOR_WORDS_PER_SITE.
STENCIL_WORDS_PER_SITE = 2 * ROWS + NBR_DIRS * 2 * SU3 + 2 * SU3

# two-row compressed gauge: U shrinks 72 -> 48 words; the vector traffic is
# unchanged (v is not a gauge field), so 102 words per site total.
STENCIL_COMP_WORDS_PER_SITE = 2 * COMP_ROWS + NBR_DIRS * 2 * SU3 + 2 * SU3


def _flat(j: int, k: int, l: int) -> int:
    return (j * SU3 + k) * SU3 + l


def _stencil_tile(u: jax.Array, v_nbr: jax.Array) -> jax.Array:
    """out-tile = sum_mu U_mu . v_fwd[mu] + U_mu^dag . v_bwd[mu], unrolled.

    u: (2, 36, T) planar link tile, v_nbr: (8, 2, 3, T) neighbor tiles.
    Accumulation order is fixed (mu outer, l inner, forward then backward
    per (mu, k, l)) — the bit-identity contract of the module docstring.
    """
    ur, ui = u[0], u[1]
    out_r: list = [None] * SU3
    out_i: list = [None] * SU3
    for mu in range(LINKS):
        vf_r, vf_i = v_nbr[mu, 0], v_nbr[mu, 1]  # (3, T)
        vb_r, vb_i = v_nbr[LINKS + mu, 0], v_nbr[LINKS + mu, 1]
        for k in range(SU3):
            acc_r, acc_i = out_r[k], out_i[k]
            for l in range(SU3):
                f = _flat(mu, k, l)  # U[mu, k, l]
                b = _flat(mu, l, k)  # U[mu, l, k], conjugated for the adjoint
                # forward: U[mu,k,l] * v(x+mu)[l]
                tr = ur[f] * vf_r[l] - ui[f] * vf_i[l]
                ti = ur[f] * vf_i[l] + ui[f] * vf_r[l]
                acc_r = tr if acc_r is None else acc_r + tr
                acc_i = ti if acc_i is None else acc_i + ti
                # backward: conj(U[mu,l,k]) * v(x-mu)[l]
                sr = ur[b] * vb_r[l] + ui[b] * vb_i[l]
                si = ur[b] * vb_i[l] - ui[b] * vb_r[l]
                acc_r = acc_r + sr
                acc_i = acc_i + si
            out_r[k], out_i[k] = acc_r, acc_i
    return jnp.stack(
        [jnp.stack(out_r, axis=0), jnp.stack(out_i, axis=0)], axis=0
    )


def _su3_stencil_kernel(
    u_ref, v_ref, o_ref, *, accum_dtype: str | None = None, compressed: bool = False
):
    """One grid step: the unrolled 8-direction FMA chain on resident tiles.

    ``accum_dtype`` widens the VREG working precision exactly as in the
    multiply kernel: tiles upcast once on VMEM load, the chain accumulates
    wide, the out-tile narrows back to storage width on the way out.
    ``compressed`` streams (2, 24, tile) two-row link blocks; unlike the
    multiply, the stencil genuinely needs row 2 (the adjoint term reads link
    COLUMNS), so the reconstruct-on-load cross product is load-bearing here.
    """
    u = u_ref[...]  # (2, 36 | 24, tile) in VMEM
    v = v_ref[...]  # (8, 2, 3, tile) in VMEM
    if accum_dtype is not None:
        u = u.astype(accum_dtype)
        v = v.astype(accum_dtype)
    if compressed:
        u = _expand_tile(u)  # f32 cross product, shared with su3_matmul
    o_ref[...] = _stencil_tile(u, v).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("tile", "interpret", "accum_dtype", "compressed")
)
def su3_stencil_planar(
    u: jax.Array,
    v_nbr: jax.Array,
    *,
    tile: int = 512,
    interpret: bool = False,
    accum_dtype: str | None = None,
    compressed: bool = False,
) -> jax.Array:
    """Planar SU(3) nearest-neighbor stencil via pallas_call.

    See the module docstring for the operator and layout contract.  The grid
    walks site tiles; per step one (2, 36, tile) link block — (2, 24, tile)
    for two-row ``compressed`` gauge, reconstructed in-register — and one
    (8, 2, 3, tile) neighbor block stream HBM->VMEM and the fully unrolled
    complex FMA chain produces the (2, 3, tile) out block.
    """
    rows = COMP_ROWS if compressed else ROWS
    assert u.ndim == 3 and u.shape[:2] == (2, rows), (u.shape, compressed)
    n_sites = u.shape[2]
    assert v_nbr.shape == (NBR_DIRS, 2, SU3, n_sites), (v_nbr.shape, n_sites)
    assert n_sites % tile == 0, (n_sites, tile)
    grid = (n_sites // tile,)
    return pl.pallas_call(
        functools.partial(
            _su3_stencil_kernel, accum_dtype=accum_dtype, compressed=compressed
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2, rows, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((NBR_DIRS, 2, SU3, tile), lambda i: (0, 0, 0, i)),
        ],
        out_specs=pl.BlockSpec((2, SU3, tile), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((2, SU3, n_sites), u.dtype),
        interpret=interpret,
    )(u, v_nbr)


# -- fused CG iteration kernel (stencil + search-direction axpy) --------------
#
# One conjugate-gradient iteration on the shifted operator A = sigma I + S
# spends most of its bytes re-reading the vector fields: the composed form
# materializes p' = r + beta p (one full read+write pass), gathers p' 's
# neighbors, then runs the stencil.  The fused kernel folds the axpy INTO the
# stencil pallas_call: the gathered neighbor tiles arrive as (r_nbr, p_nbr)
# pairs and the kernel forms p'_nbr = r_nbr + beta p_nbr in VMEM registers,
# so p' is never written to and re-read from HBM as a standalone pass, and
# the shifted apply ap = sigma p' + S(p') lands in the same epilogue.
#
# Bit-identity contract (the fused-vs-composed regression tier): gathering is
# indexing, so gather(r + beta p) == gather(r) + beta gather(p) ELEMENTWISE,
# and at f32 storage every fused expression (the axpy, the fixed-order
# stencil chain, the shift-add) is the same f32 op on the same operands as
# the composed path — the iterates match bit for bit.  Mixed-precision plans
# round at different points (the fused path rounds ap once, the composed
# path rounds S before the shift-add), so only f32 is pinned bitwise.

CG_COEFS = 2  # coefficient block columns: [beta, sigma]

# fused-iteration extra flops per site on top of the 576-flop stencil chain:
# 6 real words per color 3-vector, so each axpy/shift/dot costs 12 flops/site
# (6 mul + 6 add).  Per CG iteration: shift (12), x += alpha p (12),
# r -= alpha ap (12), p = r + beta p (12), <p, Ap> (12), <r, r> (12).
CG_ITER_FLOPS_PER_SITE = STENCIL_FLOPS_PER_SITE + 72


def _su3_cg_fused_kernel(
    u_ref, rn_ref, pn_ref, r_ref, p_ref, c_ref, pnew_ref, s_ref,
    *, accum_dtype: str | None = None, compressed: bool = False,
):
    """One grid step of the fused CG iteration.

    Forms the new search direction p' = r + beta p on the resident center
    AND neighbor tiles, then runs the fixed-order stencil chain on p'_nbr
    and writes S(p') next to p' — the axpy and the operator apply share one
    HBM round trip.  The sigma shift-add deliberately stays OUT of the
    kernel: it runs in the plan's shared jitted epilogue for both the fused
    and composed paths, because an in-kernel ``sigma p' + chain`` gets
    FMA-contracted differently than the composed path's separate shift
    program and breaks the f32 bit-identity contract (observed at ~2 ulp).
    """
    u = u_ref[...]        # (2, 36 | 24, tile)
    r_nbr = rn_ref[...]   # (8, 2, 3, tile)
    p_nbr = pn_ref[...]
    r = r_ref[...]        # (2, 3, tile)
    p = p_ref[...]
    if accum_dtype is not None:
        u = u.astype(accum_dtype)
        r_nbr = r_nbr.astype(accum_dtype)
        p_nbr = p_nbr.astype(accum_dtype)
        r = r.astype(accum_dtype)
        p = p.astype(accum_dtype)
    if compressed:
        u = _expand_tile(u)
    beta = c_ref[0, 0].astype(p.dtype)
    p_new = r + beta * p
    v_nbr = r_nbr + beta * p_nbr  # == gather(p_new): gathers are indexing
    pnew_ref[...] = p_new.astype(pnew_ref.dtype)
    s_ref[...] = _stencil_tile(u, v_nbr).astype(s_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("tile", "interpret", "accum_dtype", "compressed")
)
def su3_cg_fused_planar(
    u: jax.Array,
    r_nbr: jax.Array,
    p_nbr: jax.Array,
    r_p: jax.Array,
    p_p: jax.Array,
    coefs: jax.Array,
    *,
    tile: int = 512,
    interpret: bool = False,
    accum_dtype: str | None = None,
    compressed: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused CG iteration kernel: ``(p', S(p'))`` in one pass.

    u:            (2, 36 | 24, S) planar gauge links (two-row when compressed)
    r_nbr, p_nbr: (8, 2, 3, S) direction-major shifted neighbors of r and p
    r_p, p_p:     (2, 3, S) planar residual / old search direction
    coefs:        (1, 2) float32 [beta, sigma] — data, not static, so the
                  compiled program serves every iteration of every solve.
                  Only beta is consumed in-kernel; sigma rides along for the
                  plan's shared shift epilogue ``ap = sigma p' + S(p')``,
                  which runs OUTSIDE the kernel so the fused and composed
                  paths round identically (f32 bit-identity contract).
    -> (p_new, s): both (2, 3, S) in the storage dtype.
    """
    rows = COMP_ROWS if compressed else ROWS
    assert u.ndim == 3 and u.shape[:2] == (2, rows), (u.shape, compressed)
    n_sites = u.shape[2]
    assert r_nbr.shape == (NBR_DIRS, 2, SU3, n_sites), (r_nbr.shape, n_sites)
    assert p_nbr.shape == (NBR_DIRS, 2, SU3, n_sites), (p_nbr.shape, n_sites)
    assert r_p.shape == (2, SU3, n_sites), (r_p.shape, n_sites)
    assert p_p.shape == (2, SU3, n_sites), (p_p.shape, n_sites)
    assert coefs.shape == (1, CG_COEFS), coefs.shape
    assert n_sites % tile == 0, (n_sites, tile)
    grid = (n_sites // tile,)
    return pl.pallas_call(
        functools.partial(
            _su3_cg_fused_kernel, accum_dtype=accum_dtype, compressed=compressed
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2, rows, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((NBR_DIRS, 2, SU3, tile), lambda i: (0, 0, 0, i)),
            pl.BlockSpec((NBR_DIRS, 2, SU3, tile), lambda i: (0, 0, 0, i)),
            pl.BlockSpec((2, SU3, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((2, SU3, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((1, CG_COEFS), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((2, SU3, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((2, SU3, tile), lambda i: (0, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((2, SU3, n_sites), u.dtype),
            jax.ShapeDtypeStruct((2, SU3, n_sites), u.dtype),
        ],
        interpret=interpret,
    )(u, r_nbr, p_nbr, r_p, p_p, coefs)


def stencil_vmem_bytes(
    tile: int, word_bytes: int = 4, accum_word_bytes: int | None = None
) -> int:
    """Working-set estimate for one stencil grid step (U, 8 neighbor, out
    tiles) — the VMEM bound the autotuner gates stencil candidates on.

    With mixed-precision accumulation the resident tiles live at the wider
    of storage/accumulate width once upcast, so that bounds the set.
    """
    w = max(word_bytes, accum_word_bytes or word_bytes)
    return STENCIL_WORDS_PER_SITE * tile * w


# extra resident words/site of the fused CG grid step over the plain stencil:
# the SECOND gathered neighbor field (p alongside r), the two center vectors,
# and the second output (p' next to S(p'))
CG_EXTRA_WORDS_PER_SITE = NBR_DIRS * 2 * SU3 + 3 * (2 * SU3)


def cg_vmem_bytes(
    tile: int, word_bytes: int = 4, accum_word_bytes: int | None = None
) -> int:
    """Working-set estimate for one fused CG grid step — the stencil tile
    set plus the second gathered field and the extra vector tiles; the VMEM
    bound the autotuner gates CG candidates on."""
    w = max(word_bytes, accum_word_bytes or word_bytes)
    return (STENCIL_WORDS_PER_SITE + CG_EXTRA_WORDS_PER_SITE) * tile * w

"""Pallas TPU flash-attention kernel (prefill/train hot spot).

Blockwise online-softmax attention with explicit BlockSpec VMEM tiling —
the LM-side analog of the SU3 kernel's HBM->VMEM blocking. Grid is
(batch*kv_heads, q_blocks); the kv loop runs inside the kernel body with
jax.lax.fori_loop over VMEM-resident K/V blocks of the same head.

Layout contract (one GQA group per grid row):
  q: (B*Hkv, G*Sq, D)   — G query-heads-per-kv-head folded into rows
  k: (B*Hkv, Skv, D)
  v: (B*Hkv, Skv, D)
  -> out (B*Hkv, G*Sq, D)

This kernel targets TPU (MXU matmuls over (block_q, D) x (D, block_k));
on CPU it runs under interpret=True for correctness tests. The model stack
uses the pure-JAX chunked path for AOT dry-runs (Pallas does not lower
through the CPU pipeline) and selects this kernel on TPU backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, sq: int, g: int,
                  causal: bool, scale: float):
    """One (batch-head, q-block) grid step."""
    # size-1 leading axis is read whole and squeezed: bare int ref indexers
    # hit a discharge-rule bug in jax 0.4.x interpret mode
    q = q_ref[...][0].astype(jnp.float32) * scale  # (block_qg, d)
    block_qg, d = q.shape
    skv = k_ref.shape[1]
    nk = skv // block_k
    # absolute q positions: row r of this block maps to query index
    # (block_index * block_qg + r) // g   (G heads folded into rows)
    iq = pl.program_id(1)
    q_pos = (iq * block_qg + jax.lax.iota(jnp.int32, block_qg)) // g

    def body(ik, carry):
        acc, m, l = carry
        k_blk = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(ik * block_k, block_k), slice(None)))[0]
        v_blk = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(ik * block_k, block_k), slice(None)))[0]
        s = q @ k_blk.astype(jnp.float32).T  # (block_qg, block_k) on the MXU
        if causal:
            k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
            s = jnp.where(k_pos[None, :] <= q_pos[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v_blk.astype(jnp.float32)
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((block_qg, d), jnp.float32)
    m0 = jnp.full((block_qg,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_qg,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l[:, None], 1e-37)).astype(o_ref.dtype)[None]


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention_tpu(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = d**-0.5
    assert skv % block_k == 0, (skv, block_k)
    # fold: (B, Sq, Hkv, G, D) -> (B*Hkv, Sq*G rows, D) with q-major rows
    qf = q.reshape(b, sq, hkv, g, d).transpose(0, 2, 1, 3, 4).reshape(b * hkv, sq * g, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    block_qg = min(block_q * g, sq * g)
    assert (sq * g) % block_qg == 0
    grid = (b * hkv, sq * g // block_qg)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, block_k=block_k, sq=sq, g=g, causal=causal, scale=scale
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_qg, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, skv, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, skv, d), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_qg, d), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, sq * g, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return (
        out.reshape(b, hkv, sq, g, d).transpose(0, 2, 1, 3, 4).reshape(b, sq, hq, d)
    )


def vmem_bytes(block_q: int, block_k: int, skv: int, d: int, g: int = 1) -> int:
    """Working set per grid step: q/o blocks + the full K/V rows (streamed
    block_k at a time by the fori_loop, but resident per BlockSpec)."""
    return 4 * (block_q * g * d * 2 + 2 * skv * d * 2)

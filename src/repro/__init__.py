"""repro: SU3_Bench-on-TPU multi-pod JAX framework (see README)."""

__version__ = "1.0.0"

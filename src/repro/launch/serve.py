"""Serving launcher CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --batch 8 --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.models import registry
from repro.serve.engine import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ALL_ARCHS, required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    api = registry.get(cfg)
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(
        cfg, params,
        ServeConfig(max_len=args.prompt_len + args.tokens + 8,
                    temperature=args.temperature, seed=args.seed),
    )
    prompts = np.random.default_rng(args.seed).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32
    )
    extras = {}
    if cfg.n_patches:
        extras["patches"] = jax.random.normal(
            jax.random.PRNGKey(9), (args.batch, cfg.n_patches, cfg.d_model))
    if cfg.is_encoder_decoder:
        extras["frames"] = jax.random.normal(
            jax.random.PRNGKey(10), (args.batch, cfg.encoder_len, cfg.d_model))
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.tokens, extras=extras or None)
    dt = time.perf_counter() - t0
    print(f"{args.arch}: {out.shape[0]}x{args.tokens} tokens in {dt:.2f}s "
          f"({out.shape[0] * args.tokens / dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()

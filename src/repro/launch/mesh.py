"""Production mesh construction (assignment §MULTI-POD DRY-RUN) and the
(host, device) lattice mesh model.

FUNCTIONS, not module-level constants: importing this module never touches
jax device state.

Two mesh families live here:

* :func:`make_production_mesh` / :func:`make_mesh` — the LM-training meshes
  (``data``/``model``/``pod`` axes) used by ``launch.dryrun``.
* :class:`MeshSpec` — the SU3 lattice's (host, device) mesh.  The paper's
  NUMA lesson (§4: data must be first-touched by the socket that will stream
  it) generalizes to a fleet as *the lattice shard must be materialized by
  the host that owns it*; ``MeshSpec`` is the object that carries that
  topology from launch config into ``core.su3.plan.build_plan`` and
  ``serve.su3.SU3Service``.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

# Axis names of the lattice (host, device) mesh.  The legacy 1-D mesh uses a
# single "sites" axis; multi-host plans shard the site dimension over BOTH of
# these (host-major), so one host's sites are contiguous — the property the
# halo model in ``distributed.sharding`` and per-host first-touch init rely on.
HOST_AXIS = "hosts"
DEVICE_AXIS = "devices"


def _mk(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    # jax.sharding.AxisType landed in jax 0.5.x (explicit-sharding work); Auto
    # is the default there, so omitting axis_types on 0.4.x builds the
    # identical mesh.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests / reduced-device dry-runs / elastic re-mesh)."""
    return _mk(shape, axes)


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Topology of the lattice mesh: ``hosts`` x ``devices_per_host``.

    One instance describes where lattice shards live; :func:`resolve` turns
    it into the concrete 2-D ``jax.sharding.Mesh`` a plan shards over, and
    :func:`host_submesh` yields the 1-D per-host mesh a host-local serving
    pool runs on.

    Attributes:
        hosts: number of hosts (processes / NUMA domains / pods).  ``1``
            reproduces the legacy single-host behavior exactly.
        devices_per_host: devices each host contributes.  ``0`` (default)
            infers ``len(devices) // hosts``.

    Device assignment is host-major over the device list (``jax.devices()``
    order, which in a real multi-controller run groups devices by process),
    so host ``h`` owns the contiguous block
    ``devices[h * dph : (h + 1) * dph]`` and, under the site sharding, the
    contiguous site range ``[h * S/hosts, (h + 1) * S/hosts)``.

    When the local pool has fewer devices than ``hosts * devices_per_host``
    (a laptop / single-CPU container), :func:`host_devices` falls back to
    *oversubscription*: every simulated host maps onto the head of the local
    device list.  Routing, batching, and shard math stay exactly as they
    would be on a fleet; only the physical placement collapses.  ``resolve``
    (the full 2-D mesh) accepts an explicit ``devices`` list for the same
    simulation (tests pass ``[dev] * n``).
    """

    hosts: int = 1
    devices_per_host: int = 0

    def __post_init__(self) -> None:
        if self.hosts < 1:
            raise ValueError(f"hosts must be >= 1, got {self.hosts}")
        if self.devices_per_host < 0:
            raise ValueError(
                f"devices_per_host must be >= 0 (0 = infer), got {self.devices_per_host}"
            )

    # -- concrete meshes -------------------------------------------------------

    def _dph(self, n_available: int) -> int:
        if self.devices_per_host:
            return self.devices_per_host
        return max(n_available // self.hosts, 1)

    def resolve(self, devices: list | None = None) -> jax.sharding.Mesh:
        """The concrete (hosts, devices) mesh this spec describes.

        Args:
            devices: explicit device list (simulation / tests); defaults to
                ``jax.devices()``.  Must hold at least
                ``hosts * devices_per_host`` entries.

        Returns:
            ``jax.sharding.Mesh`` of shape ``(hosts, devices_per_host)`` with
            axes ``("hosts", "devices")`` — or, for a single-host spec over
            one device row, the legacy 1-D ``("sites",)`` mesh, so
            ``MeshSpec()`` is a drop-in for ``plan.make_site_mesh()``.
        """
        devices = list(devices if devices is not None else jax.devices())
        dph = self._dph(len(devices))
        need = self.hosts * dph
        if len(devices) < need:
            raise ValueError(
                f"MeshSpec(hosts={self.hosts}, devices_per_host={dph}) needs "
                f"{need} devices, have {len(devices)}; pass an explicit device "
                f"list to simulate, or lower the spec"
            )
        if self.hosts == 1:
            return jax.sharding.Mesh(np.array(devices[:dph]), ("sites",))
        arr = np.array(devices[:need]).reshape(self.hosts, dph)
        return jax.sharding.Mesh(arr, (HOST_AXIS, DEVICE_AXIS))

    def host_devices(self, host: int, devices: list | None = None) -> list:
        """Devices owned by ``host`` (oversubscribed when the pool is short).

        Returns host ``h``'s contiguous block of the device list; on a local
        pool smaller than the spec, every host shares the head of the list
        (simulation fallback — see class docstring).
        """
        if not 0 <= host < self.hosts:
            raise ValueError(f"host {host} out of range [0, {self.hosts})")
        devices = list(devices if devices is not None else jax.devices())
        dph = self._dph(len(devices))
        if len(devices) >= self.hosts * dph:
            return devices[host * dph:(host + 1) * dph]
        return devices[:dph]

    def host_submesh(self, host: int, devices: list | None = None) -> jax.sharding.Mesh:
        """1-D ``("sites",)`` mesh over ``host``'s devices.

        This is what a host-local serving pool (one
        ``BatchedLatticeRunner`` per warm entry) plans against: work routed
        to ``host`` dispatches only on that host's devices.
        """
        return jax.sharding.Mesh(
            np.array(self.host_devices(host, devices)), ("sites",)
        )

    # -- identity --------------------------------------------------------------

    @property
    def is_multi_host(self) -> bool:
        return self.hosts > 1

    def n_devices(self, devices: list | None = None) -> int:
        devices = list(devices if devices is not None else jax.devices())
        return self.hosts * self._dph(len(devices))

    def describe(self) -> str:
        dph = self.devices_per_host or "auto"
        return f"{self.hosts}h x {dph}d"

    # -- constructors ----------------------------------------------------------

    @classmethod
    def single_host(cls) -> "MeshSpec":
        """The legacy topology: one host, all local devices."""
        return cls(hosts=1)

    @classmethod
    def simulated(cls, hosts: int, devices_per_host: int = 0) -> "MeshSpec":
        """A fake-fleet spec for tests/dryruns; identical to the constructor,
        named so call sites read as what they are."""
        return cls(hosts=hosts, devices_per_host=devices_per_host)

"""Production mesh construction (assignment §MULTI-POD DRY-RUN).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def _mk(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    # jax.sharding.AxisType landed after 0.4.x; Auto is the default there,
    # so omitting axis_types on older jax builds the identical mesh.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests / reduced-device dry-runs / elastic re-mesh)."""
    return _mk(shape, axes)

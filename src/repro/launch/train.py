"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 300 \
        --checkpoint-dir /tmp/ckpt

Single-host driver around train.loop (reduced configs on CPU; on TPU pods
the same pieces compose with jax.distributed + the production mesh — see
launch/dryrun.py for the mesh/sharding assembly used at scale).
"""
from __future__ import annotations

import argparse

from repro.configs import ALL_ARCHS, get_config
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ALL_ARCHS, required=True)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (not reduced) architecture config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    tcfg = TrainConfig(
        steps=args.steps, seq_len=args.seq_len, global_batch=args.global_batch,
        checkpoint_dir=args.checkpoint_dir, checkpoint_every=args.checkpoint_every,
        seed=args.seed, microbatches=args.microbatches,
        opt=AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps),
    )
    out = train(cfg, tcfg)
    print(f"done; final loss {out['final_loss']}")


if __name__ == "__main__":
    main()

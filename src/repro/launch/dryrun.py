import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST run before any jax import: jax locks the device count on first init.
# REPRO_XLA_FLAGS lets tests use smaller placeholder device counts.

# Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
# cell with ShapeDtypeStruct stand-ins (no allocation), print memory/cost
# analysis, and derive the three-term roofline (compute / HBM / ICI-collective).
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
#     PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single  # 40 cells
#
# SU3 fig7 multi-controller dry-run: ONE launch, N identical controller
# processes, each running the full strong-scaling curve through the real
# (host, device) MeshSpec plan path over forced host-platform devices; the
# launcher fails on any divergence between controllers or from the d1
# single-host reference.  (jaxlib's CPU backend cannot run cross-process
# computations, so the controllers are replicas of the same SPMD program —
# the multi-controller *protocol* under simulation, byte-checked.)
#
#     PYTHONPATH=src python -m repro.launch.dryrun --su3-fig7 \
#         --L 4 --device-counts 1,2 --hosts 2 --controllers 2
#
# (Module docstring sacrificed to keep the XLA_FLAGS lines first, per the
# dry-run contract; `from __future__` must follow a docstring if present.)

import argparse
import dataclasses
import hashlib
import json
import pathlib
import subprocess
import sys
import tempfile
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import ALL_ARCHS, SHAPES, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import roofline
from repro.distributed import act_sharding, sharding
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import common, registry
from repro.optim import adamw
from repro.train.train_step import make_train_step

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


@dataclasses.dataclass
class CellPolicy:
    """Memory/precision policy for a cell (recorded in the report)."""

    param_dtype: str
    moment_dtype: str
    cache_dtype: str
    microbatches: int

    @staticmethod
    def for_cell(cfg: ModelConfig, shape: ShapeConfig) -> "CellPolicy":
        big = cfg.n_params() > 60e9
        if shape.kind == "train":
            mb = 1
            if shape.seq_len * shape.global_batch >= 2**20:
                mb = 16 if big else 4
            return CellPolicy(
                param_dtype="bfloat16" if big else "float32",
                moment_dtype="bfloat16" if big else "float32",
                cache_dtype="bfloat16",
                microbatches=mb,
            )
        return CellPolicy(
            param_dtype="bfloat16", moment_dtype="bfloat16",
            cache_dtype="bfloat16", microbatches=1,
        )


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Assignment formula: 6*N*D train (N_active for MoE), 2*N*D inference."""
    n = cfg.active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    return (6.0 if shape.kind == "train" else 2.0) * n * tokens


def _sharded_bytes(spec_tree, mesh, rules, dtype) -> int:
    """Exact per-device bytes of a ParamSpec tree under the resolved shardings."""
    total = 0
    leaves = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, common.ParamSpec))
    for s in leaves:
        pspec = sharding.resolve_spec(s.axes, s.shape, mesh, rules)
        local = 1
        for i, dim in enumerate(s.shape):
            ax = pspec[i] if i < len(pspec) else None
            div = 1
            if ax is not None:
                axes = ax if isinstance(ax, tuple) else (ax,)
                for a in axes:
                    div *= mesh.shape[a]
            local *= dim // div
        total += local * jnp.dtype(dtype).itemsize
    return total


def _state_bytes(state_sds, mesh, rules, kv_seq_shard=False) -> int:
    """Per-device bytes of the decode/prefill state under state_shardings."""
    shardings = sharding.state_shardings(state_sds, mesh, rules, kv_seq_shard=kv_seq_shard)
    total = 0
    for sds, sh in zip(jax.tree.leaves(state_sds), jax.tree.leaves(shardings)):
        spec = sh.spec
        local = 1
        for i, dim in enumerate(sds.shape):
            ax = spec[i] if i < len(spec) else None
            div = 1
            if ax is not None:
                axes = ax if isinstance(ax, tuple) else (ax,)
                for a in axes:
                    div *= mesh.shape[a]
            local *= dim // max(div, 1)
        total += local * jnp.dtype(sds.dtype).itemsize
    return total


def estimate_memory(
    cfg: ModelConfig, shape: ShapeConfig, mesh: jax.sharding.Mesh,
    rules, policy: "CellPolicy", api, *, kv_seq_shard: bool = False,
) -> dict[str, Any]:
    """TPU-side analytic memory model (per device).

    The XLA *CPU* backend has no native bf16 dot: FloatNormalization upcasts
    every bf16 matmul operand to f32 and hoists whole-stack converts, so
    ``memory_analysis()`` on the host backend over-reports bf16 programs by
    up to ~3x (verified on the qwen3 train cell: 22.5 GiB hoisted f32 copy
    of an 11.25 GiB bf16 residual stack). This analytic model is the
    TPU-faithful estimate; both are recorded.
    """
    spec_tree = api.spec(cfg)
    p_bytes = _sharded_bytes(spec_tree, mesh, rules, policy.param_dtype)
    out: dict[str, Any] = {"params_bytes": p_bytes}
    dp = 1
    for a in rules.data_axes:
        dp *= mesh.shape[a]
    if shape.kind == "train":
        m_bytes = _sharded_bytes(spec_tree, mesh, rules, policy.moment_dtype)
        g_bytes = _sharded_bytes(spec_tree, mesh, rules, "float32")
        tokens_local = shape.global_batch * shape.seq_len // max(policy.microbatches, 1) // dp
        # remat residual stacks: one (d_model) vector per layer per local token
        resid = cfg.n_layers * tokens_local * cfg.d_model * 2  # bf16
        # transient working set ~ one layer's widest intermediate x2
        widest = max(cfg.d_ff, cfg.d_model * 4, cfg.ssm_expand * cfg.d_model * 2)
        trans = 2 * tokens_local * widest * 4
        out.update(
            opt_bytes=2 * m_bytes, grad_bytes=g_bytes,
            residual_bytes=resid, transient_bytes=trans,
            total_bytes=p_bytes + 2 * m_bytes + g_bytes + resid + trans,
        )
    else:
        state_sds = api.state_spec(cfg, shape.global_batch, shape.seq_len,
                                   jnp.dtype(policy.cache_dtype))
        s_bytes = _state_bytes(state_sds, mesh, rules, kv_seq_shard=kv_seq_shard)
        tokens_local = max(shape.global_batch * (shape.seq_len if shape.kind == "prefill" else 1) // dp, 1)
        widest = max(cfg.d_ff, cfg.d_model * 4, cfg.ssm_expand * cfg.d_model * 2)
        trans = 2 * tokens_local * widest * 2
        out.update(
            state_bytes=s_bytes, transient_bytes=trans,
            total_bytes=p_bytes + s_bytes + trans,
        )
    out["fits_v5e_16g"] = out["total_bytes"] <= roofline.TPU_V5E.hbm_bytes
    return out


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: jax.sharding.Mesh,
    *,
    policy: CellPolicy | None = None,
    fsdp: bool = True,
    kv_seq_shard: bool = False,
    grad_acc_dtype: str = "float32",
    microbatches: int | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
):
    """Build + lower one cell. Returns (lowered, meta)."""
    policy = policy or CellPolicy.for_cell(cfg, shape)
    if microbatches is not None:
        policy = dataclasses.replace(policy, microbatches=microbatches)
    rules = sharding.default_rules(mesh, fsdp=fsdp)
    api = registry.get(cfg)
    spec_tree = api.spec(cfg)
    p_dt = jnp.dtype(policy.param_dtype)
    params_sds = common.shape_tree(spec_tree, dtype=p_dt)
    p_sh = sharding.param_shardings(spec_tree, mesh, rules)
    batch_sds = registry.input_specs(cfg, shape)
    b_sh = sharding.batch_shardings(batch_sds, mesh, rules)

    with compat.set_mesh(mesh), act_sharding.use_rules(mesh, rules):
        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig(moment_dtype=policy.moment_dtype)
            m_dt = jnp.dtype(policy.moment_dtype)
            opt_sds = {
                "m": common.shape_tree(spec_tree, dtype=m_dt),
                "v": common.shape_tree(spec_tree, dtype=m_dt),
                "count": jax.ShapeDtypeStruct((), jnp.int32),
            }
            opt_sh = sharding.opt_state_shardings(p_sh, mesh)
            step = make_train_step(
                cfg, opt_cfg, microbatches=policy.microbatches,
                grad_acc_dtype=grad_acc_dtype, param_shardings=p_sh,
                q_chunk=q_chunk, kv_chunk=kv_chunk,
            )
            fn = jax.jit(
                step,
                in_shardings=(p_sh, opt_sh, b_sh),
                out_shardings=(p_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(params_sds, opt_sds, batch_sds)
        else:
            c_dt = jnp.dtype(policy.cache_dtype)
            state_sds = api.state_spec(cfg, shape.global_batch, shape.seq_len, c_dt)
            s_sh = sharding.state_shardings(state_sds, mesh, rules, kv_seq_shard=kv_seq_shard)
            if shape.kind == "prefill":

                def prefill_fn(params, batch, state):
                    return api.prefill(params, batch, state, cfg,
                                       q_chunk=q_chunk, kv_chunk=kv_chunk)

                fn = jax.jit(
                    prefill_fn,
                    in_shardings=(p_sh, b_sh, s_sh),
                    out_shardings=(None, s_sh),
                    donate_argnums=(2,),
                )
                lowered = fn.lower(params_sds, batch_sds, state_sds)
            else:  # decode

                def decode_fn(params, batch, state, cur_len):
                    return api.decode_step(params, batch, state, cur_len, cfg)

                cur_sds = jax.ShapeDtypeStruct((), jnp.int32)
                fn = jax.jit(
                    decode_fn,
                    in_shardings=(p_sh, b_sh, s_sh, None),
                    out_shardings=(None, s_sh),
                    donate_argnums=(2,),
                )
                lowered = fn.lower(params_sds, batch_sds, state_sds, cur_sds)
    meta = {"policy": dataclasses.asdict(policy), "fsdp": fsdp,
            "kv_seq_shard": kv_seq_shard, "grad_acc_dtype": grad_acc_dtype,
            "q_chunk": q_chunk, "kv_chunk": kv_chunk}
    return lowered, meta


def run_cell(
    arch: str,
    shape_name: str,
    mesh: jax.sharding.Mesh,
    mesh_label: str,
    *,
    hw: roofline.HardwareSpec = roofline.TPU_V5E,
    verbose: bool = True,
    save_hlo: bool = False,
    overrides: dict[str, Any] | None = None,
    tag: str = "",
) -> dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    cell = f"{arch}/{shape_name}/{mesh_label}{('#' + tag) if tag else ''}"
    if not ok:
        if verbose:
            print(f"[skip] {cell}: {reason}")
        return {"cell": cell, "status": "skipped", "reason": reason}

    t0 = time.time()
    lowered, meta = lower_cell(cfg, shape, mesh, **(overrides or {}))
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_report = {
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
    }
    mem_report["total_bytes_per_device"] = (
        mem_report["argument_bytes"]
        + mem_report["output_bytes"]
        + mem_report["temp_bytes"]
        - mem_report["alias_bytes"]
    )
    mem_report["fits_v5e_16g"] = mem_report["total_bytes_per_device"] <= hw.hbm_bytes
    # TPU-faithful analytic model (the CPU backend f32-upcasts bf16 dots,
    # inflating temp bytes; see estimate_memory docstring).
    cfg_policy = CellPolicy(**meta["policy"]) if isinstance(meta.get("policy"), dict) else None
    rules = sharding.default_rules(mesh, fsdp=meta.get("fsdp", True))
    analytic = estimate_memory(
        cfg, shape, mesh, rules, cfg_policy or CellPolicy.for_cell(cfg, shape),
        registry.get(cfg), kv_seq_shard=bool(meta.get("kv_seq_shard", False)),
    )

    hlo_text = compiled.as_text()
    report = roofline.analyze_compiled(
        cell, compiled, n_chips=mesh.devices.size, hw=hw,
        model_flops=model_flops(cfg, shape), hlo_text=hlo_text,
    )

    out = {
        "cell": cell,
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_label,
        "n_devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_report,
        "memory_analytic": analytic,
        "roofline": report.as_dict(),
        **meta,
    }
    if verbose:
        gib = mem_report["total_bytes_per_device"] / 2**30
        agib = analytic["total_bytes_per_device" if "total_bytes_per_device" in analytic else "total_bytes"] / 2**30
        print(f"[ok] {cell}: compile {t_compile:.1f}s | xla {gib:.2f} GiB/dev, "
              f"analytic {agib:.2f} GiB/dev (fits v5e: {analytic['fits_v5e_16g']})")
        print("     " + report.summary())
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = RESULTS_DIR / f"{arch}__{shape_name}__{mesh_label}{suffix}.json"
    fname.write_text(json.dumps(out, indent=2, default=str))
    if save_hlo:
        (RESULTS_DIR / f"{arch}__{shape_name}__{mesh_label}{suffix}.hlo.txt").write_text(hlo_text)
    return out


# ---------------------------------------------------------------------------
# SU3 fig7: strong scaling as ONE multi-controller dry-run launch
# ---------------------------------------------------------------------------


def _su3_result_digest(plan, seed: int) -> str:
    """sha256 of the canonical C lattice from a seeded random (A, B) pair.

    The SU3 multiply is site-local, so the live-site bytes are identical
    across every mesh/sharding of the same program — any difference between
    controllers or device counts is a real divergence (sharding permutation,
    init bug, nondeterminism), which is exactly what the launcher gates on.

    The RNG draw covers exactly the L**4 live sites (NOT ``padded_sites``,
    which varies with the device count and would shift the stream, making
    legitimately-identical results digest differently); padding is
    deterministic zeros and ``plan.unpack`` slices back to the live sites
    before hashing.
    """
    import numpy as np

    n = plan.cfg.shape.n_sites
    rng = np.random.default_rng(seed)
    shape = (n, 4, 3, 3)
    a = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype("complex64")
    b = (rng.standard_normal((4, 3, 3)) + 1j * rng.standard_normal((4, 3, 3))).astype("complex64")
    a = np.concatenate(
        [a, np.zeros((plan.padded_sites - n, 4, 3, 3), "complex64")], axis=0
    )
    c_phys = plan.step(plan.codec.pack(jnp.asarray(a)), plan.codec.pack_b(jnp.asarray(b)))
    c = np.asarray(jax.device_get(plan.unpack(c_phys)))  # live sites only
    return hashlib.sha256(c.tobytes()).hexdigest()


def su3_fig7_rows(
    L: int,
    device_counts: tuple[int, ...],
    hosts: int,
    seed: int = 0,
    iterations: int = 3,
) -> tuple[list[dict], dict[str, str]]:
    """The fig7 strong-scaling curve over (host, device) MeshSpec plans.

    Runs in ONE process whose forced device pool covers ``max(device_counts)``;
    every point slices its mesh from that pool through
    :class:`repro.launch.mesh.MeshSpec` — the real ``build_plan`` multi-host
    path, not a per-point child process.

    Returns:
        ``(rows, digests)`` — benchmark rows named ``fig7_{placement}_d{n}``
        (schema-compatible with the historical fig7 rows, plus ``hosts`` and
        halo fields) and ``{point_name: result_sha256}`` for the launcher's
        divergence gate.
    """
    from repro.core.su3.engine import EngineConfig as SU3EngineConfig, SU3Engine
    from repro.launch.mesh import MeshSpec

    rows: list[dict] = []
    digests: dict[str, str] = {}
    for n in device_counts:
        h = min(hosts, n)
        spec = MeshSpec(hosts=h, devices_per_host=n // h)
        for placement in ("sharded", "host_scatter"):
            cfg = SU3EngineConfig(
                L=L, variant="versionX", placement=placement,
                iterations=iterations, warmups=1, tile=128,
            )
            eng = SU3Engine(cfg, spec)
            row = eng.run().row()
            row["name"] = f"fig7_{placement}_d{n}"
            row["hosts"] = h
            row.update(eng.plan.halo().as_dict() if L**4 % max(h, 1) == 0 else {})
            rows.append(row)
            if placement == "sharded":
                digests[f"d{n}"] = _su3_result_digest(eng.plan, seed)
    return rows, digests


def _su3_fig7_worker(args: argparse.Namespace) -> None:
    """One controller: compute the curve + digests, write them to a JSON."""
    counts = tuple(int(x) for x in args.device_counts.split(","))
    rows, digests = su3_fig7_rows(
        args.L, counts, args.hosts, seed=args.seed, iterations=args.iterations
    )
    payload = {
        "rank": args.rank,
        "n_devices_visible": len(jax.devices()),
        "rows": rows,
        "digests": digests,
    }
    pathlib.Path(args.out).write_text(json.dumps(payload, default=str))


def su3_fig7_launch(
    L: int,
    device_counts: tuple[int, ...],
    hosts: int,
    controllers: int,
    seed: int = 0,
    iterations: int = 3,
    timeout: int = 600,
) -> list[dict]:
    """Launch ``controllers`` identical fig7 workers; gate on divergence.

    Every worker runs the full curve (the multi-controller SPMD discipline:
    same program, same data, every rank).  The launcher then requires

      * within each controller: every device count's result digest equals
        that controller's d1 (single-host) digest;
      * across controllers: all digest tables identical.

    Raises SystemExit(1) on divergence.  Returns controller 0's rows, each
    stamped with ``controllers``.
    """
    counts = ",".join(str(c) for c in device_counts)
    max_dev = max(device_counts)
    outs = []
    procs = []
    tmpdir = tempfile.mkdtemp(prefix="su3_fig7_")
    env = dict(os.environ)
    env["REPRO_XLA_FLAGS"] = f"--xla_force_host_platform_device_count={max_dev}"
    env.setdefault("PYTHONPATH", str(pathlib.Path(__file__).resolve().parents[2]))
    for rank in range(controllers):
        out = pathlib.Path(tmpdir) / f"controller_{rank}.json"
        outs.append(out)
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--su3-fig7-worker", "--rank", str(rank), "--out", str(out),
             "--L", str(L), "--device-counts", counts, "--hosts", str(hosts),
             "--seed", str(seed), "--iterations", str(iterations)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        ))
    payloads = []
    for rank, proc in enumerate(procs):
        try:
            _, err = proc.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            proc.kill()
            raise SystemExit(f"su3-fig7 controller {rank} timed out")
        if proc.returncode != 0:
            raise SystemExit(
                f"su3-fig7 controller {rank} failed:\n{err[-2000:]}"
            )
        payloads.append(json.loads(outs[rank].read_text()))

    reference = payloads[0]["digests"]
    single_host = reference.get(f"d{min(device_counts)}")
    failures = []
    for p in payloads:
        for point, digest in p["digests"].items():
            if digest != single_host:
                failures.append(
                    f"controller {p['rank']} {point}: {digest[:12]} != "
                    f"single-host {str(single_host)[:12]}"
                )
        if p["digests"] != reference:
            failures.append(f"controller {p['rank']} digest table diverges from rank 0")
    if failures:
        for f in failures:
            print(f"[DIVERGENCE] {f}", file=sys.stderr)
        raise SystemExit(1)
    rows = payloads[0]["rows"]
    for row in rows:
        row["controllers"] = controllers
    return rows


def _mesh_for(label: str) -> jax.sharding.Mesh:
    n = len(jax.devices())
    if label == "multi":
        if n >= 512:
            return make_production_mesh(multi_pod=True)
        # reduced-device fallback (tests): keep 3-axis structure
        return make_mesh((2, 2, n // 4), ("pod", "data", "model"))
    if n >= 256:
        return make_production_mesh(multi_pod=False)
    return make_mesh((max(n // 8, 1), min(n, 8)), ("data", "model"))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true", help="run every applicable cell")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--kv-seq-shard", action="store_true",
                    help="shard KV cache sequence dim over model axis when "
                         "kv_heads cannot (flash-decoding style)")
    ap.add_argument("--grad-acc-dtype", default="float32",
                    choices=("float32", "bfloat16"))
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--tag", default="", help="suffix for the result JSON")
    # SU3 fig7 multi-controller dry-run
    ap.add_argument("--su3-fig7", action="store_true",
                    help="launch the SU3 strong-scaling curve as one "
                         "multi-controller dry-run (divergence-gated)")
    ap.add_argument("--su3-fig7-worker", action="store_true",
                    help=argparse.SUPPRESS)  # internal: one controller rank
    ap.add_argument("--rank", type=int, default=0)
    ap.add_argument("--out", default="")
    ap.add_argument("--L", type=int, default=8)
    ap.add_argument("--device-counts", default="1,2,4")
    ap.add_argument("--hosts", type=int, default=2)
    ap.add_argument("--controllers", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--iterations", type=int, default=3)
    args = ap.parse_args()

    if args.su3_fig7_worker:
        _su3_fig7_worker(args)
        return
    if args.su3_fig7:
        counts = tuple(int(x) for x in args.device_counts.split(","))
        rows = su3_fig7_launch(
            args.L, counts, args.hosts, args.controllers,
            seed=args.seed, iterations=args.iterations,
        )
        print(json.dumps(rows, default=str))
        return

    mesh_labels = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells: list[tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a in ALL_ARCHS for s in SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    overrides: dict[str, Any] = {}
    if args.no_fsdp:
        overrides["fsdp"] = False
    if args.kv_seq_shard:
        overrides["kv_seq_shard"] = True
    if args.grad_acc_dtype != "float32":
        overrides["grad_acc_dtype"] = args.grad_acc_dtype
    if args.microbatches is not None:
        overrides["microbatches"] = args.microbatches
    failures = 0
    for label in mesh_labels:
        mesh = _mesh_for(label)
        print(f"== mesh {label}: {dict(zip(mesh.axis_names, mesh.devices.shape))} ==")
        for arch, shape_name in cells:
            try:
                run_cell(arch, shape_name, mesh, label, save_hlo=args.save_hlo,
                         overrides=overrides, tag=args.tag)
            except Exception as e:  # a failing cell is a bug in the system
                failures += 1
                print(f"[FAIL] {arch}/{shape_name}/{label}: {type(e).__name__}: {e}")
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()

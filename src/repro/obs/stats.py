"""Bounded streaming statistics for long-running services.

ServiceMetrics used to append every latency/occupancy/queue-depth sample to
a Python list — unbounded growth over a service lifetime.  These two
primitives replace the lists while keeping small-sample semantics *exact*
(below capacity the reservoir holds every sample, so the pinned snapshot
tests — 3 completions, exact p50 — see identical numbers):

  Reservoir     Vitter's algorithm-R reservoir over a fixed capacity with
                a deterministic RNG (seeded per-instance: no test flake),
                plus running count/sum so ``mean`` stays exact even after
                eviction starts.
  RunningStat   O(1) count/sum/min/max — for series only ever consumed as
                mean/max (occupancies, queue depths).
"""
from __future__ import annotations

import random

import numpy as np


class Reservoir:
    """Fixed-capacity uniform sample of a stream; exact below capacity."""

    __slots__ = ("capacity", "count", "total", "_sample", "_rng")

    def __init__(self, capacity: int = 4096, seed: int = 0):
        if capacity < 1:
            raise ValueError("Reservoir capacity must be >= 1")
        self.capacity = int(capacity)
        self.count = 0
        self.total = 0.0
        self._sample: list[float] = []
        self._rng = random.Random(seed)

    def add(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        if len(self._sample) < self.capacity:
            self._sample.append(value)
        else:
            j = self._rng.randrange(self.count)
            if j < self.capacity:
                self._sample[j] = value

    def extend(self, values) -> None:
        for v in values:
            self.add(v)

    def __len__(self) -> int:
        return self.count

    def __bool__(self) -> bool:
        return self.count > 0

    @property
    def sample(self) -> list[float]:
        return list(self._sample)

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        if not self._sample:
            return 0.0
        return float(np.percentile(self._sample, q))


class RunningStat:
    """Count/sum/min/max without retaining samples."""

    __slots__ = ("count", "total", "min", "max")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def add(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    def __len__(self) -> int:
        return self.count

    def __bool__(self) -> bool:
        return self.count > 0

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def max_or(self, default: float = 0.0) -> float:
        return self.max if self.count else default

"""Run provenance: the identity stamp on every BENCH_su3.json row set.

Bench numbers are only comparable when the environment that produced them
is pinned next to them.  ``provenance_block()`` captures the run identity
— git sha, jax/jaxlib versions, backend, device kind, XLA flags, autotune
cache schema — and ``benchmarks.run`` / ``scripts/profile_dispatch.py``
stamp it into the artifact.  ``scripts/bench_diff.py`` then refuses to
diff artifacts with a missing/incomplete block, and refuses a changed
jax/backend pair unless the current block carries a re-baseline note
(``REPRO_BENCH_REBASELINE="why"`` at generation time, or
``--rebaseline-note`` on the diff).
"""
from __future__ import annotations

import os
import platform as _platform
import subprocess
import sys
import time
from typing import Any

# The keys bench_diff requires; absence of any one fails the gate.
REQUIRED_PROVENANCE_KEYS = (
    "git_sha",
    "jax_version",
    "jaxlib_version",
    "backend",
    "device_kind",
    "xla_flags",
    "autotune_cache_schema",
)

# Keys whose change across baseline->current demands a re-baseline note.
ENV_IDENTITY_KEYS = ("jax_version", "jaxlib_version", "backend", "device_kind")

REBASELINE_ENV = "REPRO_BENCH_REBASELINE"


def _git_sha(cwd: str | None = None) -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=cwd, timeout=10,
        )
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def _git_dirty(cwd: str | None = None) -> bool | None:
    try:
        out = subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True, text=True,
            cwd=cwd, timeout=10,
        )
        if out.returncode == 0:
            return bool(out.stdout.strip())
    except (OSError, subprocess.SubprocessError):
        pass
    return None


def provenance_block(cwd: str | None = None) -> dict[str, Any]:
    """Capture this process's run identity. Never raises.

    jax is imported lazily so trace/report tooling can read artifacts on
    machines without the accelerator stack; missing pieces degrade to
    "unknown" rather than omitting the key (bench_diff checks presence).
    """
    block: dict[str, Any] = {
        "git_sha": _git_sha(cwd),
        "git_dirty": _git_dirty(cwd),
        "python_version": sys.version.split()[0],
        "platform": _platform.platform(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "generated_unix_s": time.time(),
    }
    try:
        import jax
        import jaxlib

        block["jax_version"] = jax.__version__
        block["jaxlib_version"] = jaxlib.__version__
        block["backend"] = jax.default_backend()
        devices = jax.devices()
        block["device_kind"] = devices[0].device_kind if devices else "none"
        block["device_count"] = len(devices)
    except Exception as exc:  # pragma: no cover - no-jax environments
        block.update({
            "jax_version": "unknown", "jaxlib_version": "unknown",
            "backend": "unknown", "device_kind": "unknown",
            "device_count": 0, "provenance_error": repr(exc),
        })
    try:
        from repro.core import autotune

        block["autotune_cache_schema"] = autotune.SCHEMA_VERSION
    except Exception:  # pragma: no cover
        block["autotune_cache_schema"] = "unknown"
    note = os.environ.get(REBASELINE_ENV, "").strip()
    if note:
        block["rebaseline"] = note
    return block


def provenance_problems(current: dict[str, Any],
                        baseline: dict[str, Any] | None = None,
                        rebaseline_note: str = "") -> list[str]:
    """Gate logic shared by bench_diff and tests.

    Returns human-readable problems: missing block / missing required keys
    in ``current``, and — when a ``baseline`` block is available — any
    ENV_IDENTITY_KEYS drift not covered by a re-baseline note (either
    stamped into the current block or passed on the command line).
    """
    problems: list[str] = []
    block = current.get("provenance")
    if not isinstance(block, dict):
        return ["current artifact has no provenance block "
                "(regenerate with benchmarks.run)"]
    missing = [k for k in REQUIRED_PROVENANCE_KEYS if k not in block]
    if missing:
        problems.append(
            "provenance block missing required keys: " + ", ".join(missing))
    base_block = (baseline or {}).get("provenance")
    if isinstance(base_block, dict):
        changed = [
            f"{k}: {base_block.get(k)!r} -> {block.get(k)!r}"
            for k in ENV_IDENTITY_KEYS
            if k in base_block and base_block.get(k) != block.get(k)
        ]
        note = (rebaseline_note or "").strip() or str(
            block.get("rebaseline", "")).strip()
        if changed and not note:
            problems.append(
                "environment identity changed without a re-baseline note ("
                + "; ".join(changed)
                + f") — set {REBASELINE_ENV} when regenerating or pass "
                  "--rebaseline-note to bench_diff")
    return problems

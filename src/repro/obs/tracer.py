"""Flight-recorder span tracer: nested spans, bounded ring, two exports.

Design constraints (ISSUE 7):

  * monotonic clock — ``time.perf_counter`` everywhere; wall-clock never
    enters a duration.
  * bounded memory — completed spans land in a ``deque(maxlen=capacity)``
    flight recorder; the oldest spans fall off and ``dropped`` counts them.
  * near-zero cost disabled — ``NULL_TRACER.span(...)`` returns one shared
    no-op context manager and allocates NO per-call objects (``**attrs``
    would build a dict, so the fast path is checked *before* attrs exist:
    callers guard hot-path instrumentation with ``if tracer.enabled``).
  * two exports from one record — flat JSONL (one span per line, greppable)
    and Chrome trace-event JSON (``{"traceEvents": [...]}``, complete "X"
    events in microseconds) loadable in chrome://tracing / Perfetto.

Span lanes map to Chrome ``tid``s: dispatch spans ride on ``lane=host``,
request-lifecycle spans on per-request lanes, so overlapping requests do
not fake nesting in the viewer.  Real parent/child nesting is the span
stack: ``tracer.span(...)`` context managers nest; retroactive spans
(``add_span``) attach to the stack top at insertion time unless an explicit
parent id is given.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Any, Iterator

_CLOCK = time.perf_counter


class Span:
    """One completed (or in-flight) span on the monotonic clock."""

    __slots__ = ("name", "t0_s", "t1_s", "span_id", "parent_id", "lane", "attrs")

    def __init__(self, name: str, t0_s: float, span_id: int,
                 parent_id: int | None, lane: int, attrs: dict[str, Any]):
        self.name = name
        self.t0_s = t0_s
        self.t1_s = t0_s
        self.span_id = span_id
        self.parent_id = parent_id
        self.lane = lane
        self.attrs = attrs

    @property
    def dur_s(self) -> float:
        return self.t1_s - self.t0_s

    def set(self, **attrs: Any) -> "Span":
        """Attach attributes mid-span (e.g. live/padded known post-coalesce)."""
        self.attrs.update(attrs)
        return self

    def as_dict(self) -> dict[str, Any]:
        return {
            "type": "span",
            "name": self.name,
            "ts_s": self.t0_s,
            "dur_s": self.dur_s,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "lane": self.lane,
            "attrs": self.attrs,
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, dur={self.dur_s * 1e6:.1f}us, "
                f"id={self.span_id}, parent={self.parent_id})")


class _SpanContext:
    """Context manager pairing one Span with the tracer's nesting stack."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        self._tracer._stack.append(self._span)
        return self._span

    def __exit__(self, *exc) -> None:
        span = self._span
        span.t1_s = _CLOCK()
        stack = self._tracer._stack
        if stack and stack[-1] is span:
            stack.pop()
        else:  # tolerate out-of-order exits rather than corrupt the stack
            try:
                stack.remove(span)
            except ValueError:
                pass
        self._tracer._record(span)


class _NullSpan:
    """Shared do-nothing span: the disabled-tracer fast path.

    One module-level instance serves every ``span()``/``event()`` call on a
    disabled tracer — no Span, no dict, no context-manager object is
    allocated.  ``set()`` is a no-op so call sites need no branches.
    """

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class Tracer:
    """Nested-span flight recorder with counters and bounded history.

    Single-threaded by design (the serving loop is a cooperative stepper);
    there is no lock on the ring or the span stack.
    """

    def __init__(self, enabled: bool = True, capacity: int = 8192):
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self.dropped = 0
        self.counters: dict[str, float] = {}
        self._ring: deque[Span] = deque(maxlen=self.capacity)
        self._stack: list[Span] = []
        self._next_id = 1

    # ---------------------------------------------------------------- record
    def span(self, name: str, lane: int = 0, **attrs: Any):
        """Open a nested span; use as ``with tracer.span("dispatch", ...)``.

        Returns the shared no-op span when disabled.  Hot paths should
        still guard with ``if tracer.enabled`` so ``**attrs`` packing is
        skipped entirely.
        """
        if not self.enabled:
            return _NULL_SPAN
        parent = self._stack[-1].span_id if self._stack else None
        if self._stack and lane == 0:
            lane = self._stack[-1].lane
        span = Span(name, _CLOCK(), self._alloc_id(), parent, lane, attrs)
        return _SpanContext(self, span)

    def add_span(self, name: str, t0_s: float, t1_s: float, lane: int = 0,
                 parent_id: int | None = None, **attrs: Any) -> Span | None:
        """Record a retroactively-timed span (caller already holds t0/t1).

        This is the zero-overhead pattern for hot paths that time a block
        anyway (dispatch loops, profilers): measure as before, then emit
        one span after the fact under ``if tracer.enabled``.
        """
        if not self.enabled:
            return None
        if parent_id is None and self._stack:
            parent_id = self._stack[-1].span_id
        span = Span(name, t0_s, self._alloc_id(), parent_id, lane, attrs)
        span.t1_s = t1_s
        self._record(span)
        return span

    def event(self, name: str, lane: int = 0, **attrs: Any) -> Span | None:
        """Zero-duration marker (admit, seat, evict...)."""
        if not self.enabled:
            return None
        now = _CLOCK()
        return self.add_span(name, now, now, lane=lane, **attrs)

    def absorb(self, records: list[dict[str, Any]], lane_offset: int = 0) -> int:
        """Merge span records from ANOTHER tracer (e.g. a forced-device
        subprocess's JSONL) into this ring, remapping span ids so parent /
        child links survive and cannot collide with local ids.

        Timestamps are kept on the source's monotonic clock — absolute
        offsets between processes are meaningless, but durations and
        nesting are exact.  Returns the number of spans absorbed.
        """
        if not self.enabled:
            return 0
        spans = [r for r in records if r.get("type", "span") == "span"]
        # two passes: children land in a ring BEFORE their parents (they
        # exit first), so parent ids are forward references
        idmap = {rec["span_id"]: self._alloc_id() for rec in spans
                 if rec.get("span_id") is not None}
        for rec in spans:
            span = Span(rec["name"], rec["ts_s"],
                        idmap.get(rec.get("span_id"), self._alloc_id()),
                        idmap.get(rec.get("parent_id")),
                        rec.get("lane", 0) + lane_offset,
                        dict(rec.get("attrs") or {}))
            span.t1_s = rec["ts_s"] + rec["dur_s"]
            self._record(span)
        return len(spans)

    def count(self, name: str, n: float = 1) -> None:
        if not self.enabled:
            return
        self.counters[name] = self.counters.get(name, 0) + n

    def _alloc_id(self) -> int:
        i = self._next_id
        self._next_id = i + 1
        return i

    def _record(self, span: Span) -> None:
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(span)

    # ---------------------------------------------------------------- read
    def spans(self) -> list[Span]:
        """Completed spans, oldest first (bounded by ``capacity``)."""
        return list(self._ring)

    def clear(self) -> None:
        self._ring.clear()
        self._stack.clear()
        self.counters.clear()
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._ring)

    # ---------------------------------------------------------------- export
    def iter_records(self) -> Iterator[dict[str, Any]]:
        for span in self._ring:
            yield span.as_dict()
        for name, value in sorted(self.counters.items()):
            yield {"type": "counter", "name": name, "value": value}

    def to_jsonl(self, path: str) -> int:
        """Flat JSONL: one record per line. Returns the record count."""
        n = 0
        with open(path, "w") as fh:
            for rec in self.iter_records():
                fh.write(json.dumps(rec) + "\n")
                n += 1
        return n

    def chrome_trace(self, metadata: dict[str, Any] | None = None) -> dict:
        """Chrome trace-event JSON object (phase-X complete events, us)."""
        events = []
        for span in self._ring:
            events.append({
                "name": span.name,
                "cat": span.name.split(".", 1)[0],
                "ph": "X",
                "ts": span.t0_s * 1e6,
                "dur": max(span.dur_s, 0.0) * 1e6,
                "pid": 0,
                "tid": span.lane,
                "args": dict(span.attrs, span_id=span.span_id,
                             parent_id=span.parent_id),
            })
        out: dict[str, Any] = {"traceEvents": events, "displayTimeUnit": "ms"}
        other: dict[str, Any] = {"dropped_spans": self.dropped}
        if self.counters:
            other["counters"] = dict(self.counters)
        if metadata:
            other.update(metadata)
        out["otherData"] = other
        return out

    def to_chrome_trace(self, path: str,
                        metadata: dict[str, Any] | None = None) -> int:
        payload = self.chrome_trace(metadata=metadata)
        with open(path, "w") as fh:
            json.dump(payload, fh)
        return len(payload["traceEvents"])


NULL_TRACER = Tracer(enabled=False, capacity=0)


def load_jsonl(path: str) -> list[dict[str, Any]]:
    """Read a flat-JSONL trace back into record dicts (spans + counters)."""
    records = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records

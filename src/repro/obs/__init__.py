"""repro.obs — spans / counters / histograms observability core.

The paper's method is attribution: phase-level measurement joined against
a roofline model (PIUMA turned out issue-bound, not bandwidth-bound —
something no best-iteration GFLOPS number could show).  This package is
that layer for the repro:

  Tracer / Span        nested spans on a monotonic clock, bounded
                       flight-recorder ring buffer, JSONL + Chrome
                       trace-event export.  ``NULL_TRACER`` is the shared
                       disabled instance — every hot-path hook is a single
                       ``if tracer.enabled`` branch, so the untraced
                       serving path allocates nothing.
  Reservoir / RunningStat
                       bounded streaming statistics (exact below capacity)
                       backing ServiceMetrics' latency/occupancy/queue
                       accounting in long-running services.
  provenance_block     the run-identity stamp (git sha, jax/jaxlib,
                       backend, device kind, XLA flags, autotune cache
                       schema) written into BENCH_su3.json and gated by
                       scripts/bench_diff.py.
  attribution_report   joins measured dispatch/phase spans against
                       predict_pipeline / predict_stencil modeled terms
                       per (tile, fused_k, compression, depth) config and
                       emits model-vs-measured deltas.
"""
from repro.obs.attribution import (
    attribution_report,
    overlap_efficiency_from_spans,
    render_attribution,
)
from repro.obs.provenance import (
    REQUIRED_PROVENANCE_KEYS,
    provenance_block,
    provenance_problems,
)
from repro.obs.stats import Reservoir, RunningStat
from repro.obs.tracer import NULL_TRACER, Span, Tracer

__all__ = [
    "NULL_TRACER",
    "REQUIRED_PROVENANCE_KEYS",
    "Reservoir",
    "RunningStat",
    "Span",
    "Tracer",
    "attribution_report",
    "overlap_efficiency_from_spans",
    "provenance_block",
    "provenance_problems",
    "render_attribution",
]

"""Model-vs-measured attribution: join spans against the roofline terms.

The table the paper's method demands: for every (tile, fused_k,
compression, depth) config that actually dispatched, line up the measured
span time against the three/four-term roofline prediction
(``autotune.predict_pipeline`` / ``autotune.predict_stencil``) and report
the delta — "this config is issue-bound and the model under-predicts the
halo by 18%" instead of a single GFLOPS number.

Span contract (what the serve/plan instrumentation emits):

  ``dispatch`` spans    attrs: kind ("multiply" | "stencil"), L, tile, k,
                        dtype, compression, host, live, flops, mode.
                        One span per host-step dispatch; ``flops`` are the
                        useful flops of the live requests in the batch.
  ``stencil.step``      attrs: L, tile, dtype, compression, hosts,
                        overlap, depth.  Child spans ``stencil.exchange`` /
                        ``stencil.interior`` / ``stencil.boundary`` (and
                        ``stencil.ring`` at depth 2) carry the phase times
                        that make ``overlap_efficiency`` a measured
                        quantity.

Rows accept live ``Span`` objects or JSONL record dicts interchangeably,
so ``scripts/trace_report.py`` can re-run the join offline from a trace
file.  Model calls import jax lazily; on a machine without the stack the
report degrades to measured-only rows (``predicted_gflops=None``).
"""
from __future__ import annotations

import statistics
from typing import Any, Iterable

_PHASE_NAMES = ("stencil.exchange", "stencil.interior", "stencil.boundary",
                "stencil.ring")


def _norm(rec: Any) -> dict[str, Any] | None:
    """Span | JSONL record -> {name, dur_s, attrs, span_id, parent_id}."""
    if hasattr(rec, "as_dict"):
        rec = rec.as_dict()
    if not isinstance(rec, dict) or rec.get("type", "span") != "span":
        return None
    return {
        "name": rec.get("name", ""),
        "dur_s": float(rec.get("dur_s", 0.0)),
        "attrs": rec.get("attrs", {}) or {},
        "span_id": rec.get("span_id"),
        "parent_id": rec.get("parent_id"),
    }


def _spans(records: Iterable[Any]) -> list[dict[str, Any]]:
    out = []
    for rec in records:
        norm = _norm(rec)
        if norm is not None:
            out.append(norm)
    return out


def _predictors():
    """(predict_pipeline, predict_stencil, Candidates, hw) or None."""
    try:
        from repro.core import autotune, roofline

        return autotune, roofline.TPU_V5E
    except Exception:  # pragma: no cover - jax-less trace readers
        return None, None


# --------------------------------------------------------------------- joins
def _multiply_rows(spans: list[dict], autotune_mod, hw) -> list[dict]:
    groups: dict[tuple, list[dict]] = {}
    for s in spans:
        a = s["attrs"]
        if s["name"] != "dispatch" or a.get("kind") != "multiply":
            continue
        key = (int(a.get("L", 0)), int(a.get("tile", 0)), int(a.get("k", 1)),
               str(a.get("dtype", "float32")),
               str(a.get("compression", "none")))
        groups.setdefault(key, []).append(s)
    rows = []
    for (L, tile, k, dtype, compression), members in sorted(groups.items()):
        durs = [m["dur_s"] for m in members if m["dur_s"] > 0]
        flops = sum(float(m["attrs"].get("flops", 0.0)) for m in members)
        total_s = sum(m["dur_s"] for m in members)
        mults = sum(int(m["attrs"].get("live", 1)) for m in members) * k
        measured_per_mult_s = total_s / mults if mults else 0.0
        row = {
            "workload": "multiply",
            "L": L, "tile": tile, "fused_k": k,
            "dtype": dtype, "compression": compression, "depth": None,
            "n_spans": len(members),
            "measured_s": statistics.median(durs) if durs else 0.0,
            "measured_unit_s": measured_per_mult_s,
            "measured_gflops": (flops / total_s / 1e9) if total_s else 0.0,
        }
        if autotune_mod is not None and tile > 0 and L > 0:
            pred = autotune_mod.predict_pipeline(
                autotune_mod.PipelineCandidate(tile=tile, fused_k=k),
                L=L, dtype=dtype, hw=hw, compression=compression)
            row.update(_model_fields(pred, measured_per_mult_s))
        else:
            row.update(_model_fields(None, measured_per_mult_s))
        rows.append(row)
    return rows


def _stencil_dispatch_rows(spans: list[dict], autotune_mod, hw) -> list[dict]:
    groups: dict[tuple, list[dict]] = {}
    for s in spans:
        a = s["attrs"]
        if s["name"] != "dispatch" or a.get("kind") != "stencil":
            continue
        key = (int(a.get("L", 0)), int(a.get("tile", 0)),
               str(a.get("dtype", "float32")),
               str(a.get("compression", "none")))
        groups.setdefault(key, []).append(s)
    rows = []
    for (L, tile, dtype, compression), members in sorted(groups.items()):
        durs = [m["dur_s"] for m in members if m["dur_s"] > 0]
        flops = sum(float(m["attrs"].get("flops", 0.0)) for m in members)
        total_s = sum(m["dur_s"] for m in members)
        apps = sum(int(m["attrs"].get("live", 1))
                   * int(m["attrs"].get("k", 1)) for m in members)
        measured_per_app_s = total_s / apps if apps else 0.0
        row = {
            "workload": "stencil",
            "L": L, "tile": tile, "fused_k": None,
            "dtype": dtype, "compression": compression, "depth": 1,
            "n_spans": len(members),
            "measured_s": statistics.median(durs) if durs else 0.0,
            "measured_unit_s": measured_per_app_s,
            "measured_gflops": (flops / total_s / 1e9) if total_s else 0.0,
        }
        if autotune_mod is not None and tile > 0 and L > 0:
            pred = autotune_mod.predict_stencil(
                autotune_mod.StencilCandidate(tile=tile, overlap=False, depth=1),
                L=L, dtype=dtype, hosts=1, hw=hw, compression=compression)
            row.update(_model_fields(pred, measured_per_app_s))
        else:
            row.update(_model_fields(None, measured_per_app_s))
        rows.append(row)
    return rows


def _stencil_schedule_rows(spans: list[dict], autotune_mod, hw) -> list[dict]:
    """One row per traced (L, tile, overlap, depth, hosts, compression)
    schedule config, with per-phase measured seconds from child spans."""
    by_id = {s["span_id"]: s for s in spans if s["span_id"] is not None}
    steps: dict[tuple, list[dict]] = {}
    phases: dict[int, dict[str, float]] = {}
    for s in spans:
        if s["name"] == "stencil.step":
            a = s["attrs"]
            key = (int(a.get("L", 0)), int(a.get("tile", 0)),
                   bool(a.get("overlap", False)), int(a.get("depth", 1)),
                   int(a.get("hosts", 1)), str(a.get("dtype", "float32")),
                   str(a.get("compression", "none")))
            steps.setdefault(key, []).append(s)
        elif s["name"] in _PHASE_NAMES and s["parent_id"] in by_id:
            acc = phases.setdefault(s["parent_id"], {})
            short = s["name"].split(".", 1)[1]
            acc[short] = acc.get(short, 0.0) + s["dur_s"]
    rows = []
    for (L, tile, overlap, depth, hosts, dtype, compression), members in \
            sorted(steps.items()):
        durs = [m["dur_s"] for m in members if m["dur_s"] > 0]
        measured_s = statistics.median(durs) if durs else 0.0
        # per-application time: a depth-d step is d stencil applications
        measured_unit_s = measured_s / max(depth, 1)
        phase_s: dict[str, float] = {}
        n_phase_steps = 0
        for m in members:
            p = phases.get(m["span_id"])
            if p:
                n_phase_steps += 1
                for name, dur in p.items():
                    phase_s[name] = phase_s.get(name, 0.0) + dur
        if n_phase_steps:
            phase_s = {k: v / n_phase_steps for k, v in phase_s.items()}
        flops = sum(float(m["attrs"].get("flops", 0.0)) for m in members)
        total_s = sum(m["dur_s"] for m in members)
        row = {
            "workload": "stencil_schedule",
            "L": L, "tile": tile, "fused_k": None,
            "dtype": dtype, "compression": compression,
            "overlap": overlap, "depth": depth, "hosts": hosts,
            "n_spans": len(members),
            "measured_s": measured_s,
            "measured_unit_s": measured_unit_s,
            "measured_gflops": (flops / total_s / 1e9) if total_s else 0.0,
            "phase_s": {k: round(v, 9) for k, v in sorted(phase_s.items())},
            "measured_dominant_phase": (
                max(phase_s, key=phase_s.get) if phase_s else None),
        }
        if autotune_mod is not None and tile > 0 and L > 0:
            pred = autotune_mod.predict_stencil(
                autotune_mod.StencilCandidate(
                    tile=tile, overlap=overlap, depth=depth),
                L=L, dtype=dtype, hosts=hosts, hw=hw, compression=compression)
            row.update(_model_fields(pred, measured_unit_s))
        else:
            row.update(_model_fields(None, measured_unit_s))
        rows.append(row)
    return rows


def _model_fields(pred: dict | None, measured_unit_s: float) -> dict:
    """The model side of a row: predicted terms + the headline delta.

    ``delta_frac`` is (measured - predicted) / predicted on the per-unit
    time — positive means the model under-predicts (reality slower)."""
    if not pred:
        return {"predicted_s": None, "predicted_gflops": None,
                "model_dominant": None, "model_terms": None,
                "delta_frac": None}
    bound = float(pred["bound_s"])
    terms = {k: pred[k] for k in
             ("compute_s", "memory_s", "issue_s", "halo_s") if k in pred}
    return {
        "predicted_s": bound,
        "predicted_gflops": pred.get("predicted_gflops"),
        "model_dominant": pred.get("dominant"),
        "model_terms": terms,
        "delta_frac": ((measured_unit_s - bound) / bound) if bound else None,
    }


def attribution_report(records: Iterable[Any]) -> list[dict]:
    """Measured-vs-modeled rows for every config that shows up in spans.

    Three workload families: ``multiply`` (serving dispatch, joined against
    predict_pipeline), ``stencil`` (serving dispatch, predict_stencil at
    hosts=1/serial), ``stencil_schedule`` (the overlap schedule's step +
    phase spans, predict_stencil at the traced (overlap, depth, hosts)).
    """
    spans = _spans(records)
    autotune_mod, hw = _predictors()
    rows = []
    rows.extend(_multiply_rows(spans, autotune_mod, hw))
    rows.extend(_stencil_dispatch_rows(spans, autotune_mod, hw))
    rows.extend(_stencil_schedule_rows(spans, autotune_mod, hw))
    return rows


# ------------------------------------------------------------ overlap measure
def overlap_efficiency_from_spans(records: Iterable[Any]) -> dict | None:
    """Phase accounting for the overlap schedule, straight from spans.

    Returns the mean per-step phase seconds plus the traced wall.  Because
    traced runs synchronize at phase boundaries (the only way to time a
    phase), the *traced* wall cannot witness hiding — the caller divides
    ``sum_phases_s`` by an UNTRACED wall to get the real efficiency
    (``overlap_efficiency = sum_phases / untraced_wall``; 1.0 means nothing
    hidden, >1 means the exchange overlapped the interior).
    """
    spans = _spans(records)
    steps = [s for s in spans if s["name"] == "stencil.step"
             and s["attrs"].get("overlap")]
    if not steps:
        return None
    ids = {s["span_id"] for s in steps}
    phase_s: dict[str, float] = {}
    for s in spans:
        if s["name"] in _PHASE_NAMES and s["parent_id"] in ids:
            short = s["name"].split(".", 1)[1]
            phase_s[short] = phase_s.get(short, 0.0) + s["dur_s"]
    n = len(steps)
    phase_s = {k: v / n for k, v in phase_s.items()}
    wall = sum(s["dur_s"] for s in steps) / n
    return {
        "n_steps": n,
        "phase_s": {k: round(v, 9) for k, v in sorted(phase_s.items())},
        "sum_phases_s": sum(phase_s.values()),
        "traced_wall_s": wall,
    }


def overlap_efficiency(sum_phases_s: float, untraced_wall_s: float) -> float:
    if untraced_wall_s <= 0:
        return 0.0
    return sum_phases_s / untraced_wall_s


# ---------------------------------------------------------------- rendering
_COLUMNS = ("workload", "config", "n", "measured", "modeled", "delta",
            "dominant", "gflops(meas/pred)")


def _fmt_s(v: float | None) -> str:
    if v is None:
        return "-"
    if v >= 1.0:
        return f"{v:.3f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.2f}ms"
    return f"{v * 1e6:.1f}us"


def _config_tag(row: dict) -> str:
    bits = [f"L{row['L']}", f"t{row['tile']}"]
    if row.get("fused_k"):
        bits.append(f"k{row['fused_k']}")
    if row.get("depth") and row["workload"] != "multiply":
        bits.append(f"d{row['depth']}")
    if row.get("hosts") and row.get("hosts", 1) > 1:
        bits.append(f"h{row['hosts']}")
    if row.get("overlap"):
        bits.append("ovl")
    if row.get("compression", "none") != "none":
        bits.append(row["compression"])
    if row.get("dtype", "float32") != "float32":
        bits.append(row["dtype"])
    return "/".join(bits)


def render_attribution(rows: list[dict]) -> str:
    """Fixed-width model-vs-measured table (the trace_report payload)."""
    if not rows:
        return "(no attributable dispatch/schedule spans in trace)"
    table = [_COLUMNS]
    for row in rows:
        delta = row.get("delta_frac")
        meas_g = row.get("measured_gflops")
        pred_g = row.get("predicted_gflops")
        dominant = row.get("model_dominant") or "-"
        if row.get("measured_dominant_phase"):
            dominant += f" (meas: {row['measured_dominant_phase']})"
        table.append((
            row["workload"],
            _config_tag(row),
            str(row["n_spans"]),
            _fmt_s(row.get("measured_unit_s")),
            _fmt_s(row.get("predicted_s")),
            f"{delta:+.0%}" if delta is not None else "-",
            dominant,
            (f"{meas_g:.2f}/{pred_g:.2f}"
             if meas_g is not None and pred_g is not None else "-"),
        ))
    widths = [max(len(r[i]) for r in table) for i in range(len(_COLUMNS))]
    lines = []
    for i, r in enumerate(table):
        lines.append("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip())
        if i == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)

"""Deterministic, shardable, checkpoint-resumable synthetic token pipeline.

Production posture without external data dependencies:

  * deterministic: batch ``i`` is a pure function of (seed, i) — any host can
    regenerate any batch, which is what makes elastic restart trivial;
  * shardable: each data-parallel host generates only its slice (pass
    ``shard_index``/``shard_count``), matching the paper's placement lesson —
    data is born where it is consumed, never scattered from host 0;
  * resumable: the iterator state is one integer (next step), stored in the
    checkpoint; no file offsets to replay.

The token stream is a stationary Markov chain over the vocab (not uniform
noise) so cross-entropy has learnable structure: loss decreasing over a few
hundred steps is a meaningful end-to-end signal for examples/tests.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 16  # Markov out-degree: lower => more learnable


@dataclasses.dataclass
class PipelineState:
    step: int = 0


class TokenPipeline:
    def __init__(self, cfg: DataConfig, *, shard_index: int = 0, shard_count: int = 1):
        assert cfg.global_batch % shard_count == 0
        self.cfg = cfg
        self.shard_index = shard_index
        self.shard_count = shard_count
        self.local_batch = cfg.global_batch // shard_count
        # Fixed random successor table: token t may be followed only by
        # successors[t, :branching]; deterministic in the seed.
        rng = np.random.default_rng(cfg.seed)
        self._succ = rng.integers(
            0, cfg.vocab_size, size=(cfg.vocab_size, cfg.branching), dtype=np.int64
        )

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Pure function of step: the whole fleet agrees on batch contents."""
        cfg = self.cfg
        rows = []
        base = step * cfg.global_batch + self.shard_index * self.local_batch
        for r in range(self.local_batch):
            rng = np.random.default_rng((cfg.seed + 1) * 1_000_003 + base + r)
            toks = np.empty(cfg.seq_len + 2, dtype=np.int64)
            toks[0] = rng.integers(cfg.vocab_size)
            choices = rng.integers(0, cfg.branching, size=cfg.seq_len + 1)
            for t in range(1, cfg.seq_len + 2):
                toks[t] = self._succ[toks[t - 1], choices[t - 1]]
            rows.append(toks)
        arr = np.stack(rows).astype(np.int32)
        return {
            "tokens": arr[:, : cfg.seq_len],
            "labels": arr[:, 1 : cfg.seq_len + 1],
            "labels2": arr[:, 2 : cfg.seq_len + 2],
        }

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_train_batch(
    pipe: TokenPipeline,
    state: PipelineState,
    cfg: ModelConfig,
    shape: ShapeConfig | None = None,
    *,
    extras_seed: int = 17,
) -> tuple[dict[str, jax.Array], PipelineState]:
    """Next batch + advanced state; adds stub modality inputs when needed."""
    raw = pipe.batch_at(state.step)
    batch: dict[str, jax.Array] = {
        "tokens": jnp.asarray(raw["tokens"]),
        "labels": jnp.asarray(raw["labels"]),
    }
    if cfg.mtp_depth:
        batch["labels2"] = jnp.asarray(raw["labels2"])
    if cfg.n_patches:
        key = jax.random.fold_in(jax.random.PRNGKey(extras_seed), state.step)
        batch["patches"] = jax.random.normal(
            key, (pipe.local_batch, cfg.n_patches, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        key = jax.random.fold_in(jax.random.PRNGKey(extras_seed + 1), state.step)
        batch["frames"] = jax.random.normal(
            key, (pipe.local_batch, cfg.encoder_len, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    return batch, PipelineState(step=state.step + 1)

"""Version-compat shims for the span of jax versions this repo runs on.

The container pins jax 0.4.x while the code targets current jax; every
new-API touchpoint goes through here so call sites stay clean.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable

import jax


def shard_map(
    f: Callable, *, mesh: jax.sharding.Mesh, in_specs: Any, out_specs: Any,
    check_vma: bool = True,
) -> Callable:
    """jax.shard_map (new) / jax.experimental.shard_map (0.4.x; check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _sm  # 0.4.x

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma)


def set_mesh(mesh: jax.sharding.Mesh):
    """jax.set_mesh (new) / sharding.use_mesh (mid) / no-op ctx (0.4.x).

    On 0.4.x there is no ambient-mesh API; callers there always pass explicit
    NamedShardings built from the same mesh, so a null context is equivalent.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    use = getattr(jax.sharding, "use_mesh", None)
    if use is not None:
        return use(mesh)
    return contextlib.nullcontext(mesh)

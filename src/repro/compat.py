"""Version-compat shims for the span of jax versions this repo runs on.

The container pins jax 0.4.x while the code targets current jax; every
new-API touchpoint goes through here so call sites stay clean.

Retirement ledger — each shim names the jax version that obsoletes it.
Audited against the pinned container version (jax 0.4.37, 2026-07): none of
the new APIs exist there (`jax.shard_map`, `jax.set_mesh`,
`jax.sharding.use_mesh`, `jax.sharding.AxisType` are all absent), so every
shim below is still live.  When the container pin crosses a shim's
"obsolete at" version, delete the shim and inline the new API at its call
sites (grep for ``compat.<name>``).

Related shims that live OUTSIDE this module (same ledger discipline):

* ``repro.launch.mesh._mk`` — omits ``axis_types`` on 0.4.x; obsolete at
  jax >= 0.5.x (``jax.sharding.AxisType``).
* ``repro.models.common.grad_safe_barrier`` — custom-vjp wrapper because
  0.4.x ``jax.lax.optimization_barrier`` has no batching/transpose rules
  under autodiff; obsolete once the pin reaches a jax where
  ``optimization_barrier`` is differentiable (0.5.x line).
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable

import jax


def shard_map(
    f: Callable, *, mesh: jax.sharding.Mesh, in_specs: Any, out_specs: Any,
    check_vma: bool = True,
) -> Callable:
    """jax.shard_map (new) / jax.experimental.shard_map (0.4.x; check_rep).

    Obsolete at: jax >= 0.6.0, where ``jax.shard_map`` is a top-level API
    and the ``check_rep`` kwarg was renamed ``check_vma``.  On 0.4.x the
    experimental module with the old kwarg spelling is the only path.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _sm  # 0.4.x

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma)


def set_mesh(mesh: jax.sharding.Mesh):
    """jax.set_mesh (new) / sharding.use_mesh (mid) / no-op ctx (0.4.x).

    Obsolete at: jax >= 0.7.0, where ``jax.set_mesh`` is the stable ambient-
    mesh API (``jax.sharding.use_mesh`` covered the 0.5.x–0.6.x interim).
    On 0.4.x there is no ambient-mesh API at all; callers there always pass
    explicit NamedShardings built from the same mesh, so a null context is
    equivalent.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    use = getattr(jax.sharding, "use_mesh", None)
    if use is not None:
        return use(mesh)
    return contextlib.nullcontext(mesh)

"""AdamW with warmup-cosine schedule, global-norm clipping, and a
memory-precision knob for the optimizer moments (fp32 default; bf16 halves
optimizer HBM for the 671B-class configs).

Optimizer state shards exactly like the params (same logical axes), so FSDP
partitioning of m/v falls out of the param sharding resolver for free — the
ZeRO pattern expressed as shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # bf16 halves optimizer memory


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Any, cfg: AdamWConfig) -> dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(
    grads: Any, state: dict[str, Any], params: Any, cfg: AdamWConfig
) -> tuple[Any, dict[str, Any], dict[str, jax.Array]]:
    """-> (new_params, new_state, opt_metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * step).astype(p.dtype),
            m32.astype(mdt),
            v32.astype(mdt),
        )

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

"""Gradient compression with error feedback.

Two wire formats for the gradient reduction, both with fp32 error-feedback
accumulators (the compression error is fed back into the next step's
gradient, which keeps SGD/Adam convergence — Seide et al. 1-bit SGD,
Karimireddy et al. EF-SGD):

  bf16   halve all-reduce bytes; the production default.
  int8   per-tensor symmetric quantization, 4x fewer bytes on the wire.

Under pjit the all-reduce happens on whatever dtype the gradient tree holds
when it crosses the data axis, so compressing before the reduction is
exactly a wire-format change; the roofline collective term picks it up from
the HLO (all-reduce operand dtype shrinks).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    mode: str = "none"  # none | bf16 | int8


def init_error_state(params: Any, cfg: CompressionConfig) -> Any:
    if cfg.mode == "none":
        return None
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g: jax.Array, mode: str) -> tuple[jax.Array, jax.Array]:
    """-> (wire tensor, scale). Decompress with wire * scale."""
    if mode == "bf16":
        return g.astype(jnp.bfloat16), jnp.ones((), jnp.float32)
    if mode == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(g)) / 127.0, 1e-12)
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q, scale
    raise ValueError(mode)


def decompress(wire: jax.Array, scale: jax.Array) -> jax.Array:
    return wire.astype(jnp.float32) * scale


def apply_error_feedback(
    grads: Any, error_state: Any, cfg: CompressionConfig
) -> tuple[Any, Any, dict[str, jax.Array]]:
    """grads -> (decompressed grads as reduced on the wire, new error state).

    g_eff = compress(g + e);  e' = (g + e) - decompress(g_eff)
    """
    if cfg.mode == "none" or error_state is None:
        return grads, error_state, {"compression_err": jnp.zeros((), jnp.float32)}

    def one(g: jax.Array, e: jax.Array):
        corrected = g.astype(jnp.float32) + e
        wire, scale = compress(corrected, cfg.mode)
        restored = decompress(wire, scale)
        return restored, corrected - restored

    out = jax.tree.map(one, grads, error_state)
    new_grads = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    total_err = sum(
        jnp.sum(jnp.square(e)) for e in jax.tree.leaves(new_err)
    )
    return new_grads, new_err, {"compression_err": total_err}

"""repro.serve.su3 — dynamic-batching SU3 lattice serving.

Public surface:

  ServiceConfig / SU3Service   the traffic-handling front door over the
                               warm ExecutionPlan pool (bf16-storage plans
                               via dtype="bfloat16", accum_dtype="float32")
  BatcherConfig / DynamicBatcher / ServeRequest / CoalescedBatch
                               the (L, k)-bucketed coalescing queue
  LocalityRouter / InflightChain
                               host-locality routing and continuous-batching
                               chain admission (multi-host serving)
  ServiceMetrics               latency/throughput/occupancy accounting
  RequestFailure taxonomy      structured per-request failures delivered
                               through the result channel (DeadlineExceeded
                               / RetriesExhausted / LoadShed), plus the
                               RetryPolicy / HostHealth robustness knobs
"""
from repro.serve.su3.batcher import (
    BatcherConfig,
    CoalescedBatch,
    DynamicBatcher,
    InflightChain,
    LocalityRouter,
    ServeRequest,
)
from repro.serve.su3.metrics import ServiceMetrics, request_flops
from repro.serve.su3.robustness import (
    PRIORITY,
    DeadlineExceededError,
    HostHealth,
    LoadShedError,
    RequestFailure,
    RetriesExhaustedError,
    RetryPolicy,
)
from repro.serve.su3.service import ServiceConfig, SU3Service

__all__ = [
    "BatcherConfig",
    "CoalescedBatch",
    "DeadlineExceededError",
    "DynamicBatcher",
    "HostHealth",
    "InflightChain",
    "LoadShedError",
    "LocalityRouter",
    "PRIORITY",
    "RequestFailure",
    "RetriesExhaustedError",
    "RetryPolicy",
    "ServeRequest",
    "ServiceMetrics",
    "ServiceConfig",
    "SU3Service",
    "request_flops",
]

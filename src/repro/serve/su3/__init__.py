"""repro.serve.su3 — dynamic-batching SU3 lattice serving.

Public surface:

  ServiceConfig / SU3Service   the traffic-handling front door over the
                               warm ExecutionPlan pool (bf16-storage plans
                               via dtype="bfloat16", accum_dtype="float32")
  BatcherConfig / DynamicBatcher / ServeRequest / CoalescedBatch
                               the (L, k)-bucketed coalescing queue
  LocalityRouter / InflightChain
                               host-locality routing and continuous-batching
                               chain admission (multi-host serving)
  ServiceMetrics               latency/throughput/occupancy accounting
  RequestFailure taxonomy      structured per-request failures delivered
                               through the result channel (DeadlineExceeded
                               / RetriesExhausted / LoadShed), plus the
                               RetryPolicy / HostHealth robustness knobs
  tenancy layer                SLO classes (latency/bulk) + SLOPolicy,
                               per-tenant TenantQuota token buckets, the
                               DeficitFairScheduler over (tenant, class)
                               groups, WarmPoolAutoscaler, and the
                               three-rung BrownoutLadder overload control
"""
from repro.serve.su3.batcher import (
    BatcherConfig,
    CoalescedBatch,
    DynamicBatcher,
    InflightChain,
    LocalityRouter,
    ServeRequest,
)
from repro.serve.su3.metrics import ServiceMetrics, request_flops
from repro.serve.su3.robustness import (
    PRIORITY,
    DeadlineExceededError,
    HostHealth,
    LoadShedError,
    RequestFailure,
    RetriesExhaustedError,
    RetryPolicy,
)
from repro.serve.su3.service import ServiceConfig, SU3Service
from repro.serve.su3.tenancy import (
    DEFAULT_TENANT,
    SLO_BULK,
    SLO_CLASSES,
    SLO_LATENCY,
    AutoscaleConfig,
    BrownoutConfig,
    BrownoutLadder,
    DeficitFairScheduler,
    SLOPolicy,
    TenantQuota,
    TokenBucket,
    WarmPoolAutoscaler,
)

__all__ = [
    "AutoscaleConfig",
    "BatcherConfig",
    "BrownoutConfig",
    "BrownoutLadder",
    "CoalescedBatch",
    "DEFAULT_TENANT",
    "DeadlineExceededError",
    "DeficitFairScheduler",
    "DynamicBatcher",
    "HostHealth",
    "InflightChain",
    "LoadShedError",
    "LocalityRouter",
    "PRIORITY",
    "RequestFailure",
    "RetriesExhaustedError",
    "RetryPolicy",
    "SLOPolicy",
    "SLO_BULK",
    "SLO_CLASSES",
    "SLO_LATENCY",
    "ServeRequest",
    "ServiceMetrics",
    "ServiceConfig",
    "SU3Service",
    "TenantQuota",
    "TokenBucket",
    "WarmPoolAutoscaler",
    "request_flops",
]

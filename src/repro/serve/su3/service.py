"""SU3Service: the plan layer behind a traffic-handling front door.

Composition (everything below the service already exists in the plan layer;
the service adds the queueing discipline and the warm-pool policy):

    submit(a, b, k)                      arun(a, b, k)  [asyncio face]
          │ LocalityRouter — sticky L -> host (work follows the warm data)
          ▼
    per-host DynamicBatcher — (L, k) buckets, warm-size padding, admission
          │  next_batch()  one CoalescedBatch per step()        [batch mode]
          │  next_for_L()  iteration-boundary admission     [continuous mode]
          ▼
    host-sharded warm pool:
          {(host, L, dtype, layout, tile) -> BatchedLatticeRunner}
          │  each host's runners plan against THAT host's submesh
          │  (MeshSpec.host_submesh) and are built through the persistent
          │  autotune cache: the FIRST request for an (L, dtype) pays
          │  compile + tile/K sweep, every later request hits the warm plan
          ▼
    one vmapped, sharded, (optionally bf16-storage/f32-accumulate) dispatch
          │
          ▼
    split + unpad per request  ->  results keyed by request id

The chain depth ``k`` defaults to the autotuned fused depth for the request's
(backend, L) — ``autotune.tuned_fused_k`` — so callers that don't care get
the measured-best dispatch amortization instead of a hardcoded constant.

Stencil requests (``submit_stencil``) ride the same front door: same
locality router, same per-host batcher (their own by-L queue family), same
warm runner pool.  They coalesce into one vmapped stencil dispatch per
scheduling turn and return canonical vector fields; they never join multiply
chains in any dispatch mode.

Solve requests (``submit_solve``) are the flagship iterative workload: a
staggered CG solve ``(sigma I + S) x = b`` through the plan's fused
stencil+axpy iteration (``ExecutionPlan.cg_state_init`` / ``cg_iterate``).
Each host runs ONE active solve at a time, advanced
``solve_iters_per_step`` CG iterations per scheduling turn — its iteration
count is data-dependent, so it retires *mid-chain* the turn its residual
crosses tol (or at ``max_iters``), freeing its seat and queue budget while
multiply chains are still in flight.  When a host has several kinds
pending, turns rotate multiply → stencil → solve so no sustained stream of
one kind starves the others.

Dispatch modes
--------------
``batch-per-step`` (default): one ``step()`` call dispatches one coalesced
(L, k) bucket through one fused-k vmapped call.  Requests arriving while a
chain runs wait for the next ``step()``.

``continuous`` (``ServiceConfig(continuous=True)``): each (host, L) keeps an
:class:`~repro.serve.su3.batcher.InflightChain` whose lattice batch is
re-dispatched ONE iteration at a time; at every iteration boundary, waiting
same-L requests are admitted into free slots (mid-chain admission — each
slot carries its own remaining-iteration count, so mixed k coexists in one
chain).  A request for a different L is shape-incompatible with the
in-flight batch and queues for its own chain.  Under open-loop load this
keeps the dispatched slots fuller than batch-per-step — measured by
``benchmarks/serve_traffic.py``'s continuous-vs-batch row.

``megakernel`` (``ServiceConfig(continuous=True, megakernel=True)``): the
continuous path's dispatch bill — one kernel launch per (host, L) chain per
iteration, the pipeline-throughput tax the paper measures on PIUMA — is
collapsed to ONE batched K-chain ``pallas_call`` per host per iteration.
Each host keeps a single mixed-L :class:`SlotTable`; every slot is padded to
the table's site capacity (grown, with live slots re-seated, when a larger L
arrives), per-slot chain depths ride in as scalar-prefetched data, and
mid-chain admission becomes a slot swap.  ``chain_horizon`` chains that many
multiplies in-kernel between admission boundaries.
"""
from __future__ import annotations

import asyncio
import dataclasses
import math
import random
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.chaos.faults import NULL_FAULT_PLAN, FaultPlan, poison_array
from repro.core import autotune
from repro.core.su3.layouts import Layout
from repro.core.su3.plan import (
    CG_DIVERGENCE_FACTOR,
    BatchedLatticeRunner,
    CGDivergedError,
    EngineConfig,
)
from repro.kernels.su3_stencil import (
    CG_ITER_FLOPS_PER_SITE,
    STENCIL_FLOPS_PER_SITE,
)
from repro.launch.mesh import MeshSpec
from repro.obs.tracer import NULL_TRACER, Tracer
from repro.serve.su3.batcher import (
    BatcherConfig,
    DynamicBatcher,
    InflightChain,
    LocalityRouter,
    ServeRequest,
    SlotTable,
)
from repro.serve.su3.metrics import ServiceMetrics, request_flops
from repro.serve.su3.robustness import (
    PRIORITY,
    DeadlineExceededError,
    HostHealth,
    LoadShedError,
    RequestFailure,
    RetriesExhaustedError,
    RetryPolicy,
)
from repro.serve.su3.tenancy import (
    DEFAULT_KIND_SLO,
    DEFAULT_TENANT,
    SLO_BULK,
    SLO_CLASSES,
    SLO_LATENCY,
    AutoscaleConfig,
    BrownoutConfig,
    BrownoutLadder,
    DeficitFairScheduler,
    GroupKey,
    SLOPolicy,
    TenantQuota,
    TokenBucket,
    WarmPoolAutoscaler,
)

DEFAULT_TILE = 128  # small enough that every L >= 2 bucket is a few tiles

# Chrome-trace lane assignment: dispatch spans ride the host's lane so one
# timeline row per host shows the dispatch cadence; request-lifecycle spans
# spread over a block of per-request lanes so overlapping requests don't
# fake nesting in the viewer.
_REQUEST_LANE_BASE = 100
_REQUEST_LANES = 32


def _request_lane(req_id: int) -> int:
    return _REQUEST_LANE_BASE + req_id % _REQUEST_LANES


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """The serving tuple: storage/compute dtypes, layout, tuning, batching,
    host topology, and dispatch mode.

    Attributes:
        dtype: storage dtype of every plan in the pool.
        accum_dtype: ``"float32"`` with ``dtype="bfloat16"`` = bf16-storage /
            f32-accumulate serving plans.
        compression: ``"two_row"`` serves 12-real compressed-gauge plans
            (row 2 reconstructed in-register by every kernel in the pool);
            stacks with the bf16/f32 mixed-precision tuple.
        layout: physical lattice layout (planar-view layouts only).
        autotune: build runner configs through the persistent cache.
        tile: explicit Pallas tile when ``autotune=False`` (0 = DEFAULT_TILE).
        default_k: chain depth when a request leaves k unset; 0 = autotuned.
        batcher: per-host queue discipline (each host gets its own
            DynamicBatcher with this config — admission control is per host).
        cache_directory: autotune cache override (tests).
        hosts: shard the warm pool over this many hosts; each host's runners
            plan on its :meth:`~repro.launch.mesh.MeshSpec.host_submesh` and
            requests route to an L's home host (sticky locality routing).
            On a device pool smaller than the host count, hosts
            oversubscribe the local devices — the routing/batching semantics
            are identical, only physical placement collapses (simulation).
        continuous: continuous-batching dispatch (iteration-boundary
            admission into in-flight chains) instead of batch-per-step.
        chain_slots: slots per in-flight chain (continuous mode);
            0 = the batcher's ``padded_size(max_batch)``.
        megakernel: continuous mode dispatches ONE batched K-chain
            megakernel per host per iteration over a single mixed-L slot
            table (``ExecutionPlan.fused_batched_step``) instead of one
            k=1 dispatch per (host, L) chain — the dispatch-amortized path
            (requires ``continuous=True``).
        chain_horizon: megakernel in-kernel chain depth per slot between
            admission boundaries; 1 re-opens admission at every multiply,
            larger values amortize more dispatches per request at the cost
            of admission latency.
        solve_iters_per_step: CG iterations the host's active solve advances
            per scheduling turn; small values re-open kind rotation (and
            thus multiply/stencil service) more often, large values amortize
            more solver work per turn at the cost of mix latency.
        faults: optional :class:`repro.chaos.FaultPlan` armed over the
            service's injection seams (dispatch / kernel / pool; the halo
            seam lives on the plan).  None = the shared disabled plan —
            every seam is one ``if faults.enabled`` branch, zero cost.
        retry: capped-exponential-backoff retry policy plus the service-wide
            retry budget for failed dispatches.
        default_deadline_s: relative deadline applied to every request that
            does not pass its own (0 = none); a request past its deadline is
            evicted — from the queue OR its live chain/table seat — and
            completes with a structured ``DeadlineExceededError``.
        quarantine_after: consecutive dispatch failures that latch a host
            out of service (its requests re-seat onto healthy hosts);
            single-host services never self-quarantine.
        numerics_guard: check dispatch outputs for NaN/Inf even with no
            fault plan armed (chaos runs always check).
        slo: per-class policy — deadline defaults and fair-scheduler weights
            for the ``latency`` and ``bulk`` lanes.
        quotas: optional per-tenant :class:`TenantQuota` token buckets
            (``{tenant: TenantQuota}``); a tenant past its bucket is
            rejected at the front door (``submit_*`` returns None, counted
            in ``quota_rejected``).  Tenants absent from the map are
            unmetered.
        autoscale: warm-pool controller; when enabled the service starts at
            ``min_hosts`` active hosts and grows/shrinks the active set
            from queue-depth/occupancy pressure with hysteresis (shrink
            never evicts a seated latency request).  Disabled = every
            configured host stays active (pre-tenancy behavior).
        brownout: optional three-rung overload ladder over the bulk lane
            (None = disabled): rung 1 sheds bulk admissions past a reduced
            queue share, rung 2 additionally degrades bulk solves, rung 3
            rejects new bulk with a Retry-After hint in the LoadShedError.
    """

    dtype: str = "float32"  # storage dtype of every plan in the pool
    accum_dtype: str = ""  # "float32" + dtype="bfloat16" = bf16 serving plans
    compression: str = "none"  # "two_row" = 12-real compressed-gauge plans
    layout: Layout = Layout.SOA
    autotune: bool = True  # build runner configs through the persistent cache
    tile: int = 0  # explicit tile when autotune=False (0 = DEFAULT_TILE)
    default_k: int = 0  # chain depth when a request leaves k unset; 0 = tuned
    batcher: BatcherConfig = dataclasses.field(default_factory=BatcherConfig)
    cache_directory: str | None = None  # autotune cache override (tests)
    hosts: int = 1  # shard the warm pool across this many hosts
    continuous: bool = False  # iteration-boundary admission dispatch
    chain_slots: int = 0  # continuous-chain slots; 0 = padded max_batch
    megakernel: bool = False  # one batched dispatch/host/iteration (continuous)
    chain_horizon: int = 1  # megakernel in-kernel chain depth between boundaries
    solve_iters_per_step: int = 4  # CG iterations per solve scheduling turn
    faults: FaultPlan | None = None  # chaos plan armed over the serve seams
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    default_deadline_s: float = 0.0  # relative per-request deadline (0 = none)
    quarantine_after: int = 3  # consecutive failures latching a host out
    numerics_guard: bool = False  # NaN/Inf-check outputs without a fault plan
    slo: SLOPolicy = dataclasses.field(default_factory=SLOPolicy)
    quotas: Any = None  # {tenant: TenantQuota} token buckets (None = unmetered)
    autoscale: AutoscaleConfig = dataclasses.field(default_factory=AutoscaleConfig)
    brownout: BrownoutConfig | None = None  # overload ladder (None = disabled)

    def __post_init__(self) -> None:
        # the pool serves the planar Pallas kernel; AOS has no planar view,
        # so reject it here instead of inside the first user request
        if Layout(self.layout) not in (Layout.SOA, Layout.AOSOA):
            raise ValueError(
                f"serving pool requires a planar-view layout (soa/aosoa), "
                f"got {Layout(self.layout).value!r}"
            )
        # best_config sweeps (and cache-keys) SoA plans only — applying its
        # tile/fused_k to another layout would serve never-measured numbers
        # under a mislabeled cache entry
        if self.autotune and Layout(self.layout) != Layout.SOA:
            raise ValueError(
                "the autotune cache tunes SoA plans only; serve "
                f"{Layout(self.layout).value!r} with autotune=False and an "
                "explicit tile"
            )
        if self.hosts < 1:
            raise ValueError(f"hosts must be >= 1, got {self.hosts}")
        if self.chain_slots < 0:
            raise ValueError(f"chain_slots must be >= 0, got {self.chain_slots}")
        if self.megakernel and not self.continuous:
            raise ValueError(
                "megakernel dispatch is the continuous path's amortizer; "
                "set continuous=True (batch-per-step already fuses its k "
                "chain in one dispatch)"
            )
        if self.chain_horizon < 1:
            raise ValueError(f"chain_horizon must be >= 1, got {self.chain_horizon}")
        if self.solve_iters_per_step < 1:
            raise ValueError(
                f"solve_iters_per_step must be >= 1, got "
                f"{self.solve_iters_per_step}"
            )
        if self.default_deadline_s < 0:
            raise ValueError(
                f"default_deadline_s must be >= 0, got {self.default_deadline_s}"
            )
        if self.quarantine_after < 1:
            raise ValueError(
                f"quarantine_after must be >= 1, got {self.quarantine_after}"
            )
        if self.quotas is not None:
            for tenant, quota in dict(self.quotas).items():
                if not tenant or not isinstance(tenant, str):
                    raise ValueError(
                        f"quota tenants must be non-empty strings, got "
                        f"{tenant!r}"
                    )
                if not isinstance(quota, TenantQuota):
                    raise ValueError(
                        f"quotas values must be TenantQuota, got "
                        f"{type(quota).__name__} for tenant {tenant!r}"
                    )
        if self.autoscale.enabled and self.autoscale.min_hosts > self.hosts:
            raise ValueError(
                f"autoscale.min_hosts={self.autoscale.min_hosts} exceeds "
                f"hosts={self.hosts}"
            )


class _ChainArrays:
    """Device-array half of one in-flight chain (scheduling half:
    :class:`~repro.serve.su3.batcher.InflightChain`).

    Holds the physical lattice batch ``a_phys (slots, ...)`` and planar B
    batch ``b_p (slots, 2, 36)``; free slots carry zero lattices (they step
    harmlessly and are charged as padding by the metrics).
    """

    def __init__(self, runner: BatchedLatticeRunner, slots: int):
        self.runner = runner
        zero_canon = jnp.zeros(
            (slots, runner.plan.padded_sites, 4, 3, 3), jnp.complex64
        )
        self.a_phys = jax.vmap(runner.plan.codec.pack)(zero_canon)
        self.b_p = jnp.zeros(
            (slots, 2, 36), runner.plan.codec.word_dtype
        )

    def seat(self, slot: int, a: jax.Array, b: jax.Array) -> None:
        """Pack one request's canonical (A, B) into ``slot``."""
        a_one = self.runner.pack_batch(a[None])[0]
        b_one = self.runner.plan.codec.pack_b(b)
        self.a_phys = self.a_phys.at[slot].set(a_one)
        self.b_p = self.b_p.at[slot].set(b_one)

    def advance(self) -> None:
        """One vmapped physical multiply over every slot (k=1)."""
        self.a_phys = self.runner.run(self.a_phys, self.b_p, k=1)

    def result(self, slot: int, n_sites: int) -> jax.Array:
        """Canonical complex C of ``slot``, sliced to the live sites."""
        return self.runner.plan.codec.unpack(self.a_phys[slot], n_sites)

    def clear(self, slot: int) -> None:
        """Zero a freed slot (its stale lattice would otherwise keep
        stepping and confuse a later occupant's first iteration)."""
        self.a_phys = self.a_phys.at[slot].set(jnp.zeros_like(self.a_phys[slot]))
        self.b_p = self.b_p.at[slot].set(jnp.zeros_like(self.b_p[slot]))


class _SlotTableArrays:
    """Device-array half of one host's megakernel slot table (scheduling
    half: :class:`~repro.serve.su3.batcher.SlotTable`).

    Every slot is padded to ``cap_L``'s site capacity, so requests of ANY
    L <= cap_L share the one dispatched shape; the whole table advances in
    ONE ``fused_batched_step`` dispatch with per-slot chain depths.  Dead
    slots carry zero lattices and depth 0 (the kernel passes them through).
    """

    def __init__(self, runner: BatchedLatticeRunner, slots: int, max_k: int):
        self.runner = runner
        self.slots = slots
        self.max_k = max_k
        self.cap_L = runner.cfg.L
        plan = runner.plan
        zero_canon = jnp.zeros((slots, plan.padded_sites, 4, 3, 3), jnp.complex64)
        self.a_phys = jax.vmap(plan.codec.pack)(zero_canon)
        self.b_p = jnp.zeros((slots, 2, 36), plan.codec.word_dtype)
        self._step = plan.fused_batched_step(slots, max_k=max_k)

    def seat(self, slot: int, a: jax.Array, b: jax.Array) -> None:
        """Pack one request's canonical (A, B) into ``slot``, zero-padding
        its sites up to the table's capacity."""
        a_one = self.runner.pack_batch(a[None])[0]
        b_one = self.runner.plan.codec.pack_b(b)
        self.a_phys = self.a_phys.at[slot].set(a_one)
        self.b_p = self.b_p.at[slot].set(b_one)

    def advance(self, slot_k: list[int]) -> None:
        """ONE megakernel dispatch: slot ``i`` advances ``slot_k[i]``
        multiplies in-kernel (0 = pass-through)."""
        ks = jnp.asarray(slot_k, jnp.int32)
        self.a_phys = self._step(self.a_phys, self.b_p, ks)

    def result(self, slot: int, n_sites: int) -> jax.Array:
        """Canonical complex C of ``slot``, sliced to the live sites."""
        return self.runner.plan.codec.unpack(self.a_phys[slot], n_sites)

    def clear(self, slot: int) -> None:
        """Zero a freed slot."""
        self.a_phys = self.a_phys.at[slot].set(jnp.zeros_like(self.a_phys[slot]))
        self.b_p = self.b_p.at[slot].set(jnp.zeros_like(self.b_p[slot]))


class SU3Service:
    """Dynamic-batching SU3 lattice serving over a warm ExecutionPlan pool.

    Args:
        cfg: the :class:`ServiceConfig` serving tuple.
        mesh: optional explicit mesh every runner plans against (single-host
            only; mutually exclusive with ``cfg.hosts > 1``, where each
            host's runners plan on their own submesh).
        tracer: optional :class:`repro.obs.Tracer` recording the request
            lifecycle (admit → queue wait → seat → dispatch → complete) and
            per-dispatch spans.  Defaults to the shared disabled tracer —
            every instrumentation site is one ``if tracer.enabled`` branch,
            so untraced serving allocates nothing.
    """

    def __init__(self, cfg: ServiceConfig | None = None, mesh: Any = None,
                 tracer: Tracer | None = None):
        self.cfg = cfg if cfg is not None else ServiceConfig()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if self.cfg.hosts > 1 and mesh is not None:
            raise ValueError(
                "pass EITHER an explicit mesh (single-host pool) OR "
                "hosts > 1 (per-host submeshes derived from MeshSpec)"
            )
        self.mesh = mesh
        self.mesh_spec = MeshSpec(hosts=self.cfg.hosts)
        self.router = LocalityRouter(self.cfg.hosts)
        self._batchers = [
            DynamicBatcher(self.cfg.batcher) for _ in range(self.cfg.hosts)
        ]
        self.batcher = self._batchers[0]  # host 0; aggregate depth: queued()
        self.metrics = ServiceMetrics()
        self._pool: dict[tuple, BatchedLatticeRunner] = {}
        self._ecfg: dict[int, EngineConfig] = {}  # L -> resolved plan tuple
        self._tuned_k: dict[int, int] = {}
        self._results: dict[int, jax.Array] = {}
        # (host, L, dtype, layout, tile) -> jitted vmapped stencil dispatch
        self._stencil_steps: dict[tuple, Any] = {}
        # (host, group) kind fairness: the kind that group's LAST turn on
        # the host served; the next turn serves the first pending kind
        # strictly after it in the multiply -> stencil -> solve rotation,
        # so within one (tenant, class) group no sustained stream of one
        # kind starves the others.  WHICH group owns a turn is the deficit
        # fair scheduler's call (replacing the old global kind rotation).
        self._last_kind: dict[tuple[int, GroupKey], str] = {}
        self._sched = DeficitFairScheduler(weight_for=self.cfg.slo.weight_for)
        # per-host active solve: ONE data-dependent CG solve advanced a few
        # iterations per scheduling turn (kind="solve" seat)
        self._solves: dict[int, dict[str, Any]] = {}
        self._awaited: set[int] = set()  # ids owned by pending arun callers
        self._seen_shapes: set[tuple] = set()
        self._next_id = 0
        self._rr_host = 0  # round-robin cursor over hosts for step()
        # continuous mode: (host, L) -> (InflightChain, _ChainArrays)
        self._chains: dict[tuple[int, int], tuple[InflightChain, _ChainArrays]] = {}
        # megakernel mode: host -> (SlotTable, _SlotTableArrays)
        self._tables: dict[int, tuple[SlotTable, _SlotTableArrays]] = {}
        # -- robustness state (ISSUE 9) ---------------------------------------
        self.faults = self.cfg.faults if self.cfg.faults is not None \
            else NULL_FAULT_PLAN
        self.health = HostHealth(self.cfg.hosts, self.cfg.quarantine_after)
        self._retry_rng = random.Random(self.cfg.retry.seed)
        self._retry_budget = self.cfg.retry.budget
        # requests waiting out a backoff: (eligible perf_counter s, request)
        self._retry_q: list[tuple[float, ServeRequest]] = []
        # set the first time any request carries a deadline, so the
        # deadline-free hot path never scans queues/seats for expiry
        self._deadlines_armed = bool(
            self.cfg.default_deadline_s
            or self.cfg.slo.latency_deadline_s
            or self.cfg.slo.bulk_deadline_s)
        # -- tenancy state (ISSUE 10) ------------------------------------------
        self._quota_buckets: dict[str, TokenBucket] = {}
        self._brownout = BrownoutLadder(self.cfg.brownout) \
            if self.cfg.brownout is not None else None
        if self.cfg.autoscale.enabled:
            self._autoscaler: WarmPoolAutoscaler | None = WarmPoolAutoscaler(
                self.cfg.autoscale, self.cfg.hosts)
            self._active_hosts = self.cfg.autoscale.min_hosts
        else:
            self._autoscaler = None
            self._active_hosts = self.cfg.hosts
        self.metrics.active_hosts = self._active_hosts

    # -- warm pool -----------------------------------------------------------

    def _engine_config(self, L: int) -> EngineConfig:
        """Resolved plan tuple for L, memoized — the autotune path otherwise
        re-reads the JSON cache file on every dispatch."""
        if L not in self._ecfg:
            cfg = self.cfg
            if cfg.autotune:
                self._ecfg[L] = autotune.tuned_engine_config(
                    L=L, dtype=cfg.dtype, cache_directory=cfg.cache_directory,
                    layout=cfg.layout, accum_dtype=cfg.accum_dtype,
                    compression=cfg.compression,
                )
            else:
                self._ecfg[L] = EngineConfig(
                    L=L, dtype=cfg.dtype, layout=cfg.layout,
                    tile=cfg.tile or DEFAULT_TILE, accum_dtype=cfg.accum_dtype,
                    compression=cfg.compression,
                )
        return self._ecfg[L]

    def _host_mesh(self, host: int) -> Any:
        """The mesh host ``host``'s runners plan against."""
        if self.cfg.hosts == 1:
            return self.mesh  # explicit mesh or None (all local devices)
        return self.mesh_spec.host_submesh(host)

    def runner_for(self, L: int, host: int | None = None) -> BatchedLatticeRunner:
        """The warm runner for lattice size L on its home host.

        Args:
            L: lattice extent (requests carry L**4 sites).
            host: explicit host override; default = the router's sticky
                home for L (assigned least-loaded-first on first sight).

        Returns:
            The host-local :class:`BatchedLatticeRunner` (built + autotuned
            on first use; warm afterwards).
        """
        if host is None:
            host = self._home(L)
        ecfg = self._engine_config(L)
        key = (host, L, ecfg.dtype, ecfg.layout.value, ecfg.tile, ecfg.compression)
        runner = self._pool.get(key)
        if runner is None:
            if self.faults.enabled:
                # "pool" seam: warm-runner construction fails (a host that
                # cannot compile/allocate its plan).  The build is retried
                # immediately — charged as one retry — and repeated cold-
                # build failures walk the host toward quarantine.
                f = self.faults.ask("pool", host=host, L=L)
                if f is not None:
                    self.metrics.record_fault()
                    self.metrics.record_retry()
                    if self.tracer.enabled:
                        self.tracer.event("chaos.fault", lane=host,
                                          site="pool", action=f.action,
                                          seq=f.seq, host=host, L=L)
                    if self.health.record_failure(host, "pool-build"):
                        self._quarantine(host)
            runner = BatchedLatticeRunner(ecfg, self._host_mesh(host))
            self._pool[key] = runner
        return runner

    def _serving_hosts(self) -> list[int]:
        """Hosts eligible for new work: active (autoscaler set) and not
        quarantined.  Never empty — if quarantine has eaten the whole
        active set, the healthy hosts beyond it serve (HostHealth never
        quarantines the last healthy host)."""
        hosts = [
            h for h in range(self._active_hosts)
            if not self.health.is_quarantined(h)
        ]
        return hosts or self.health.healthy_hosts()

    def _home(self, L: int) -> int:
        """The lattice size's home host, re-homed deterministically onto a
        serving host when the sticky assignment is quarantined or scaled
        out of the active pool."""
        host = self.router.host_for(L)
        serving = self._serving_hosts()
        if host not in serving:
            host = serving[L % len(serving)]
        return host

    def pool_keys(self) -> list[tuple]:
        """Sorted warm-pool keys:
        ``(host, L, dtype, layout, tile, compression)``."""
        return sorted(self._pool)

    def default_k_for(self, L: int) -> int:
        """Request chain depth when unspecified: configured or autotuned."""
        if self.cfg.default_k:
            return self.cfg.default_k
        if not self.cfg.autotune:
            return 1
        if L not in self._tuned_k:
            self._tuned_k[L] = autotune.tuned_fused_k(
                L=L, dtype=self.cfg.dtype, accum_dtype=self.cfg.accum_dtype,
                compression=self.cfg.compression,
                cache_directory=self.cfg.cache_directory,
            )
        return self._tuned_k[L]

    def _chain_slots(self) -> int:
        return self.cfg.chain_slots or self.cfg.batcher.padded_size(
            self.cfg.batcher.max_batch
        )

    def warm(self, Ls: tuple[int, ...], ks: tuple[int, ...] = (1,),
             batch_sizes: tuple[int, ...] = (), stencil: bool = False) -> None:
        """Pre-build runners (and optionally compile dispatch shapes).

        Serving cold-start control: first-touch compiles happen here instead
        of inside a user request's latency.  In continuous mode this also
        compiles the (chain_slots, k=1) iteration shape each chain
        re-dispatches.  ``stencil=True`` additionally compiles the vmapped
        stencil dispatch at each warm batch size.
        """
        for L in Ls:
            runner = self.runner_for(L)
            n_sites = L**4
            for bsz in batch_sizes:
                a = jnp.zeros((bsz, n_sites, 4, 3, 3), jnp.complex64)
                b = jnp.zeros((bsz, 4, 3, 3), jnp.complex64)
                for k in ks:
                    runner.multiply(a, b, k=k).block_until_ready()
                    self._seen_shapes.add(self._shape_key(runner, L, k, bsz))
                if stencil:
                    plan = runner.plan
                    host = self.router.host_for(L)
                    dispatched = bsz + (-bsz) % runner.n_devices
                    u_w = jnp.zeros(
                        (dispatched, n_sites, 4, 3, 3), jnp.complex64
                    )
                    v = jnp.zeros((dispatched, n_sites, 3), jnp.complex64)
                    u_phys = runner.pack_batch(u_w)
                    v_p = jax.vmap(
                        lambda x: plan.codec.pack_vec(x, plan.padded_sites)
                    )(v)
                    step = self._stencil_step_for(runner, host, L)
                    step(u_phys, v_p).block_until_ready()
                    self._seen_shapes.add(("stencil", L, dispatched))
            if self.cfg.megakernel:
                # per-slot depths are data, so ONE compile at this capacity
                # serves every (k mix, admission pattern) the table will see
                slots = self._chain_slots()
                arrays = _SlotTableArrays(runner, slots, max_k=self.cfg.chain_horizon)
                arrays.advance([0] * slots)
                arrays.a_phys.block_until_ready()
                self._seen_shapes.add(("mega", L, slots, self.cfg.chain_horizon))
            elif self.cfg.continuous:
                arrays = _ChainArrays(runner, self._chain_slots())
                arrays.advance()
                arrays.a_phys.block_until_ready()
                self._seen_shapes.add(
                    self._shape_key(runner, L, 1, self._chain_slots())
                )

    @staticmethod
    def _shape_key(runner: BatchedLatticeRunner, L: int, k: int, bsz: int) -> tuple:
        """Compiled-shape identity: the runner pads the batch up to a device
        multiple, so that post-pad size — not the request count — is what
        the jit cache keys on."""
        return (L, k, bsz + (-bsz) % runner.n_devices)

    # -- tracing -------------------------------------------------------------

    def _trace_dispatch(self, runner: BatchedLatticeRunner, host: int,
                        kind: str, L: int, k: int, mode: str, t0: float,
                        step_s: float, live: int, padded: int, flops: float,
                        cold: bool) -> None:
        """One retroactive dispatch span (the timed block already ran —
        zero extra clock reads on the hot path).  Callers guard with
        ``if self.tracer.enabled``."""
        ecfg = runner.cfg
        self.tracer.add_span(
            "dispatch", t0, t0 + step_s, lane=host,
            kind=kind, mode=mode, host=host, L=L, k=k,
            tile=ecfg.tile, dtype=ecfg.dtype, compression=ecfg.compression,
            live=live, padded=padded, flops=flops, cold=cold)

    def _trace_request(self, req: ServeRequest, done_s: float, host: int,
                       mode: str) -> None:
        """Whole-lifecycle span for one completed request: admission →
        completion, with the queue wait (admit → first seat) as an attr."""
        seated = req.seated_s or req.arrival_s
        self.tracer.add_span(
            "request", req.arrival_s, done_s, lane=_request_lane(req.req_id),
            req_id=req.req_id, kind=req.kind, L=req.L, k=req.k, host=host,
            mode=mode, queue_wait_s=seated - req.arrival_s)

    # -- request intake ------------------------------------------------------

    @staticmethod
    def _infer_L(a: jax.Array) -> int:
        n_sites = a.shape[0]
        L = round(n_sites ** 0.25)
        if L**4 != n_sites or a.shape[1:] != (4, 3, 3):
            raise ValueError(
                f"request lattice must be (L**4, 4, 3, 3) canonical complex, "
                f"got {a.shape}"
            )
        return L

    def queued(self) -> int:
        """Total waiting requests across every host's batcher."""
        return sum(len(b) for b in self._batchers)

    def _deadline(self, deadline_s: float | None, arrival_s: float,
                  slo: str = SLO_BULK) -> float:
        """Absolute deadline for a request: its own relative deadline, else
        the SLO class's default, else the service-wide default, else none
        (0.0)."""
        d = deadline_s
        if d is None:
            d = self.cfg.slo.deadline_for(slo) or self.cfg.default_deadline_s
        if d and d > 0:
            self._deadlines_armed = True
            return arrival_s + d
        return 0.0

    @staticmethod
    def _resolve_slo(kind: str, slo: str | None) -> str:
        """The request's SLO class: explicit, else the kind's default."""
        if slo is None:
            return DEFAULT_KIND_SLO[kind]
        if slo not in SLO_CLASSES:
            raise ValueError(
                f"slo must be one of {SLO_CLASSES}, got {slo!r}"
            )
        return slo

    @staticmethod
    def _check_tenant(tenant: str) -> str:
        if not tenant or not isinstance(tenant, str):
            raise ValueError(
                f"tenant must be a non-empty string, got {tenant!r}"
            )
        return tenant

    def _quota_admit(self, tenant: str, now: float) -> bool:
        """Charge the tenant's token bucket; False = quota backpressure
        (the submit returns None before touching any queue)."""
        quotas = self.cfg.quotas
        if not quotas:
            return True
        spec = quotas.get(tenant)
        if spec is None:
            return True
        bucket = self._quota_buckets.get(tenant)
        if bucket is None:
            bucket = self._quota_buckets[tenant] = TokenBucket(spec)
        if bucket.try_take(now):
            return True
        self.metrics.record_quota_reject(tenant)
        if self.tracer.enabled:
            self.tracer.event("quota.reject", lane=0, tenant=tenant)
        return False

    def _brownout_door(self, req: ServeRequest, host: int) -> int | None:
        """The brownout ladder's bulk-lane admission check.  Returns the
        request id when the ladder SHED the arrival (the id resolves
        immediately to a LoadShedError — zero-lost accounting holds, the
        caller can pop the structured error), or None to admit normally.
        Latency-class requests are never browned out."""
        ladder = self._brownout
        if ladder is None or ladder.rung < 1 or req.slo != SLO_BULK:
            return None
        rung = ladder.rung
        retry_after = 0.0
        if rung >= 3:
            retry_after = self.cfg.brownout.retry_after_s
        else:
            # rung 1/2: bulk keeps only a reduced share of the queue budget
            budget = max(1, int(self.cfg.batcher.max_queue_depth
                                * self.cfg.brownout.bulk_queue_fraction))
            if self._batchers[host].depth_for_slo(SLO_BULK) < budget:
                return None
        self._next_id += 1
        self.metrics.record_shed(req.kind, for_kind="brownout",
                                 tenant=req.tenant, slo=req.slo)
        self._results[req.req_id] = LoadShedError(
            req_id=req.req_id, kind=req.kind, priority=req.priority,
            shed_for_kind="brownout", attempts=req.attempts,
            retry_after_s=retry_after)
        if self.tracer.enabled:
            self.tracer.event(
                "brownout.shed", lane=_request_lane(req.req_id),
                req_id=req.req_id, kind=req.kind, tenant=req.tenant,
                rung=rung, retry_after_s=retry_after)
        return req.req_id

    def _shed(self, victim: ServeRequest, for_kind: str) -> None:
        """Deliver a structured LoadShedError to a queue victim evicted to
        admit a higher-priority arrival."""
        self.metrics.record_shed(victim.kind, for_kind=for_kind,
                                 tenant=victim.tenant, slo=victim.slo)
        self._results[victim.req_id] = LoadShedError(
            req_id=victim.req_id, kind=victim.kind, priority=victim.priority,
            shed_for_kind=for_kind, attempts=victim.attempts)
        if self.tracer.enabled:
            self.tracer.event(
                "shed", lane=_request_lane(victim.req_id),
                req_id=victim.req_id, kind=victim.kind)

    def _preempt_bulk(self, occupants: list, evict_fn: Any, host: int) -> bool:
        """Latency-lane seat preemption: evict the youngest-arrival seated
        BULK request to free one slot for a waiting latency-class multiply.
        The victim is not failed — it re-queues on its home batcher (the
        deterministic re-run the quarantine re-seat path already relies on)
        and only resolves as a structured shed if its queue is full."""
        bulk = [(slot, req) for slot, req, _rem in occupants
                if req.slo == SLO_BULK]
        if not bulk:
            return False
        slot, victim = max(bulk, key=lambda t: t[1].arrival_s)
        evict_fn(slot)
        self.metrics.record_preemption()
        if self.tracer.enabled:
            self.tracer.event(
                "preempt", lane=_request_lane(victim.req_id),
                req_id=victim.req_id, kind=victim.kind, host=host, slot=slot,
                tenant=victim.tenant)
        if not self._batchers[host].submit(victim):
            self._shed(victim, "latency-preempt")
        return True

    def _admit(self, req: ServeRequest, host: int, load_flops: float,
               depth: int) -> int | None:
        """Shared admission tail: queue-budget check with priority-aware
        shedding (the youngest strictly-lower-priority BULK-class request
        is evicted — with a structured error — to admit a latency-sensitive
        arrival; the latency lane is never shed), then load/metrics/trace
        accounting."""
        batcher = self._batchers[host]
        if not batcher.submit(req):
            victim = batcher.shed_lowest(req.priority, sheddable_slo=SLO_BULK)
            if victim is not None:
                self._shed(victim, req.kind)
            if victim is None or not batcher.submit(req):
                self.metrics.record_reject(req.kind)
                return None
        self.router.record_load(host, load_flops)
        self._next_id += 1
        self.metrics.record_admit(depth + 1, tenant=req.tenant, slo=req.slo)
        if self.tracer.enabled:
            self.tracer.event(
                "admit", lane=_request_lane(req.req_id), req_id=req.req_id,
                kind=req.kind, L=req.L, k=req.k, host=host, tenant=req.tenant,
                slo=req.slo, queue_depth=depth + 1)
        return req.req_id

    def submit(self, a: jax.Array, b: jax.Array, k: int | None = None,
               deadline_s: float | None = None,
               tenant: str = DEFAULT_TENANT,
               slo: str | None = None) -> int | None:
        """Queue one lattice multiply on its home host's batcher.

        Args:
            a: canonical complex lattice ``(L**4, 4, 3, 3)``.
            b: canonical complex link matrix set ``(4, 3, 3)``.
            k: chain depth (``C = A⊗B`` applied k times); None = the
                autotuned default for (backend, L).
            deadline_s: relative deadline; None = the SLO class default,
                else the configured service default.  A request past its
                deadline is evicted wherever it sits and completes with a
                structured ``DeadlineExceededError``.
            tenant: tenant identity (quota metering + fairness group);
                every pre-tenancy call site rides the default tenant.
            slo: SLO class ("latency"/"bulk"); None = the kind's default
                (multiplies are bulk).

        Returns:
            A request id, or None when the tenant's quota bucket is dry or
            the home host's queue budget is exhausted (backpressure —
            caller retries later) and nothing lower-priority could be shed
            to make room.  Under brownout the id may resolve immediately
            to a ``LoadShedError`` carrying a Retry-After hint.
        """
        L = self._infer_L(a)
        tenant = self._check_tenant(tenant)
        slo = self._resolve_slo("multiply", slo)
        host = self._home(L)
        depth = self.queued()
        arrival = time.perf_counter()
        if not self._quota_admit(tenant, arrival):
            return None
        req = ServeRequest(
            req_id=self._next_id, a=a, b=b, L=L,
            k=k if k is not None else self.default_k_for(L),
            arrival_s=arrival,
            deadline_s=self._deadline(deadline_s, arrival, slo),
            priority=PRIORITY["multiply"], tenant=tenant, slo=slo,
        )
        shed_id = self._brownout_door(req, host)
        if shed_id is not None:
            return shed_id
        return self._admit(req, host, request_flops(req.n_sites, req.k), depth)

    def submit_stencil(self, u: jax.Array, v: jax.Array,
                       deadline_s: float | None = None,
                       tenant: str = DEFAULT_TENANT,
                       slo: str | None = None) -> int | None:
        """Queue one nearest-neighbor stencil application on its home host.

        Args:
            u: canonical complex gauge lattice ``(L**4, 4, 3, 3)``.
            v: canonical complex color-vector field ``(L**4, 3)``.

        Returns:
            A request id (result: the canonical ``(L**4, 3)`` output vector
            field), or None under backpressure — same contract as
            :meth:`submit`.  Stencil requests ride the SAME warm pool,
            locality router, and per-host batcher as multiplies; they
            coalesce by lattice size into one vmapped stencil dispatch and
            never join multiply chains (their output is a vector field).
        """
        L = self._infer_L(u)
        if v.shape != (L**4, 3):
            raise ValueError(
                f"stencil vector field must be (L**4, 3) canonical complex "
                f"matching the lattice, got {v.shape} for L={L}"
            )
        tenant = self._check_tenant(tenant)
        slo = self._resolve_slo("stencil", slo)
        host = self._home(L)
        depth = self.queued()
        arrival = time.perf_counter()
        if not self._quota_admit(tenant, arrival):
            return None
        req = ServeRequest(
            req_id=self._next_id, a=u, b=v, L=L, k=1,
            arrival_s=arrival, kind="stencil",
            deadline_s=self._deadline(deadline_s, arrival, slo),
            priority=PRIORITY["stencil"], tenant=tenant, slo=slo,
        )
        shed_id = self._brownout_door(req, host)
        if shed_id is not None:
            return shed_id
        return self._admit(
            req, host, float(STENCIL_FLOPS_PER_SITE) * req.n_sites, depth)

    def submit_solve(self, u: jax.Array, b: jax.Array, tol: float = 1e-6,
                     max_iters: int = 200,
                     deadline_s: float | None = None,
                     tenant: str = DEFAULT_TENANT,
                     slo: str | None = None) -> int | None:
        """Queue one staggered CG solve ``(sigma I + S) x = b`` on its home
        host.

        Args:
            u: canonical complex gauge lattice ``(L**4, 4, 3, 3)``.
            b: canonical complex right-hand side ``(L**4, 3)``.
            tol: relative-residual target ``||r|| <= tol ||b||``.
            max_iters: iteration cap; the request retires (best iterate
                delivered) rather than spinning past it.

        Returns:
            A request id (result: the canonical ``(L**4, 3)`` solution
            field), or None under backpressure — same contract as
            :meth:`submit`.  The solve rides the SAME warm pool, locality
            router, and per-host batcher; its iteration count is
            data-dependent, so it advances ``solve_iters_per_step`` CG
            iterations per scheduling turn through the fused stencil+axpy
            kernel and retires mid-chain when the residual crosses tol.
        """
        L = self._infer_L(u)
        if b.shape != (L**4, 3):
            raise ValueError(
                f"solve right-hand side must be (L**4, 3) canonical complex "
                f"matching the lattice, got {b.shape} for L={L}"
            )
        if tol < 0:
            raise ValueError(f"tol must be >= 0, got {tol}")
        if max_iters < 1:
            raise ValueError(f"max_iters must be >= 1, got {max_iters}")
        tenant = self._check_tenant(tenant)
        slo = self._resolve_slo("solve", slo)
        host = self._home(L)
        depth = self.queued()
        arrival = time.perf_counter()
        if not self._quota_admit(tenant, arrival):
            return None
        req = ServeRequest(
            req_id=self._next_id, a=u, b=b, L=L, k=1,
            arrival_s=arrival, kind="solve",
            tol=tol, max_iters=max_iters,
            deadline_s=self._deadline(deadline_s, arrival, slo),
            priority=PRIORITY["solve"], tenant=tenant, slo=slo,
        )
        shed_id = self._brownout_door(req, host)
        if shed_id is not None:
            return shed_id
        # nominal admission charge: a typical shifted-CG iteration count;
        # the true data-dependent bill is charged per dispatched chunk
        return self._admit(
            req, host, float(CG_ITER_FLOPS_PER_SITE) * req.n_sites * 10, depth)

    # -- dispatch ------------------------------------------------------------

    def _work_pending(self) -> bool:
        if any(len(b) for b in self._batchers):
            return True
        if self._solves:
            return True
        if self._retry_q:
            return True
        if any(chain.live for chain, _ in self._chains.values()):
            return True
        return any(table.live for table, _ in self._tables.values())

    def pending(self) -> bool:
        """True while any request waits in a queue, a retry backoff, or an
        in-flight chain — the loop condition for external step() drivers."""
        return self._work_pending()

    # -- failure lifecycle (ISSUE 9) ------------------------------------------

    @staticmethod
    def _finite(x: jax.Array) -> bool:
        return bool(jax.device_get(jnp.all(jnp.isfinite(x))))

    def _fail(self, req: ServeRequest, err: Exception) -> None:
        """Deliver a structured failure through the result channel: a
        stepping caller gets the exception object from ``pop_result``, an
        ``arun`` caller gets it raised."""
        self._results[req.req_id] = err

    def _timeout(self, req: ServeRequest, now: float,
                 partial: Any = None) -> None:
        self.metrics.record_timeout(req.kind, tenant=req.tenant, slo=req.slo)
        self._fail(req, DeadlineExceededError(
            req_id=req.req_id, kind=req.kind,
            deadline_s=req.deadline_s - req.arrival_s,
            waited_s=now - req.arrival_s, attempts=req.attempts,
            partial=partial))
        if self.tracer.enabled:
            self.tracer.event(
                "timeout", lane=_request_lane(req.req_id), req_id=req.req_id,
                kind=req.kind, waited_s=now - req.arrival_s)

    def _retry_or_fail(self, req: ServeRequest, cause: str,
                       terminal: Exception | None = None) -> bool:
        """Charge one failed attempt: requeue with capped-exponential
        backoff while the per-request cap and the service-wide retry budget
        allow, else deliver the terminal structured error.  Returns True
        when the request was requeued."""
        req.attempts += 1
        policy = self.cfg.retry
        if req.attempts <= policy.max_retries and self._retry_budget > 0:
            self._retry_budget -= 1
            self.metrics.record_retry()
            delay = policy.backoff_s(req.attempts, self._retry_rng)
            self._retry_q.append((time.perf_counter() + delay, req))
            if self.tracer.enabled:
                self.tracer.event(
                    "retry", lane=_request_lane(req.req_id),
                    req_id=req.req_id, attempt=req.attempts, cause=cause,
                    backoff_s=delay)
            return True
        self.metrics.record_retries_exhausted()
        if terminal is None:
            terminal = RetriesExhaustedError(
                req_id=req.req_id, kind=req.kind, attempts=req.attempts,
                cause=cause,
                budget_exhausted=(self._retry_budget <= 0
                                  and req.attempts <= policy.max_retries))
        self._fail(req, terminal)
        return False

    def _charge_seated(self, occupants: list, evict_fn: Any,
                       cause: str) -> None:
        """Charge one failed dispatch to every seated occupant of a chain or
        slot table: seated requests KEEP their seats while attempts remain
        (the next turn re-dispatches the same state, bitwise clean); past
        the per-request cap — or with the service retry budget dry — they
        are evicted with a structured error.  One budget unit covers the
        whole failed dispatch, not one per occupant."""
        policy = self.cfg.retry
        budget_dry = self._retry_budget <= 0
        if not budget_dry:
            self._retry_budget -= 1
            self.metrics.record_retry()
        for slot, req, _rem in occupants:
            req.attempts += 1
            if budget_dry or req.attempts > policy.max_retries:
                evict_fn(slot)
                self.metrics.record_retries_exhausted()
                self._fail(req, RetriesExhaustedError(
                    req_id=req.req_id, kind=req.kind, attempts=req.attempts,
                    cause=cause, budget_exhausted=budget_dry))

    def _drain_retry_queue(self, now: float) -> None:
        """Move backoff-expired requests back into their (healthy) home
        host's queue; a still-full queue waits another beat rather than
        dropping the request (the deadline sweep bounds that wait)."""
        still: list[tuple[float, ServeRequest]] = []
        for eligible_s, req in self._retry_q:
            if eligible_s > now:
                still.append((eligible_s, req))
            elif not self._batchers[self._home(req.L)].submit(req):
                still.append((now + self.cfg.retry.base_s, req))
        self._retry_q = still

    def _evict_expired(self, now: float) -> None:
        """The deadline sweep: evict every expired request wherever it sits
        — queued, waiting out a backoff, seated in a live chain/table slot,
        or the active solve — and deliver structured timeouts.  Freed seats
        are immediately admissible (the same re-seating machinery mid-chain
        admission uses)."""
        for batcher in self._batchers:
            for req in batcher.evict_expired(now):
                self._timeout(req, now)
        if self._retry_q:
            keep = []
            for eligible_s, req in self._retry_q:
                if req.deadline_s and req.deadline_s <= now:
                    self._timeout(req, now)
                else:
                    keep.append((eligible_s, req))
            self._retry_q = keep
        for host in list(self._solves):
            active = self._solves[host]
            req = active["req"]
            if req.deadline_s and req.deadline_s <= now:
                # best iterate so far rides out as the timeout's partial
                partial = active["plan"].unpack_vec(active["state"]["x"])
                del self._solves[host]
                self._timeout(req, now, partial=partial)
        for chain, arrays in self._chains.values():
            for slot, req, _rem in chain.occupants():
                if req.deadline_s and req.deadline_s <= now:
                    chain.evict(slot)
                    arrays.clear(slot)
                    self._timeout(req, now)
        for table, arrays in self._tables.values():
            for slot, req, _rem in table.occupants():
                if req.deadline_s and req.deadline_s <= now:
                    table.evict(slot)
                    arrays.clear(slot)
                    self._timeout(req, now)

    def _drain_host(self, host: int) -> list[ServeRequest]:
        """Pull every request ``host`` holds — queued, the active solve, and
        seated chain/table slots — off the host (mid-chain progress is
        discarded; the re-run is deterministic).  Shared by the quarantine
        and scale-down paths."""
        moved: list[ServeRequest] = list(self._batchers[host].drain())
        active = self._solves.pop(host, None)
        if active is not None:
            moved.append(active["req"])
        for key in [k for k in self._chains if k[0] == host]:
            chain, arrays = self._chains.pop(key)
            for slot, req, _rem in chain.occupants():
                chain.evict(slot)
                arrays.clear(slot)
                moved.append(req)
        entry = self._tables.pop(host, None)
        if entry is not None:
            table, arrays = entry
            for slot, req, _rem in table.occupants():
                table.evict(slot)
                arrays.clear(slot)
                moved.append(req)
        return moved

    def _reseat(self, moved: list[ServeRequest], cause: str) -> int:
        """Re-seat displaced requests onto serving hosts; returns the count
        that landed.  A request whose deadline has ALREADY passed resolves
        as a DeadlineExceededError right here — exactly once — instead of
        being resubmitted only for the next sweep to evict it (the
        deadline-expiry x re-seat race).  Re-seats that bounce off a full
        queue fail structurally."""
        now = time.perf_counter()
        reseated = 0
        for req in moved:
            if req.deadline_s and req.deadline_s <= now:
                self._timeout(req, now)
                continue
            target = self._home(req.L)
            if self._batchers[target].submit(req):
                reseated += 1
            else:
                self.metrics.record_retries_exhausted()
                self._fail(req, RetriesExhaustedError(
                    req_id=req.req_id, kind=req.kind, attempts=req.attempts,
                    cause=cause))
        return reseated

    def _quarantine(self, host: int) -> None:
        """Last rung of the degradation ladder: the health tracker latched
        ``host`` out.  Every request it holds re-seats onto a healthy host
        via :meth:`_reseat` (``_home`` already excludes the latched host)."""
        moved = self._drain_host(host)
        reseated = self._reseat(
            moved, "quarantine re-seat rejected under backpressure")
        self.metrics.record_quarantine(reseated=reseated)
        if self.tracer.enabled:
            self.tracer.event(
                "chaos.quarantine", lane=host, host=host, reseated=reseated,
                cause=self.health.last_cause[host])

    def _dispatch_fault(self, host: int, kind: str, mode: str):
        """Consult the ``dispatch`` seam.  Returns the Fault only for the
        "fail" action (the caller runs its failure path); "delay" is applied
        here — a stalled-rank injection, the launch still runs."""
        f = self.faults.ask("dispatch", host=host, kind=kind, mode=mode)
        if f is None:
            return None
        self.metrics.record_fault()
        if self.tracer.enabled:
            self.tracer.event(
                "chaos.fault", lane=host, site="dispatch", action=f.action,
                seq=f.seq, host=host, kind=kind, mode=mode)
        if f.action == "delay":
            time.sleep(f.delay_s)
            return None
        return f

    def _poison_output(self, x: jax.Array, host: int, kind: str) -> jax.Array:
        """Consult the ``kernel`` seam; a fired fault poisons the dispatch
        output with NaN/Inf for the finiteness guard to catch."""
        f = self.faults.ask("kernel", host=host, kind=kind)
        if f is None:
            return x
        self.metrics.record_fault()
        if self.tracer.enabled:
            self.tracer.event(
                "chaos.fault", lane=host, site="kernel", action=f.action,
                seq=f.seq, host=host, kind=kind)
        return poison_array(x, f.action)

    def _host_busy(self, host: int) -> bool:
        """A live seat on ``host``: the active solve, a live chain, or a
        live slot table (the occupancy half of the pressure signal)."""
        if host in self._solves:
            return True
        if self.cfg.megakernel:
            entry = self._tables.get(host)
            return bool(entry and entry[0].live)
        if self.cfg.continuous:
            return any(
                h == host and chain.live
                for (h, _L), (chain, _a) in self._chains.items()
            )
        return False

    def _seated_latency(self, host: int) -> bool:
        """True when ``host`` holds a seated latency-class request (a shrink
        must never evict one — the veto the autoscaler docs promise)."""
        active = self._solves.get(host)
        if active is not None and active["req"].slo == SLO_LATENCY:
            return True
        for (h, _L), (chain, _a) in self._chains.items():
            if h == host and any(
                    req.slo == SLO_LATENCY
                    for _s, req, _rem in chain.occupants()):
                return True
        entry = self._tables.get(host)
        if entry is not None and any(
                req.slo == SLO_LATENCY
                for _s, req, _rem in entry[0].occupants()):
            return True
        return False

    def _scale_down(self) -> None:
        """Retire the top active host: drain its queued/seated work onto the
        remaining hosts (the quarantine re-seat machinery).  Vetoed when the
        victim holds a seated latency request — the controller proposes
        again after its next cold streak."""
        victim = self._active_hosts - 1
        if self._seated_latency(victim):
            if self.tracer.enabled:
                self.tracer.event("scale.veto", lane=victim, host=victim)
            return
        self._active_hosts -= 1  # _home() now excludes the victim
        moved = self._drain_host(victim)
        reseated = self._reseat(
            moved, "scale-down re-seat rejected under backpressure")
        self.metrics.record_scale(-1, self._active_hosts)
        if self.tracer.enabled:
            self.tracer.event(
                "scale.down", lane=victim, host=victim,
                active=self._active_hosts, reseated=reseated)

    def _observe_pressure(self) -> None:
        """One control-loop sample per step(): feed the brownout ladder and
        the warm-pool autoscaler the same load signals — queued fraction of
        the active queue budget, blended with seat occupancy while a
        backlog exists — and apply their decisions.  Both controllers are
        functions of the observation SEQUENCE, so a same-seed replay of the
        same traffic reproduces every transition and scale event."""
        active = self._serving_hosts()
        n = max(1, len(active))
        depth = self.queued()
        cap = max(1, self.cfg.batcher.max_queue_depth) * n
        occupancy = sum(1 for h in active if self._host_busy(h)) / n
        pressure = min(1.0, depth / cap)
        if depth:
            pressure = max(pressure, occupancy)
        if self._brownout is not None:
            new_rung = self._brownout.observe(pressure)
            self.metrics.record_brownout_turn(self._brownout.rung)
            if new_rung is not None:
                self.metrics.record_brownout_transition(new_rung)
                if self.tracer.enabled:
                    t = self._brownout.transitions[-1]
                    self.tracer.event(
                        "brownout.transition", lane=0, rung=new_rung,
                        from_rung=t["from"], pressure=t["pressure"])
        if self._autoscaler is not None:
            delta = self._autoscaler.observe(
                depth_per_host=depth / n, occupancy=occupancy,
                active=self._active_hosts)
            if delta > 0:
                self._active_hosts += 1
                self.metrics.record_scale(+1, self._active_hosts)
                if self.tracer.enabled:
                    self.tracer.event(
                        "scale.up", lane=0, active=self._active_hosts)
            elif delta < 0:
                self._scale_down()

    def step(self) -> int:
        """Advance the service by one scheduling turn; returns completed
        request count.

        Turn ownership is two-level.  The deficit-weighted fair scheduler
        first picks WHICH (tenant, SLO class) group owns the turn on the
        next host with pending work — every pending group accrues
        weight-proportional credit, so a backlogged bulk tenant cannot
        monopolize turns and a pending latency group is served within a
        provable bound (tests/test_tenancy.py pins it).  Within the granted
        group, kinds rotate multiply -> stencil -> solve exactly as before
        — per (host, group) now — so no sustained stream of one kind
        starves the others *inside* a group.  Dispatch is unchanged:
        batch-per-step serves one coalesced (L, k) bucket from the group's
        buckets; continuous admits the group's waiters at the iteration
        boundary then advances ALL the host's live chains; megakernel
        slot-swaps then fires one batched K-chain dispatch.  Each step also
        feeds one pressure sample to the brownout ladder and the warm-pool
        autoscaler (when configured).
        """
        now = time.perf_counter()
        if self._retry_q:
            self._drain_retry_queue(now)
        if self._deadlines_armed:
            self._evict_expired(now)
        if self._brownout is not None or self._autoscaler is not None:
            self._observe_pressure()
        order = ("multiply", "stencil", "solve")
        for _ in range(self.cfg.hosts):
            host = self._rr_host
            self._rr_host = (self._rr_host + 1) % self.cfg.hosts
            if self.health.is_quarantined(host):
                continue
            groups = self._pending_groups(host)
            if not groups:
                continue
            group = self._sched.next_group(sorted(groups))
            if group is None:  # pragma: no cover - groups is non-empty
                continue
            pending = groups[group]
            last = self._last_kind.get((host, group), "multiply")
            start = order.index(last) if last in order else 0
            for off in range(1, len(order) + 1):
                kind = order[(start + off) % len(order)]
                if kind not in pending:
                    continue
                self._last_kind[(host, group)] = kind
                if kind == "stencil":
                    return self._step_stencil(host, group)
                if kind == "solve":
                    return self._step_solve(host, group)
                if self.cfg.megakernel:
                    return self._step_megakernel(host, group)
                if self.cfg.continuous:
                    return self._step_continuous(host, group)
                return self._step_batch(host, group)
        return 0

    def _pending_groups(self, host: int) -> dict[GroupKey, set[str]]:
        """Pending work on ``host`` keyed by (tenant, SLO class) group, each
        with its waiting kinds.  Live chain/table seats count as multiply
        work for their occupants' groups; the single active solve counts
        for ITS group only and suppresses other groups' queued solves (one
        solve seat per host — their turn comes when it retires)."""
        groups = {
            g: set(kinds)
            for g, kinds in
            self._batchers[host].pending_kinds_by_group().items()
        }
        active = self._solves.get(host)
        if active is not None:
            owner = active["req"].group
            for g, kinds in groups.items():
                if g != owner:
                    kinds.discard("solve")
            groups.setdefault(owner, set()).add("solve")
        if self.cfg.megakernel:
            entry = self._tables.get(host)
            if entry is not None:
                for _slot, req, _rem in entry[0].occupants():
                    groups.setdefault(req.group, set()).add("multiply")
        elif self.cfg.continuous:
            for (h, _L), (chain, _arr) in self._chains.items():
                if h != host:
                    continue
                for _slot, req, _rem in chain.occupants():
                    groups.setdefault(req.group, set()).add("multiply")
        return {g: kinds for g, kinds in groups.items() if kinds}

    def _step_batch(self, host: int, group: GroupKey | None = None) -> int:
        """One coalesced fused-k dispatch for ``host`` (batch-per-step),
        drawn from ``group``'s buckets when the fair scheduler granted the
        turn to a specific (tenant, class) group."""
        batch = self._batchers[host].next_batch(group=group)
        if batch is None:
            return 0
        reqs = batch.requests
        runner = self.runner_for(batch.L, host)
        n_sites = batch.L**4
        if self.faults.enabled:
            f = self._dispatch_fault(host, "multiply", "batch")
            if f is not None:
                # launch failed: every popped request goes down the retry
                # path (backoff requeue, or structured exhaustion)
                quarantined = self.health.record_failure(host, "dispatch")
                for r in reqs:
                    self._retry_or_fail(r, "injected dispatch failure")
                if quarantined:
                    self._quarantine(host)
                return 0
        a = jnp.stack([r.a for r in reqs])
        b = jnp.stack([r.b for r in reqs])
        if batch.pad:
            a = jnp.concatenate(
                [a, jnp.zeros((batch.pad,) + a.shape[1:], a.dtype)], axis=0
            )
            b = jnp.concatenate(
                [b, jnp.zeros((batch.pad,) + b.shape[1:], b.dtype)], axis=0
            )
        shape_key = self._shape_key(runner, batch.L, batch.k, batch.padded_size)
        cold = shape_key not in self._seen_shapes
        t0 = time.perf_counter()
        c = runner.multiply(a, b, k=batch.k)
        if self.faults.enabled:
            c = self._poison_output(c, host, "multiply")
        c.block_until_ready()
        step_s = time.perf_counter() - t0
        if (self.faults.enabled or self.cfg.numerics_guard) \
                and not self._finite(c):
            # poisoned (or genuinely non-finite) output: never delivered —
            # the batch re-runs through the retry path, bitwise clean
            quarantined = self.health.record_failure(host, "non-finite output")
            for r in reqs:
                self._retry_or_fail(r, "non-finite kernel output")
            if quarantined:
                self._quarantine(host)
            return 0
        if self.faults.enabled or self.cfg.numerics_guard:
            self.health.record_success(host)
        self._seen_shapes.add(shape_key)
        self.metrics.record_dispatch(
            live=len(reqs), padded=batch.padded_size, step_s=step_s,
            flops=request_flops(n_sites, batch.k) * len(reqs), cold=cold,
            host=host,
        )
        if self.tracer.enabled:
            self._trace_dispatch(
                runner, host, "multiply", batch.L, batch.k, "batch", t0,
                step_s, live=len(reqs), padded=batch.padded_size,
                flops=request_flops(n_sites, batch.k) * len(reqs), cold=cold)
        done_s = time.perf_counter()
        for i, r in enumerate(reqs):
            self._results[r.req_id] = c[i]
            self.metrics.record_completion(
                done_s - r.arrival_s, tenant=r.tenant, slo=r.slo)
            if self.tracer.enabled:
                r.seated_s = t0  # batch mode: seating IS the dispatch start
                self._trace_request(r, done_s, host, "batch")
        self.metrics.record_queue_depth(self.queued())
        return len(reqs)

    def _stencil_step_for(self, runner: BatchedLatticeRunner, host: int, L: int):
        """The host's jitted, vmapped stencil dispatch for L — built once per
        warm-pool entry from the plan's reference stencil (the serving path
        runs on a host-local submesh, where the overlap schedule degenerates
        to the reference anyway).  Dispatch parity with the multiply path:
        the batch axis shards whole request lattices over the host's devices
        (the same placement ``BatchedLatticeRunner.run`` gives multiplies).
        """
        ecfg = runner.cfg
        key = (host, L, ecfg.dtype, ecfg.layout.value, ecfg.tile, ecfg.compression)
        step = self._stencil_steps.get(key)
        if step is None:
            plan = runner.plan
            axes = plan.site_axes
            batch_axis = axes if len(axes) > 1 else axes[0]
            out_sh = NamedSharding(plan.mesh, P(batch_axis, None, None, None))
            step = jax.jit(
                jax.vmap(plan.raw_stencil_reference()), out_shardings=out_sh
            )
            self._stencil_steps[key] = step
        return step

    def _step_stencil(self, host: int, group: GroupKey | None = None) -> int:
        """One coalesced stencil dispatch for ``host``: the granted group's
        oldest waiting lattice size, vmapped through the warm runner's
        plan."""
        batch = self._batchers[host].next_stencil_batch(group=group)
        if batch is None:
            return 0
        reqs = batch.requests
        runner = self.runner_for(batch.L, host)
        plan = runner.plan
        n_sites = batch.L**4
        if self.faults.enabled:
            f = self._dispatch_fault(host, "stencil", "batch")
            if f is not None:
                quarantined = self.health.record_failure(host, "dispatch")
                for r in reqs:
                    self._retry_or_fail(r, "injected dispatch failure")
                if quarantined:
                    self._quarantine(host)
                return 0
        # warm-size padding (jit-cache control) + device-multiple padding
        # (whole lattices per device, as the multiply path's run() pads)
        dispatched = batch.padded_size + (-batch.padded_size) % runner.n_devices
        pad = dispatched - len(reqs)
        u = jnp.stack([r.a for r in reqs])
        v = jnp.stack([r.b for r in reqs])
        if pad:
            u = jnp.concatenate(
                [u, jnp.zeros((pad,) + u.shape[1:], u.dtype)], axis=0
            )
            v = jnp.concatenate(
                [v, jnp.zeros((pad,) + v.shape[1:], v.dtype)], axis=0
            )
        u_phys = runner.pack_batch(u)
        v_p = jax.vmap(lambda x: plan.codec.pack_vec(x, plan.padded_sites))(v)
        step = self._stencil_step_for(runner, host, batch.L)
        shape_key = ("stencil", batch.L, dispatched)
        cold = shape_key not in self._seen_shapes
        t0 = time.perf_counter()
        out_p = step(u_phys, v_p)
        if self.faults.enabled:
            out_p = self._poison_output(out_p, host, "stencil")
        out_p.block_until_ready()
        step_s = time.perf_counter() - t0
        if (self.faults.enabled or self.cfg.numerics_guard) \
                and not self._finite(out_p):
            quarantined = self.health.record_failure(host, "non-finite output")
            for r in reqs:
                self._retry_or_fail(r, "non-finite kernel output")
            if quarantined:
                self._quarantine(host)
            return 0
        if self.faults.enabled or self.cfg.numerics_guard:
            self.health.record_success(host)
        self._seen_shapes.add(shape_key)
        self.metrics.record_dispatch(
            live=len(reqs), padded=dispatched, step_s=step_s,
            flops=float(STENCIL_FLOPS_PER_SITE) * n_sites * len(reqs),
            cold=cold, host=host,
        )
        if self.tracer.enabled:
            self._trace_dispatch(
                runner, host, "stencil", batch.L, 1, "batch", t0, step_s,
                live=len(reqs), padded=dispatched,
                flops=float(STENCIL_FLOPS_PER_SITE) * n_sites * len(reqs),
                cold=cold)
        done_s = time.perf_counter()
        for i, r in enumerate(reqs):
            self._results[r.req_id] = plan.codec.unpack_vec(out_p[i], n_sites)
            self.metrics.record_completion(
                done_s - r.arrival_s, tenant=r.tenant, slo=r.slo)
            if self.tracer.enabled:
                r.seated_s = t0
                self._trace_request(r, done_s, host, "batch")
        self.metrics.record_queue_depth(self.queued())
        return len(reqs)

    def _seat_solve(self, host: int,
                    group: GroupKey | None = None) -> dict[str, Any] | None:
        """Pop the granted group's oldest queued solve and seat it as the
        active one: pack the gauge field and right-hand side through the
        warm runner's plan, initialize the CG state, and pin the
        convergence threshold ``||r||^2 <= tol^2 ||b||^2`` from the packed
        b."""
        req = self._batchers[host].next_solve(group=group)
        if req is None:
            return None
        runner = self.runner_for(req.L, host)
        if (self._brownout is not None and self._brownout.rung >= 2
                and self.cfg.brownout.degrade_bulk_bf16
                and req.slo == SLO_BULK
                and runner.cfg.dtype != "bfloat16"):
            # rung 2 degradation: a BULK solve rides a warm bf16-storage
            # plan when the pool already holds one for this (host, L) —
            # never builds a new plan mid-overload
            for key, cand in self._pool.items():
                if key[0] == host and key[1] == req.L \
                        and key[2] == "bfloat16":
                    runner = cand
                    break
        plan = runner.plan
        u_phys = plan.pack_gauge(jnp.asarray(req.a))
        b_p = plan.pack_rhs(jnp.asarray(req.b))
        state = plan.cg_state_init(b_p)
        b_rs = float(jax.device_get(state["rs"]))  # r_0 = b, so rs_0 = ||b||^2
        active = {
            "req": req, "plan": plan, "runner": runner, "u_phys": u_phys,
            "state": state, "b_rs": b_rs, "stop2": req.tol * req.tol * b_rs,
            "best": None,  # (rs_host, x) — carried on structured failures
        }
        self._solves[host] = active
        if self.tracer.enabled:
            req.seated_s = time.perf_counter()
            self.tracer.event(
                "seat", lane=_request_lane(req.req_id), req_id=req.req_id,
                L=req.L, host=host, kind="solve", midchain=False)
        return active

    def _step_solve(self, host: int, group: GroupKey | None = None) -> int:
        """Advance the host's active solve by ``solve_iters_per_step`` CG
        iterations (seating the granted group's oldest queued solve first
        if none is active); retires it — mid-chain, its seat and queue
        budget free immediately — once the residual crosses tol or
        ``max_iters`` runs out, delivering the best iterate either way."""
        active = self._solves.get(host)
        if active is None:
            active = self._seat_solve(host, group)
            if active is None:
                return 0
        req, plan, state = active["req"], active["plan"], active["state"]
        if active["b_rs"] == 0.0:
            # zero right-hand side: x = 0 exactly; retire without iterating
            # (CG's alpha = <r,r>/<p,Ap> is 0/0 on this input)
            return self._retire_solve(host, active, state)
        if self.faults.enabled:
            f = self._dispatch_fault(host, "solve", "solve")
            if f is not None:
                # failed launch unseats the solve; a retry re-seats it fresh
                # (CG restarts are deterministic — same b, same schedule)
                del self._solves[host]
                quarantined = self.health.record_failure(host, "dispatch")
                self._retry_or_fail(req, "injected dispatch failure")
                if quarantined:
                    self._quarantine(host)
                return 0
        n = min(self.cfg.solve_iters_per_step,
                req.max_iters - state["iterations"])
        if (self._brownout is not None and self._brownout.rung >= 2
                and req.slo == SLO_BULK):
            # rung 2: bulk solves advance fewer CG iterations per turn,
            # returning turns to the latency lane sooner
            n = max(1, n // self.cfg.brownout.degrade_solve_factor)
            self.metrics.record_degraded_solve_turn()
        runner = active["runner"]
        shape_key = ("solve", req.L)
        cold = shape_key not in self._seen_shapes
        tr = self.tracer
        t0 = time.perf_counter()
        for _ in range(n):
            if tr.enabled:
                with tr.span("cg.iter", lane=host, req_id=req.req_id,
                             it=state["iterations"] + 1):
                    state = plan.cg_iterate(active["u_phys"], state)
                    jax.block_until_ready(state["rs"])
            else:
                state = plan.cg_iterate(active["u_phys"], state)
        if self.faults.enabled:
            # "kernel" seam for solves: poison the chunk's residual scalar —
            # the corrupted-iterate case the residual guard below must catch
            fk = self.faults.ask("kernel", host=host, kind="solve")
            if fk is not None:
                self.metrics.record_fault()
                if tr.enabled:
                    tr.event("chaos.fault", lane=host, site="kernel",
                             action=fk.action, seq=fk.seq, host=host,
                             kind="solve")
                state["rs"] = jnp.full_like(state["rs"], float("nan"))
        if tr.enabled:
            with tr.span("cg.reduce", lane=host, req_id=req.req_id,
                         it=state["iterations"]):
                rs_host = float(jax.device_get(state["rs"]))
        else:
            rs_host = float(jax.device_get(state["rs"]))  # syncs the chunk
        step_s = time.perf_counter() - t0
        active["state"] = state
        if self.faults.enabled or self.cfg.numerics_guard:
            # CG residual guard: NaN/Inf or blow-up is numerical breakdown —
            # structured failure carrying the best iterate, never a hang
            bad = not math.isfinite(rs_host) or (
                rs_host > CG_DIVERGENCE_FACTOR * active["b_rs"])
            if bad:
                del self._solves[host]
                reason = ("non-finite residual" if not math.isfinite(rs_host)
                          else "diverged")
                quarantined = self.health.record_failure(host, f"cg {reason}")
                best = active["best"]
                residual = (rs_host / active["b_rs"]) ** 0.5 \
                    if math.isfinite(rs_host) else float("nan")
                terminal = CGDivergedError(
                    state["iterations"], residual, req.tol, reason=reason)
                # canonical best iterate rides along for the caller (same
                # shape the request's normal result would have had)
                terminal.partial = (
                    None if best is None else plan.unpack_vec(best[1]))
                self._retry_or_fail(req, f"cg {reason}", terminal=terminal)
                if quarantined:
                    self._quarantine(host)
                return 0
            self.health.record_success(host)
            best = active["best"]
            if best is None or rs_host < best[0]:
                active["best"] = (rs_host, state["x"])
        self._seen_shapes.add(shape_key)
        flops = float(CG_ITER_FLOPS_PER_SITE) * req.n_sites * n
        self.metrics.record_dispatch(
            live=1, padded=1, step_s=step_s, flops=flops, cold=cold, host=host,
        )
        self.metrics.record_iteration(host, kind="solve", n=n)
        if tr.enabled:
            self._trace_dispatch(
                runner, host, "solve", req.L, n, "solve", t0, step_s,
                live=1, padded=1, flops=flops, cold=cold)
        if rs_host <= active["stop2"] or state["iterations"] >= req.max_iters:
            return self._retire_solve(host, active, state)
        self.metrics.record_queue_depth(self.queued())
        return 0

    def _retire_solve(self, host: int, active: dict[str, Any],
                      state: dict[str, Any]) -> int:
        """Deliver the active solve's iterate and free its seat."""
        req, plan = active["req"], active["plan"]
        self._results[req.req_id] = plan.unpack_vec(state["x"])
        del self._solves[host]
        done_s = time.perf_counter()
        self.metrics.record_completion(
            done_s - req.arrival_s, tenant=req.tenant, slo=req.slo)
        if self.tracer.enabled:
            self._trace_request(req, done_s, host, "solve")
        self.metrics.record_queue_depth(self.queued())
        return 1

    def _step_continuous(self, host: int, group: GroupKey | None = None) -> int:
        """One iteration boundary for ``host``: admit the granted group's
        waiters, then advance each of its chains by one multiply."""
        batcher = self._batchers[host]
        self.metrics.record_iteration(host)
        slots = self._chain_slots()

        # 1) admission — existing chains first (mid-chain admits), then new
        #    chains for queued Ls that have none.  A request whose L differs
        #    from a chain's is never seated in it (InflightChain.admit
        #    enforces the shape incompatibility); it reaches its own chain
        #    here.
        for L in batcher.queued_Ls(group):
            chain_key = (host, L)
            if chain_key not in self._chains:
                runner = self.runner_for(L, host)
                self._chains[chain_key] = (
                    InflightChain(L=L, slots=slots),
                    _ChainArrays(runner, slots),
                )
            chain, arrays = self._chains[chain_key]
            free = slots - chain.live
            if not free and group is not None and group[1] == SLO_LATENCY:
                # a full chain never blocks the latency lane: the youngest
                # bulk seat is preempted (re-queued) to admit this turn
                if self._preempt_bulk(
                        chain.occupants(),
                        lambda s, c=chain, a=arrays: (c.evict(s), a.clear(s)),
                        host):
                    free = 1
            if not free:
                continue
            admitted = batcher.next_for_L(L, free, group=group)
            for req in admitted:
                slot = chain.admit(req)
                arrays.seat(slot, req.a, req.b)
                if self.tracer.enabled:
                    req.seated_s = time.perf_counter()
                    self.tracer.event(
                        "seat", lane=_request_lane(req.req_id),
                        req_id=req.req_id, slot=slot, L=L, host=host,
                        midchain=chain.midchain)
            if admitted and chain.midchain:
                self.metrics.record_midchain_admits(len(admitted))

        # 2) advance every live chain of this host by ONE iteration
        completed = 0
        queued_Ls = set(batcher.queued_Ls())
        for (h, L) in [key for key in self._chains if key[0] == host]:
            chain, arrays = self._chains[(h, L)]
            if not chain.live:
                if L not in queued_Ls:
                    # dead chain with nothing queued: drop it (its compiled
                    # shape stays warm in the jit cache)
                    del self._chains[(h, L)]
                continue
            runner = arrays.runner
            n_sites = L**4
            if self.faults.enabled:
                f = self._dispatch_fault(host, "multiply", "continuous")
                if f is not None:
                    quarantined = self.health.record_failure(host, "dispatch")
                    self._charge_seated(
                        chain.occupants(),
                        lambda s, c=chain, a=arrays: (c.evict(s), a.clear(s)),
                        "injected dispatch failure")
                    if quarantined:
                        self._quarantine(host)
                        return completed  # this host's chains are gone
                    continue  # seated survivors re-dispatch next turn
            shape_key = self._shape_key(runner, L, 1, slots)
            cold = shape_key not in self._seen_shapes
            live = chain.live
            t0 = time.perf_counter()
            prev_a = arrays.a_phys
            arrays.advance()
            if self.faults.enabled:
                arrays.a_phys = self._poison_output(
                    arrays.a_phys, host, "multiply")
            arrays.a_phys.block_until_ready()
            step_s = time.perf_counter() - t0
            if (self.faults.enabled or self.cfg.numerics_guard) \
                    and not self._finite(arrays.a_phys):
                # roll the chain state back: the retried advance re-runs
                # from the same iterate, bitwise clean
                arrays.a_phys = prev_a
                quarantined = self.health.record_failure(
                    host, "non-finite output")
                self._charge_seated(
                    chain.occupants(),
                    lambda s, c=chain, a=arrays: (c.evict(s), a.clear(s)),
                    "non-finite kernel output")
                if quarantined:
                    self._quarantine(host)
                    return completed
                continue
            if self.faults.enabled or self.cfg.numerics_guard:
                self.health.record_success(host)
            self._seen_shapes.add(shape_key)
            self.metrics.record_dispatch(
                live=live, padded=slots, step_s=step_s,
                flops=request_flops(n_sites, 1) * live, cold=cold, host=host,
            )
            if self.tracer.enabled:
                self._trace_dispatch(
                    runner, host, "multiply", L, 1, "continuous", t0, step_s,
                    live=live, padded=slots,
                    flops=request_flops(n_sites, 1) * live, cold=cold)
            done_s = time.perf_counter()
            for slot, req in chain.advance():
                self._results[req.req_id] = arrays.result(slot, n_sites)
                arrays.clear(slot)
                self.metrics.record_completion(
                    done_s - req.arrival_s, tenant=req.tenant, slo=req.slo)
                if self.tracer.enabled:
                    self._trace_request(req, done_s, host, "continuous")
                completed += 1
        self.metrics.record_queue_depth(self.queued())
        return completed

    # -- megakernel dispatch (one batched K-chain call per host) --------------

    def _table_for(self, host: int, cap_L: int) -> tuple[SlotTable, _SlotTableArrays]:
        """The host's slot table, built (or capacity-grown) for ``cap_L``.

        Growing re-seats every live slot's *current* mid-chain lattice into
        the larger-capacity arrays at the same slot index — the scheduling
        half (SlotTable) is untouched, so remaining counts and admission
        bookkeeping survive the grow.
        """
        slots = self._chain_slots()
        entry = self._tables.get(host)
        if entry is not None and cap_L <= entry[1].cap_L:
            return entry
        runner = self.runner_for(cap_L, host)
        arrays = _SlotTableArrays(runner, slots, max_k=self.cfg.chain_horizon)
        if entry is None:
            self._tables[host] = (SlotTable(slots), arrays)
        else:
            table, old = entry
            for slot, req, _remaining in table.occupants():
                a_mid = old.result(slot, req.n_sites)  # mid-chain state
                arrays.seat(slot, a_mid, req.b)
            self._tables[host] = (table, arrays)
        return self._tables[host]

    def _step_megakernel(self, host: int, group: GroupKey | None = None) -> int:
        """One iteration boundary for ``host``: slot-swap admission across
        the granted group's queued lattice sizes, then ONE batched K-chain
        dispatch."""
        batcher = self._batchers[host]
        self.metrics.record_iteration(host)
        queued = batcher.queued_Ls(group)
        entry = self._tables.get(host)
        if entry is None and not queued:
            return 0

        # 1) admission — a slot swap per request, any L (grow capacity first
        #    so every queued size fits the one dispatched shape)
        if queued:
            cap_L = max(queued + ([entry[1].cap_L] if entry else []))
            table, arrays = self._table_for(host, cap_L)
            for L in queued:
                free = self._chain_slots() - table.live
                if not free and group is not None \
                        and group[1] == SLO_LATENCY:
                    # full table: preempt the youngest bulk seat so the
                    # latency lane admits this turn
                    if self._preempt_bulk(
                            table.occupants(),
                            lambda s, t=table, a=arrays: (
                                t.evict(s), a.clear(s)),
                            host):
                        free = 1
                if not free:
                    break
                admitted = batcher.next_for_L(L, free, group=group)
                for req in admitted:
                    slot = table.admit(req)
                    arrays.seat(slot, req.a, req.b)
                    if self.tracer.enabled:
                        req.seated_s = time.perf_counter()
                        self.tracer.event(
                            "seat", lane=_request_lane(req.req_id),
                            req_id=req.req_id, slot=slot, L=L, host=host,
                            midchain=table.midchain)
                if admitted and table.midchain:
                    self.metrics.record_midchain_admits(len(admitted))
        table, arrays = self._tables[host]

        # 2) ONE megakernel dispatch advancing every live slot by its own
        #    scheduled depth (min(remaining, horizon))
        completed = 0
        ks = table.plan_k(self.cfg.chain_horizon)
        if any(ks):
            occupants = table.occupants()
            degraded = False
            quarantine_pending = False
            if self.faults.enabled:
                f = self._dispatch_fault(host, "multiply", "megakernel")
                if f is not None:
                    # degradation ladder: the failed megakernel batch
                    # re-dispatches down the per-(L) chained path this turn
                    # (one runner.multiply per live slot); repeated failures
                    # still walk the host toward quarantine
                    degraded = True
                    self.metrics.record_degraded()
                    quarantine_pending = self.health.record_failure(
                        host, "dispatch")
            shape_key = ("mega", arrays.cap_L, table.slots, self.cfg.chain_horizon)
            cold = shape_key not in self._seen_shapes
            live = table.live
            t0 = time.perf_counter()
            prev_a = arrays.a_phys
            if degraded:
                for slot, req, _rem in occupants:
                    if not ks[slot]:
                        continue
                    a_mid = arrays.result(slot, req.n_sites)
                    c = self.runner_for(req.L, host).multiply(
                        a_mid[None], jnp.asarray(req.b)[None], k=ks[slot])[0]
                    arrays.seat(slot, c, req.b)
            else:
                arrays.advance(ks)
                if self.faults.enabled:
                    arrays.a_phys = self._poison_output(
                        arrays.a_phys, host, "multiply")
            arrays.a_phys.block_until_ready()
            step_s = time.perf_counter() - t0
            if not degraded and (self.faults.enabled or self.cfg.numerics_guard) \
                    and not self._finite(arrays.a_phys):
                arrays.a_phys = prev_a  # retried advance is bitwise clean
                quarantined = self.health.record_failure(
                    host, "non-finite output")
                self._charge_seated(
                    table.occupants(),
                    lambda s, t=table, a=arrays: (t.evict(s), a.clear(s)),
                    "non-finite kernel output")
                if quarantined:
                    self._quarantine(host)
                else:
                    self.metrics.record_queue_depth(self.queued())
                return 0
            if not degraded and (self.faults.enabled or self.cfg.numerics_guard):
                self.health.record_success(host)
            self._seen_shapes.add(shape_key)
            dispatch_flops = sum(
                request_flops(req.n_sites, ks[slot])
                for slot, req, _rem in occupants
            )
            self.metrics.record_dispatch(
                live=live, padded=table.slots, step_s=step_s,
                flops=dispatch_flops,
                cold=cold, host=host,
            )
            if self.tracer.enabled:
                self._trace_dispatch(
                    arrays.runner, host, "multiply", arrays.cap_L,
                    self.cfg.chain_horizon, "megakernel", t0, step_s,
                    live=live, padded=table.slots, flops=dispatch_flops,
                    cold=cold)
            done_s = time.perf_counter()
            for slot, req in table.advance(ks):
                self._results[req.req_id] = arrays.result(slot, req.n_sites)
                arrays.clear(slot)
                self.metrics.record_completion(
                    done_s - req.arrival_s, tenant=req.tenant, slo=req.slo)
                if self.tracer.enabled:
                    self._trace_request(req, done_s, host, "megakernel")
                completed += 1
            if quarantine_pending:
                # crossed the consecutive-failure latch this turn: deliver
                # the degraded batch's completions above, then re-seat the
                # survivors onto healthy hosts
                self._quarantine(host)
        self.metrics.record_queue_depth(self.queued())
        return completed

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        """Step until queues, retry backoffs AND in-flight chains empty;
        returns completed."""
        total = 0
        for _ in range(max_steps):
            if not self._work_pending():
                return total
            n = self.step()
            total += n
            if n == 0 and self._retry_q and not self._solves \
                    and not any(len(b) for b in self._batchers):
                # only backoff waits remain: sleep to the earliest eligible
                # retry instead of spinning max_steps away
                nxt = min(t for t, _ in self._retry_q)
                time.sleep(max(0.0, min(nxt - time.perf_counter(), 0.01)))
        raise RuntimeError(f"queue not drained after {max_steps} steps")

    # -- results -------------------------------------------------------------

    def has_result(self, req_id: int) -> bool:
        return req_id in self._results

    def pop_result(self, req_id: int) -> Any:
        """The canonical complex result for a completed request (once) — or
        the structured failure object (:class:`RequestFailure` subclass, or
        a ``CGDivergedError``) the request resolved with; check
        ``isinstance(out, Exception)``.  ``arun`` raises these instead."""
        return self._results.pop(req_id)

    def pop_ready(self) -> dict[int, jax.Array]:
        """All completed results, cleared from the service (delivery drain).

        A caller that steps the service itself (replay harnesses, pollers)
        must drain results this way or via ``pop_result`` — undelivered C
        lattices are device arrays and accumulate for the service lifetime.
        Results owned by a pending :meth:`arun` coroutine are left in place;
        only that coroutine delivers them.
        """
        if not self._awaited:
            out, self._results = self._results, {}
            return out
        out = {rid: c for rid, c in self._results.items() if rid not in self._awaited}
        for rid in out:
            del self._results[rid]
        return out

    # -- asyncio face --------------------------------------------------------

    async def arun(self, a: jax.Array, b: jax.Array, k: int | None = None,
                   deadline_s: float | None = None,
                   tenant: str = DEFAULT_TENANT,
                   slo: str | None = None) -> jax.Array:
        """Submit and await one request from an asyncio front-end.

        Concurrent ``arun`` coroutines submitted in the same scheduler tick
        coalesce into one dispatch — whichever coroutine steps first serves
        the whole bucket.  Backpressure surfaces as cooperative retry with
        CAPPED EXPONENTIAL BACKOFF: the first rejection yields to the loop
        (letting other coroutines drain the queue) and retries immediately;
        sustained rejection sleeps the retry policy's jittered, capped
        schedule instead of pegging the event loop with submit attempts.
        Quota backpressure (a dry tenant bucket) rides the same loop — the
        coroutine backs off until the bucket refills.  A request that
        resolves with a structured failure (deadline, shed, brownout,
        retries exhausted, CG divergence) RAISES it here.
        """
        req_id = self.submit(a, b, k, deadline_s=deadline_s,
                             tenant=tenant, slo=slo)
        attempt = 0
        while req_id is None:
            if attempt == 0:
                await asyncio.sleep(0)  # same-tick coalescing fast path
            else:
                await asyncio.sleep(
                    self.cfg.retry.backoff_s(attempt, self._retry_rng))
            attempt += 1
            self.step()
            req_id = self.submit(a, b, k, deadline_s=deadline_s,
                                 tenant=tenant, slo=slo)
        self._awaited.add(req_id)  # shield from a concurrent pop_ready drain
        try:
            while not self.has_result(req_id):
                await asyncio.sleep(0)
                self.step()
            out = self.pop_result(req_id)
            if isinstance(out, Exception):
                raise out
            return out
        finally:
            self._awaited.discard(req_id)

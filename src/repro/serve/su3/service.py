"""SU3Service: the plan layer behind a traffic-handling front door.

Composition (everything below the service already exists in the plan layer;
the service adds the queueing discipline and the warm-pool policy):

    submit(a, b, k)                      arun(a, b, k)  [asyncio face]
          │                                   │
          ▼                                   ▼
    DynamicBatcher — (L, k) buckets, warm-size padding, admission control
          │  next_batch()  one CoalescedBatch per step()
          ▼
    warm pool: {(L, dtype, layout, tile) -> BatchedLatticeRunner}
          │  built through the persistent autotune cache: the FIRST request
          │  for an (L, dtype) pays compile + tile/K sweep, every later
          │  request (and every later process) hits the tuned warm plan
          ▼
    one vmapped, sharded, (optionally bf16-storage/f32-accumulate) dispatch
          │
          ▼
    split + unpad per request  ->  results keyed by request id

The chain depth ``k`` defaults to the autotuned fused depth for the request's
(backend, L) — ``autotune.tuned_fused_k`` — so callers that don't care get
the measured-best dispatch amortization instead of a hardcoded constant.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.su3.layouts import Layout
from repro.core.su3.plan import BatchedLatticeRunner, EngineConfig
from repro.serve.su3.batcher import BatcherConfig, DynamicBatcher, ServeRequest
from repro.serve.su3.metrics import ServiceMetrics, request_flops

DEFAULT_TILE = 128  # small enough that every L >= 2 bucket is a few tiles


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """The serving tuple: storage/compute dtypes, layout, tuning, batching."""

    dtype: str = "float32"  # storage dtype of every plan in the pool
    accum_dtype: str = ""  # "float32" + dtype="bfloat16" = bf16 serving plans
    layout: Layout = Layout.SOA
    autotune: bool = True  # build runner configs through the persistent cache
    tile: int = 0  # explicit tile when autotune=False (0 = DEFAULT_TILE)
    default_k: int = 0  # chain depth when a request leaves k unset; 0 = tuned
    batcher: BatcherConfig = dataclasses.field(default_factory=BatcherConfig)
    cache_directory: str | None = None  # autotune cache override (tests)

    def __post_init__(self) -> None:
        # the pool serves the planar Pallas kernel; AOS has no planar view,
        # so reject it here instead of inside the first user request
        if Layout(self.layout) not in (Layout.SOA, Layout.AOSOA):
            raise ValueError(
                f"serving pool requires a planar-view layout (soa/aosoa), "
                f"got {Layout(self.layout).value!r}"
            )
        # best_config sweeps (and cache-keys) SoA plans only — applying its
        # tile/fused_k to another layout would serve never-measured numbers
        # under a mislabeled cache entry
        if self.autotune and Layout(self.layout) != Layout.SOA:
            raise ValueError(
                "the autotune cache tunes SoA plans only; serve "
                f"{Layout(self.layout).value!r} with autotune=False and an "
                "explicit tile"
            )


class SU3Service:
    """Dynamic-batching SU3 lattice serving over a warm ExecutionPlan pool."""

    def __init__(self, cfg: ServiceConfig | None = None, mesh: Any = None):
        self.cfg = cfg if cfg is not None else ServiceConfig()
        self.mesh = mesh
        self.batcher = DynamicBatcher(self.cfg.batcher)
        self.metrics = ServiceMetrics()
        self._pool: dict[tuple, BatchedLatticeRunner] = {}
        self._ecfg: dict[int, EngineConfig] = {}  # L -> resolved plan tuple
        self._tuned_k: dict[int, int] = {}
        self._results: dict[int, jax.Array] = {}
        self._awaited: set[int] = set()  # ids owned by pending arun callers
        self._seen_shapes: set[tuple] = set()
        self._next_id = 0

    # -- warm pool -----------------------------------------------------------

    def _engine_config(self, L: int) -> EngineConfig:
        """Resolved plan tuple for L, memoized — the autotune path otherwise
        re-reads the JSON cache file on every dispatch."""
        if L not in self._ecfg:
            cfg = self.cfg
            if cfg.autotune:
                self._ecfg[L] = autotune.tuned_engine_config(
                    L=L, dtype=cfg.dtype, cache_directory=cfg.cache_directory,
                    layout=cfg.layout, accum_dtype=cfg.accum_dtype,
                )
            else:
                self._ecfg[L] = EngineConfig(
                    L=L, dtype=cfg.dtype, layout=cfg.layout,
                    tile=cfg.tile or DEFAULT_TILE, accum_dtype=cfg.accum_dtype,
                )
        return self._ecfg[L]

    def runner_for(self, L: int) -> BatchedLatticeRunner:
        """The warm runner for lattice size L (built + tuned on first use)."""
        ecfg = self._engine_config(L)
        key = (L, ecfg.dtype, ecfg.layout.value, ecfg.tile)
        runner = self._pool.get(key)
        if runner is None:
            runner = BatchedLatticeRunner(ecfg, self.mesh)
            self._pool[key] = runner
        return runner

    def pool_keys(self) -> list[tuple]:
        return sorted(self._pool)

    def default_k_for(self, L: int) -> int:
        """Request chain depth when unspecified: configured or autotuned."""
        if self.cfg.default_k:
            return self.cfg.default_k
        if not self.cfg.autotune:
            return 1
        if L not in self._tuned_k:
            self._tuned_k[L] = autotune.tuned_fused_k(
                L=L, dtype=self.cfg.dtype, accum_dtype=self.cfg.accum_dtype,
                cache_directory=self.cfg.cache_directory,
            )
        return self._tuned_k[L]

    def warm(self, Ls: tuple[int, ...], ks: tuple[int, ...] = (1,),
             batch_sizes: tuple[int, ...] = ()) -> None:
        """Pre-build runners (and optionally compile dispatch shapes).

        Serving cold-start control: first-touch compiles happen here instead
        of inside a user request's latency.
        """
        for L in Ls:
            runner = self.runner_for(L)
            n_sites = L**4
            for bsz in batch_sizes:
                a = jnp.zeros((bsz, n_sites, 4, 3, 3), jnp.complex64)
                b = jnp.zeros((bsz, 4, 3, 3), jnp.complex64)
                for k in ks:
                    runner.multiply(a, b, k=k).block_until_ready()
                    self._seen_shapes.add(self._shape_key(runner, L, k, bsz))

    @staticmethod
    def _shape_key(runner: BatchedLatticeRunner, L: int, k: int, bsz: int) -> tuple:
        """Compiled-shape identity: the runner pads the batch up to a device
        multiple, so that post-pad size — not the request count — is what
        the jit cache keys on."""
        return (L, k, bsz + (-bsz) % runner.n_devices)

    # -- request intake ------------------------------------------------------

    @staticmethod
    def _infer_L(a: jax.Array) -> int:
        n_sites = a.shape[0]
        L = round(n_sites ** 0.25)
        if L**4 != n_sites or a.shape[1:] != (4, 3, 3):
            raise ValueError(
                f"request lattice must be (L**4, 4, 3, 3) canonical complex, "
                f"got {a.shape}"
            )
        return L

    def submit(self, a: jax.Array, b: jax.Array, k: int | None = None) -> int | None:
        """Queue one lattice multiply; returns a request id, or None when the
        queue budget is exhausted (backpressure — caller retries later)."""
        L = self._infer_L(a)
        depth = len(self.batcher)
        req = ServeRequest(
            req_id=self._next_id, a=a, b=b, L=L,
            k=k if k is not None else self.default_k_for(L),
            arrival_s=time.perf_counter(),
        )
        if not self.batcher.submit(req):
            self.metrics.record_reject()
            return None
        self._next_id += 1
        self.metrics.record_admit(depth + 1)
        return req.req_id

    # -- dispatch ------------------------------------------------------------

    def step(self) -> int:
        """Dispatch ONE coalesced batch; returns completed request count.

        Pads the batch to the warm size with zero lattices, runs the whole
        bucket through one vmapped (fused-k) plan dispatch, then splits and
        unpads results back per request id.
        """
        batch = self.batcher.next_batch()
        if batch is None:
            return 0
        reqs = batch.requests
        runner = self.runner_for(batch.L)
        n_sites = batch.L**4
        a = jnp.stack([r.a for r in reqs])
        b = jnp.stack([r.b for r in reqs])
        if batch.pad:
            a = jnp.concatenate(
                [a, jnp.zeros((batch.pad,) + a.shape[1:], a.dtype)], axis=0
            )
            b = jnp.concatenate(
                [b, jnp.zeros((batch.pad,) + b.shape[1:], b.dtype)], axis=0
            )
        shape_key = self._shape_key(runner, batch.L, batch.k, batch.padded_size)
        cold = shape_key not in self._seen_shapes
        t0 = time.perf_counter()
        c = runner.multiply(a, b, k=batch.k)
        c.block_until_ready()
        step_s = time.perf_counter() - t0
        self._seen_shapes.add(shape_key)
        self.metrics.record_dispatch(
            live=len(reqs), padded=batch.padded_size, step_s=step_s,
            flops=request_flops(n_sites, batch.k) * len(reqs), cold=cold,
        )
        done_s = time.perf_counter()
        for i, r in enumerate(reqs):
            self._results[r.req_id] = c[i]
            self.metrics.record_completion(done_s - r.arrival_s)
        self.metrics.record_queue_depth(len(self.batcher))
        return len(reqs)

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        """Step until the queue empties; returns total completed requests."""
        total = 0
        for _ in range(max_steps):
            done = self.step()
            if done == 0:
                return total
            total += done
        raise RuntimeError(f"queue not drained after {max_steps} steps")

    # -- results -------------------------------------------------------------

    def has_result(self, req_id: int) -> bool:
        return req_id in self._results

    def pop_result(self, req_id: int) -> jax.Array:
        """The canonical complex C lattice for a completed request (once)."""
        return self._results.pop(req_id)

    def pop_ready(self) -> dict[int, jax.Array]:
        """All completed results, cleared from the service (delivery drain).

        A caller that steps the service itself (replay harnesses, pollers)
        must drain results this way or via ``pop_result`` — undelivered C
        lattices are device arrays and accumulate for the service lifetime.
        Results owned by a pending :meth:`arun` coroutine are left in place;
        only that coroutine delivers them.
        """
        if not self._awaited:
            out, self._results = self._results, {}
            return out
        out = {rid: c for rid, c in self._results.items() if rid not in self._awaited}
        for rid in out:
            del self._results[rid]
        return out

    # -- asyncio face --------------------------------------------------------

    async def arun(self, a: jax.Array, b: jax.Array, k: int | None = None) -> jax.Array:
        """Submit and await one request from an asyncio front-end.

        Concurrent ``arun`` coroutines submitted in the same scheduler tick
        coalesce into one dispatch — whichever coroutine steps first serves
        the whole bucket.  Backpressure surfaces as cooperative retry: a
        rejected submit yields to the loop (letting other coroutines drain
        the queue) and tries again.
        """
        req_id = self.submit(a, b, k)
        while req_id is None:
            await asyncio.sleep(0)
            self.step()
            req_id = self.submit(a, b, k)
        self._awaited.add(req_id)  # shield from a concurrent pop_ready drain
        try:
            while not self.has_result(req_id):
                await asyncio.sleep(0)
                self.step()
            return self.pop_result(req_id)
        finally:
            self._awaited.discard(req_id)

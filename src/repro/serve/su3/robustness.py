"""Request-lifecycle robustness: structured failures, retry policy, host
health.

The contract this module pins for the serving stack (ISSUE 9): a request
that enters :class:`~repro.serve.su3.service.SU3Service` leaves it in
exactly one of three ways — a result, a structured error, or a structured
timeout.  Nothing is silently dropped, nothing hangs.  The pieces:

  structured failures   :class:`RequestFailure` subclasses delivered
                        *through the result channel* (``pop_result`` /
                        ``pop_ready`` return them; ``arun`` raises them),
                        so synchronous steppers and asyncio callers see
                        the same taxonomy;
  RetryPolicy           capped exponential backoff with jitter and a
                        service-wide retry *budget* — a failing host
                        cannot convert the whole queue into an unbounded
                        retry storm;
  HostHealth            per-host consecutive-failure tracker fed by both
                        injected (repro.chaos) and real failures; crossing
                        ``quarantine_after`` quarantines the host, and the
                        service re-seats its requests onto healthy pools
                        (the last rung of the degradation ladder).

Priorities: load shedding under backpressure is priority-aware — bulk
multiplies shed before latency-sensitive solves (the first step toward
the ROADMAP's SLO classes).  ``PRIORITY`` maps request kinds to that
order; higher sheds later.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any

# kind -> shedding priority: higher = more latency-sensitive = shed last.
# Solves are the flagship interactive workload; multiplies are the bulk tier.
PRIORITY = {"multiply": 0, "stencil": 1, "solve": 2}


class RequestFailure(RuntimeError):
    """Base of every structured per-request failure the service delivers.

    Instances ride the result channel: ``has_result`` turns True, a
    stepping caller gets the exception *object* from ``pop_result`` (check
    ``isinstance(out, RequestFailure)``), an ``arun`` caller gets it
    raised.  ``req_id``/``kind``/``attempts`` make every failure
    attributable without parsing the message.
    """

    def __init__(self, message: str, *, req_id: int, kind: str,
                 attempts: int = 0):
        super().__init__(message)
        self.req_id = req_id
        self.kind = kind
        self.attempts = attempts


class DeadlineExceededError(RequestFailure):
    """The request's deadline passed while queued or seated; it was evicted
    (queue slot and any live chain/slot-table seat freed).  ``partial``
    carries the best iterate for solves evicted mid-CG (None otherwise)."""

    def __init__(self, *, req_id: int, kind: str, deadline_s: float,
                 waited_s: float, attempts: int = 0, partial: Any = None):
        super().__init__(
            f"request {req_id} ({kind}) exceeded its {deadline_s:.3f}s "
            f"deadline after {waited_s:.3f}s",
            req_id=req_id, kind=kind, attempts=attempts,
        )
        self.deadline_s = deadline_s
        self.waited_s = waited_s
        self.partial = partial


class RetriesExhaustedError(RequestFailure):
    """Every allowed retry failed (or the service-wide retry budget ran
    dry).  ``cause`` is the last failure's short reason string."""

    def __init__(self, *, req_id: int, kind: str, attempts: int, cause: str,
                 budget_exhausted: bool = False):
        why = "retry budget exhausted" if budget_exhausted else \
            f"{attempts} attempts failed"
        super().__init__(
            f"request {req_id} ({kind}) gave up: {why} (last cause: {cause})",
            req_id=req_id, kind=kind, attempts=attempts,
        )
        self.cause = cause
        self.budget_exhausted = budget_exhausted


class LoadShedError(RequestFailure):
    """The request was shed from the queue to admit a higher-priority one
    under backpressure (bulk multiplies shed before solves) — or rejected
    at the door by the brownout ladder (``shed_for_kind="brownout"``).
    ``retry_after_s > 0`` is a Retry-After hint: the service is browning
    out and the caller should back off at least that long before
    resubmitting (rung 3 sets it; ordinary sheds leave it 0)."""

    def __init__(self, *, req_id: int, kind: str, priority: int,
                 shed_for_kind: str, attempts: int = 0,
                 retry_after_s: float = 0.0):
        hint = f"; retry after {retry_after_s:.3f}s" if retry_after_s else ""
        super().__init__(
            f"request {req_id} ({kind}, priority {priority}) shed under "
            f"backpressure for an arriving {shed_for_kind}{hint}",
            req_id=req_id, kind=kind, attempts=attempts,
        )
        self.priority = priority
        self.shed_for_kind = shed_for_kind
        self.retry_after_s = retry_after_s


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with jitter plus a service-wide budget.

    ``backoff_s(attempt)`` grows ``base_s * 2**attempt`` up to ``cap_s``,
    then multiplies by ``1 + U[0, jitter]`` from a seeded stream (decorrelates
    retry herds without losing reproducibility).  ``budget`` bounds TOTAL
    retries across the service lifetime: once spent, further failures turn
    into :class:`RetriesExhaustedError` immediately — the storm cannot
    amplify itself into an unbounded retry load.
    """

    max_retries: int = 3  # per-request attempt cap (beyond the first try)
    base_s: float = 0.002
    cap_s: float = 0.25
    jitter: float = 0.2  # multiplicative spread: delay *= 1 + U[0, jitter]
    budget: int = 256  # total retries the whole service may spend
    seed: int = 0  # jitter stream seed (reproducible backoff schedules)

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.base_s <= 0 or self.cap_s < self.base_s:
            raise ValueError(
                f"need 0 < base_s <= cap_s, got base_s={self.base_s} "
                f"cap_s={self.cap_s}"
            )
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")
        if self.budget < 0:
            raise ValueError(f"budget must be >= 0, got {self.budget}")

    def backoff_s(self, attempt: int, rng: random.Random) -> float:
        """Delay before retry number ``attempt`` (1-based)."""
        raw = min(self.base_s * (2.0 ** max(attempt - 1, 0)), self.cap_s)
        return raw * (1.0 + self.jitter * rng.random())


class HostHealth:
    """Per-host failure tracker and quarantine latch.

    Fed by every dispatch outcome — injected faults and real exceptions
    alike record a failure; a completed dispatch records a success and
    clears the consecutive count.  ``quarantine_after`` consecutive
    failures latch the host into quarantine: the router stops homing work
    there and the service re-seats its live requests onto healthy pools.
    ``reinstate`` is the explicit operator/probe path back in (the service
    never auto-heals a host it has seen fail repeatedly — a flapping host
    is worse than a missing one).
    """

    def __init__(self, n_hosts: int, quarantine_after: int = 3):
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
        if quarantine_after < 1:
            raise ValueError(
                f"quarantine_after must be >= 1, got {quarantine_after}"
            )
        self.n_hosts = n_hosts
        self.quarantine_after = quarantine_after
        self.failures = [0] * n_hosts  # lifetime totals
        self.successes = [0] * n_hosts
        self.consecutive = [0] * n_hosts
        self.last_cause: list[str] = [""] * n_hosts
        self._quarantined: set[int] = set()

    def record_failure(self, host: int, cause: str) -> bool:
        """Account one failure; returns True iff this crossing quarantined
        the host (the caller re-seats its work exactly once)."""
        self.failures[host] += 1
        self.consecutive[host] += 1
        self.last_cause[host] = cause
        if (host not in self._quarantined
                and self.consecutive[host] >= self.quarantine_after
                and self.n_hosts - len(self._quarantined) > 1):
            # quarantining must leave a healthy host to re-seat onto: a
            # single-host service (or the last healthy host) keeps
            # retrying/degrading instead of quarantining itself to death
            self._quarantined.add(host)
            return True
        return False

    def record_success(self, host: int) -> None:
        self.successes[host] += 1
        self.consecutive[host] = 0

    def quarantined(self) -> set[int]:
        return set(self._quarantined)

    def is_quarantined(self, host: int) -> bool:
        return host in self._quarantined

    def healthy_hosts(self) -> list[int]:
        return [h for h in range(self.n_hosts) if h not in self._quarantined]

    def reinstate(self, host: int) -> None:
        """Operator/probe path: clear the latch and the consecutive count."""
        self._quarantined.discard(host)
        self.consecutive[host] = 0

    def snapshot(self) -> dict[str, Any]:
        return {
            "failures": list(self.failures),
            "successes": list(self.successes),
            "consecutive": list(self.consecutive),
            "quarantined": sorted(self._quarantined),
            "last_cause": list(self.last_cause),
        }

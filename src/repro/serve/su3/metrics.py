"""Serving metrics: the sustained-throughput view of the SU3 kernel.

The paper reports best-iteration GFLOPS of a dedicated loop; a service is
judged differently — by *sustained* useful throughput and tail latency under
a traffic mix.  This module owns that accounting so the service and the
traffic benchmark report identical quantities:

  latency      per-request wall seconds from admission to completion
               (p50/p95/p99 — the tail is what queueing and padding cost);
  gflops       useful flops only (864 x sites x chain depth per request,
               the paper's flop model) over busy time (kernel walls) and
               over total wall — padded slots are NOT credited;
  occupancy    live fraction of dispatched batch slots — the price of warm
               batch-size padding, averaged over dispatches;
  queue depth  sampled at every admission and dispatch — the backpressure
               signal admission control acts on.

Everything exports as one flat dict (``snapshot()``) so benchmark rows,
logs, and tests consume the same schema.

Memory is bounded for long-running services: latencies feed a fixed-size
:class:`repro.obs.Reservoir` (exact percentiles below capacity — the pinned
small-sample tests see identical numbers — uniform subsample beyond it, with
count/mean always exact), and occupancy/queue-depth series keep only running
count/sum/max (:class:`repro.obs.RunningStat`) since only their mean/max are
ever exported.  No per-sample list grows with traffic.
"""
from __future__ import annotations

import dataclasses
import time

from repro.obs.stats import Reservoir, RunningStat
from repro.serve.su3.tenancy import class_key

FLOPS_PER_SITE = 864  # 4 links x 3x3x3 complex MACs x 8 real flops (paper §3.1)


LATENCY_RESERVOIR_CAPACITY = 4096  # exact percentiles below this many samples
CLASS_RESERVOIR_CAPACITY = 1024  # per-(tenant, class) latency reservoirs


def request_flops(n_sites: int, k: int) -> float:
    """Useful flops of one request: k chained multiplies over the lattice."""
    return float(FLOPS_PER_SITE) * n_sites * k


@dataclasses.dataclass
class ServiceMetrics:
    """Mutable counters; ``snapshot()`` is the exported read-only view."""

    started_s: float = dataclasses.field(default_factory=time.perf_counter)
    admitted: int = 0
    rejected: int = 0
    completed: int = 0
    dispatches: int = 0
    padded_slots: int = 0
    live_slots: int = 0
    busy_s: float = 0.0
    useful_flops: float = 0.0
    latencies_s: Reservoir = dataclasses.field(
        default_factory=lambda: Reservoir(LATENCY_RESERVOIR_CAPACITY))
    occupancies: RunningStat = dataclasses.field(default_factory=RunningStat)
    queue_depths: RunningStat = dataclasses.field(default_factory=RunningStat)
    compiles: int = 0  # cold (first-shape) dispatches, charged to busy_s too
    midchain_admits: int = 0  # continuous mode: requests seated into an
    # already-running chain (the admissions batch-per-step cannot make)
    host_dispatches: dict = dataclasses.field(default_factory=dict)  # host -> n
    iterations: int = 0  # continuous/megakernel scheduling turns (iteration
    # boundaries); dispatches/iterations is the dispatch-amortization figure
    # the megakernel path drives to 1.0 per host
    host_iterations: dict = dataclasses.field(default_factory=dict)  # host -> n
    kind_iterations: dict = dataclasses.field(default_factory=dict)  # kind -> n
    # per-kind iteration counts: "multiply"/"stencil" turns vs "solve" CG
    # iterations — the traffic mix's iteration bill by request family
    rejected_by_kind: dict = dataclasses.field(default_factory=dict)  # kind -> n
    # backpressure rejects split per request family (``rejected`` stays the
    # total, so the pre-existing snapshot key is unchanged)
    shed: int = 0  # queued requests evicted to admit higher-priority arrivals
    shed_by_kind: dict = dataclasses.field(default_factory=dict)
    timeouts: int = 0  # deadline evictions (queued or seated)
    timeouts_by_kind: dict = dataclasses.field(default_factory=dict)
    retries: int = 0  # re-dispatches consumed from the retry budget
    retries_exhausted: int = 0  # requests that gave up with a structured error
    faults_injected: int = 0  # chaos faults applied to this service's seams
    degraded_dispatches: int = 0  # megakernel batches re-run down the
    # per-(L) chained fallback path after a dispatch failure
    quarantines: int = 0  # hosts latched out by the health tracker
    reseated: int = 0  # requests moved off a quarantined host onto a healthy one
    # -- tenancy splits (ISSUE 10) --------------------------------------------
    # per-(tenant, SLO class) views keyed "tenant/class"; the legacy totals
    # above are unchanged — the default tenant's traffic lands in
    # "default/bulk" / "default/latency" and sums to the old numbers
    admitted_by_class: dict = dataclasses.field(default_factory=dict)
    shed_by_class: dict = dataclasses.field(default_factory=dict)
    timeouts_by_class: dict = dataclasses.field(default_factory=dict)
    latencies_by_class: dict = dataclasses.field(default_factory=dict)
    # "tenant/class" -> Reservoir of completion latencies
    shed_for_kind: dict = dataclasses.field(default_factory=dict)
    # beneficiary attribution: which arriving kind (or "brownout") each shed
    # paid for — sums to ``shed``, so shed accounting reconciles with admits
    quota_rejected: int = 0  # submits refused by a tenant's token bucket
    quota_rejected_by_tenant: dict = dataclasses.field(default_factory=dict)
    preemptions: int = 0  # bulk seats evicted for a waiting latency request
    scale_ups: int = 0  # autoscaler grow events
    scale_downs: int = 0  # autoscaler shrink events
    active_hosts: int = 0  # current active pool size (gauge; 0 = unset)
    brownout_rung: int = 0  # current ladder rung (gauge)
    brownout_transitions: int = 0  # ladder moves (either direction)
    brownout_rung_turns: dict = dataclasses.field(default_factory=dict)
    # rung -> scheduling turns spent there (rung occupancy for the bench row)
    brownout_degraded_solve_turns: int = 0  # bulk solve turns run at reduced
    # iterations (and/or on a warm bf16 pool entry) by rung >= 2

    def reset(self) -> None:
        """Zero every counter and restart the wall clock (post-warmup)."""
        self.__init__()

    # -- recording -----------------------------------------------------------

    @staticmethod
    def _bump(d: dict, key: str, n: int = 1) -> None:
        d[key] = d.get(key, 0) + n

    def record_admit(self, queue_depth: int, tenant: str | None = None,
                     slo: str | None = None) -> None:
        self.admitted += 1
        self.queue_depths.add(queue_depth)
        if tenant is not None and slo is not None:
            self._bump(self.admitted_by_class, class_key(tenant, slo))

    def record_reject(self, kind: str = "multiply") -> None:
        self.rejected += 1
        self.rejected_by_kind[kind] = self.rejected_by_kind.get(kind, 0) + 1

    def record_quota_reject(self, tenant: str) -> None:
        self.quota_rejected += 1
        self._bump(self.quota_rejected_by_tenant, tenant)

    def record_shed(self, kind: str, for_kind: str = "",
                    tenant: str | None = None, slo: str | None = None) -> None:
        """One shed victim of ``kind``; ``for_kind`` is the BENEFICIARY —
        the arriving kind the victim paid for (or "brownout" for ladder
        sheds) — so ``shed_for_kind`` reconciles sheds against admits."""
        self.shed += 1
        self.shed_by_kind[kind] = self.shed_by_kind.get(kind, 0) + 1
        if for_kind:
            self._bump(self.shed_for_kind, for_kind)
        if tenant is not None and slo is not None:
            self._bump(self.shed_by_class, class_key(tenant, slo))

    def record_timeout(self, kind: str, tenant: str | None = None,
                       slo: str | None = None) -> None:
        self.timeouts += 1
        self.timeouts_by_kind[kind] = self.timeouts_by_kind.get(kind, 0) + 1
        if tenant is not None and slo is not None:
            self._bump(self.timeouts_by_class, class_key(tenant, slo))

    def record_preemption(self) -> None:
        self.preemptions += 1

    def record_scale(self, delta: int, active: int) -> None:
        if delta > 0:
            self.scale_ups += 1
        elif delta < 0:
            self.scale_downs += 1
        self.active_hosts = active

    def record_brownout_transition(self, rung: int) -> None:
        self.brownout_transitions += 1
        self.brownout_rung = rung

    def record_brownout_turn(self, rung: int) -> None:
        self._bump(self.brownout_rung_turns, str(rung))

    def record_degraded_solve_turn(self) -> None:
        self.brownout_degraded_solve_turns += 1

    def record_retry(self, n: int = 1) -> None:
        self.retries += n

    def record_retries_exhausted(self) -> None:
        self.retries_exhausted += 1

    def record_fault(self, n: int = 1) -> None:
        self.faults_injected += n

    def record_degraded(self) -> None:
        self.degraded_dispatches += 1

    def record_quarantine(self, reseated: int = 0) -> None:
        self.quarantines += 1
        self.reseated += reseated

    def record_dispatch(
        self, *, live: int, padded: int, step_s: float, flops: float,
        cold: bool = False, host: int = 0,
    ) -> None:
        """Account one device dispatch.

        ``live``/``padded`` are request slots (continuous mode charges each
        per-iteration dispatch at its chain's slot count, so occupancy is
        directly comparable with batch-per-step at the same warm size);
        ``host`` attributes the dispatch to a pool shard.
        """
        self.dispatches += 1
        self.live_slots += live
        self.padded_slots += padded - live
        self.busy_s += step_s
        self.useful_flops += flops
        self.occupancies.add(live / padded if padded else 0.0)
        self.host_dispatches[host] = self.host_dispatches.get(host, 0) + 1
        if cold:
            self.compiles += 1

    def record_midchain_admits(self, n: int = 1) -> None:
        self.midchain_admits += n

    def record_iteration(self, host: int = 0, kind: str = "multiply",
                         n: int = 1) -> None:
        """Account ``n`` iteration boundaries (continuous/megakernel
        scheduling turns, or solver CG iterations) of ``kind`` for ``host``
        — the denominator of dispatches-per-iteration, split per request
        family in ``kind_iterations``."""
        self.iterations += n
        self.host_iterations[host] = self.host_iterations.get(host, 0) + n
        self.kind_iterations[kind] = self.kind_iterations.get(kind, 0) + n

    def record_completion(self, latency_s: float, tenant: str | None = None,
                          slo: str | None = None) -> None:
        self.completed += 1
        self.latencies_s.add(latency_s)
        if tenant is not None and slo is not None:
            key = class_key(tenant, slo)
            res = self.latencies_by_class.get(key)
            if res is None:
                res = self.latencies_by_class[key] = Reservoir(
                    CLASS_RESERVOIR_CAPACITY)
            res.add(latency_s)

    def record_queue_depth(self, depth: int) -> None:
        self.queue_depths.add(depth)

    # -- export --------------------------------------------------------------

    def _pct(self, q: float) -> float:
        return self.latencies_s.percentile(q)

    def snapshot(self) -> dict:
        wall = time.perf_counter() - self.started_s
        total_slots = self.live_slots + self.padded_slots
        return {
            "admitted": self.admitted,
            "rejected": self.rejected,
            "completed": self.completed,
            "dispatches": self.dispatches,
            "compiles": self.compiles,
            "latency_p50_ms": round(self._pct(50) * 1e3, 3),
            "latency_p95_ms": round(self._pct(95) * 1e3, 3),
            "latency_p99_ms": round(self._pct(99) * 1e3, 3),
            "latency_mean_ms": round(self.latencies_s.mean() * 1e3, 3),
            "sustained_gflops_busy": round(
                self.useful_flops / self.busy_s / 1e9, 3
            ) if self.busy_s else 0.0,
            "sustained_gflops_wall": round(
                self.useful_flops / wall / 1e9, 3
            ) if wall else 0.0,
            "mean_batch_occupancy": round(self.occupancies.mean(), 3),
            "mean_live_batch": round(
                self.live_slots / self.dispatches, 3
            ) if self.dispatches else 0.0,
            "padded_slot_fraction": round(
                self.padded_slots / total_slots, 3
            ) if total_slots else 0.0,
            "midchain_admits": self.midchain_admits,
            "iterations": self.iterations,
            "dispatches_per_iteration": round(
                self.dispatches / self.iterations, 3
            ) if self.iterations else 0.0,
            "host_dispatches": {str(h): n for h, n in sorted(self.host_dispatches.items())},
            "kind_iterations": {k: n for k, n in sorted(self.kind_iterations.items())},
            "rejected_by_kind": {k: n for k, n in sorted(self.rejected_by_kind.items())},
            "shed": self.shed,
            "shed_by_kind": {k: n for k, n in sorted(self.shed_by_kind.items())},
            "timeouts": self.timeouts,
            "timeouts_by_kind": {k: n for k, n in sorted(self.timeouts_by_kind.items())},
            "retries": self.retries,
            "retries_exhausted": self.retries_exhausted,
            "faults_injected": self.faults_injected,
            "degraded_dispatches": self.degraded_dispatches,
            "quarantines": self.quarantines,
            "reseated": self.reseated,
            "queue_depth_max": int(self.queue_depths.max_or(0)),
            "queue_depth_mean": round(self.queue_depths.mean(), 3),
            "busy_s": round(self.busy_s, 4),
            "wall_s": round(wall, 4),
            # -- tenancy splits (additive keys; legacy keys above unchanged) --
            "admitted_by_class": {
                k: n for k, n in sorted(self.admitted_by_class.items())},
            "shed_by_class": {
                k: n for k, n in sorted(self.shed_by_class.items())},
            "shed_for_kind": {
                k: n for k, n in sorted(self.shed_for_kind.items())},
            "timeouts_by_class": {
                k: n for k, n in sorted(self.timeouts_by_class.items())},
            "latency_by_class_ms": {
                k: {
                    "p50": round(r.percentile(50) * 1e3, 3),
                    "p99": round(r.percentile(99) * 1e3, 3),
                    "count": r.count,
                }
                for k, r in sorted(self.latencies_by_class.items())
            },
            "quota_rejected": self.quota_rejected,
            "quota_rejected_by_tenant": {
                k: n for k, n in sorted(self.quota_rejected_by_tenant.items())},
            "preemptions": self.preemptions,
            "scale_ups": self.scale_ups,
            "scale_downs": self.scale_downs,
            "active_hosts": self.active_hosts,
            "brownout_rung": self.brownout_rung,
            "brownout_transitions": self.brownout_transitions,
            "brownout_rung_turns": {
                k: n for k, n in sorted(self.brownout_rung_turns.items())},
            "brownout_degraded_solve_turns": self.brownout_degraded_solve_turns,
        }

"""Multi-tenant SLO scheduling: quotas, fairness, autoscaling, brownout.

The paper's lesson generalized to traffic (ISSUE 10): SU3_Bench saturates
whichever pipeline resource binds first — and a shared serving stack dies
the same way, except the casualty is another tenant's p99.  This module is
the control plane that keeps one tenant's burst from becoming everyone's
tail latency.  Pure host-side scheduling state — no jax — so every policy
is unit-testable without a device:

  SLO classes       two lanes: ``latency`` (preempting, never shed) and
                    ``bulk`` (preemptible, the only sheddable lane).  Each
                    request kind has a default class (multiplies are bulk;
                    stencils and solves are the interactive tier) that
                    ``submit_*(slo=...)`` overrides per request.
  TenantQuota       token-bucket admission rate per tenant: ``burst``
                    tokens of headroom refilled at ``rate_per_s``.  A
                    tenant past its bucket is rejected at the front door
                    before it can queue against anyone else.  ``rate_per_s
                    = 0`` makes the bucket a pure burst budget — fully
                    deterministic, what the reproducible benches use.
  DeficitFairScheduler
                    deficit-weighted round robin over ``(tenant, class)``
                    groups, replacing the global kind rotation: every
                    pending group accrues ``quantum x weight`` credit per
                    visit and is served when it covers one turn, so a
                    backlogged bulk tenant cannot monopolize turns and a
                    lone latency tenant is served within a provable bound
                    (tested: a continuously-pending group is served within
                    ``ceil(1/(quantum x weight))`` ring passes, each pass
                    costing at most ``sum(ceil(1 + quantum x weight_h))``
                    turns over the other groups).
  WarmPoolAutoscaler
                    grow/shrink the ACTIVE host-submesh pool set from
                    queue-depth/occupancy pressure with hysteresis
                    (``grow_turns`` hot observations to add a host,
                    ``shrink_turns`` cold ones to retire the top host).
                    The service vetoes any shrink that would evict a
                    seated latency request.
  BrownoutLadder    three overload rungs entered on SUSTAINED pressure and
                    exited with hysteresis: rung 1 sheds bulk admissions
                    past a reduced queue share, rung 2 additionally
                    degrades bulk solves (fewer CG iterations per turn,
                    bf16 plans where a warm pool entry exists), rung 3
                    rejects new bulk outright with a ``Retry-After`` hint
                    in the LoadShedError.  Transitions are keyed by
                    observation index — not wall clock — so a same-seed
                    replay reproduces the transition log bit-for-bit.

Latency-class work is protected three ways, in escalating order: fair
turns (the scheduler), seats (latency preempts the youngest bulk seat via
the PR 4/PR 9 re-seating machinery), and admission (brownout only ever
sheds the bulk lane).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable

DEFAULT_TENANT = "default"

SLO_LATENCY = "latency"
SLO_BULK = "bulk"
SLO_CLASSES = (SLO_LATENCY, SLO_BULK)

# request kind -> default SLO class: solves/stencils are the interactive
# tier (mirrors robustness.PRIORITY, where multiplies shed first);
# submit_*(slo=...) overrides per request.
DEFAULT_KIND_SLO = {
    "multiply": SLO_BULK,
    "stencil": SLO_LATENCY,
    "solve": SLO_LATENCY,
}

GroupKey = tuple[str, str]  # (tenant, SLO class)


def class_key(tenant: str, slo: str) -> str:
    """The flat ``tenant/class`` key metrics snapshots export."""
    return f"{tenant}/{slo}"


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Per-class serving policy: deadline defaults and scheduler weights.

    ``*_deadline_s`` is the relative deadline a request of that class gets
    when it passes none of its own (0 = fall through to the service-wide
    ``default_deadline_s``).  ``*_weight`` is the class's share of fair
    turns: with the defaults a latency group earns 4 turns for every bulk
    turn when both are backlogged.
    """

    latency_deadline_s: float = 0.0
    bulk_deadline_s: float = 0.0
    latency_weight: float = 4.0
    bulk_weight: float = 1.0

    def __post_init__(self) -> None:
        if self.latency_deadline_s < 0 or self.bulk_deadline_s < 0:
            raise ValueError(
                f"class deadlines must be >= 0, got latency="
                f"{self.latency_deadline_s} bulk={self.bulk_deadline_s}"
            )
        if self.latency_weight <= 0 or self.bulk_weight <= 0:
            raise ValueError(
                f"class weights must be > 0, got latency="
                f"{self.latency_weight} bulk={self.bulk_weight}"
            )

    def deadline_for(self, slo: str) -> float:
        return self.latency_deadline_s if slo == SLO_LATENCY \
            else self.bulk_deadline_s

    def weight_for(self, group: GroupKey) -> float:
        return self.latency_weight if group[1] == SLO_LATENCY \
            else self.bulk_weight


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Token-bucket spec for one tenant: ``burst`` tokens of headroom,
    refilled at ``rate_per_s``.  ``rate_per_s = 0`` never refills — the
    bucket is a pure burst budget, deterministic under replay."""

    rate_per_s: float = 0.0
    burst: float = 8.0

    def __post_init__(self) -> None:
        if self.rate_per_s < 0:
            raise ValueError(f"rate_per_s must be >= 0, got {self.rate_per_s}")
        if self.burst < 1:
            raise ValueError(f"burst must be >= 1, got {self.burst}")


class TokenBucket:
    """Runtime state of one tenant's :class:`TenantQuota` (the spec stays
    frozen in the config; every service instance meters independently)."""

    def __init__(self, quota: TenantQuota):
        self.quota = quota
        self._tokens = float(quota.burst)
        self._last_s: float | None = None

    @property
    def tokens(self) -> float:
        return self._tokens

    def try_take(self, now: float, n: float = 1.0) -> bool:
        """Spend ``n`` tokens at time ``now``; False when the bucket is dry
        (the caller rejects the submit — quota backpressure)."""
        if self._last_s is not None and self.quota.rate_per_s > 0:
            elapsed = max(0.0, now - self._last_s)
            self._tokens = min(
                float(self.quota.burst),
                self._tokens + elapsed * self.quota.rate_per_s,
            )
        self._last_s = now
        if self._tokens >= n:
            self._tokens -= n
            return True
        return False


class DeficitFairScheduler:
    """Deficit-weighted round robin over ``(tenant, class)`` groups.

    Each ``next_group`` call serves ONE scheduling turn (cost 1.0).  Groups
    join a stable ring in first-seen order; a visited pending group accrues
    ``quantum x weight(group)`` deficit and is served once its deficit
    covers a turn, staying current until the grant is spent (so weights > 1
    buy consecutive turns, weights < 1 are served every few ring passes).
    A group observed idle forfeits its deficit — classic DRR, so an idle
    tenant cannot bank credit and burst past the others later.

    Non-starvation: a group with weight w needs ``ceil(1/(quantum x w))``
    ring visits to bank one turn, and between two of its visits every other
    group h can hold the floor for at most ``ceil(1 + quantum x weight(h))``
    consecutive turns (its deficit cap).  So while a group stays pending it
    is served at least once every ``ceil(1/(quantum x w)) x
    sum_h ceil(1 + quantum x weight(h))`` calls — the property test in
    tests/test_tenancy.py pins this bound.
    """

    def __init__(self, weight_for=None, quantum: float = 1.0):
        if quantum <= 0:
            raise ValueError(f"quantum must be > 0, got {quantum}")
        self.quantum = quantum
        self._weight_for = weight_for if weight_for is not None \
            else (lambda _g: 1.0)
        self._ring: list[GroupKey] = []  # stable first-seen order
        self._seen: set[GroupKey] = set()
        self._deficit: dict[GroupKey, float] = {}
        self._cursor = 0
        self._current: GroupKey | None = None
        self.turns: dict[GroupKey, int] = {}  # lifetime served-turn counts

    def _weight(self, group: GroupKey) -> float:
        w = float(self._weight_for(group))
        if w <= 0:
            raise ValueError(f"group weight must be > 0, got {w} for {group}")
        return w

    def next_group(self, pending: Iterable[GroupKey]) -> GroupKey | None:
        """The group that owns the next scheduling turn (None = idle)."""
        pend = list(dict.fromkeys(pending))
        pset = set(pend)
        for g in pend:
            if g not in self._seen:
                self._seen.add(g)
                self._ring.append(g)
        # DRR empty-queue rule: going idle forfeits banked credit
        for g in list(self._deficit):
            if g not in pset:
                del self._deficit[g]
        if not pend:
            self._current = None
            return None
        # stay on the current group while its grant covers another turn
        cur = self._current
        if cur in pset and self._deficit.get(cur, 0.0) >= 1.0:
            self._deficit[cur] -= 1.0
            self.turns[cur] = self.turns.get(cur, 0) + 1
            return cur
        # walk the ring: each visited pending group accrues one quantum
        min_w = min(self._weight(g) for g in pend)
        max_passes = max(1, math.ceil(1.0 / (self.quantum * min_w)))
        for _ in range(len(self._ring) * max_passes + len(self._ring)):
            g = self._ring[self._cursor % len(self._ring)]
            self._cursor += 1
            if g not in pset:
                continue
            grant = self.quantum * self._weight(g)
            # cap: one turn's cost plus one grant — idle groups already
            # forfeit, this bounds banked credit for always-pending ones
            self._deficit[g] = min(
                self._deficit.get(g, 0.0) + grant, 1.0 + grant
            )
            if self._deficit[g] >= 1.0:
                self._deficit[g] -= 1.0
                self._current = g
                self.turns[g] = self.turns.get(g, 0) + 1
                return g
        raise RuntimeError(
            "deficit scheduler failed to pick a pending group "
            f"(ring={self._ring}, pending={pend})"
        )  # pragma: no cover - the pass bound above makes this unreachable


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    """Warm-pool controller thresholds.  Disabled by default: the service
    keeps every configured host active, exactly the pre-tenancy behavior."""

    enabled: bool = False
    min_hosts: int = 1
    grow_queue_depth: int = 8  # queued backlog PER ACTIVE HOST that is hot
    grow_occupancy: float = 0.85  # mean seat occupancy that is hot
    shrink_queue_depth: int = 1  # backlog per active host that is cold
    shrink_occupancy: float = 0.25  # seat occupancy that is cold
    grow_turns: int = 2  # consecutive hot observations before growing
    shrink_turns: int = 6  # consecutive cold observations before shrinking

    def __post_init__(self) -> None:
        if self.min_hosts < 1:
            raise ValueError(f"min_hosts must be >= 1, got {self.min_hosts}")
        if self.grow_queue_depth <= self.shrink_queue_depth:
            raise ValueError(
                f"need grow_queue_depth > shrink_queue_depth for hysteresis, "
                f"got {self.grow_queue_depth} <= {self.shrink_queue_depth}"
            )
        if not 0.0 <= self.shrink_occupancy < self.grow_occupancy <= 1.0:
            raise ValueError(
                f"need 0 <= shrink_occupancy < grow_occupancy <= 1, got "
                f"{self.shrink_occupancy} / {self.grow_occupancy}"
            )
        if self.grow_turns < 1 or self.shrink_turns < 1:
            raise ValueError(
                f"grow/shrink_turns must be >= 1, got "
                f"{self.grow_turns}/{self.shrink_turns}"
            )


class WarmPoolAutoscaler:
    """Hysteresis controller over the active host-pool size.

    ``observe`` ingests one control-loop sample (aggregate queued backlog
    per active host + mean seat occupancy) and returns +1/-1/0: grow after
    ``grow_turns`` consecutive hot samples, shrink after ``shrink_turns``
    consecutive cold ones, hold otherwise.  The streak resets whenever the
    signal flips OR a decision fires, so scaling never oscillates on a
    boundary sample.  The SERVICE owns the active count (it must veto
    shrinks that would evict a seated latency request); this controller is
    pure decision state.
    """

    def __init__(self, cfg: AutoscaleConfig, max_hosts: int):
        if max_hosts < cfg.min_hosts:
            raise ValueError(
                f"max_hosts={max_hosts} below autoscale min_hosts="
                f"{cfg.min_hosts}"
            )
        self.cfg = cfg
        self.max_hosts = max_hosts
        self._hot = 0
        self._cold = 0

    def observe(self, *, depth_per_host: float, occupancy: float,
                active: int) -> int:
        """One control-loop sample; returns the proposed delta (+1/-1/0)."""
        cfg = self.cfg
        hot = (depth_per_host >= cfg.grow_queue_depth
               or occupancy >= cfg.grow_occupancy)
        cold = (depth_per_host <= cfg.shrink_queue_depth
                and occupancy <= cfg.shrink_occupancy)
        self._hot = self._hot + 1 if hot else 0
        self._cold = self._cold + 1 if cold else 0
        if self._hot >= cfg.grow_turns and active < self.max_hosts:
            self._hot = 0
            return 1
        if self._cold >= cfg.shrink_turns and active > cfg.min_hosts:
            self._cold = 0
            return -1
        return 0


@dataclasses.dataclass(frozen=True)
class BrownoutConfig:
    """Overload-ladder thresholds.  Pressure is the fraction of the active
    queue budget in use (queued depth / (max_queue_depth x active hosts)),
    blended with seat occupancy where seats exist; the ladder escalates one
    rung per ``sustain_turns`` consecutive pressured observations and steps
    down one rung per ``exit_turns`` consecutive calm ones — the dead band
    between ``exit_pressure`` and ``enter_pressure`` is the hysteresis."""

    enter_pressure: float = 0.75
    exit_pressure: float = 0.35
    sustain_turns: int = 3
    exit_turns: int = 6
    max_rung: int = 3
    bulk_queue_fraction: float = 0.5  # rung >= 1: bulk's share of the queue
    degrade_solve_factor: int = 2  # rung >= 2: solve_iters_per_step divisor
    degrade_bulk_bf16: bool = True  # rung >= 2: bulk solves ride a warm bf16
    # pool entry when one exists (never builds one mid-overload)
    retry_after_s: float = 0.05  # rung 3: Retry-After hint in LoadShedError

    def __post_init__(self) -> None:
        if not 0.0 <= self.exit_pressure < self.enter_pressure:
            raise ValueError(
                f"need 0 <= exit_pressure < enter_pressure (the hysteresis "
                f"band), got {self.exit_pressure} / {self.enter_pressure}"
            )
        if self.sustain_turns < 1 or self.exit_turns < 1:
            raise ValueError(
                f"sustain/exit_turns must be >= 1, got "
                f"{self.sustain_turns}/{self.exit_turns}"
            )
        if not 1 <= self.max_rung <= 3:
            raise ValueError(f"max_rung must be in [1, 3], got {self.max_rung}")
        if not 0.0 < self.bulk_queue_fraction <= 1.0:
            raise ValueError(
                f"bulk_queue_fraction must be in (0, 1], got "
                f"{self.bulk_queue_fraction}"
            )
        if self.degrade_solve_factor < 1:
            raise ValueError(
                f"degrade_solve_factor must be >= 1, got "
                f"{self.degrade_solve_factor}"
            )
        if self.retry_after_s < 0:
            raise ValueError(
                f"retry_after_s must be >= 0, got {self.retry_after_s}"
            )


class BrownoutLadder:
    """Three-rung overload state machine with hysteresis.

    Transitions are a function of the OBSERVATION SEQUENCE only (turn
    index, not wall clock), so a same-seed replay of the same traffic
    reproduces ``transitions`` exactly — the bench's reproducibility
    verdict diffs the two logs.
    """

    def __init__(self, cfg: BrownoutConfig):
        self.cfg = cfg
        self.rung = 0
        self.transitions: list[dict] = []  # {turn, from, to, pressure}
        self.rung_turns: dict[int, int] = {}  # rung -> observations spent
        self._turn = 0
        self._hot = 0
        self._calm = 0

    def observe(self, pressure: float) -> int | None:
        """Ingest one pressure sample; returns the new rung on a transition
        (None otherwise)."""
        self._turn += 1
        self.rung_turns[self.rung] = self.rung_turns.get(self.rung, 0) + 1
        if pressure >= self.cfg.enter_pressure:
            self._hot += 1
            self._calm = 0
        elif pressure <= self.cfg.exit_pressure:
            self._calm += 1
            self._hot = 0
        else:  # dead band: neither streak advances
            self._hot = 0
            self._calm = 0
        if self._hot >= self.cfg.sustain_turns and self.rung < self.cfg.max_rung:
            return self._move(self.rung + 1, pressure)
        if self._calm >= self.cfg.exit_turns and self.rung > 0:
            return self._move(self.rung - 1, pressure)
        return None

    def _move(self, to: int, pressure: float) -> int:
        self.transitions.append({
            "turn": self._turn, "from": self.rung, "to": to,
            "pressure": round(pressure, 4),
        })
        self.rung = to
        self._hot = 0
        self._calm = 0
        return to

    def signature(self) -> list[tuple[int, int, int]]:
        """The replay-comparable transition log: (turn, from, to)."""
        return [(t["turn"], t["from"], t["to"]) for t in self.transitions]

"""Dynamic request batching for SU3 lattice serving.

The serving analog of the paper's layout lesson: throughput is decided by
what you fix *before* the hot loop runs.  For traffic, that is the batch
shape — every distinct (lattice size, chain depth, batch size) triple is a
separate compiled dispatch, so an unmanaged request stream recompiles
constantly and runs batch-of-one.  The batcher makes the batch shape a
controlled, warm quantity:

  * **bucketing** — arriving requests are queued per ``(L, k)`` bucket
    (lattice size x chain depth); only shape-compatible requests coalesce
    into one vmapped dispatch.
  * **warm batch sizes** — a coalesced batch is padded up to the nearest
    size in ``warm_batch_sizes``, so the jit cache holds a handful of
    compiled batch shapes instead of one per observed batch size.  The
    padding cost is explicit: ``CoalescedBatch.occupancy`` is the live
    fraction, and the metrics charge padded slots as overhead.
  * **admission control** — ``submit`` rejects when the total queued depth
    would exceed ``max_queue_depth`` (backpressure to the caller), bounding
    queue-growth latency instead of letting p99 run away under overload.

The batcher is a plain steppable object — no threads, no event loop — so it
drops into a synchronous replay harness (benchmarks/serve_traffic.py), an
asyncio front-end (``SU3Service.arun``), or a test with the same semantics.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any

BucketKey = tuple[int, int]  # (L, chain depth k)


@dataclasses.dataclass
class ServeRequest:
    """One user's lattice multiply: C = A (x) B chained ``k`` times."""

    req_id: int
    a: Any  # canonical complex (n_sites, 4, 3, 3)
    b: Any  # canonical complex (4, 3, 3)
    L: int
    k: int
    arrival_s: float = 0.0  # perf_counter timestamp at admission

    @property
    def n_sites(self) -> int:
        return self.L**4

    @property
    def bucket(self) -> BucketKey:
        return (self.L, self.k)


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    max_batch: int = 8  # hard cap on requests coalesced into one dispatch
    warm_batch_sizes: tuple[int, ...] = (1, 2, 4, 8)  # pad-to sizes (jit cache keys)
    max_queue_depth: int = 64  # admission control: reject submits beyond this

    def __post_init__(self) -> None:
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1, got {self.max_queue_depth} "
                f"(0 would reject every submit and livelock arun retries)"
            )
        if not self.warm_batch_sizes or sorted(self.warm_batch_sizes) != list(
            self.warm_batch_sizes
        ):
            raise ValueError(
                f"warm_batch_sizes must be ascending and non-empty, "
                f"got {self.warm_batch_sizes}"
            )
        if self.max_batch > self.warm_batch_sizes[-1]:
            raise ValueError(
                f"max_batch={self.max_batch} exceeds the largest warm batch "
                f"size {self.warm_batch_sizes[-1]}: batches above it would "
                f"dispatch at never-warmed sizes, recompiling per observed "
                f"batch size"
            )

    def padded_size(self, n: int) -> int:
        """Nearest warm batch size >= n (n itself past the largest warm size)."""
        for w in self.warm_batch_sizes:
            if w >= n:
                return w
        return n


@dataclasses.dataclass
class CoalescedBatch:
    """Shape-compatible requests headed for one vmapped dispatch."""

    key: BucketKey
    requests: list[ServeRequest]
    padded_size: int

    @property
    def L(self) -> int:
        return self.key[0]

    @property
    def k(self) -> int:
        return self.key[1]

    @property
    def occupancy(self) -> float:
        """Live fraction of the dispatched batch (1.0 = no padding waste)."""
        return len(self.requests) / self.padded_size

    @property
    def pad(self) -> int:
        return self.padded_size - len(self.requests)


class DynamicBatcher:
    """Steppable coalescing queue with per-(L, k) buckets and backpressure."""

    def __init__(self, cfg: BatcherConfig | None = None):
        self.cfg = cfg if cfg is not None else BatcherConfig()
        # bucket -> FIFO of requests; OrderedDict keeps bucket creation order
        # as the tiebreak when head-request arrival times are equal.
        self._buckets: "OrderedDict[BucketKey, list[ServeRequest]]" = OrderedDict()
        self._depth = 0

    def __len__(self) -> int:
        return self._depth

    @property
    def depth(self) -> int:
        return self._depth

    def bucket_depths(self) -> dict[BucketKey, int]:
        return {k: len(v) for k, v in self._buckets.items() if v}

    def submit(self, req: ServeRequest) -> bool:
        """Admit a request; False under backpressure (queue budget exhausted)."""
        if self._depth >= self.cfg.max_queue_depth:
            return False
        if not req.arrival_s:
            req.arrival_s = time.perf_counter()
        self._buckets.setdefault(req.bucket, []).append(req)
        self._depth += 1
        return True

    def next_batch(self) -> CoalescedBatch | None:
        """Coalesce up to ``max_batch`` requests from the most urgent bucket.

        Urgency is head-of-line arrival time (oldest waiting request first),
        so no bucket starves under mixed traffic: a lone L=2 request queued
        behind a stream of L=4 batches is picked as soon as it is oldest.
        """
        live = [(key, q) for key, q in self._buckets.items() if q]
        if not live:
            return None
        key, queue = min(live, key=lambda kv: kv[1][0].arrival_s)
        take = queue[: self.cfg.max_batch]
        self._buckets[key] = queue[len(take):]
        self._depth -= len(take)
        return CoalescedBatch(
            key=key, requests=take, padded_size=self.cfg.padded_size(len(take))
        )

"""Dynamic request batching for SU3 lattice serving.

The serving analog of the paper's layout lesson: throughput is decided by
what you fix *before* the hot loop runs.  For traffic, that is the batch
shape — every distinct (lattice size, chain depth, batch size) triple is a
separate compiled dispatch, so an unmanaged request stream recompiles
constantly and runs batch-of-one.  The batcher makes the batch shape a
controlled, warm quantity:

  * **bucketing** — arriving requests are queued per ``(L, k)`` bucket
    (lattice size x chain depth); only shape-compatible requests coalesce
    into one vmapped dispatch.
  * **warm batch sizes** — a coalesced batch is padded up to the nearest
    size in ``warm_batch_sizes``, so the jit cache holds a handful of
    compiled batch shapes instead of one per observed batch size.  The
    padding cost is explicit: ``CoalescedBatch.occupancy`` is the live
    fraction, and the metrics charge padded slots as overhead.
  * **admission control** — ``submit`` rejects when the total queued depth
    would exceed ``max_queue_depth`` (backpressure to the caller), bounding
    queue-growth latency instead of letting p99 run away under overload.

The batcher is a plain steppable object — no threads, no event loop — so it
drops into a synchronous replay harness (benchmarks/serve_traffic.py), an
asyncio front-end (``SU3Service.arun``), or a test with the same semantics.

Two scheduling policies layered on top (both host-side bookkeeping only —
no jax in this module):

  * **locality routing** — :class:`LocalityRouter` pins each lattice size L
    to one host, sticky after first sight: the host that paid the compile +
    tile sweep for an L's warm runner keeps serving that L (the serving
    analog of the paper's first-touch rule — work follows the warm data).
  * **continuous batching** — :class:`InflightChain` tracks the slots of a
    chain that is being *re-dispatched one iteration at a time*: requests
    with the same L join at any iteration boundary (mid-chain admission)
    instead of waiting for the whole chain to drain; a request for another
    L can never join (the lattice shapes differ) and queues for its own
    chain.
  * **megakernel slot table** — :class:`SlotTable` is the per-host
    generalization the batched K-chain megakernel dispatches against: slots
    hold requests of ANY lattice size (the kernel pads every slot to one
    site capacity), each with its own remaining-iteration count, and one
    dispatch per host per iteration advances them all.  Mid-chain admission
    degenerates to a slot swap — seat the request, set its depth.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any

from repro.serve.su3.tenancy import DEFAULT_TENANT, SLO_BULK, GroupKey

BucketKey = tuple[int, int]  # (L, chain depth k)


@dataclasses.dataclass
class ServeRequest:
    """One user's lattice request.

    ``kind="multiply"`` (default): C = A (x) B chained ``k`` times, with
    ``a`` the canonical lattice and ``b`` the (4, 3, 3) link matrix set.
    ``kind="stencil"``: one application of the nearest-neighbor Dslash-style
    operator, with ``a`` the canonical gauge lattice and ``b`` the canonical
    color-vector field (n_sites, 3); ``k`` is always 1 (the stencil is not
    chained — its output is a vector field, not a lattice).
    ``kind="solve"``: a staggered CG solve ``(sigma I + S) x = b`` with
    ``a`` the canonical gauge lattice and ``b`` the canonical right-hand
    side (n_sites, 3); ``tol``/``max_iters`` bound the solver and the
    request's iteration count is DATA-DEPENDENT — the service advances it a
    few CG iterations per scheduling turn and it retires mid-chain the turn
    its residual crosses tol.
    """

    req_id: int
    a: Any  # canonical complex (n_sites, 4, 3, 3)
    b: Any  # canonical complex (4, 3, 3) | (n_sites, 3) for stencil/solve
    L: int
    k: int
    arrival_s: float = 0.0  # perf_counter timestamp at admission
    kind: str = "multiply"  # "multiply" | "stencil" | "solve"
    seated_s: float = 0.0  # perf_counter timestamp when seated in a slot/batch
    # (0.0 until seated; the request-lifecycle span derives queue_wait from it)
    tol: float = 0.0  # solve: relative-residual convergence target
    max_iters: int = 0  # solve: iteration cap (retires unconverged at cap)
    deadline_s: float = 0.0  # absolute perf_counter deadline (0 = none); a
    # request past it is EVICTED (queue slot and live chain/table seat freed)
    # and completes with a structured DeadlineExceededError
    priority: int = 0  # shedding priority (robustness.PRIORITY[kind]): under
    # backpressure, lower priorities shed first to admit higher ones
    attempts: int = 0  # dispatch attempts consumed (retry accounting)
    tenant: str = DEFAULT_TENANT  # tenant identity (quota + fairness group)
    slo: str = SLO_BULK  # SLO class: "latency" (preempting, never shed) or
    # "bulk" (preemptible, the only sheddable lane); defaults bulk so a raw
    # request stays sheddable — the service sets the per-kind class default

    @property
    def n_sites(self) -> int:
        return self.L**4

    @property
    def bucket(self) -> BucketKey:
        return (self.L, self.k)

    @property
    def group(self) -> GroupKey:
        """The (tenant, SLO class) fairness group this request bills to."""
        return (self.tenant, self.slo)


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    max_batch: int = 8  # hard cap on requests coalesced into one dispatch
    warm_batch_sizes: tuple[int, ...] = (1, 2, 4, 8)  # pad-to sizes (jit cache keys)
    max_queue_depth: int = 64  # admission control: reject submits beyond this

    def __post_init__(self) -> None:
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1, got {self.max_queue_depth} "
                f"(0 would reject every submit and livelock arun retries)"
            )
        if not self.warm_batch_sizes or sorted(self.warm_batch_sizes) != list(
            self.warm_batch_sizes
        ):
            raise ValueError(
                f"warm_batch_sizes must be ascending and non-empty, "
                f"got {self.warm_batch_sizes}"
            )
        if self.max_batch > self.warm_batch_sizes[-1]:
            raise ValueError(
                f"max_batch={self.max_batch} exceeds the largest warm batch "
                f"size {self.warm_batch_sizes[-1]}: batches above it would "
                f"dispatch at never-warmed sizes, recompiling per observed "
                f"batch size"
            )

    def padded_size(self, n: int) -> int:
        """Nearest warm batch size >= n (n itself past the largest warm size)."""
        for w in self.warm_batch_sizes:
            if w >= n:
                return w
        return n


@dataclasses.dataclass
class CoalescedBatch:
    """Shape-compatible requests headed for one vmapped dispatch."""

    key: BucketKey
    requests: list[ServeRequest]
    padded_size: int

    @property
    def L(self) -> int:
        return self.key[0]

    @property
    def k(self) -> int:
        return self.key[1]

    @property
    def occupancy(self) -> float:
        """Live fraction of the dispatched batch (1.0 = no padding waste)."""
        return len(self.requests) / self.padded_size

    @property
    def pad(self) -> int:
        return self.padded_size - len(self.requests)


class DynamicBatcher:
    """Steppable coalescing queue with per-(L, k) buckets and backpressure."""

    def __init__(self, cfg: BatcherConfig | None = None):
        self.cfg = cfg if cfg is not None else BatcherConfig()
        # (group, bucket) -> FIFO of requests; OrderedDict keeps creation
        # order as the tiebreak when head-request arrival times are equal.
        # Keying the families by (tenant, SLO class) FIRST means a coalesced
        # dispatch only ever carries one group's requests — tenant isolation
        # extends into the batch, not just the queue.
        self._buckets: "OrderedDict[tuple[GroupKey, BucketKey], list[ServeRequest]]" \
            = OrderedDict()
        # stencil requests coalesce by L only (no chain depth); they never
        # ride multiply chains, so they live in their own queue family
        self._stencil: "OrderedDict[tuple[GroupKey, int], list[ServeRequest]]" \
            = OrderedDict()
        # solve requests also queue by L; the service advances ONE active
        # solve per host a few CG iterations per turn, so this family feeds
        # that seat oldest-first
        self._solve: "OrderedDict[tuple[GroupKey, int], list[ServeRequest]]" \
            = OrderedDict()
        self._depth = 0

    def __len__(self) -> int:
        return self._depth

    @property
    def depth(self) -> int:
        return self._depth

    def bucket_depths(self) -> dict[BucketKey, int]:
        """Waiting multiplies per (L, k), aggregated over tenant groups
        (the pre-tenancy key shape every caller and test pins)."""
        out: dict[BucketKey, int] = {}
        for (_g, key), q in self._buckets.items():
            if q:
                out[key] = out.get(key, 0) + len(q)
        return out

    def stencil_depths(self) -> dict[int, int]:
        """Waiting stencil requests per lattice size (all groups)."""
        out: dict[int, int] = {}
        for (_g, L), q in self._stencil.items():
            if q:
                out[L] = out.get(L, 0) + len(q)
        return out

    def solve_depths(self) -> dict[int, int]:
        """Waiting solve requests per lattice size (all groups)."""
        out: dict[int, int] = {}
        for (_g, L), q in self._solve.items():
            if q:
                out[L] = out.get(L, 0) + len(q)
        return out

    # -- tenancy views ---------------------------------------------------------

    def pending_kinds_by_group(self) -> dict[GroupKey, set[str]]:
        """Queued work per (tenant, SLO class) group: group -> kinds with at
        least one waiting request — the fair scheduler's pending set."""
        out: dict[GroupKey, set[str]] = {}
        for kind, (group, _key), q in self._family_items():
            if q:
                out.setdefault(group, set()).add(kind)
        return out

    def depth_for_slo(self, slo: str) -> int:
        """Total queued requests of one SLO class (any tenant, any kind) —
        the brownout ladder's reduced-bulk-budget check."""
        return sum(
            len(q) for _kind, (group, _key), q in self._family_items()
            if group[1] == slo
        )

    def has_waiting(self, kind: str, L: int | None = None,
                    slo: str | None = None) -> bool:
        """Any queued request of ``kind`` (optionally restricted to one
        lattice size and/or SLO class) — the preemption trigger check."""
        for fam_kind, (group, key), q in self._family_items():
            if fam_kind != kind or not q:
                continue
            if slo is not None and group[1] != slo:
                continue
            fam_L = key[0] if fam_kind == "multiply" else key
            if L is not None and fam_L != L:
                continue
            return True
        return False

    def submit(self, req: ServeRequest) -> bool:
        """Admit a request; False under backpressure (queue budget exhausted).
        Multiply requests bucket by (group, (L, k)); stencil and solve
        requests by (group, L) — all families draw on one depth budget."""
        if self._depth >= self.cfg.max_queue_depth:
            return False
        if not req.arrival_s:
            req.arrival_s = time.perf_counter()
        if req.kind == "stencil":
            self._stencil.setdefault((req.group, req.L), []).append(req)
        elif req.kind == "solve":
            self._solve.setdefault((req.group, req.L), []).append(req)
        else:
            self._buckets.setdefault((req.group, req.bucket), []).append(req)
        self._depth += 1
        return True

    def next_solve(self, group: GroupKey | None = None) -> ServeRequest | None:
        """Pop the oldest waiting solve request (across lattice sizes) —
        the service seats it as the host's active solve.  Solves never
        coalesce: each carries its own data-dependent iteration count.
        ``group`` restricts the pop to one (tenant, class) — the fair
        scheduler serves exactly the group that owns the turn."""
        live = [
            (key, q) for (g, key), q in self._solve.items()
            if q and (group is None or g == group)
        ]
        if not live:
            return None
        _L, queue = min(live, key=lambda kv: kv[1][0].arrival_s)
        req = queue.pop(0)
        self._depth -= 1
        return req

    def next_stencil_batch(self, group: GroupKey | None = None) -> CoalescedBatch | None:
        """Coalesce up to ``max_batch`` stencil requests of the most urgent
        lattice size (oldest waiting head first), warm-size padded like the
        multiply buckets.  The batch ``key`` is ``(L, 1)`` — one stencil
        application per request.  ``group`` restricts to one (tenant, class);
        batches never mix groups either way (the families are group-keyed)."""
        live = [
            (key, q) for (g, key), q in self._stencil.items()
            if q and (group is None or g == group)
        ]
        if not live:
            return None
        L, queue = min(live, key=lambda kv: kv[1][0].arrival_s)
        take = queue[: self.cfg.max_batch]
        queue[:] = queue[len(take):]
        self._depth -= len(take)
        return CoalescedBatch(
            key=(L, 1), requests=take, padded_size=self.cfg.padded_size(len(take))
        )

    def next_batch(self, group: GroupKey | None = None) -> CoalescedBatch | None:
        """Coalesce up to ``max_batch`` requests from the most urgent bucket.

        Urgency is head-of-line arrival time (oldest waiting request first),
        so no bucket starves under mixed traffic: a lone L=2 request queued
        behind a stream of L=4 batches is picked as soon as it is oldest.
        ``group`` restricts to one (tenant, class); a batch never mixes
        groups either way — the buckets themselves are group-keyed.
        """
        live = [
            (key, q) for (g, key), q in self._buckets.items()
            if q and (group is None or g == group)
        ]
        if not live:
            return None
        key, queue = min(live, key=lambda kv: kv[1][0].arrival_s)
        take = queue[: self.cfg.max_batch]
        queue[:] = queue[len(take):]
        self._depth -= len(take)
        return CoalescedBatch(
            key=key, requests=take, padded_size=self.cfg.padded_size(len(take))
        )

    # -- robustness views ------------------------------------------------------

    def _family_items(self):
        """Every queue as a (kind, (group, key), queue) triple."""
        for gkey, q in self._buckets.items():
            yield "multiply", gkey, q
        for gkey, q in self._stencil.items():
            yield "stencil", gkey, q
        for gkey, q in self._solve.items():
            yield "solve", gkey, q

    def _families(self):
        """The three queue families as (kind, key, queue) triples (legacy
        key shape: (L, k) for multiplies, L otherwise)."""
        for kind, (_group, key), q in self._family_items():
            yield kind, key, q

    def evict_expired(self, now: float) -> list[ServeRequest]:
        """Pop every queued request whose deadline passed; the caller turns
        them into structured timeouts.  Requests without a deadline
        (``deadline_s == 0``) never expire."""
        evicted: list[ServeRequest] = []
        for _kind, _key, q in self._families():
            keep = []
            for req in q:
                if req.deadline_s and req.deadline_s <= now:
                    evicted.append(req)
                else:
                    keep.append(req)
            q[:] = keep
        self._depth -= len(evicted)
        return evicted

    def shed_lowest(self, max_priority: int,
                    sheddable_slo: str | None = None) -> ServeRequest | None:
        """Pop the YOUNGEST queued request with priority < ``max_priority``
        (the freshest bulk work pays for the latency-sensitive arrival —
        oldest bulk requests have waited longest and keep their place).
        ``sheddable_slo`` additionally restricts victims to one SLO class
        (the service passes "bulk": the latency lane is never shed).
        Returns None when nothing sheddable waits."""
        best: tuple[float, Any, list] | None = None
        for _kind, key, q in self._families():
            for req in q:
                if sheddable_slo is not None and req.slo != sheddable_slo:
                    continue
                if req.priority < max_priority and (
                    best is None or req.arrival_s > best[0]
                ):
                    best = (req.arrival_s, req, q)
        if best is None:
            return None
        _arrival, req, q = best
        q.remove(req)
        self._depth -= 1
        return req

    def drain(self) -> list[ServeRequest]:
        """Pop EVERY queued request (quarantine re-seating: the caller
        resubmits them through the router onto healthy hosts)."""
        out: list[ServeRequest] = []
        for _kind, _key, q in self._families():
            out.extend(q)
            q.clear()
        self._depth = 0
        return out

    # -- continuous-batching admission views ----------------------------------

    def queued_Ls(self, group: GroupKey | None = None) -> list[int]:
        """Distinct lattice sizes with waiting requests, oldest-head first
        (optionally restricted to one (tenant, class) group)."""
        heads: dict[int, float] = {}
        for (g, (L, _k)), q in self._buckets.items():
            if q and (group is None or g == group):
                heads[L] = min(heads.get(L, q[0].arrival_s), q[0].arrival_s)
        return sorted(heads, key=heads.__getitem__)

    def next_for_L(self, L: int, max_n: int,
                   group: GroupKey | None = None) -> list[ServeRequest]:
        """Pop up to ``max_n`` oldest waiting requests of lattice size ``L``,
        across every chain depth k.

        Continuous batching admits by *shape* compatibility only — a chain
        in flight for L can absorb requests of any k (each slot tracks its
        own remaining iterations), so the (L, k) buckets merge here by
        arrival order.  ``group`` restricts the pops to one (tenant, class)
        — a fair turn admits only the turn owner's requests, though seated
        slots of every group still advance together (the chain's dispatch
        is shared).  Returns ``[]`` when nothing eligible of size L waits.
        """
        if max_n < 1:
            return []
        out: list[ServeRequest] = []
        while len(out) < max_n:
            candidates = [
                (gkey, q) for gkey, q in self._buckets.items()
                if q and gkey[1][0] == L
                and (group is None or gkey[0] == group)
            ]
            if not candidates:
                break
            _gkey, queue = min(candidates, key=lambda kv: kv[1][0].arrival_s)
            out.append(queue.pop(0))
            self._depth -= 1
        return out


class LocalityRouter:
    """Sticky (lattice size -> host) routing for a host-sharded warm pool.

    The first request for a lattice size L is assigned to the least-loaded
    host (by cumulative admitted flops); every later L request follows it.
    That host's pool holds L's warm ``BatchedLatticeRunner`` — the compile
    and tile/K sweeps were paid there, its devices hold the warm dispatch
    shapes — so routing by locality means never re-warming an L on a second
    host while the first sits idle (the serving analog of the paper's
    "work runs where the data was first touched").

    Host-side bookkeeping only; safe under any request mix.
    """

    def __init__(self, n_hosts: int):
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
        self.n_hosts = n_hosts
        self._home: dict[int, int] = {}  # L -> host
        self._load: list[float] = [0.0] * n_hosts  # cumulative admitted flops

    def host_for(self, L: int) -> int:
        """The home host for lattice size L (assigned on first sight).

        Assignment charges the chosen host a nominal placement load (one
        multiply's flops, 864·L⁴) immediately — otherwise a burst of
        first-sight Ls with no traffic in between (``SU3Service.warm``
        pre-building several sizes) would all see every host at zero load
        and pile onto host 0, pinning the whole pool there forever.
        """
        if L not in self._home:
            host = min(range(self.n_hosts), key=self._load.__getitem__)
            self._home[L] = host
            self._load[host] += 864.0 * L**4  # nominal placement charge
        return self._home[L]

    def peek(self, L: int) -> int | None:
        """L's home host, or None if L has never been routed."""
        return self._home.get(L)

    def record_load(self, host: int, flops: float) -> None:
        """Charge admitted work to ``host`` (steers future first-sight Ls)."""
        self._load[host] += flops

    def assignments(self) -> dict[int, int]:
        """Snapshot of the sticky (L -> host) table."""
        return dict(self._home)

    def loads(self) -> list[float]:
        return list(self._load)


@dataclasses.dataclass
class InflightChain:
    """Slot bookkeeping of one continuously-batched chain (one L, one host).

    The chain's lattice batch is dispatched ONE iteration at a time; between
    iterations (`advance`) this object decides who occupies the slots:

      * ``admit`` places a same-L request into a free slot with its own
        remaining-iteration count — mid-chain admission at an iteration
        boundary, the continuous-batching move;
      * a request for a different L is *rejected* (``can_admit`` False):
        its lattice shape is incompatible with the in-flight batch and it
        must queue for its own chain;
      * ``advance`` decrements every live slot and frees the finished ones.

    Array state (the physical lattice batch) lives with the service; this is
    the scheduling half, testable without a device.
    """

    L: int
    slots: int
    iterations_run: int = 0
    _req: list[ServeRequest | None] = dataclasses.field(default_factory=list)
    _remaining: list[int] = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        if self.slots < 1:
            raise ValueError(f"chain needs >= 1 slot, got {self.slots}")
        self._req = [None] * self.slots
        self._remaining = [0] * self.slots

    # -- occupancy -------------------------------------------------------------

    @property
    def live(self) -> int:
        """Slots currently carrying a request."""
        return sum(1 for r in self._req if r is not None)

    @property
    def occupancy(self) -> float:
        return self.live / self.slots

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self._req) if r is None]

    def requests(self) -> list[ServeRequest]:
        return [r for r in self._req if r is not None]

    def occupants(self) -> list[tuple[int, ServeRequest, int]]:
        """Live ``(slot, request, remaining)`` triples (eviction scans)."""
        return [
            (i, r, self._remaining[i])
            for i, r in enumerate(self._req)
            if r is not None
        ]

    # -- admission -------------------------------------------------------------

    def can_admit(self, req: ServeRequest) -> bool:
        """Shape-compatible (same L) and a slot is free."""
        return req.L == self.L and self.live < self.slots

    def admit(self, req: ServeRequest) -> int:
        """Seat ``req`` in a free slot; returns the slot index.

        Raises ValueError on an incompatible lattice size — the caller must
        check :meth:`can_admit` (or catch) and queue the request for its own
        chain instead.
        """
        if req.L != self.L:
            raise ValueError(
                f"request L={req.L} cannot join an in-flight L={self.L} chain "
                f"(incompatible lattice shape); it must wait for its own chain"
            )
        for i, r in enumerate(self._req):
            if r is None:
                self._req[i] = req
                self._remaining[i] = req.k
                return i
        raise ValueError(f"chain L={self.L} is full ({self.slots} slots)")

    @property
    def midchain(self) -> bool:
        """True once the chain has advanced at least one iteration — a later
        admit is a mid-chain admit (the case batch-per-step cannot serve)."""
        return self.iterations_run > 0

    def evict(self, slot: int) -> ServeRequest:
        """Free a LIVE slot mid-chain (deadline eviction / quarantine
        re-seating) and return its request; the freed slot is immediately
        admissible — the same re-seating machinery mid-chain admission
        uses.  A fully-drained chain resets to fresh, exactly as a drain
        through :meth:`advance` does."""
        req = self._req[slot]
        if req is None:
            raise ValueError(f"slot {slot} is not live")
        self._req[slot] = None
        self._remaining[slot] = 0
        if self.live == 0:
            self.iterations_run = 0
        return req

    # -- advancement -----------------------------------------------------------

    def advance(self) -> list[tuple[int, ServeRequest]]:
        """Account one executed iteration; returns [(slot, request)] finished.

        Call AFTER the iteration's dispatch: every live slot consumed one
        multiply; slots reaching zero remaining iterations complete and
        free.  A chain that fully drains resets to fresh (``midchain``
        False): an admit into a retained-but-empty chain is exactly a new
        batch start, not a mid-chain join, and must not be counted as one.
        """
        done: list[tuple[int, ServeRequest]] = []
        for i, r in enumerate(self._req):
            if r is None:
                continue
            self._remaining[i] -= 1
            if self._remaining[i] <= 0:
                done.append((i, r))
                self._req[i] = None
                self._remaining[i] = 0
        self.iterations_run = 0 if self.live == 0 else self.iterations_run + 1
        return done


@dataclasses.dataclass
class SlotTable:
    """Slot bookkeeping of one host's megakernel dispatch table.

    The megakernel generalization of :class:`InflightChain`: ONE table per
    host, slots hold in-flight requests of ANY lattice size (the batched
    K-chain kernel pads every slot to a common site capacity), and one
    dispatch per host per iteration advances every live slot by its own
    scheduled depth.  What was "mid-chain admission" in the per-L chain
    becomes a *slot swap*: seat the request in a free slot, set its
    remaining count — no shape compatibility gate, because the dispatched
    shape is the table's, not the request's.

    Array state (the physical slot-table batch) lives with the service; this
    is the scheduling half, testable without a device.
    """

    slots: int
    iterations_run: int = 0
    _req: list[ServeRequest | None] = dataclasses.field(default_factory=list)
    _remaining: list[int] = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        if self.slots < 1:
            raise ValueError(f"slot table needs >= 1 slot, got {self.slots}")
        self._req = [None] * self.slots
        self._remaining = [0] * self.slots

    # -- occupancy -------------------------------------------------------------

    @property
    def live(self) -> int:
        return sum(1 for r in self._req if r is not None)

    @property
    def occupancy(self) -> float:
        return self.live / self.slots

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self._req) if r is None]

    def requests(self) -> list[ServeRequest]:
        return [r for r in self._req if r is not None]

    def occupants(self) -> list[tuple[int, ServeRequest, int]]:
        """Live ``(slot, request, remaining)`` triples — what a capacity-grow
        re-seats into the replacement table."""
        return [
            (i, r, self._remaining[i])
            for i, r in enumerate(self._req)
            if r is not None
        ]

    @property
    def max_live_L(self) -> int:
        """Largest lattice size seated (0 when empty) — the capacity floor."""
        return max((r.L for r in self._req if r is not None), default=0)

    # -- admission (slot swap) -------------------------------------------------

    def can_admit(self) -> bool:
        return self.live < self.slots

    def admit(self, req: ServeRequest, remaining: int | None = None) -> int:
        """Seat ``req`` in a free slot; returns the slot index.

        Any lattice size is admissible — the megakernel pads every slot to
        the table's site capacity, so there is no shape gate to fail (the
        *capacity* gate lives with the service, which grows the physical
        table when a larger L arrives).
        """
        for i, r in enumerate(self._req):
            if r is None:
                self._req[i] = req
                self._remaining[i] = req.k if remaining is None else remaining
                return i
        raise ValueError(f"slot table is full ({self.slots} slots)")

    @property
    def midchain(self) -> bool:
        """True once the table has advanced at least one iteration with live
        slots — a later admit is a mid-chain slot swap."""
        return self.iterations_run > 0

    def evict(self, slot: int) -> ServeRequest:
        """Free a LIVE slot mid-chain (deadline eviction / quarantine
        re-seating) and return its request — the inverse slot swap of
        :meth:`admit`, leaving the slot immediately admissible.  A table
        drained by evictions resets to fresh like one drained by
        :meth:`advance`."""
        req = self._req[slot]
        if req is None:
            raise ValueError(f"slot {slot} is not live")
        self._req[slot] = None
        self._remaining[slot] = 0
        if self.live == 0:
            self.iterations_run = 0
        return req

    def slot_of(self, req_id: int) -> int | None:
        """The slot seating ``req_id`` (None when not seated)."""
        for i, r in enumerate(self._req):
            if r is not None and r.req_id == req_id:
                return i
        return None

    # -- advancement -----------------------------------------------------------

    def plan_k(self, horizon: int = 1) -> list[int]:
        """Per-slot chain depths for the NEXT megakernel dispatch.

        Each live slot advances ``min(remaining, horizon)`` multiplies; dead
        slots get 0 (the kernel passes them through).  ``horizon`` trades
        admission latency for dispatch amortization: 1 re-opens admission at
        every multiply, larger values chain deeper in-kernel between
        boundaries.
        """
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        return [
            min(self._remaining[i], horizon) if self._req[i] is not None else 0
            for i in range(self.slots)
        ]

    def advance(self, applied: list[int]) -> list[tuple[int, ServeRequest]]:
        """Account one executed dispatch that ran ``applied[i]`` multiplies on
        slot ``i``; returns [(slot, request)] finished.

        Call AFTER the dispatch with the ``plan_k`` schedule that was run.
        A table that fully drains resets to fresh (``midchain`` False).
        """
        if len(applied) != self.slots:
            raise ValueError(f"applied must cover all {self.slots} slots")
        done: list[tuple[int, ServeRequest]] = []
        for i, r in enumerate(self._req):
            if r is None:
                continue
            self._remaining[i] -= applied[i]
            if self._remaining[i] <= 0:
                done.append((i, r))
                self._req[i] = None
                self._remaining[i] = 0
        self.iterations_run = 0 if self.live == 0 else self.iterations_run + 1
        return done

"""Batched serving engine: prefill + decode with greedy/temperature sampling.

Static-batch engine (one prefill, N decode steps) — the serve_step the
decode_* dry-run shapes lower is exactly ``_decode_fn`` here.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0
    cache_dtype: str = "float32"  # bf16 on TPU


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.api = registry.get(cfg)
        self._prefill = jax.jit(
            lambda p, b, s: self.api.prefill(
                p, b, s, cfg,
                q_chunk=min(512, scfg.max_len), kv_chunk=min(1024, scfg.max_len),
            )
        )
        self._decode = jax.jit(
            lambda p, b, s, n: self.api.decode_step(p, b, s, n, cfg)
        )

    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        probs_logits = logits[:, -1].astype(jnp.float32) / self.scfg.temperature
        return jax.random.categorical(key, probs_logits, axis=-1)[:, None].astype(jnp.int32)

    def generate(
        self, prompts: np.ndarray, n_new_tokens: int, extras: dict[str, Any] | None = None
    ) -> np.ndarray:
        """prompts: (B, prompt_len) int32 -> (B, prompt_len + n_new_tokens)."""
        b, plen = prompts.shape
        assert plen + n_new_tokens <= self.scfg.max_len
        state = self.api.init_state(
            self.cfg, b, self.scfg.max_len, jnp.dtype(self.scfg.cache_dtype)
        )
        batch: dict[str, Any] = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extras:
            batch.update(extras)
        logits, state = self._prefill(self.params, batch, state)
        key = jax.random.PRNGKey(self.scfg.seed)
        out = [jnp.asarray(prompts, jnp.int32)]
        key, sub = jax.random.split(key)
        tok = self._sample(logits, sub)
        out.append(tok)
        cur = plen
        for _ in range(n_new_tokens - 1):
            logits, state = self._decode(self.params, {"tokens": tok}, state, jnp.int32(cur))
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
            out.append(tok)
            cur += 1
        return np.asarray(jnp.concatenate(out, axis=1))

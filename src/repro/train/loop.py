"""The training loop: restore-or-init, step, checkpoint, fault hooks.

This is the single-process driver (examples + CPU e2e tests); the
multi-pod launcher composes the same pieces with jax.distributed.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, PipelineState, TokenPipeline, make_train_batch
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.models import registry
from repro.optim import adamw
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    seq_len: int = 256
    global_batch: int = 8
    checkpoint_dir: str | None = None
    checkpoint_every: int = 50
    log_every: int = 10
    seed: int = 0
    microbatches: int = 1
    opt: adamw.AdamWConfig = dataclasses.field(default_factory=adamw.AdamWConfig)


def train(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    *,
    log: Callable[[str], None] = print,
) -> dict[str, Any]:
    api = registry.get(cfg)
    pipe = TokenPipeline(
        DataConfig(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=tcfg.seed)
    )
    params = api.init(jax.random.PRNGKey(tcfg.seed), cfg)
    opt_state = adamw.init(params, tcfg.opt)
    pstate = PipelineState()
    start_step = 0

    ckpt = None
    if tcfg.checkpoint_dir:
        ckpt = CheckpointManager(CheckpointConfig(tcfg.checkpoint_dir))
        if ckpt.latest_step() is not None:
            (params, opt_state), extra, start_step = ckpt.restore((params, opt_state))
            pstate = PipelineState(step=int(extra.get("pipeline_step", start_step)))
            log(f"restored checkpoint at step {start_step}")

    step_fn = jax.jit(
        make_train_step(cfg, tcfg.opt, microbatches=tcfg.microbatches,
                        q_chunk=min(512, tcfg.seq_len), kv_chunk=min(1024, tcfg.seq_len)),
        donate_argnums=(0, 1),
    )
    monitor = HeartbeatMonitor(["host0"])
    losses: list[float] = []
    t_last = time.perf_counter()
    for step in range(start_step, tcfg.steps):
        batch, pstate = make_train_batch(pipe, pstate, cfg)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % tcfg.log_every == 0 or step == tcfg.steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            now = time.perf_counter()
            monitor.beat("host0", step_time_s=(now - t_last) / tcfg.log_every)
            t_last = now
            log(f"step {step + 1:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} lr {float(metrics['lr']):.2e}")
        if ckpt and (step + 1) % tcfg.checkpoint_every == 0:
            ckpt.save(step + 1, (params, opt_state), {"pipeline_step": pstate.step})
    if ckpt:
        ckpt.save(tcfg.steps, (params, opt_state), {"pipeline_step": pstate.step})
        ckpt.wait()
    return {"params": params, "losses": losses, "final_loss": losses[-1] if losses else None}

"""Training step factory: loss + grad (+ microbatched accumulation) + AdamW.

Gradient accumulation runs as a lax.scan over microbatches — each microbatch
re-runs the remat'd forward/backward and adds into the (param-sharded) grad
buffer. This bounds activation memory to one microbatch and is the overlap
unit for the latency-hiding scheduler (grad all-reduces of microbatch k
overlap with compute of k+1 under XLA's scheduler on TPU).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.optim import adamw


def make_loss_fn(cfg: ModelConfig, **loss_kwargs) -> Callable[..., Any]:
    api = registry.get(cfg)

    def loss_fn(params: Any, batch: dict[str, jax.Array]):
        return api.loss_fn(params, batch, cfg, **loss_kwargs)

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.AdamWConfig,
    *,
    microbatches: int = 1,
    grad_acc_dtype: str = "float32",
    param_shardings: Any = None,
    **loss_kwargs,
) -> Callable[..., Any]:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``param_shardings``: when given, the gradient accumulator is pinned to
    the param shardings — without this XLA's propagation through the
    microbatch scan can replicate the f32 accumulator and reduce gradients
    with a full-tensor all-reduce instead of a sharded reduce-scatter
    (observed: 4.6 TB/device/step of all-reduce on the 671B train cell).
    ``grad_acc_dtype``: bf16 halves both accumulator HBM and reduction wire
    bytes (error-feedback-free: acceptable at 8-16 microbatches, recorded
    as a §Perf tradeoff).
    """
    loss_fn = make_loss_fn(cfg, **loss_kwargs)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    acc_dt = jnp.dtype(grad_acc_dtype)

    def _pin(tree: Any) -> Any:
        if param_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, param_shardings)

    def train_step(params: Any, opt_state: dict[str, Any], batch: dict[str, jax.Array]):
        if microbatches == 1:
            (_, metrics), grads = grad_fn(params, batch)
            grads = _pin(grads)
        else:
            # (B, ...) -> (k, B/k, ...) and scan-accumulate
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc(carry, b):
                g_acc, m_acc = carry
                (_, metrics), g = grad_fn(params, b)
                # Pin g BEFORE the add: converts the partial (unreduced)
                # per-device grads into the FSDP layout via reduce-scatter;
                # without it SPMD all-reduces the full tensors then slices
                # (2x the link bytes — 2.67 TB/step on the 671B cell).
                g = _pin(g)
                g_acc = _pin(jax.tree.map(lambda a, x: a + x.astype(a.dtype), g_acc, g))
                m_acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = _pin(jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params))
            # probe the metric tree structure so the accumulator matches
            metric_shapes = jax.eval_shape(
                lambda p, b: grad_fn(p, b)[0][1], params, jax.tree.map(lambda x: x[0], mb)
            )
            m0 = jax.tree.map(lambda _: jnp.zeros((), jnp.float32), metric_shapes)
            (grads, msum), _ = jax.lax.scan(acc, (g0, m0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, msum)
        new_params, new_opt, om = adamw.update(grads, opt_state, params, opt_cfg)
        metrics = dict(metrics)
        metrics.update(om)
        return new_params, new_opt, metrics

    return train_step

"""Shared model machinery: param specs (single source of truth for shapes,
logical sharding axes, and init), norms, RoPE, embeddings, losses.

Every module defines a ``spec(cfg) -> {name: ParamSpec | nested dict}``;
``init_params`` materializes arrays (smoke tests / real training) while
``shape_tree`` yields ShapeDtypeStructs (dry-run — no allocation) and
``axes_tree`` yields the logical-axis tuples the sharding resolver consumes.
Keeping all three derived from one spec eliminates drift between init,
sharding, and dry-run paths.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

# Logical axis names (resolved to mesh axes in distributed/sharding.py):
#   embed   - d_model dim of params (FSDP target)
#   vocab   - vocabulary dim (TP)
#   heads   - query-head dim (TP)
#   kv_heads- kv-head dim (TP when divisible, else replicated)
#   mlp     - FFN hidden dim (TP)
#   experts - MoE expert dim (EP)
#   layers  - scan-stacked layer dim (never sharded)
#   qkv/head_dim/state/conv/latent/... - small dims, replicated


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float | None = None  # None -> 1/sqrt(fan_in)

    def __post_init__(self) -> None:
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


SpecTree = dict[str, Any]  # nested dicts of ParamSpec


def _is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def init_params(spec: SpecTree, key: jax.Array, dtype: Any = jnp.float32) -> Any:
    leaves, treedef = jax.tree.flatten(spec, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dtype))
        else:
            fan_in = s.shape[0] if len(s.shape) > 1 else max(s.shape[0], 1)
            if s.init == "embed":
                scale = s.scale if s.scale is not None else 1.0
            else:
                scale = s.scale if s.scale is not None else 1.0 / math.sqrt(fan_in)
            out.append(scale * jax.random.normal(k, s.shape, dtype))
    return jax.tree.unflatten(treedef, out)


def shape_tree(spec: SpecTree, dtype: Any = jnp.bfloat16) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), spec, is_leaf=_is_spec
    )


def axes_tree(spec: SpecTree) -> Any:
    return jax.tree.map(lambda s: s.axes, spec, is_leaf=_is_spec)


def stack_specs(spec: SpecTree, n: int) -> SpecTree:
    """Prefix every param with a scan-stacked 'layers' dim."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        spec,
        is_leaf=_is_spec,
    )


def count_params(tree: Any) -> int:
    return sum(
        int(jnp.size(x)) if hasattr(x, "size") else int(jnp.prod(jnp.array(x.shape)))
        for x in jax.tree.leaves(tree)
    )


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


import functools as _functools


@jax.custom_vjp
def grad_safe_barrier(x: jax.Array) -> jax.Array:
    """``optimization_barrier`` usable under autodiff on every jax we run.

    jax 0.4.x has no differentiation rule for the primitive; this custom VJP
    applies the barrier to the primal on the forward pass and to the
    cotangent on the backward pass (which is also the semantically right
    pin — both directions of the residual stream stay per-layer).
    """
    return jax.lax.optimization_barrier(x)


def _gsb_fwd(x: jax.Array):
    return jax.lax.optimization_barrier(x), None


def _gsb_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


grad_safe_barrier.defvjp(_gsb_fwd, _gsb_bwd)


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 stats but NO materialized f32 copy of x.

    Custom VJP: with the standard autodiff rule, the backward pass promotes
    the (layer-stacked, remat-saved) bf16 residual `x` to f32 inside the
    backward layer scan, and XLA hoists that promotion out of the loop as a
    full fp32 copy of the residual stack (+22 GiB/device observed on a
    36-layer 4k cell). The custom bwd puts an optimization_barrier on the
    per-layer residual slice so the upcast cannot be hoisted stack-wide.
    """
    out, _ = _rmsnorm_fwd(x, w, eps)
    return out


def _rmsnorm_fwd(x: jax.Array, w: jax.Array, eps: float):
    var = (
        jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)
        / x.shape[-1]
    )
    inv32 = jax.lax.rsqrt(var + eps)  # (...,) f32 row stats
    out = (x * inv32[..., None].astype(x.dtype)) * w.astype(x.dtype)
    return out, (x, inv32, w)


def _rmsnorm_bwd(eps: float, res, g: jax.Array):
    x, inv32, w = res
    x = jax.lax.optimization_barrier(x)  # pin: no stack-wide f32 hoist
    d = x.shape[-1]
    gw = g.astype(jnp.float32) * w.astype(jnp.float32)  # (..., d)
    s = jnp.sum(gw * x.astype(jnp.float32), axis=-1)  # (...,)
    inv = inv32[..., None]
    dx = (gw * inv - x.astype(jnp.float32) * (inv**3) * (s / d)[..., None]).astype(x.dtype)
    dw_full = g.astype(jnp.float32) * x.astype(jnp.float32) * inv
    dw = jnp.sum(dw_full.reshape(-1, d), axis=0).astype(w.dtype)
    return dx, dw


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, d/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softmax_cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Mean token NLL; logits (..., vocab) computed in fp32.

    The gold logit is extracted with an iota-compare-reduce rather than
    ``take_along_axis``: a gather over the vocab axis forces SPMD to
    all-gather the (tokens, vocab) fp32 logits when vocab is TP-sharded
    (tens of GB/device for 150k-vocab models); the masked reduction stays
    local to each vocab shard and fuses into one pass.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    v_idx = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(jnp.where(v_idx == labels[..., None], lf, 0.0), axis=-1)
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def embed_lookup(embedding: jax.Array, tokens: jax.Array) -> jax.Array:
    """Token embedding via one-hot matmul when vocab is TP-sharded would
    be wasteful; gather is fine — XLA partitions it over the vocab dim."""
    return jnp.take(embedding, tokens, axis=0)

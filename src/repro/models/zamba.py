"""Zamba2-style hybrid: Mamba2 backbone with a single *shared* attention
block applied every k layers (weight sharing across applications — the
Zamba/Zamba2 signature). Each application keeps its own KV cache.

Decode state:
  {"mamba": stacked mamba2 states (L, ...),
   "attn":  stacked KV caches (n_apps, B, S, kv, hd)}
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import shard
from repro.models import attention, common, ffn, mamba2
from repro.models.common import ParamSpec


def _counts(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, group_size, n_tail). layers = n_groups*k + tail."""
    k = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // k
    return n_groups, k, cfg.n_layers - n_groups * k


def mamba_layer_spec(cfg: ModelConfig) -> common.SpecTree:
    return {
        "norm": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "mixer": mamba2.spec(cfg),
    }


def shared_block_spec(cfg: ModelConfig) -> common.SpecTree:
    d = cfg.d_model
    return {
        "attn_norm": ParamSpec((d,), ("embed",), init="ones"),
        "attn": attention.spec(cfg),
        "ffn_norm": ParamSpec((d,), ("embed",), init="ones"),
        "ffn": ffn.spec(cfg),
    }


def spec(cfg: ModelConfig) -> common.SpecTree:
    d, v = cfg.d_model, cfg.vocab_size
    return {
        "embed": ParamSpec((v, d), ("vocab", "embed"), init="embed", scale=0.02),
        "mamba_layers": common.stack_specs(mamba_layer_spec(cfg), cfg.n_layers),
        "shared_attn": shared_block_spec(cfg),  # ONE param set, many applications
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
        "lm_head": ParamSpec((d, v), ("embed", "vocab"), scale=0.02),
    }


def init(key: jax.Array, cfg: ModelConfig, dtype: Any = jnp.float32) -> Any:
    return common.init_params(spec(cfg), key, dtype)


def _mamba_block(lp: Any, x: jax.Array, cfg: ModelConfig, state: Any = None):
    x = shard(x, "btd")
    h = common.rmsnorm(x, lp["norm"], cfg.norm_eps)
    y, new_state = mamba2.apply(lp["mixer"], h, cfg, state=state)
    return shard(x + y, "btd"), new_state


def _shared_block(
    sp: Any, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
    cache: Any = None, cur_len: jax.Array | None = None,
):
    h = common.rmsnorm(x, sp["attn_norm"], cfg.norm_eps)
    a, new_cache = attention.apply(
        sp["attn"], h, cfg, positions=positions, cache=cache, cur_len=cur_len
    )
    x = x + a
    h = common.rmsnorm(x, sp["ffn_norm"], cfg.norm_eps)
    return x + ffn.apply(sp["ffn"], h), new_cache


def _slice_layers(params: Any, start: int, n: int) -> Any:
    return jax.tree.map(lambda p: jax.lax.slice_in_dim(p, start, start + n, axis=0), params)


def forward(
    params: Any,
    batch: dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    state: Any = None,
    cur_len: jax.Array | None = None,
    remat: bool = False,
) -> tuple[jax.Array, Any]:
    b, s = batch["tokens"].shape
    positions = (
        jnp.broadcast_to(jnp.arange(s), (b, s))
        if cur_len is None
        else jnp.broadcast_to(cur_len + jnp.arange(s), (b, s))
    )
    x = shard(
        common.embed_lookup(params["embed"], batch["tokens"]).astype(jnp.dtype(cfg.dtype)),
        "btd",
    )
    n_groups, k, tail = _counts(cfg)

    def mamba_scan(stack, x, states):
        def body(carry, layer_in):
            xc = carry
            lp, st = layer_in
            y, new_st = _mamba_block(lp, xc, cfg, state=st)
            return y, new_st

        if remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        return jax.lax.scan(body, x, (stack, states))

    mamba_states = state["mamba"] if state is not None else None
    new_mamba, new_attn = [], []
    for g in range(n_groups):
        stack = _slice_layers(params["mamba_layers"], g * k, k)
        states = _slice_layers(mamba_states, g * k, k) if state is not None else None
        x, ns = mamba_scan(stack, x, states)
        new_mamba.append(ns)
        cache = (
            jax.tree.map(lambda c: c[g], state["attn"]) if state is not None else None
        )
        x, nc = _shared_block(
            params["shared_attn"], x, cfg, positions, cache=cache, cur_len=cur_len
        )
        new_attn.append(nc)
    if tail:
        stack = _slice_layers(params["mamba_layers"], n_groups * k, tail)
        states = (
            _slice_layers(mamba_states, n_groups * k, tail) if state is not None else None
        )
        x, ns = mamba_scan(stack, x, states)
        new_mamba.append(ns)

    new_state = None
    if state is not None:
        new_state = {
            "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_mamba),
            "attn": jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *new_attn),
        }
    return x, new_state


def _logits(params: Any, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = common.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return shard(jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(h.dtype)), "btv")


def loss_fn(params: Any, batch: dict[str, jax.Array], cfg: ModelConfig, *, remat: bool = True, **_):
    x, _ = forward(params, batch, cfg, remat=remat)
    logits = _logits(params, x, cfg)
    loss = common.softmax_cross_entropy(logits, batch["labels"])
    return loss, {"nll": loss, "loss": loss}


def state_spec(cfg: ModelConfig, batch: int, max_len: int, dtype: Any = jnp.bfloat16) -> Any:
    n_groups, _, _ = _counts(cfg)
    kv_len = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
    m = mamba2.state_spec(cfg, batch)
    c = attention.cache_spec(cfg, batch, kv_len, dtype)
    return {
        "mamba": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype), m
        ),
        "attn": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_groups,) + s.shape, s.dtype), c
        ),
    }


def init_state(cfg: ModelConfig, batch: int, max_len: int, dtype: Any = jnp.bfloat16) -> Any:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), state_spec(cfg, batch, max_len, dtype)
    )


def prefill(params: Any, batch: dict[str, jax.Array], state: Any, cfg: ModelConfig, **_):
    cur = jnp.zeros((), jnp.int32)
    x, new_state = forward(params, batch, cfg, state=state, cur_len=cur)
    return _logits(params, x[:, -1:], cfg), new_state


def decode_step(params: Any, batch: dict[str, jax.Array], state: Any, cur_len: jax.Array, cfg: ModelConfig):
    x, new_state = forward(params, batch, cfg, state=state, cur_len=cur_len)
    return _logits(params, x, cfg), new_state

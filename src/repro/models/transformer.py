"""Decoder-only LM assembly with scan-over-layers.

Covers the dense / moe / vlm families (GQA or MLA attention, dense or MoE
FFN, optional patch-embedding injection and multi-token-prediction heads).
Layers are parameter-stacked and driven by lax.scan so compile time is O(1)
in depth (88-layer granite-34b compiles the same HLO as a 4-layer smoke).

API (uniform across families via models.registry):
  spec(cfg) / init(key, cfg)            params
  loss_fn(params, batch, cfg)           train forward -> (loss, metrics)
  prefill(params, batch, cfg)           -> (logits, state)
  decode_step(params, batch, state, cfg)-> (logits, state)
  state_spec(cfg, batch, max_len)       decode-state ShapeDtypeStructs
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import shard
from repro.models import attention, common, ffn, mla, moe
from repro.models.common import ParamSpec

# §Perf A2 knob — see _scan_stack. Flip via transformer.CACHE_IN_CARRY.
CACHE_IN_CARRY = False


# ---------------------------------------------------------------------------
# Layer spec/apply
# ---------------------------------------------------------------------------


def _attn_spec(cfg: ModelConfig) -> common.SpecTree:
    return mla.spec(cfg) if cfg.use_mla else attention.spec(cfg)


def layer_spec(cfg: ModelConfig, *, moe_layer: bool) -> common.SpecTree:
    d = cfg.d_model
    s: common.SpecTree = {
        "attn_norm": ParamSpec((d,), ("embed",), init="ones"),
        "attn": _attn_spec(cfg),
        "ffn_norm": ParamSpec((d,), ("embed",), init="ones"),
    }
    if moe_layer:
        s["moe"] = moe.spec(cfg)
    else:
        s["ffn"] = ffn.spec(cfg)
    return s


def layer_apply(
    params: Any,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    moe_layer: bool,
    cache: Any = None,
    cur_len: jax.Array | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> tuple[jax.Array, Any, jax.Array]:
    """Pre-norm block. Returns (x, new_cache, aux_loss)."""
    x = shard(x, "btd")
    h = common.rmsnorm(x, params["attn_norm"], cfg.norm_eps)
    attn_mod = mla if cfg.use_mla else attention
    a, new_cache = attn_mod.apply(
        params["attn"], h, cfg, positions=positions, cache=cache, cur_len=cur_len,
        q_chunk=q_chunk, kv_chunk=kv_chunk,
    )
    x = shard(x + a, "btd")
    h = common.rmsnorm(x, params["ffn_norm"], cfg.norm_eps)
    if moe_layer:
        f, aux = moe.apply(params["moe"], h, cfg)
    else:
        f = ffn.apply(params["ffn"], h)
        aux = jnp.zeros((), jnp.float32)
    return shard(x + f, "btd"), new_cache, aux


# ---------------------------------------------------------------------------
# Model spec
# ---------------------------------------------------------------------------


def _layer_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(n_dense_scan, n_moe_scan). Non-MoE models: all layers in dense scan."""
    if cfg.is_moe:
        return cfg.n_dense_layers, cfg.n_layers - cfg.n_dense_layers
    return cfg.n_layers, 0


def spec(cfg: ModelConfig) -> common.SpecTree:
    d, v = cfg.d_model, cfg.vocab_size
    n_dense, n_moe = _layer_counts(cfg)
    s: common.SpecTree = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), init="embed", scale=0.02),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
    }
    if n_dense:
        s["layers"] = common.stack_specs(layer_spec(cfg, moe_layer=False), n_dense)
    if n_moe:
        s["moe_layers"] = common.stack_specs(layer_spec(cfg, moe_layer=True), n_moe)
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((d, v), ("embed", "vocab"), scale=0.02)
    if cfg.mtp_depth:
        s["mtp"] = {
            "proj": ParamSpec((2 * d, d), ("embed", None)),
            "norm_h": ParamSpec((d,), ("embed",), init="ones"),
            "norm_e": ParamSpec((d,), ("embed",), init="ones"),
            "layer": layer_spec(cfg, moe_layer=False),
        }
    return s


def init(key: jax.Array, cfg: ModelConfig, dtype: Any = jnp.float32) -> Any:
    return common.init_params(spec(cfg), key, dtype)


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------


def _scan_stack(
    stack_params: Any,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    moe_layer: bool,
    caches: Any = None,
    cur_len: jax.Array | None = None,
    remat: bool = False,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> tuple[jax.Array, Any, jax.Array]:
    """Scan x through a stacked-parameter layer stack."""

    if caches is not None and CACHE_IN_CARRY:
        # OPTIONAL serve-path variant (§Perf A2): thread the FULL cache
        # stack through the carry and dynamic-update each layer's slice.
        # Measured: -54% XLA allocation (8.19 -> 3.76 GiB/dev on qwen3
        # decode_32k) because the stacked-ys buffer + its copies vanish;
        # BUT the CPU pipeline then inserts per-ITERATION defensive copies
        # of the carried stack (aliasing analysis fails on read-then-write
        # at a dynamic index), so HLO-level traffic is worse on this host.
        # On TPU the carry+DUS pattern is the production one (MaxText);
        # default stays OFF until validated on hardware.
        def body_c(carry, lp):
            xc, aux_acc, cstack, idx = carry
            lcache = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0, keepdims=False),
                cstack,
            )
            y, new_lcache, aux = layer_apply(
                lp, xc, cfg, positions=positions, moe_layer=moe_layer,
                cache=lcache, cur_len=cur_len, q_chunk=q_chunk, kv_chunk=kv_chunk,
            )
            cstack = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), idx, 0
                ),
                cstack, new_lcache,
            )
            return (y, aux_acc + aux, cstack, idx + 1), None

        (x, aux, new_caches, _), _ = jax.lax.scan(
            body_c,
            (x, jnp.zeros((), jnp.float32), caches, jnp.zeros((), jnp.int32)),
            stack_params,
        )
        return x, new_caches, aux

    def body(carry, layer_in):
        xc, aux_acc = carry
        lp, lcache = layer_in
        # Barrier: stops XLA hoisting the f32 upcast of the residual slice
        # out of the backward scan as a full-stack fp32 copy (observed:
        # +22 GiB/device on the qwen3 train cell without it).
        xc = common.grad_safe_barrier(xc)
        y, new_cache, aux = layer_apply(
            lp, xc, cfg, positions=positions, moe_layer=moe_layer,
            cache=lcache, cur_len=cur_len, q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        return (y, aux_acc + aux), new_cache

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stack_params, caches)
    )
    return x, new_caches, aux


def _embed_inputs(params: Any, batch: dict[str, jax.Array], cfg: ModelConfig) -> jax.Array:
    x = common.embed_lookup(params["embed"], batch["tokens"]).astype(jnp.dtype(cfg.dtype))
    if cfg.n_patches and "patches" in batch:
        # VLM stub frontend: precomputed patch embeddings replace the first
        # n_patches sequence positions (input_specs provides them).
        p = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([p, x[:, cfg.n_patches :]], axis=1)
    return x


def _logits(params: Any, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = common.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return shard(jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype)), "btv")


def forward(
    params: Any,
    batch: dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    state: Any = None,
    cur_len: jax.Array | None = None,
    remat: bool = False,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> tuple[jax.Array, Any, jax.Array]:
    """Returns (hidden (B,S,d), new_state, aux)."""
    b, s = batch["tokens"].shape
    if cur_len is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    else:
        positions = jnp.broadcast_to(cur_len + jnp.arange(s), (b, s))
    x = shard(_embed_inputs(params, batch, cfg), "btd")
    n_dense, n_moe = _layer_counts(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_state: dict[str, Any] = {}
    if n_dense:
        caches = state["dense"] if state is not None else None
        x, nc, aux = _scan_stack(
            params["layers"], x, cfg, positions=positions, moe_layer=False,
            caches=caches, cur_len=cur_len, remat=remat, q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        aux_total += aux
        new_state["dense"] = nc
    if n_moe:
        caches = state["moe"] if state is not None else None
        x, nc, aux = _scan_stack(
            params["moe_layers"], x, cfg, positions=positions, moe_layer=True,
            caches=caches, cur_len=cur_len, remat=remat, q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        aux_total += aux
        new_state["moe"] = nc
    return x, (new_state if state is not None else None), aux_total


# ---------------------------------------------------------------------------
# Train / serve entry points
# ---------------------------------------------------------------------------


def loss_fn(
    params: Any,
    batch: dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    remat: bool = True,
    aux_weight: float = 0.01,
    mtp_weight: float = 0.3,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    x, _, aux = forward(params, batch, cfg, remat=remat, q_chunk=q_chunk, kv_chunk=kv_chunk)
    logits = _logits(params, x, cfg)
    loss = common.softmax_cross_entropy(logits, batch["labels"])
    metrics = {"nll": loss, "aux": aux}
    total = loss + aux_weight * aux
    if cfg.mtp_depth and "labels2" in batch:
        # DeepSeek-V3 MTP: predict t+2 from h_t and embed(label_t (=token t+1)).
        m = params["mtp"]
        e_next = common.embed_lookup(params["embed"], batch["labels"]).astype(x.dtype)
        h_in = jnp.concatenate(
            [common.rmsnorm(x, m["norm_h"], cfg.norm_eps),
             common.rmsnorm(e_next, m["norm_e"], cfg.norm_eps)],
            axis=-1,
        )
        h_in = jnp.einsum("bse,ed->bsd", h_in, m["proj"].astype(x.dtype))
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        h_mtp, _, _ = (
            layer_apply(m["layer"], h_in, cfg, positions=positions, moe_layer=False,
                        q_chunk=q_chunk, kv_chunk=kv_chunk)
        )
        mtp_logits = _logits(params, h_mtp, cfg)
        mtp_loss = common.softmax_cross_entropy(mtp_logits, batch["labels2"])
        metrics["mtp_nll"] = mtp_loss
        total = total + mtp_weight * mtp_loss
    metrics["loss"] = total
    return total, metrics


def state_spec(cfg: ModelConfig, batch: int, max_len: int, dtype: Any = jnp.bfloat16) -> Any:
    n_dense, n_moe = _layer_counts(cfg)
    mod = mla if cfg.use_mla else attention
    out: dict[str, Any] = {}

    def stacked(n: int) -> Any:
        per = mod.cache_spec(cfg, batch, max_len, dtype)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), per
        )

    if n_dense:
        out["dense"] = stacked(n_dense)
    if n_moe:
        out["moe"] = stacked(n_moe)
    return out


def init_state(cfg: ModelConfig, batch: int, max_len: int, dtype: Any = jnp.bfloat16) -> Any:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), state_spec(cfg, batch, max_len, dtype)
    )


def prefill(
    params: Any, batch: dict[str, jax.Array], state: Any, cfg: ModelConfig,
    *, q_chunk: int = 512, kv_chunk: int = 1024,
) -> tuple[jax.Array, Any]:
    """Prefill writes the cache and returns last-position logits.

    MLA note: prefill uses the decompressed flash path; the latent cache is
    produced by projecting the prefix once (decode then uses absorbed path).
    """
    b, s = batch["tokens"].shape
    if cfg.use_mla:
        # run forward cache-less, then write latent caches per layer via scan
        x, _, _ = forward(params, batch, cfg, q_chunk=q_chunk, kv_chunk=kv_chunk)
        logits = _logits(params, x[:, -1:], cfg)
        new_state = _mla_prefill_cache(params, batch, state, cfg)
        return logits, new_state
    cur = jnp.zeros((), jnp.int32)
    x, new_state, _ = forward(
        params, batch, cfg, state=state, cur_len=cur, q_chunk=q_chunk, kv_chunk=kv_chunk
    )
    logits = _logits(params, x[:, -1:], cfg)
    return logits, new_state


def _mla_prefill_cache(params: Any, batch: dict[str, jax.Array], state: Any, cfg: ModelConfig) -> Any:
    """Recompute per-layer latents to fill the MLA cache (prefill path)."""
    b, s = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = shard(_embed_inputs(params, batch, cfg), "btd")
    n_dense, n_moe = _layer_counts(cfg)
    new_state = {}
    for key, stack_key, is_moe in (("dense", "layers", False), ("moe", "moe_layers", True)):
        n = n_dense if key == "dense" else n_moe
        if not n:
            continue

        def body(carry, layer_in):
            xc = carry
            lp, lcache = layer_in
            h = common.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
            c, k_rope = mla._kv_latent(lp["attn"], h, cfg, positions)
            lcache = {
                "ckv": jax.lax.dynamic_update_slice(
                    lcache["ckv"], c.astype(lcache["ckv"].dtype), (0, 0, 0)
                ),
                "k_rope": jax.lax.dynamic_update_slice(
                    lcache["k_rope"], k_rope.astype(lcache["k_rope"].dtype), (0, 0, 0)
                ),
            }
            y, _, _ = layer_apply(lp, xc, cfg, positions=positions, moe_layer=is_moe)
            return y, lcache

        x, nc = jax.lax.scan(body, x, (params[stack_key], state[key]))
        new_state[key] = nc
    return new_state


def decode_step(
    params: Any,
    batch: dict[str, jax.Array],
    state: Any,
    cur_len: jax.Array,
    cfg: ModelConfig,
) -> tuple[jax.Array, Any]:
    """One-token decode: batch['tokens'] is (B, 1)."""
    x, new_state, _ = forward(params, batch, cfg, state=state, cur_len=cur_len)
    return _logits(params, x, cfg), new_state

"""xLSTM LM stack: mLSTM blocks with sLSTM blocks at cfg.slstm_layers.

Heterogeneous 12-layer stack -> plain python loop over per-layer param dicts
(compile-time cost is fine at this depth; the homogeneous-scan machinery in
transformer.py is for the 48-88 layer archs).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import shard
from repro.models import common, xlstm
from repro.models.common import ParamSpec


def _is_slstm(cfg: ModelConfig, i: int) -> bool:
    return i in cfg.slstm_layers


def spec(cfg: ModelConfig) -> common.SpecTree:
    d, v = cfg.d_model, cfg.vocab_size
    blocks = []
    for i in range(cfg.n_layers):
        cell = xlstm.slstm_spec(cfg) if _is_slstm(cfg, i) else xlstm.mlstm_spec(cfg)
        blocks.append({"norm": ParamSpec((d,), ("embed",), init="ones"), "cell": cell})
    return {
        "embed": ParamSpec((v, d), ("vocab", "embed"), init="embed", scale=0.02),
        "blocks": blocks,
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
        "lm_head": ParamSpec((d, v), ("embed", "vocab"), scale=0.02),
    }


def init(key: jax.Array, cfg: ModelConfig, dtype: Any = jnp.float32) -> Any:
    return common.init_params(spec(cfg), key, dtype)


def forward(
    params: Any,
    batch: dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    state: Any = None,
    remat: bool = False,
) -> tuple[jax.Array, Any]:
    x = shard(
        common.embed_lookup(params["embed"], batch["tokens"]).astype(jnp.dtype(cfg.dtype)),
        "btd",
    )
    new_states = []
    for i, bp in enumerate(params["blocks"]):
        apply = xlstm.slstm_apply if _is_slstm(cfg, i) else xlstm.mlstm_apply
        st = state[i] if state is not None else None

        def block(bp, x, st, apply=apply):
            x = shard(x, "btd")
            h = common.rmsnorm(x, bp["norm"], cfg.norm_eps)
            y, new_st = apply(bp["cell"], h, cfg, state=st)
            return shard(x + y, "btd"), new_st

        if remat:
            block = jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable)
        x, new_st = block(bp, x, st)
        new_states.append(new_st)
    return x, (new_states if state is not None else None)


def _logits(params: Any, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = common.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return shard(jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(h.dtype)), "btv")


def loss_fn(params: Any, batch: dict[str, jax.Array], cfg: ModelConfig, *, remat: bool = True, **_):
    x, _ = forward(params, batch, cfg, remat=remat)
    loss = common.softmax_cross_entropy(_logits(params, x, cfg), batch["labels"])
    return loss, {"nll": loss, "loss": loss}


def state_spec(cfg: ModelConfig, batch: int, max_len: int = 0, dtype: Any = jnp.float32) -> Any:
    out = []
    for i in range(cfg.n_layers):
        if _is_slstm(cfg, i):
            out.append(xlstm.slstm_state_spec(cfg, batch, dtype))
        else:
            out.append(xlstm.mlstm_state_spec(cfg, batch, dtype))
    return out


def init_state(cfg: ModelConfig, batch: int, max_len: int = 0, dtype: Any = jnp.float32) -> Any:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), state_spec(cfg, batch, max_len, dtype)
    )


def prefill(params: Any, batch: dict[str, jax.Array], state: Any, cfg: ModelConfig, **_):
    x, new_state = forward(params, batch, cfg, state=state)
    return _logits(params, x[:, -1:], cfg), new_state


def decode_step(params: Any, batch: dict[str, jax.Array], state: Any, cur_len: jax.Array, cfg: ModelConfig):
    x, new_state = forward(params, batch, cfg, state=state)
    return _logits(params, x, cfg), new_state

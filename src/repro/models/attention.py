"""GQA/MQA attention with chunked-flash prefill and KV-cache decode.

Pure-JAX reference formulation (this is what the multi-pod dry-run lowers;
Pallas flash kernels in kernels/ are selected on real TPU backends). The
chunked path is a lax.scan-over-(q-chunks, kv-chunks) online-softmax — a
flash-attention schedule expressed in HLO, so 32k prefill never materializes
an (S, S) score matrix.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import shard
from repro.models import common
from repro.models.common import ParamSpec

NEG_INF = -1e30


def spec(cfg: ModelConfig) -> common.SpecTree:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s: common.SpecTree = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", None)),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": ParamSpec((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        s["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return s


def _project_qkv(
    params: Any, x: jax.Array, cfg: ModelConfig, positions: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    q = shard(jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype)), "bthd")
    k = shard(jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype)), "bthd")
    v = shard(jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype)), "bthd")
    if cfg.qk_norm:
        q = common.rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = common.rmsnorm(k, params["k_norm"], cfg.norm_eps)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Online-softmax chunked attention. q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]  # may differ from d (e.g. MLA)
    g = hq // hkv
    sq_orig, skv_orig = sq, skv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    if sq % q_chunk:  # pad ragged lengths; padded keys masked out below
        pad = (-sq) % q_chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        sq += pad
    if skv % kv_chunk:
        pad = (-skv) % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        skv += pad
    nq, nk = sq // q_chunk, skv // kv_chunk
    scale = d**-0.5

    # (nq, B, cq, Hkv, G, D)
    qc = q.reshape(b, nq, q_chunk, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(b, nk, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, kv_chunk, hkv, dv).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        qf = qi.astype(jnp.float32) * scale
        q_pos = iq * q_chunk + jnp.arange(q_chunk) + q_offset  # absolute q pos

        def kv_step(carry, kv_and_idx):
            acc, m, l = carry
            ki, vi, ik = kv_and_idx
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, ki.astype(jnp.float32))
            # Additive (cq, ck) f32 penalty instead of a broadcast boolean
            # mask: XLA (CPU especially) hoists loop-invariant predicates out
            # of the kv scan as stacked pred[...] buffers at the full
            # (b,h,g,cq,ck) shape — hundreds of MB of dead weight. A small
            # 2-D penalty added to the scores fuses cleanly.
            k_pos = ik * kv_chunk + jnp.arange(kv_chunk)
            penalty = jnp.zeros((q_chunk, kv_chunk), jnp.float32)
            if causal:
                penalty = jnp.where(k_pos[None, :] <= q_pos[:, None], 0.0, NEG_INF)
            if skv != skv_orig:
                penalty = penalty + jnp.where(k_pos[None, :] < skv_orig, 0.0, NEG_INF)
            s = s + penalty[None, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vi.astype(jnp.float32)
            )
            return (acc_new, m_new, l_new), None

        acc0 = shard(jnp.zeros((b, hkv, g, q_chunk, dv), jnp.float32), "bhgqd")
        m0 = shard(jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32), "bhgq")
        l0 = shard(jnp.zeros((b, hkv, g, q_chunk), jnp.float32), "bhgq")
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), (kc, vc, jnp.arange(nk))
        )
        out = acc / jnp.maximum(l[..., None], 1e-37)  # (b,hkv,g,cq,d)
        return None, shard(out.transpose(0, 3, 1, 2, 4), "bqhgd")  # (b,cq,hkv,g,d)

    _, outs = jax.lax.scan(q_step, None, (qc, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, dv)
    return out[:, :sq_orig].astype(q.dtype)


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, cur_len: jax.Array
) -> jax.Array:
    """Single-step decode: q (B,1,Hq,D) against cache (B,S,Hkv,D)."""
    b, _, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = hq // hkv
    qf = q.reshape(b, hkv, g, d).astype(jnp.float32) * d**-0.5
    logits = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    valid = jnp.arange(s)[None, None, None, :] < cur_len
    logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype: Any = jnp.bfloat16
) -> dict[str, jax.Array]:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def cache_spec(
    cfg: ModelConfig, batch: int, max_len: int, dtype: Any = jnp.bfloat16
) -> dict[str, jax.ShapeDtypeStruct]:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    shp = (batch, max_len, kv, hd)
    return {
        "k": jax.ShapeDtypeStruct(shp, dtype),
        "v": jax.ShapeDtypeStruct(shp, dtype),
    }


def apply(
    params: Any,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    cache: dict[str, jax.Array] | None = None,
    cur_len: jax.Array | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> tuple[jax.Array, dict[str, jax.Array] | None]:
    """Self-attention. If ``cache`` is given, runs one decode step (Sq==1 or
    prefill-writing-cache when Sq>1); else full-sequence flash attention."""
    b, sq, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, positions)

    if cache is None:
        out = flash_attention(
            q, k, v, causal=causal, q_chunk=q_chunk, kv_chunk=kv_chunk
        )
        new_cache = None
    else:
        assert cur_len is not None
        start = cur_len if jnp.ndim(cur_len) == 0 else cur_len[0]
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, start, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, start, 0, 0)
        )
        new_cache = {"k": k_cache, "v": v_cache}
        if sq == 1:
            out = decode_attention(q, k_cache, v_cache, cur_len + 1)
        else:  # prefill into cache: attend over the fresh prefix only
            out = flash_attention(
                q, k, v, causal=causal, q_chunk=q_chunk, kv_chunk=kv_chunk
            )
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, new_cache


def attention_ref(params: Any, x: jax.Array, cfg: ModelConfig, positions: jax.Array) -> jax.Array:
    """Full-materialization oracle for tests."""
    from repro.kernels import ref as kref

    q, k, v = _project_qkv(params, x, cfg, positions)
    out = kref.flash_attention_ref(q, k, v, causal=True)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))

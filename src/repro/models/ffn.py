"""Dense FFN (SwiGLU, LLaMA-style) and the GELU variant for Whisper."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import shard
from repro.models import common
from repro.models.common import ParamSpec


def spec(cfg: ModelConfig, d_ff: int | None = None) -> common.SpecTree:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("embed", "mlp")),
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }


def apply(params: Any, x: jax.Array) -> jax.Array:
    dt = x.dtype
    gate = shard(jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt)), "btf")
    up = shard(jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dt)), "btf")
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up, params["w_down"].astype(dt))


def spec_gelu(cfg: ModelConfig) -> common.SpecTree:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_in": ParamSpec((d, f), ("embed", "mlp")),
        "b_in": ParamSpec((f,), ("mlp",), init="zeros"),
        "w_out": ParamSpec((f, d), ("mlp", "embed")),
        "b_out": ParamSpec((d,), ("embed",), init="zeros"),
    }


def apply_gelu(params: Any, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = shard(
        jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(dt)) + params["b_in"].astype(dt),
        "btf",
    )
    return (
        jnp.einsum("bsf,fd->bsd", jax.nn.gelu(h), params["w_out"].astype(dt))
        + params["b_out"].astype(dt)
    )

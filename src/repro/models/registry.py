"""Uniform model API per architecture family + input_specs for the dry-run.

registry.get(cfg) returns a ModelApi with:
  spec/init/loss_fn/prefill/decode_step/state_spec/init_state
``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for every model
input of an (arch x shape) cell — weak-type-correct, shardable, and
allocation-free, as the multi-pod dry-run requires.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer, whisper, xlstm_model, zamba


@dataclasses.dataclass(frozen=True)
class ModelApi:
    spec: Callable[..., Any]
    init: Callable[..., Any]
    loss_fn: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    state_spec: Callable[..., Any]
    init_state: Callable[..., Any]


_TRANSFORMER = ModelApi(
    spec=transformer.spec,
    init=transformer.init,
    loss_fn=transformer.loss_fn,
    prefill=transformer.prefill,
    decode_step=transformer.decode_step,
    state_spec=transformer.state_spec,
    init_state=transformer.init_state,
)

_ZAMBA = ModelApi(
    spec=zamba.spec, init=zamba.init, loss_fn=zamba.loss_fn,
    prefill=zamba.prefill, decode_step=zamba.decode_step,
    state_spec=zamba.state_spec, init_state=zamba.init_state,
)

_XLSTM = ModelApi(
    spec=xlstm_model.spec, init=xlstm_model.init, loss_fn=xlstm_model.loss_fn,
    prefill=xlstm_model.prefill, decode_step=xlstm_model.decode_step,
    state_spec=xlstm_model.state_spec, init_state=xlstm_model.init_state,
)

_WHISPER = ModelApi(
    spec=whisper.spec, init=whisper.init, loss_fn=whisper.loss_fn,
    prefill=whisper.prefill, decode_step=whisper.decode_step,
    state_spec=whisper.state_spec, init_state=whisper.init_state,
)


def get(cfg: ModelConfig) -> ModelApi:
    if cfg.is_encoder_decoder:
        return _WHISPER
    if cfg.hybrid_attn_every:
        return _ZAMBA
    if cfg.family == "ssm":
        return _XLSTM
    return _TRANSFORMER


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch x shape) dry-run cell.

    train:   {tokens, labels, (labels2), (patches), (frames)} full seq_len
    prefill: {tokens, (patches), (frames)} full seq_len (cache written)
    decode:  {tokens (B,1)} — the KV cache/state comes from state_spec.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    emb = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        specs: dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.mtp_depth:
            specs["labels2"] = jax.ShapeDtypeStruct((b, s), i32)
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:  # decode
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.n_patches and shape.kind != "decode":
        specs["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), emb)
    if cfg.is_encoder_decoder and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_len, cfg.d_model), emb)
    return specs


def make_inputs(cfg: ModelConfig, shape: ShapeConfig, key: jax.Array) -> dict[str, jax.Array]:
    """Concrete random inputs matching input_specs (smoke tests/examples)."""
    specs = input_specs(cfg, shape)
    out: dict[str, jax.Array] = {}
    keys = jax.random.split(key, len(specs))
    for k, (name, sds) in zip(keys, sorted(specs.items())):
        if sds.dtype == jnp.int32:
            out[name] = jax.random.randint(k, sds.shape, 0, cfg.vocab_size, jnp.int32)
        else:
            out[name] = jax.random.normal(k, sds.shape, jnp.float32).astype(sds.dtype)
    return out

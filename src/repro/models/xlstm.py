"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, strictly sequential) with exponential gating and stabilizer state,
per Beck et al. 2024 (arXiv:2405.04517).

Both cells run as a lax.scan over time for train/prefill and as a one-step
update for decode — decode state is O(1) in sequence length, which is why
xlstm-125m is a `long_500k` architecture.

mLSTM state: {"c": (B,H,dk,dv), "n": (B,H,dk), "m": (B,H)}
sLSTM state: {"c","n","h": (B,d_inner), "m": (B,d_inner)}
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import shard
from repro.models import common
from repro.models.common import ParamSpec


def _dims(cfg: ModelConfig) -> tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    return d_inner, h, d_inner // h


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_spec(cfg: ModelConfig) -> common.SpecTree:
    d = cfg.d_model
    d_inner, h, p = _dims(cfg)
    return {
        "w_up": ParamSpec((d, 2 * d_inner), ("embed", "mlp")),  # x_inner, z
        "conv_w": ParamSpec((cfg.ssm_conv, d_inner), (None, "mlp")),
        "conv_b": ParamSpec((d_inner,), ("mlp",), init="zeros"),
        "w_q": ParamSpec((d_inner, h, p), ("mlp", None, None)),
        "w_k": ParamSpec((d_inner, h, p), ("mlp", None, None)),
        "w_v": ParamSpec((d_inner, h, p), ("mlp", None, None)),
        "w_i": ParamSpec((d_inner, h), ("mlp", None), scale=0.02),
        "w_f": ParamSpec((d_inner, h), ("mlp", None), scale=0.02),
        "b_i": ParamSpec((h,), (None,), init="zeros"),
        "b_f": ParamSpec((h,), (None,), init="ones"),  # forget-bias > 0
        "skip": ParamSpec((d_inner,), ("mlp",), init="ones"),
        "out_norm": ParamSpec((d_inner,), ("mlp",), init="ones"),
        "w_down": ParamSpec((d_inner, d), ("mlp", "embed")),
    }


def _mlstm_cell(carry, qkvif):
    """One timestep. carry: (c (b,h,dk,dv), n (b,h,dk), m (b,h))."""
    c, n, m = carry
    q, k, v, i_pre, f_pre = qkvif  # (b,h,p) x3, (b,h) x2
    log_f = -jax.nn.softplus(-f_pre)  # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = f_g[..., None, None] * c + i_g[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n_new = f_g[..., None] * n + i_g[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", c_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)), 1.0)
    h_t = num / den[..., None]
    return (c_new, n_new, m_new), h_t


def mlstm_apply(
    params: Any,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    state: dict[str, jax.Array] | None = None,
) -> tuple[jax.Array, dict[str, jax.Array] | None]:
    d_inner, h, p = _dims(cfg)
    bsz, s, _ = x.shape
    dt = x.dtype
    up = shard(jnp.einsum("bsd,de->bse", x, params["w_up"].astype(dt)), "btf")
    x_in, z = up[..., :d_inner], up[..., d_inner:]

    # causal conv on the qk path
    k_conv = cfg.ssm_conv
    if state is not None:
        ctx = jnp.concatenate([state["conv"].astype(dt), x_in], axis=1)
        new_conv = ctx[:, -(k_conv - 1) :, :]
    else:
        ctx = jnp.pad(x_in, ((0, 0), (k_conv - 1, 0), (0, 0)))
        new_conv = None
    w = params["conv_w"].astype(dt)
    x_c = sum(ctx[:, i : i + s, :] * w[i] for i in range(k_conv))
    x_c = jax.nn.silu(x_c + params["conv_b"].astype(dt))

    f32 = jnp.float32
    q = jnp.einsum("bse,ehp->bshp", x_c, params["w_q"].astype(dt)).astype(f32)
    k = jnp.einsum("bse,ehp->bshp", x_c, params["w_k"].astype(dt)).astype(f32) * (p**-0.5)
    v = jnp.einsum("bse,ehp->bshp", x_in, params["w_v"].astype(dt)).astype(f32)
    i_pre = (jnp.einsum("bse,eh->bsh", x_in, params["w_i"].astype(dt)) + params["b_i"]).astype(f32)
    f_pre = (jnp.einsum("bse,eh->bsh", x_in, params["w_f"].astype(dt)) + params["b_f"]).astype(f32)

    if state is None:
        carry0 = (
            jnp.zeros((bsz, h, p, p), f32),
            jnp.zeros((bsz, h, p), f32),
            jnp.zeros((bsz, h), f32),
        )
    else:
        carry0 = (state["c"].astype(f32), state["n"].astype(f32), state["m"].astype(f32))
    if s == 1 and state is not None:
        carry, h_t = _mlstm_cell(carry0, (q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0]))
        h_seq = h_t[:, None]
    else:
        seq = (
            jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
            jnp.moveaxis(i_pre, 1, 0), jnp.moveaxis(f_pre, 1, 0),
        )
        carry, hs = jax.lax.scan(_mlstm_cell, carry0, seq)
        h_seq = jnp.moveaxis(hs, 0, 1)  # (b,s,h,p)
    if state is None:
        new_state = None
    else:
        new_state = {
            "c": carry[0].astype(state["c"].dtype),
            "n": carry[1].astype(state["n"].dtype),
            "m": carry[2].astype(state["m"].dtype),
            "conv": new_conv.astype(state["conv"].dtype),
        }

    h_flat = h_seq.reshape(bsz, s, d_inner).astype(dt)
    h_flat = h_flat + params["skip"].astype(dt) * x_c
    h_flat = common.rmsnorm(h_flat, params["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", h_flat, params["w_down"].astype(dt)), new_state


def mlstm_state_spec(cfg: ModelConfig, batch: int, dtype: Any = jnp.float32):
    d_inner, h, p = _dims(cfg)
    return {
        "c": jax.ShapeDtypeStruct((batch, h, p, p), dtype),
        "n": jax.ShapeDtypeStruct((batch, h, p), dtype),
        "m": jax.ShapeDtypeStruct((batch, h), dtype),
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, d_inner), dtype),
    }


def mlstm_init_state(cfg: ModelConfig, batch: int, dtype: Any = jnp.float32):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), mlstm_state_spec(cfg, batch, dtype))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_spec(cfg: ModelConfig) -> common.SpecTree:
    d = cfg.d_model
    d_inner, h, p = _dims(cfg)
    return {
        "w_up": ParamSpec((d, d_inner), ("embed", "mlp")),
        # input projections for i, f, z, o gates
        "w_gates": ParamSpec((d_inner, 4, d_inner), ("mlp", None, None), scale=0.02),
        "b_gates": ParamSpec((4, d_inner), (None, None), init="zeros"),
        # block-diagonal (per-head) recurrent weights for each gate
        "r_gates": ParamSpec((4, h, p, p), (None, None, None, None), scale=0.02),
        "out_norm": ParamSpec((d_inner,), ("mlp",), init="ones"),
        "w_down": ParamSpec((d_inner, d), ("mlp", "embed")),
    }


def _slstm_cell(params, cfg, carry, x_t):
    """x_t: (b, 4, d_inner) pre-computed input gate contributions."""
    d_inner, h, p = _dims(cfg)
    c, n, m, h_prev = carry  # all (b, d_inner) f32
    hp = h_prev.reshape(-1, h, p)
    rec = jnp.einsum("ghpq,bhq->gbhp", params["r_gates"].astype(jnp.float32), hp)
    rec = jnp.moveaxis(rec, 0, 1).reshape(-1, 4, d_inner)
    pre = x_t + rec + params["b_gates"].astype(jnp.float32)[None]
    i_pre, f_pre, z_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(z_pre)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_apply(
    params: Any,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    state: dict[str, jax.Array] | None = None,
) -> tuple[jax.Array, dict[str, jax.Array] | None]:
    d_inner, h, p = _dims(cfg)
    bsz, s, _ = x.shape
    dt = x.dtype
    f32 = jnp.float32
    u = shard(jnp.einsum("bsd,de->bse", x, params["w_up"].astype(dt)), "btf")
    gates_in = jnp.einsum("bse,egf->bsgf", u, params["w_gates"].astype(dt))
    gates_in = gates_in.astype(f32)  # (b, s, 4, d_inner)

    if state is None:
        zeros = jnp.zeros((bsz, d_inner), f32)
        carry = (zeros, zeros, zeros, zeros)
    else:
        carry = (
            state["c"].astype(f32), state["n"].astype(f32),
            state["m"].astype(f32), state["h"].astype(f32),
        )
    if s == 1 and state is not None:
        carry, h_t = _slstm_cell(params, cfg, carry, gates_in[:, 0])
        h_seq = h_t[:, None]
    else:
        carry, hs = jax.lax.scan(
            lambda c, g: _slstm_cell(params, cfg, c, g), carry, jnp.moveaxis(gates_in, 1, 0)
        )
        h_seq = jnp.moveaxis(hs, 0, 1)
    if state is None:
        new_state = None
    else:
        new_state = {
            "c": carry[0].astype(state["c"].dtype), "n": carry[1].astype(state["n"].dtype),
            "m": carry[2].astype(state["m"].dtype), "h": carry[3].astype(state["h"].dtype),
        }

    y = common.rmsnorm(h_seq.astype(dt), params["out_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["w_down"].astype(dt)), new_state


def slstm_state_spec(cfg: ModelConfig, batch: int, dtype: Any = jnp.float32):
    d_inner, _, _ = _dims(cfg)
    shp = jax.ShapeDtypeStruct((batch, d_inner), dtype)
    return {"c": shp, "n": shp, "m": shp, "h": shp}


def slstm_init_state(cfg: ModelConfig, batch: int, dtype: Any = jnp.float32):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), slstm_state_spec(cfg, batch, dtype))

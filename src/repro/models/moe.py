"""Mixture-of-Experts FFN with expert parallelism, dropless-ish capacity
dispatch, shared experts, and both softmax (Switch/granite) and
sigmoid+aux-free (DeepSeek-V3) routing.

Dispatch design (pjit-auto friendly, EP over the 'model'/'experts' axis):

  tokens stay sharded over the data axes; expert weights are sharded over
  the expert dim ('experts' -> model axis). Routing is computed redundantly
  on every model column (cheap), then each column *locally gathers* the
  tokens assigned to its expert shard into an (G, E, C, d) capacity buffer
  (activations are model-replicated between ops, so the gather needs no
  communication), runs its experts, and scatter-adds weighted outputs back;
  the scatter's partial sums across model columns become one all-reduce —
  the EP combine collective.

  Slot assignment within an expert's capacity is computed with a sort
  (dropless up to the capacity factor; overflow tokens are dropped exactly
  like GShard/Switch capacity semantics). Sentinel index == T makes both the
  OOB gather (mode="fill" -> zeros) and the scatter (extra row) self-masking.

  For long sequences the dispatch runs under lax.scan over token chunks so
  only one chunk's capacity buffer is ever live.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import shard
from repro.models import common, ffn
from repro.models.common import ParamSpec


def spec(cfg: ModelConfig) -> common.SpecTree:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    s: common.SpecTree = {
        "router": ParamSpec((d, e), ("embed", "experts"), scale=0.02),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", None)),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", None)),
        "w_down": ParamSpec((e, f, d), ("experts", None, "embed")),
    }
    if cfg.router_aux_free:
        # DeepSeek aux-loss-free routing bias: updated outside the gradient.
        s["router_bias"] = ParamSpec((e,), ("experts",), init="zeros")
    if cfg.n_shared_experts:
        shared_cfg = dataclasses.replace(cfg)  # same d_model
        s["shared"] = ffn.spec(shared_cfg, d_ff=cfg.n_shared_experts * cfg.d_ff_expert)
    return s


def capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = math.ceil(tokens_per_group * cfg.experts_per_token / cfg.n_experts * cfg.capacity_factor)
    return max(c, 1)


def _route(
    params: Any, x: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (G, T, d) -> (weights (G,T,k), idx (G,T,k), aux_loss scalar)."""
    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32), params["router"].astype(jnp.float32))
    k = cfg.experts_per_token
    if cfg.router_aux_free:
        scores = jax.nn.sigmoid(logits)
        sel = scores + jax.lax.stop_gradient(params["router_bias"].astype(jnp.float32))
        _, idx = jax.lax.top_k(sel, k)
        w = jnp.take_along_axis(scores, idx, axis=-1)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
        aux = jnp.zeros((), jnp.float32)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        top, idx = jax.lax.top_k(probs, k)
        w = top / jnp.maximum(jnp.sum(top, axis=-1, keepdims=True), 1e-9)
        # Switch load-balance loss: E * sum_e f_e * p_e
        e = cfg.n_experts
        f_e = jnp.mean(
            jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=2), axis=(0, 1)
        ) / k
        p_e = jnp.mean(probs, axis=(0, 1))
        aux = e * jnp.sum(f_e * p_e)
    return w.astype(x.dtype), idx, aux


def _dispatch_indices(
    idx: jax.Array, n_tokens: int, cfg: ModelConfig, cap: int
) -> tuple[jax.Array, jax.Array]:
    """idx: (G, T, k) expert ids -> (token_for_slot (G,E,C), kslot (G,E,C)).

    token_for_slot[g,e,c] = flat token index in [0,T) or T (sentinel/empty).
    kslot identifies which of the token's k choices routed here (for weights).
    """
    g_dim, t_dim, k = idx.shape
    e_dim = cfg.n_experts
    tk = t_dim * k

    def per_group(idx_g: jax.Array) -> tuple[jax.Array, jax.Array]:
        e_flat = idx_g.reshape(tk)  # expert of each assignment
        tok_flat = jnp.repeat(jnp.arange(t_dim), k)
        k_flat = jnp.tile(jnp.arange(k), t_dim)
        order = jnp.argsort(e_flat)  # stable: preserves token order in expert
        e_sorted = e_flat[order]
        counts = jnp.bincount(e_flat, length=e_dim)
        starts = jnp.cumsum(counts) - counts
        slot = jnp.arange(tk) - starts[e_sorted]  # position within expert
        buf_tok = jnp.full((e_dim, cap), t_dim, dtype=jnp.int32)
        buf_k = jnp.zeros((e_dim, cap), dtype=jnp.int32)
        # slots >= cap fall out of bounds and are dropped (capacity overflow).
        buf_tok = buf_tok.at[e_sorted, slot].set(tok_flat[order].astype(jnp.int32), mode="drop")
        buf_k = buf_k.at[e_sorted, slot].set(k_flat[order].astype(jnp.int32), mode="drop")
        return buf_tok, buf_k

    return jax.vmap(per_group)(idx)


def _expert_ffn(params: Any, xs: jax.Array) -> jax.Array:
    """xs: (G, E, C, d) -> (G, E, C, d), per-expert SwiGLU."""
    dt = xs.dtype
    gate = jnp.einsum("gecd,edf->gecf", xs, params["w_gate"].astype(dt))
    up = jnp.einsum("gecd,edf->gecf", xs, params["w_up"].astype(dt))
    return jnp.einsum("gecf,efd->gecd", jax.nn.silu(gate) * up, params["w_down"].astype(dt))


def _moe_chunk(params: Any, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """x: (G, T, d) one token-chunk -> (out (G,T,d), aux)."""
    g_dim, t_dim, d = x.shape
    cap = capacity(t_dim, cfg)
    w, idx, aux = _route(params, x, cfg)
    tok_slot, k_slot = _dispatch_indices(idx, t_dim, cfg, cap)  # (G,E,C)

    def gather_group(xg: jax.Array, tokg: jax.Array) -> jax.Array:
        return jnp.take(xg, tokg, axis=0, mode="fill", fill_value=0)  # (E,C,d)

    xs = shard(jax.vmap(gather_group)(x, tok_slot), "gecd")  # (G,E,C,d)
    ys = shard(_expert_ffn(params, xs), "gecd")

    # combine weights per slot
    def slot_weights(wg: jax.Array, tokg: jax.Array, kg: jax.Array) -> jax.Array:
        flat = tokg * cfg.experts_per_token + kg  # (E,C) index into (T*k,)
        return jnp.take(wg.reshape(-1), flat, axis=0, mode="fill", fill_value=0)

    ws = jax.vmap(slot_weights)(w, tok_slot, k_slot)  # (G,E,C)
    ys = ys * ws[..., None].astype(ys.dtype)

    def scatter_group(ysg: jax.Array, tokg: jax.Array) -> jax.Array:
        out = jnp.zeros((t_dim + 1, d), ysg.dtype)  # extra row = sentinel sink
        out = out.at[tokg.reshape(-1)].add(ysg.reshape(-1, d))
        return out[:t_dim]

    out = shard(jax.vmap(scatter_group)(ys, tok_slot), "btd")
    return out, aux


def apply(
    params: Any, x: jax.Array, cfg: ModelConfig, *, token_chunk: int = 8192
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss). Groups = batch rows; long sequences
    are scanned in chunks so one capacity buffer is live at a time."""
    b, s, d = x.shape
    if s > token_chunk and s % token_chunk == 0:
        n_chunks = s // token_chunk
        xc = x.reshape(b, n_chunks, token_chunk, d).transpose(1, 0, 2, 3)

        def step(_, xi):
            out_i, aux_i = _moe_chunk(params, xi, cfg)
            return None, (out_i, aux_i)

        _, (outs, auxs) = jax.lax.scan(step, None, xc)
        out = outs.transpose(1, 0, 2, 3).reshape(b, s, d)
        aux = jnp.mean(auxs)
    else:
        out, aux = _moe_chunk(params, x, cfg)

    if cfg.n_shared_experts:
        out = out + ffn.apply(params["shared"], x)
    return out, aux


def moe_ref(params: Any, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Oracle: dense per-token expert evaluation (no capacity drops).

    Matches `apply` exactly when no expert exceeds capacity.
    """
    b, s, d = x.shape
    w, idx, _ = _route(params, x.reshape(b, s, d), cfg)
    out = jnp.zeros_like(x)
    for kk in range(cfg.experts_per_token):
        e_ids = idx[..., kk]  # (b, s)
        wg = jnp.take(params["w_gate"], e_ids, axis=0)  # (b,s,d,f)
        wu = jnp.take(params["w_up"], e_ids, axis=0)
        wd = jnp.take(params["w_down"], e_ids, axis=0)
        gate = jnp.einsum("bsd,bsdf->bsf", x, wg.astype(x.dtype))
        up = jnp.einsum("bsd,bsdf->bsf", x, wu.astype(x.dtype))
        y = jnp.einsum("bsf,bsfd->bsd", jax.nn.silu(gate) * up, wd.astype(x.dtype))
        out = out + y * w[..., kk, None].astype(x.dtype)
    if cfg.n_shared_experts:
        out = out + ffn.apply(params["shared"], x)
    return out

"""Multi-head Latent Attention (DeepSeek-V2/V3).

Faithful structure: low-rank q (w_dq -> norm -> w_uq), latent kv compression
(w_dkv -> norm), decoupled RoPE channel (k_rope shared across heads), and —
for decode — the *absorbed* formulation that scores queries directly against
the latent cache (q_nope @ w_uk folded into the query), so the per-step cost
and the KV cache are O(kv_lora_rank + rope_dim) per token instead of
O(heads * head_dim): the latent cache IS the paper-faithful production trick.

Cache layout: {"ckv": (B, S, kv_lora_rank), "k_rope": (B, S, rope_dim)}.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import shard
from repro.models import common
from repro.models.attention import NEG_INF, flash_attention
from repro.models.common import ParamSpec


def spec(cfg: ModelConfig) -> common.SpecTree:
    d, h = cfg.d_model, cfg.n_heads
    ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        # 'latent' dims shard over the model axis: leaving these replicated
        # costs a per-layer-per-microbatch f32 grad all-reduce over model
        # (2.67 TB/device/step observed on the 671B train cell, §Perf it.2).
        "w_dq": ParamSpec((d, ql), ("embed", "latent")),
        "q_norm": ParamSpec((ql,), ("latent",), init="ones"),
        "w_uq": ParamSpec((ql, h, nope + rope), (None, "heads", None)),
        "w_dc": ParamSpec((d, kvl), ("embed", "latent")),
        "w_dr": ParamSpec((d, rope), ("embed", None)),
        "kv_norm": ParamSpec((kvl,), ("latent",), init="ones"),
        "w_uk": ParamSpec((kvl, h, nope), (None, "heads", None)),
        "w_uv": ParamSpec((kvl, h, vd), (None, "heads", None)),
        "wo": ParamSpec((h, vd, d), ("heads", None, "embed")),
    }


def _q_proj(params: Any, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    dt = x.dtype
    ql = jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(dt))
    ql = common.rmsnorm(ql, params["q_norm"], cfg.norm_eps)
    q = shard(jnp.einsum("bsr,rhk->bshk", ql, params["w_uq"].astype(dt)), "bthd")
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = common.apply_rope(q[..., cfg.qk_nope_head_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def _kv_latent(params: Any, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    dt = x.dtype
    c = jnp.einsum("bsd,dr->bsr", x, params["w_dc"].astype(dt))
    k_rope = jnp.einsum("bsd,dr->bsr", x, params["w_dr"].astype(dt))
    c = common.rmsnorm(c, params["kv_norm"], cfg.norm_eps)
    # shared (head-less) rope channel: add singleton head dim for apply_rope
    k_rope = common.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c, k_rope


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype: Any = jnp.bfloat16):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype: Any = jnp.bfloat16):
    return {
        "ckv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def apply(
    params: Any,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: dict[str, jax.Array] | None = None,
    cur_len: jax.Array | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> tuple[jax.Array, dict[str, jax.Array] | None]:
    b, sq, _ = x.shape
    dt = x.dtype
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _q_proj(params, x, cfg, positions)
    c, k_rope = _kv_latent(params, x, cfg, positions)

    if cache is None:
        # Train/prefill: decompress K/V and run flash attention (MHA: one KV
        # head per query head after decompression).
        k_nope = shard(jnp.einsum("bsr,rhk->bshk", c, params["w_uk"].astype(dt)), "bthd")
        v = shard(jnp.einsum("bsr,rhk->bshk", c, params["w_uv"].astype(dt)), "bthd")
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, sq, cfg.n_heads, cfg.qk_rope_head_dim))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        # flash_attention scales by d^-0.5 of its input; pre-scale correction:
        q = q * (scale / (q.shape[-1] ** -0.5))
        out = flash_attention(q, k, v, causal=True, q_chunk=q_chunk, kv_chunk=kv_chunk)
        new_cache = None
    else:
        assert cur_len is not None and sq == 1
        start = cur_len if jnp.ndim(cur_len) == 0 else cur_len[0]
        ckv_cache = jax.lax.dynamic_update_slice(
            cache["ckv"], c.astype(cache["ckv"].dtype), (0, start, 0)
        )
        rope_cache = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, start, 0)
        )
        new_cache = {"ckv": ckv_cache, "k_rope": rope_cache}
        # Absorbed decode: fold w_uk into the query, score against latents.
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"].astype(dt))
        s_lat = jnp.einsum("bhr,bsr->bhs", q_abs[:, 0], ckv_cache.astype(dt))
        s_rope = jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], rope_cache.astype(dt))
        logits = (s_lat + s_rope).astype(jnp.float32) * scale
        valid = jnp.arange(cache["ckv"].shape[1])[None, None, :] < (cur_len + 1)
        logits = jnp.where(valid, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(dt)
        ctx = jnp.einsum("bhs,bsr->bhr", probs, ckv_cache.astype(dt))
        out = jnp.einsum("bhr,rhk->bhk", ctx, params["w_uv"].astype(dt))[:, None]
        out_w = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
        return out_w, new_cache

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, new_cache


def mla_ref(params: Any, x: jax.Array, cfg: ModelConfig, positions: jax.Array) -> jax.Array:
    """Full-materialization oracle (decompressed path, naive softmax)."""
    from repro.kernels import ref as kref

    b, s, _ = x.shape
    dt = x.dtype
    q_nope, q_rope = _q_proj(params, x, cfg, positions)
    c, k_rope = _kv_latent(params, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c, params["w_uk"].astype(dt))
    v = jnp.einsum("bsr,rhk->bshk", c, params["w_uv"].astype(dt))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, s, cfg.n_heads, cfg.qk_rope_head_dim))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    out = kref.flash_attention_ref(q, k, v, causal=True, scale=scale)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))

"""Whisper-style encoder-decoder backbone (conv frontend STUBBED per the
assignment: ``input_specs()`` provides precomputed frame embeddings).

Encoder: non-causal self-attn + GELU FFN over (B, encoder_len, d) frames.
Decoder: causal self-attn + cross-attn(encoder output) + GELU FFN.
Positions: sinusoidal on both sides (Whisper's learned decoder table tops
out at 448 — the assigned 32k decode shapes require extending it, so we use
sinusoidal everywhere; recorded as a deviation in DESIGN.md).

Decode state: {"self": stacked KV (L,...), "cross_k"/"cross_v": (L,B,F,h,hd),
"enc_done": encoder output is folded into cross K/V at prefill}.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import shard
from repro.models import attention, common, ffn
from repro.models.common import ParamSpec


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _xattn_spec(cfg: ModelConfig) -> common.SpecTree:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", None)),
        "wk": ParamSpec((d, h, hd), ("embed", "heads", None)),
        "wv": ParamSpec((d, h, hd), ("embed", "heads", None)),
        "wo": ParamSpec((h, hd, d), ("heads", None, "embed")),
    }


def _enc_layer_spec(cfg: ModelConfig) -> common.SpecTree:
    d = cfg.d_model
    return {
        "attn_norm": ParamSpec((d,), ("embed",), init="ones"),
        "attn": _xattn_spec(cfg),
        "ffn_norm": ParamSpec((d,), ("embed",), init="ones"),
        "ffn": ffn.spec_gelu(cfg),
    }


def _dec_layer_spec(cfg: ModelConfig) -> common.SpecTree:
    d = cfg.d_model
    return {
        "self_norm": ParamSpec((d,), ("embed",), init="ones"),
        "self": _xattn_spec(cfg),
        "cross_norm": ParamSpec((d,), ("embed",), init="ones"),
        "cross": _xattn_spec(cfg),
        "ffn_norm": ParamSpec((d,), ("embed",), init="ones"),
        "ffn": ffn.spec_gelu(cfg),
    }


def spec(cfg: ModelConfig) -> common.SpecTree:
    d, v = cfg.d_model, cfg.vocab_size
    return {
        "embed": ParamSpec((v, d), ("vocab", "embed"), init="embed", scale=0.02),
        "enc_layers": common.stack_specs(_enc_layer_spec(cfg), cfg.n_encoder_layers),
        "enc_norm": ParamSpec((d,), ("embed",), init="ones"),
        "dec_layers": common.stack_specs(_dec_layer_spec(cfg), cfg.n_layers),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
        "lm_head": ParamSpec((d, v), ("embed", "vocab"), scale=0.02),
    }


def init(key: jax.Array, cfg: ModelConfig, dtype: Any = jnp.float32) -> Any:
    return common.init_params(spec(cfg), key, dtype)


def _mha(params: Any, xq: jax.Array, xkv: jax.Array, *, causal: bool) -> jax.Array:
    dt = xq.dtype
    q = shard(jnp.einsum("bsd,dhk->bshk", xq, params["wq"].astype(dt)), "bthd")
    k = shard(jnp.einsum("bsd,dhk->bshk", xkv, params["wk"].astype(dt)), "bthd")
    v = shard(jnp.einsum("bsd,dhk->bshk", xkv, params["wv"].astype(dt)), "bthd")
    out = attention.flash_attention(q, k, v, causal=causal)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))


def encode(params: Any, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, f, _ = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(jnp.arange(f), cfg.d_model)[None].astype(x.dtype)

    def body(xc, lp):
        h = common.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        xc = xc + _mha(lp["attn"], h, h, causal=False)
        h = common.rmsnorm(xc, lp["ffn_norm"], cfg.norm_eps)
        return xc + ffn.apply_gelu(lp["ffn"], h), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return common.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def forward_train(params: Any, batch: dict[str, jax.Array], cfg: ModelConfig, *, remat: bool = False):
    enc = encode(params, batch["frames"], cfg)
    b, s = batch["tokens"].shape
    x = common.embed_lookup(params["embed"], batch["tokens"]).astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)

    def body(xc, lp):
        h = common.rmsnorm(xc, lp["self_norm"], cfg.norm_eps)
        xc = xc + _mha(lp["self"], h, h, causal=True)
        h = common.rmsnorm(xc, lp["cross_norm"], cfg.norm_eps)
        xc = xc + _mha(lp["cross"], h, enc, causal=False)
        h = common.rmsnorm(xc, lp["ffn_norm"], cfg.norm_eps)
        return xc + ffn.apply_gelu(lp["ffn"], h), None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return x


def _logits(params: Any, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = common.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return shard(jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(h.dtype)), "btv")


def loss_fn(params: Any, batch: dict[str, jax.Array], cfg: ModelConfig, *, remat: bool = True, **_):
    x = forward_train(params, batch, cfg, remat=remat)
    loss = common.softmax_cross_entropy(_logits(params, x, cfg), batch["labels"])
    return loss, {"nll": loss, "loss": loss}


def state_spec(cfg: ModelConfig, batch: int, max_len: int, dtype: Any = jnp.bfloat16) -> Any:
    h, hd, f, n = cfg.n_heads, cfg.head_dim, cfg.encoder_len, cfg.n_layers
    kv = jax.ShapeDtypeStruct((n, batch, max_len, h, hd), dtype)
    cross = jax.ShapeDtypeStruct((n, batch, f, h, hd), dtype)
    return {"self_k": kv, "self_v": kv, "cross_k": cross, "cross_v": cross}


def init_state(cfg: ModelConfig, batch: int, max_len: int, dtype: Any = jnp.bfloat16) -> Any:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), state_spec(cfg, batch, max_len, dtype)
    )


def prefill(params: Any, batch: dict[str, jax.Array], state: Any, cfg: ModelConfig, **_):
    """Encode frames, fill cross K/V, prefill decoder self-attn cache."""
    enc = encode(params, batch["frames"], cfg)
    b, s = batch["tokens"].shape
    x = common.embed_lookup(params["embed"], batch["tokens"]).astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)

    def body(xc, layer_in):
        lp, sk, sv, ck, cv = layer_in
        dt = xc.dtype
        h = common.rmsnorm(xc, lp["self_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["self"]["wq"].astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", h, lp["self"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", h, lp["self"]["wv"].astype(dt))
        sk = jax.lax.dynamic_update_slice(sk, k.astype(sk.dtype), (0, 0, 0, 0))
        sv = jax.lax.dynamic_update_slice(sv, v.astype(sv.dtype), (0, 0, 0, 0))
        out = attention.flash_attention(q, k, v, causal=True)
        xc = xc + jnp.einsum("bshk,hkd->bsd", out, lp["self"]["wo"].astype(dt))
        h = common.rmsnorm(xc, lp["cross_norm"], cfg.norm_eps)
        ck_new = jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wk"].astype(dt)).astype(ck.dtype)
        cv_new = jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wv"].astype(dt)).astype(cv.dtype)
        qx = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"].astype(dt))
        out = attention.flash_attention(qx, ck_new.astype(dt), cv_new.astype(dt), causal=False)
        xc = xc + jnp.einsum("bshk,hkd->bsd", out, lp["cross"]["wo"].astype(dt))
        h = common.rmsnorm(xc, lp["ffn_norm"], cfg.norm_eps)
        return xc + ffn.apply_gelu(lp["ffn"], h), (sk, sv, ck_new, cv_new)

    x, (sk, sv, ck, cv) = jax.lax.scan(
        body, x, (params["dec_layers"], state["self_k"], state["self_v"],
                  state["cross_k"], state["cross_v"])
    )
    new_state = {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv}
    return _logits(params, x[:, -1:], cfg), new_state


def decode_step(params: Any, batch: dict[str, jax.Array], state: Any, cur_len: jax.Array, cfg: ModelConfig):
    b, s = batch["tokens"].shape
    assert s == 1
    x = common.embed_lookup(params["embed"], batch["tokens"]).astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(cur_len[None] + jnp.zeros((b, 1)), cfg.d_model).astype(x.dtype)

    def body(xc, layer_in):
        lp, sk, sv, ck, cv = layer_in
        dt = xc.dtype
        h = common.rmsnorm(xc, lp["self_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["self"]["wq"].astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", h, lp["self"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", h, lp["self"]["wv"].astype(dt))
        sk = jax.lax.dynamic_update_slice(sk, k.astype(sk.dtype), (0, cur_len, 0, 0))
        sv = jax.lax.dynamic_update_slice(sv, v.astype(sv.dtype), (0, cur_len, 0, 0))
        out = attention.decode_attention(q, sk.astype(dt), sv.astype(dt), cur_len + 1)
        xc = xc + jnp.einsum("bshk,hkd->bsd", out, lp["self"]["wo"].astype(dt))
        h = common.rmsnorm(xc, lp["cross_norm"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"].astype(dt))
        f = ck.shape[1]
        out = attention.decode_attention(qx, ck.astype(dt), cv.astype(dt), jnp.int32(f))
        xc = xc + jnp.einsum("bshk,hkd->bsd", out, lp["cross"]["wo"].astype(dt))
        h = common.rmsnorm(xc, lp["ffn_norm"], cfg.norm_eps)
        return xc + ffn.apply_gelu(lp["ffn"], h), (sk, sv)

    x, (sk, sv) = jax.lax.scan(
        body, x, (params["dec_layers"], state["self_k"], state["self_v"],
                  state["cross_k"], state["cross_v"])
    )
    new_state = dict(state, self_k=sk, self_v=sv)
    return _logits(params, x, cfg), new_state

"""Mamba-2 (SSD) mixer — the Zamba2 backbone block.

Training/prefill uses the chunked state-space-duality algorithm (minimal SSD
from the Mamba-2 paper): intra-chunk attention-like einsums with a decay mask
plus an inter-chunk state scan. Decode keeps the O(1) recurrent state
  h_t = h_{t-1} * exp(dt*A) + dt * B_t (x) x_t,   y_t = C_t . h_t + D*x_t
with states {"ssm": (B, H, P, N), "conv": (B, K-1, conv_dim)}.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import shard
from repro.models import common
from repro.models.common import ParamSpec


def dims(cfg: ModelConfig) -> tuple[int, int, int, int, int]:
    """(d_inner, n_heads, head_p, d_state, conv_dim)."""
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = cfg.ssm_heads if cfg.ssm_heads else d_inner // 64
    head_p = d_inner // n_heads
    n = cfg.ssm_state
    conv_dim = d_inner + 2 * n  # x, B, C share the causal conv (n_groups=1)
    return d_inner, n_heads, head_p, n, conv_dim


def spec(cfg: ModelConfig) -> common.SpecTree:
    d = cfg.d_model
    d_inner, h, p, n, conv_dim = dims(cfg)
    proj_out = 2 * d_inner + 2 * n + h  # z, x, B, C, dt
    return {
        "in_proj": ParamSpec((d, proj_out), ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_dim), (None, "mlp")),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), init="zeros"),
        "dt_bias": ParamSpec((h,), (None,), init="zeros"),
        "a_log": ParamSpec((h,), (None,), init="ones"),
        "d_skip": ParamSpec((h,), (None,), init="ones"),
        "gate_norm": ParamSpec((d_inner,), ("mlp",), init="ones"),
        "out_proj": ParamSpec((d_inner, d), ("mlp", "embed")),
    }


def _split_proj(params: Any, u: jax.Array, cfg: ModelConfig):
    d_inner, h, p, n, conv_dim = dims(cfg)
    zxbcdt = shard(jnp.einsum("bsd,de->bse", u, params["in_proj"].astype(u.dtype)), "btf")
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim :]
    return z, xbc, dt


def _causal_conv(params: Any, xbc: jax.Array, conv_state: jax.Array | None, cfg: ModelConfig):
    """Depthwise causal conv over (B, S, conv_dim). Returns (out, new_state)."""
    k = cfg.ssm_conv
    if conv_state is not None:
        ctx = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    else:
        ctx = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    w = params["conv_w"].astype(xbc.dtype)  # (k, conv_dim)
    out = sum(ctx[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    out = jax.nn.silu(out + params["conv_b"].astype(xbc.dtype))
    new_state = ctx[:, -(k - 1) :, :] if k > 1 else None
    return out, new_state


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., q) -> (..., q, q) lower-triangular pairwise segment sums."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]  # sum over (j, i]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (b, s, h, p)
    dt: jax.Array,  # (b, s, h) — post-softplus
    a: jax.Array,  # (h,) negative
    b_in: jax.Array,  # (b, s, n)
    c_in: jax.Array,  # (b, s, n)
    *,
    chunk: int = 128,
    h0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Minimal SSD. Returns (y (b,s,h,p), final state (b,h,p,n))."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    f32 = jnp.float32

    # One scan over chunks carrying the SSM state: each step materializes a
    # single (b, h, q, q) decay matrix instead of all nc at once (the
    # all-chunks einsum costs nc * b * h * q^2 floats — GBs at 4k/500k seq).
    xd = (x.astype(f32) * dt[..., None].astype(f32)).reshape(bsz, nc, chunk, h, p)
    da = jnp.moveaxis((dt.astype(f32) * a.astype(f32)).reshape(bsz, nc, chunk, h), 2, 3)
    bc = b_in.astype(f32).reshape(bsz, nc, chunk, n)
    cc = c_in.astype(f32).reshape(bsz, nc, chunk, n)
    init = h0.astype(f32) if h0 is not None else jnp.zeros((bsz, h, p, n), f32)

    def step(carry, inp):
        xd_c, da_c, b_c, c_c = inp  # (b,q,h,p), (b,h,q), (b,q,n), (b,q,n)
        da_cum = jnp.cumsum(da_c, axis=-1)  # (b,h,q)
        l_mat = jnp.exp(_segsum(da_c))  # (b,h,q,q)
        y_diag = jnp.einsum("bln,bsn,bhls,bshp->blhp", c_c, b_c, l_mat, xd_c)
        # inter-chunk contribution from the carried state
        state_decay = jnp.exp(da_cum)  # (b,h,q)
        y_off = jnp.einsum("bsn,bhpn,bhs->bshp", c_c, carry, state_decay)
        # update carried state to end of chunk
        decay_states = jnp.exp(da_cum[..., -1:] - da_cum)  # (b,h,q)
        states = jnp.einsum("bsn,bhs,bshp->bhpn", b_c, decay_states, xd_c)
        new = shard(carry * jnp.exp(da_cum[..., -1])[..., None, None] + states, "bhpn")
        return new, shard(y_diag + y_off, "bshp")

    seq = (
        jnp.moveaxis(xd, 1, 0),
        jnp.moveaxis(da, 1, 0),
        jnp.moveaxis(bc, 1, 0),
        jnp.moveaxis(cc, 1, 0),
    )
    init = shard(init, "bhpn")
    final, ys = jax.lax.scan(step, init, seq)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    return y, final


def apply(
    params: Any,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    state: dict[str, jax.Array] | None = None,
    chunk: int = 128,
) -> tuple[jax.Array, dict[str, jax.Array] | None]:
    """Mamba2 mixer. state=None -> train/prefill; else one-step decode."""
    d_inner, h, p, n, conv_dim = dims(cfg)
    bsz, s, _ = x.shape
    dt_f32 = jnp.float32
    z, xbc, dt_raw = _split_proj(params, x, cfg)
    dt = jax.nn.softplus(dt_raw.astype(dt_f32) + params["dt_bias"].astype(dt_f32))
    a = -jnp.exp(params["a_log"].astype(dt_f32))  # (h,) negative

    if state is None:
        xbc_c, _ = _causal_conv(params, xbc, None, cfg)
        xs = xbc_c[..., :d_inner].reshape(bsz, s, h, p)
        b_in = xbc_c[..., d_inner : d_inner + n]
        c_in = xbc_c[..., d_inner + n :]
        y, _ = ssd_chunked(xs, dt, a, b_in, c_in, chunk=chunk)
        new_state = None
    else:
        xbc_c, conv_state = _causal_conv(params, xbc, state["conv"], cfg)
        xs = xbc_c[..., :d_inner].reshape(bsz, s, h, p)
        b_in = xbc_c[..., d_inner : d_inner + n]
        c_in = xbc_c[..., d_inner + n :]
        hprev = state["ssm"].astype(dt_f32)
        if s == 1:  # one-step decode recurrence
            dec = jnp.exp(dt[:, 0] * a)  # (b, h)
            upd = jnp.einsum(
                "bhp,bn->bhpn", xs[:, 0].astype(dt_f32) * dt[:, 0, :, None], b_in[:, 0]
            )
            hnew = hprev * dec[..., None, None] + upd
            y = jnp.einsum("bn,bhpn->bhp", c_in[:, 0], hnew)[:, None]  # (b,1,h,p)
        else:  # prefill-with-state: chunked SSD carrying h0
            y, hnew = ssd_chunked(xs, dt, a, b_in, c_in, chunk=chunk, h0=hprev)
        new_state = {"ssm": hnew.astype(state["ssm"].dtype), "conv": conv_state.astype(state["conv"].dtype)}

    y = y + xs.astype(y.dtype) * params["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = common.rmsnorm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype)), new_state


def init_state(cfg: ModelConfig, batch: int, dtype: Any = jnp.float32) -> dict[str, jax.Array]:
    d_inner, h, p, n, conv_dim = dims(cfg)
    return {
        "ssm": jnp.zeros((batch, h, p, n), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def state_spec(cfg: ModelConfig, batch: int, dtype: Any = jnp.float32):
    d_inner, h, p, n, conv_dim = dims(cfg)
    return {
        "ssm": jax.ShapeDtypeStruct((batch, h, p, n), dtype),
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def ssd_ref(x, dt, a, b_in, c_in):
    """Sequential-recurrence oracle for ssd_chunked."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    f32 = jnp.float32
    hst = jnp.zeros((bsz, h, p, n), f32)
    ys = []
    for t in range(s):
        dec = jnp.exp(dt[:, t].astype(f32) * a.astype(f32))  # (b,h)
        upd = jnp.einsum(
            "bhp,bn->bhpn", x[:, t].astype(f32) * dt[:, t, :, None].astype(f32), b_in[:, t].astype(f32)
        )
        hst = hst * dec[..., None, None] + upd
        ys.append(jnp.einsum("bn,bhpn->bhp", c_in[:, t].astype(f32), hst))
    return jnp.stack(ys, axis=1), hst

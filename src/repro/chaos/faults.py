"""repro.chaos — deterministic, seeded fault injection for the serving stack.

The paper's lesson is that SU3_Bench's peak is *fragile*: init placement,
NUMA, and pipeline-throughput subtleties degrade silently instead of
failing loudly.  A production serving stack needs the failure modes made
explicit and survivable — and testable on demand.  This module is the
"on demand" half: a :class:`FaultPlan` draws from per-site seeded RNG
streams and decides, at each of four real seams, whether that call fails
and how:

  ``dispatch``   a host's (mega)kernel launch fails or is delayed —
                 the slow/failed-rank case every multi-node lattice stack
                 hits (one stalled rank stalls the solve);
  ``halo``       a ghost slab of the stencil exchange is dropped (zeros)
                 or corrupted (NaN) before the boundary pass consumes it;
  ``kernel``     a kernel's output is poisoned with NaN/Inf — the silent
                 numerical corruption the CG residual guards must catch;
  ``pool``       warm-pool runner construction fails (the cold-build seam:
                 a host that cannot compile/allocate its plan).

Determinism contract: each site draws from its OWN ``random.Random``
stream seeded by ``(seed, site)``, so a site's fire/no-fire schedule
depends only on how many times *that site* was asked — not on how asks
interleave across sites.  The same seed over the same request schedule
reproduces the same fault sequence exactly (``log()`` equality is the
test), which is what makes a chaos failure a *bug report* instead of a
shrug.

Cost contract: the disabled plan (:data:`NULL_FAULT_PLAN`) is the default
everywhere; every injection point is one ``if faults.enabled`` branch
(same guard style as ``tracer.enabled``), so the fault-free hot path
allocates nothing and the fault-free results stay bitwise identical to a
build without this module.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any

SITES = ("dispatch", "halo", "kernel", "pool")

# action vocabulary per site (the first action is the default)
SITE_ACTIONS = {
    "dispatch": ("fail", "delay"),
    "halo": ("drop", "corrupt"),
    "kernel": ("nan", "inf"),
    "pool": ("fail",),
}


@dataclasses.dataclass(frozen=True)
class Fault:
    """One fired injection: what happened, where, and in what order."""

    site: str
    action: str
    seq: int  # global fire sequence number (0-based, across sites)
    site_seq: int  # how many times this site had been asked when it fired
    delay_s: float = 0.0  # "delay" action: injected stall seconds
    ctx: tuple = ()  # sorted (key, value) call-site context, hashable

    def as_dict(self) -> dict[str, Any]:
        return {
            "site": self.site, "action": self.action, "seq": self.seq,
            "site_seq": self.site_seq, "delay_s": self.delay_s,
            "ctx": dict(self.ctx),
        }


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-site schedule: when and how one injection point fires.

    Attributes:
        probability: per-ask fire probability from the site's seeded stream.
        actions: actions drawn (uniformly, same stream) when firing; must be
            a subset of :data:`SITE_ACTIONS` for the site.
        delay_s: stall injected by the ``delay`` action.
        after: never fire for the first ``after`` asks (lets warmup and
            compile paths run clean so a storm hits steady state).
        max_fires: stop firing after this many (``-1`` = unbounded) — a
            storm that ends, so recovery is observable.
    """

    probability: float = 0.0
    actions: tuple[str, ...] = ()
    delay_s: float = 0.005
    after: int = 0
    max_fires: int = -1

    def __post_init__(self) -> None:
        if not 0.0 <= self.probability <= 1.0:
            raise ValueError(f"probability must be in [0, 1], got {self.probability}")
        if self.after < 0:
            raise ValueError(f"after must be >= 0, got {self.after}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")

    def describe(self) -> dict[str, Any]:
        return {
            "probability": self.probability, "actions": list(self.actions),
            "delay_s": self.delay_s, "after": self.after,
            "max_fires": self.max_fires,
        }


class FaultPlan:
    """Seeded, per-site fault schedule with a complete fire log.

    Args:
        seed: the reproduction handle — the same seed over the same ask
            schedule fires the same faults in the same order.
        sites: ``{site: FaultSpec}``; unknown sites are rejected, missing
            sites never fire.  Actions default to the site's first
            vocabulary entry.
        enabled: ``False`` builds a dead plan (every ``ask`` returns None
            without drawing); :data:`NULL_FAULT_PLAN` is the shared one.
    """

    def __init__(self, seed: int = 0, sites: dict[str, FaultSpec] | None = None,
                 enabled: bool = True):
        sites = dict(sites or {})
        for site, spec in sites.items():
            if site not in SITES:
                raise ValueError(f"unknown fault site {site!r}; known: {SITES}")
            bad = set(spec.actions) - set(SITE_ACTIONS[site])
            if bad:
                raise ValueError(
                    f"site {site!r} does not support actions {sorted(bad)}; "
                    f"supported: {SITE_ACTIONS[site]}"
                )
        self.seed = int(seed)
        self.specs = sites
        self.enabled = bool(enabled) and any(
            s.probability > 0 for s in sites.values()
        )
        self._rngs = {
            site: random.Random(f"{self.seed}:{site}") for site in sites
        }
        self._asked = {site: 0 for site in sites}
        self._fired_per_site = {site: 0 for site in sites}
        self._log: list[Fault] = []

    # ------------------------------------------------------------------ fire
    def ask(self, site: str, **ctx: Any) -> Fault | None:
        """One injection-point consultation; returns the Fault to apply or
        None.  Callers guard with ``if faults.enabled`` so the disabled
        path never packs ``ctx``."""
        spec = self.specs.get(site)
        if not self.enabled or spec is None or spec.probability <= 0.0:
            return None
        rng = self._rngs[site]
        site_seq = self._asked[site]
        self._asked[site] = site_seq + 1
        # one draw per ask keeps the site stream aligned with the ask count
        u = rng.random()
        if site_seq < spec.after:
            return None
        if spec.max_fires >= 0 and self._fired_per_site[site] >= spec.max_fires:
            return None
        if u >= spec.probability:
            return None
        actions = spec.actions or (SITE_ACTIONS[site][0],)
        action = actions[rng.randrange(len(actions))] if len(actions) > 1 else actions[0]
        fault = Fault(
            site=site, action=action, seq=len(self._log), site_seq=site_seq,
            delay_s=spec.delay_s if action == "delay" else 0.0,
            ctx=tuple(sorted(ctx.items())),
        )
        self._fired_per_site[site] += 1
        self._log.append(fault)
        return fault

    # ------------------------------------------------------------------ read
    def log(self) -> list[dict[str, Any]]:
        """Every fired fault, in fire order — the reproduction record two
        same-seed runs must agree on."""
        return [f.as_dict() for f in self._log]

    @property
    def fired(self) -> int:
        return len(self._log)

    def fired_by_site(self) -> dict[str, int]:
        return {s: n for s, n in sorted(self._fired_per_site.items()) if n}

    def describe(self) -> dict[str, Any]:
        """The provenance block: seed + per-site schedule (what to stamp
        next to any result produced under this plan)."""
        return {
            "seed": self.seed,
            "sites": {s: spec.describe() for s, spec in sorted(self.specs.items())},
        }

    def reset(self) -> "FaultPlan":
        """A fresh plan with the identical schedule (same seed, same specs)
        — the second run of a reproduction pair."""
        return FaultPlan(self.seed, self.specs, enabled=True)


NULL_FAULT_PLAN = FaultPlan(enabled=False)


def storm(seed: int = 0, *, dispatch_p: float = 0.0, halo_p: float = 0.0,
          kernel_p: float = 0.0, pool_p: float = 0.0, after: int = 0,
          max_fires: int = -1, delay_s: float = 0.005) -> FaultPlan:
    """Convenience builder: one probability per site, all actions enabled."""
    sites = {}
    for site, p in (("dispatch", dispatch_p), ("halo", halo_p),
                    ("kernel", kernel_p), ("pool", pool_p)):
        if p > 0:
            sites[site] = FaultSpec(
                probability=p, actions=SITE_ACTIONS[site], delay_s=delay_s,
                after=after, max_fires=max_fires,
            )
    return FaultPlan(seed, sites)


def poison_array(x, action: str):
    """Apply a ``kernel``-site fault to a device array: overwrite the first
    element with NaN ("nan") or Inf ("inf").  Deterministic — the poison
    lands at a fixed position so a retried clean dispatch is bitwise
    comparable."""
    import jax.numpy as jnp

    bad = float("nan") if action == "nan" else float("inf")
    flat = jnp.ravel(x)
    flat = flat.at[0].set(bad)
    return jnp.reshape(flat, x.shape)


def corrupt_ghosts(ghosts: tuple, action: str) -> tuple:
    """Apply a ``halo``-site fault to an exchanged ghost-slab tuple:
    "drop" zeroes the slabs (a lost message), "corrupt" fills them with
    NaN (a mangled one)."""
    import jax.numpy as jnp

    if action == "drop":
        return tuple(jnp.zeros_like(g) for g in ghosts)
    return tuple(jnp.full_like(g, float("nan")) for g in ghosts)

"""repro.chaos — seeded fault injection (see :mod:`repro.chaos.faults`).

``FaultPlan`` decides, deterministically per seed, whether each consulted
seam (host dispatch, halo exchange, kernel output, warm-pool build) fails
and how; ``NULL_FAULT_PLAN`` is the shared disabled instance every hot
path defaults to (one ``if faults.enabled`` branch, zero cost).
"""
from repro.chaos.faults import (
    NULL_FAULT_PLAN,
    SITE_ACTIONS,
    SITES,
    Fault,
    FaultPlan,
    FaultSpec,
    corrupt_ghosts,
    poison_array,
    storm,
)

__all__ = [
    "NULL_FAULT_PLAN",
    "SITE_ACTIONS",
    "SITES",
    "Fault",
    "FaultPlan",
    "FaultSpec",
    "corrupt_ghosts",
    "poison_array",
    "storm",
]

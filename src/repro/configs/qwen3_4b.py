"""Qwen3-4B: qk_norm + GQA, d_head=128 (decoupled from d_model/n_heads)
[hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    d_head=128,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
)

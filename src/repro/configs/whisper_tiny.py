"""Whisper-tiny backbone [arXiv:2212.04356]: 4L enc + 4L dec, d=384, 6H.

Conv frontend STUBBED: input_specs() provides 1500 precomputed frame
embeddings. Assigned 32k decode shapes exceed Whisper's 448-token decoder
context; honored structurally with sinusoidal positions (DESIGN.md note).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    is_encoder_decoder=True,
    n_encoder_layers=4,
    encoder_len=1500,
    max_decode_len=448,
)

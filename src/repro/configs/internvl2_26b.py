"""InternVL2-26B language backbone (InternLM2-20B-chat side) [arXiv:2404.16821].

[vlm]: the InternViT-6B frontend is a STUB per the assignment — input_specs()
provides precomputed patch embeddings (256 visual tokens after pixel
shuffle) injected at the head of the sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    rope_theta=1e6,
    n_patches=256,
)

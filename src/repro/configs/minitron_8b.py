"""Minitron-8B (pruned Nemotron-4) [arXiv:2407.14679]. Assignment dims;
the squared-ReLU FFN of Nemotron is mapped to the SwiGLU substrate (noted
in DESIGN.md deviations)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
)

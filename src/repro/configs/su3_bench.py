"""The paper's own workload: SU3_Bench lattice configs (core.su3.engine)."""
from repro.core.su3.engine import EngineConfig
from repro.core.su3.layouts import Layout

# Paper's headline configuration: L=32, fp32 (640 MiB A+C working set).
PAPER_L32 = EngineConfig(L=32, dtype="float32", layout=Layout.SOA, variant="pallas",
                         iterations=100, warmups=1)
# PIUMA-section configuration: L=16 and L=32, 4 iterations (paper §5).
PIUMA_L16 = EngineConfig(L=16, dtype="float32", layout=Layout.SOA, variant="pallas",
                         iterations=4, warmups=0)
# CPU-friendly smoke configuration.
SMOKE_L8 = EngineConfig(L=8, dtype="float32", layout=Layout.SOA, variant="pallas",
                        iterations=3, warmups=1, tile=128)

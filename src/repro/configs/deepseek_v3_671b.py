"""DeepSeek-V3 671B [arXiv:2412.19437]: MLA (q_lora 1536 / kv_lora 512 /
nope 128 / rope 64 / v 128), 3 dense layers + 58 MoE layers of 256 routed
experts (top-8, sigmoid aux-loss-free routing) + 1 shared expert, MTP.

The assignment's d_ff=2048 is the *expert* width; dense layers use 18432.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,  # MLA: per-head K/V decompressed from the shared latent
    d_ff=18432,
    vocab_size=129280,
    n_experts=256,
    experts_per_token=8,
    n_shared_experts=1,
    d_ff_expert=2048,
    n_dense_layers=3,
    router_aux_free=True,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    mtp_depth=1,
)

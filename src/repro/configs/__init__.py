"""Architecture config registry: get_config("<arch-id>")."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable

_MODULES = {
    "internvl2-26b": "internvl2_26b",
    "granite-34b": "granite_34b",
    "qwen3-4b": "qwen3_4b",
    "minitron-8b": "minitron_8b",
    "yi-6b": "yi_6b",
    "zamba2-1.2b": "zamba2_1p2b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "xlstm-125m": "xlstm_125m",
    "whisper-tiny": "whisper_tiny",
}

ALL_ARCHS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


__all__ = ["ALL_ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "get_config", "shape_applicable"]

"""xLSTM-125M [arXiv:2405.04517]: mLSTM blocks with sLSTM blocks interleaved
(~7:1 ratio -> positions 5 and 11 of 12). d_ff=0 per assignment: the xLSTM
block's up/down projections subsume the FFN. Runs long_500k (O(1) state)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm_expand=2,
    ssm_conv=4,
    slstm_layers=(5, 11),
)

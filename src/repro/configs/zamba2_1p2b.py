"""Zamba2-1.2B hybrid: Mamba2 backbone + ONE shared attention block applied
every 6 layers (weight sharing across applications) [arXiv:2411.15242].

Runs long_500k: decode state is O(1) per Mamba2 layer; the shared-attention
KV caches (6 applications) are head-sharded over the model axis.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_heads=64,  # d_inner=4096, headdim=64 (Mamba2 default)
    ssm_conv=4,
    hybrid_attn_every=6,
)

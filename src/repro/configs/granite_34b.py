"""Granite-34B-Code (llama-arch MQA variant per assignment) [arXiv:2405.04324].

kv=1 (MQA): KV heads cannot shard over a 16-way model axis — the sharding
resolver replicates KV and shards the 48 query heads (see distributed/sharding).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
)

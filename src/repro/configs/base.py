"""Configuration system: model configs (assigned architecture pool) + shapes.

Every assigned architecture is a ``ModelConfig``; input-shape cells are
``ShapeConfig``s. ``reduced()`` produces the CPU-smoke-test variant of the
same family (small layers/width/experts, tiny vocab) per the assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # -- MoE ----------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0  # leading dense layers (DeepSeek-V3: 3)
    capacity_factor: float = 1.25
    router_aux_free: bool = False  # DeepSeek aux-loss-free bias routing

    # -- MLA (DeepSeek) -------------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0  # multi-token-prediction modules

    # -- SSM / hybrid ---------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_heads: int = 0  # 0 -> d_inner // 64
    hybrid_attn_every: int = 0  # zamba: shared attn block applied every k layers
    slstm_layers: tuple[int, ...] = ()  # xlstm: which layers are sLSTM
    attn_window: int = 0  # sliding window cap for hybrid long-context attn

    # -- encoder-decoder / frontend stubs ------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 0  # stub frame count (whisper: 1500)
    n_patches: int = 0  # vlm stub patch count injected at sequence head
    max_decode_len: int = 0  # architectural decoder context (0 = unlimited)

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_recurrent(self) -> bool:
        """True if decode state is O(1) in sequence length (SSM families)."""
        return self.family in ("hybrid", "ssm")

    @property
    def supports_long_context(self) -> bool:
        """long_500k eligibility: sub-quadratic / O(1)-state decode families."""
        return self.is_recurrent

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        total += self._block_params()
        return total

    def _block_params(self) -> int:
        d = self.d_model
        hd = self.head_dim
        # attention
        if self.use_mla:
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        ffn_dense = 3 * d * self.d_ff
        if self.is_moe:
            expert = 3 * d * self.d_ff_expert
            moe = self.n_experts * expert + self.n_shared_experts * expert + d * self.n_experts
            n_moe = self.n_layers - self.n_dense_layers
            ffn_total = self.n_dense_layers * ffn_dense + n_moe * moe
            return self.n_layers * attn + ffn_total
        if self.family in ("hybrid", "ssm"):
            d_in = self.ssm_expand * d
            ssm = d * (2 * d_in + 2 * self.ssm_state) + d_in * d  # rough
            return self.n_layers * ssm + (attn + ffn_dense) * max(
                1, self.n_layers // max(self.hybrid_attn_every, 1) if self.hybrid_attn_every else self.n_layers
            )
        enc = self.n_encoder_layers * (attn + 2 * d * self.d_ff)
        dec_cross = self.n_layers * attn if self.is_encoder_decoder else 0
        return self.n_layers * (attn + ffn_dense) + enc + dec_cross

    def active_params(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        expert = 3 * d * self.d_ff_expert
        n_moe = self.n_layers - self.n_dense_layers
        dense_total = self.n_params() - n_moe * (self.n_experts - 0) * expert
        active_moe = n_moe * (self.experts_per_token + self.n_shared_experts) * expert
        return dense_total + active_moe

    def reduced(self) -> "ModelConfig":
        """Smoke-test config of the same family: tiny dims, same structure."""
        scale = dict(
            n_layers=min(self.n_layers, 4 if not self.hybrid_attn_every else 5),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            d_head=32,
            d_ff=256,
            vocab_size=512,
            dtype="float32",
        )
        if self.is_moe:
            scale.update(
                n_experts=8,
                experts_per_token=min(self.experts_per_token, 2),
                d_ff_expert=64,
                n_dense_layers=min(self.n_dense_layers, 1),
            )
        if self.use_mla:
            scale.update(
                q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                qk_rope_head_dim=16, v_head_dim=32, d_head=0,
            )
        if self.family in ("hybrid", "ssm"):
            scale.update(ssm_state=16, ssm_heads=4, d_head=32)
        if self.slstm_layers:
            scale.update(n_layers=4, slstm_layers=(1, 3))
        if self.hybrid_attn_every:
            scale.update(hybrid_attn_every=2)
        if self.is_encoder_decoder:
            scale.update(n_encoder_layers=2, encoder_len=64)
        if self.n_patches:
            scale.update(n_patches=16)
        if self.mtp_depth:
            scale.update(mtp_depth=1)
        return dataclasses.replace(self, **scale)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# The assigned LM shape set (seq_len x global_batch); decode_* / long_* lower
# serve_step (one new token against a KV cache of seq_len), not train_step.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable, reason-if-not) per the assignment's skip rules."""
    if shape.name == "long_500k" and not model.supports_long_context:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""

"""SU3 autotune: the paper's §4/§5.4 methodology as a driver, with a cache.

Hillclimbs the SU3 kernel the way the paper does — enumerate candidates
(layout, variant, Pallas tile), napkin-math the expected effect, measure,
keep the winner:

  * layout sweep charges the traffic model (AOS streams 320 B/site vs SoA
    288 B — the paper's streaming-store/padding point) and cross-checks it
    at the HLO level by lowering the *physical* ExecutionPlan step, so the
    packed layout actually shows up in the counted bytes;
  * tile sweep bounds the VMEM working set (the paper's register-blocking
    point re-derived for HBM->VMEM) and measures each candidate;
  * ``best_config`` selects the tile with the best *measured* GFLOPS among
    VMEM-fitting, verified candidates and persists the decision in a JSON
    cache keyed by (backend, device_kind, layout, dtype, L, n_devices) — a
    second call loads the tuned plan with zero measurements, so engines,
    serving, and benchmarks all start from the tuned tuple for free.

Cache location: ``$REPRO_SU3_CACHE_DIR`` or ``~/.cache/repro_su3``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import hlo_costs, roofline
from repro.core.su3 import layouts, registry, variants
from repro.core.su3.engine import EngineConfig, SU3Engine
from repro.core.su3.plan import make_raw_step
from repro.kernels import su3_matmul

CACHE_ENV = "REPRO_SU3_CACHE_DIR"
CACHE_FILE = "su3_autotune.json"


@dataclasses.dataclass
class TuneResult:
    config: dict[str, Any]
    measured_gflops: float
    hlo_bytes_per_site: float
    model_bytes_per_site: float
    vmem_bytes: int
    v5e_bound_gf: float


# ---------------------------------------------------------------------------
# HLO-level accounting
# ---------------------------------------------------------------------------


def hlo_bytes_for_variant(
    variant: str, layout: layouts.Layout, n_sites: int = 4096, tile: int = 512
) -> float:
    """Lower the *physical* plan step through XLA; count HLO bytes per site.

    The operands are packed per the requested layout before lowering (via the
    layout codec), so AOS genuinely streams its 80-word sites and SOA its
    72-word sites — previously the canonical complex operands were lowered
    for every non-Pallas variant and the ``layout`` argument was ignored,
    making the AOS and SOA rows identical.
    """
    codec = layouts.make_codec(layout, tile=tile, dtype="float32")
    entry = registry.get_kernel(variant)
    interpret = True if entry.form == registry.PLANAR else None
    step = make_raw_step(codec, entry, tile=tile, interpret=interpret)
    pad = (-n_sites) % tile
    a = jnp.zeros((n_sites + pad, 4, 3, 3), jnp.complex64)
    a_phys = codec.pack(a)
    b_p = codec.pack_b(jnp.zeros((4, 3, 3), jnp.complex64))
    compiled = jax.jit(step).lower(a_phys, b_p).compile()
    cost = hlo_costs.analyze_hlo(compiled.as_text())
    return cost.bytes / (n_sites + pad)  # bytes per site actually lowered


# ---------------------------------------------------------------------------
# Sweeps
# ---------------------------------------------------------------------------


def tile_sweep(
    tiles: tuple[int, ...] = (128, 256, 512, 1024, 2048, 4096),
    L: int = 8,
    dtype: str = "float32",
) -> list[dict]:
    """VMEM working set + measured engine time per Pallas tile."""
    rows = []
    for tile in tiles:
        vmem = su3_matmul.vmem_bytes(tile)
        fits = vmem <= roofline.TPU_V5E.vmem_bytes
        cfg = EngineConfig(L=L, dtype=dtype, variant="pallas", layout=layouts.Layout.SOA,
                           tile=tile, iterations=2, warmups=1)
        r = SU3Engine(cfg).run()
        rows.append({
            "tile": tile, "vmem_kib": vmem // 1024, "fits_vmem": fits,
            "measured_gflops": round(r.gflops, 3), "verified": r.verified,
        })
    return rows


def layout_sweep(n_sites: int = 4096) -> list[dict]:
    """The paper's AoS->SoA traffic claim, measured at the HLO level."""
    rows = []
    for variant, layout in (("versionX", layouts.Layout.AOS),
                            ("versionX", layouts.Layout.SOA),
                            ("version_gemm", layouts.Layout.SOA),
                            ("pallas", layouts.Layout.SOA)):
        tm = layouts.TrafficModel(layout, n_sites, 4)
        hlo_b = hlo_bytes_for_variant(variant, layout, n_sites)
        bound = roofline.TPU_V5E.hbm_bw * tm.arithmetic_intensity / 1e9
        rows.append({
            "variant": variant, "layout": layout.value,
            "model_bytes_per_site": tm.bytes_per_site_rw,
            "hlo_bytes_per_site": round(hlo_b, 1),
            "ai": round(tm.arithmetic_intensity, 3),
            "v5e_bound_gf": round(bound, 1),
        })
    return rows


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------


def cache_dir() -> str:
    return os.environ.get(
        CACHE_ENV, os.path.join(os.path.expanduser("~"), ".cache", "repro_su3")
    )


def cache_key(
    *, backend: str, device_kind: str, layout: str, dtype: str, L: int, n_devices: int
) -> str:
    return f"{backend}|{device_kind}|{layout}|{dtype}|L{L}|d{n_devices}"


def _cache_path(directory: str | None) -> str:
    return os.path.join(directory or cache_dir(), CACHE_FILE)


def load_cache(directory: str | None = None) -> dict[str, Any]:
    path = _cache_path(directory)
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {}


def store_cache_entry(
    key: str, entry: dict[str, Any], directory: str | None = None
) -> None:
    """Read-modify-write the cache file via an atomic rename."""
    path = _cache_path(directory)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    cache = load_cache(directory)
    cache[key] = entry
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(cache, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _device_identity() -> tuple[str, str, int]:
    devs = jax.devices()
    return jax.default_backend(), devs[0].device_kind, len(devs)


# ---------------------------------------------------------------------------
# The tuned production config
# ---------------------------------------------------------------------------


def best_config(
    L: int = 8,
    dtype: str = "float32",
    *,
    cache: bool = True,
    cache_directory: str | None = None,
    refresh: bool = False,
) -> dict[str, Any]:
    """The tuned production config: SoA + the tile with the best MEASURED GFLOPS.

    Selection is by measured throughput among VMEM-fitting, verified tiles —
    not the largest fitting tile, which on real devices can sit past the
    occupancy knee.  The decision is persisted; later calls (any process)
    with the same (backend, device_kind, layout, dtype, L, n_devices) key do
    zero measurements.
    """
    backend, device_kind, n_devices = _device_identity()
    key = cache_key(
        backend=backend, device_kind=device_kind, layout="soa",
        dtype=dtype, L=L, n_devices=n_devices,
    )
    if cache and not refresh:
        hit = load_cache(cache_directory).get(key)
        if hit is not None:
            return dict(hit["config"], cached=True)

    rows = [r for r in tile_sweep(L=L, dtype=dtype) if r["fits_vmem"] and r["verified"]]
    if not rows:
        raise RuntimeError("no VMEM-fitting verified tile candidate")
    winner = max(rows, key=lambda r: r["measured_gflops"])
    config = {"layout": "soa", "variant": "pallas", "tile": winner["tile"]}
    if cache:
        store_cache_entry(
            key,
            {"config": config, "measured_gflops": winner["measured_gflops"], "key": key},
            cache_directory,
        )
    return dict(config, cached=False)


def tuned_engine_config(
    L: int = 8, dtype: str = "float32", *, cache_directory: str | None = None, **overrides
) -> EngineConfig:
    """EngineConfig built from the (cached) tuned tuple, override-able."""
    tuned = best_config(L=L, dtype=dtype, cache_directory=cache_directory)
    base = {
        "L": L, "dtype": dtype, "layout": layouts.Layout(tuned["layout"]),
        "variant": tuned["variant"], "tile": tuned["tile"],
    }
    base.update(overrides)
    return EngineConfig(**base)


if __name__ == "__main__":
    print("== tile sweep (VMEM blocking) ==")
    for r in tile_sweep():
        print("  ", r)
    print("== layout sweep (traffic) ==")
    for r in layout_sweep():
        print("  ", r)
    print("best:", best_config())

"""SU3 autotune: the paper's §4/§5.4 methodology as a driver, with a cache.

Hillclimbs the SU3 kernel the way the paper does — enumerate candidates,
napkin-math the expected effect, measure, keep the winner:

  * layout sweep charges the traffic model (AOS streams 320 B/site vs SoA
    288 B — the paper's streaming-store/padding point) and cross-checks it
    at the HLO level by lowering the *physical* ExecutionPlan step, so the
    packed layout actually shows up in the counted bytes;
  * the **pipeline sweep** enumerates the joint (tile, fused_k) grid,
    *ranks* it with the three-term roofline model — memory (traffic model,
    amortized over the fused chain), compute (VPU roof), and the paper's
    §5.3 **issue-rate term**, estimated from the lowered kernel's
    instruction mix — and only MEASURES the top ``prune`` fraction.  The
    exhaustive sweep's measurement bill drops by >= 2x while the model keeps
    the true winner inside the measured set (asserted by tests);
  * ``best_config`` selects the candidate with the best *measured* GFLOPS
    among verified, VMEM-fitting candidates and persists the decision —
    tile, fused chain depth, and the ``pipeline`` provenance block (schema
    version, candidates ranked vs measured, predicted rank of the winner) —
    in a JSON cache keyed by (schema, backend, device_kind, layout, dtype,
    L, n_devices).  A second call loads the tuned plan with zero
    measurements, so engines, serving, and benchmarks all start from the
    tuned tuple for free.

Cache schema: v3 (the ``compression`` axis on multiply configs and the
``depth`` axis on stencil configs).  Keys carry the version, so v1/v2
entries simply miss and re-measure — they are never read with missing
fields.

Cache location: ``$REPRO_SU3_CACHE_DIR`` or ``~/.cache/repro_su3``.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import hlo_costs, roofline
from repro.core.su3 import layouts, registry, variants
from repro.core.su3.engine import EngineConfig, SU3Engine
from repro.core.su3.layouts import Layout
from repro.core.su3.plan import make_raw_step
from repro.kernels import su3_matmul, su3_stencil

CACHE_ENV = "REPRO_SU3_CACHE_DIR"
CACHE_FILE = "su3_autotune.json"
SCHEMA_VERSION = 3  # v3: compression axis in the key + depth axis on stencils
DEFAULT_PRUNE = 0.5  # measure the top half of the model-ranked candidates
DEFAULT_TILES = (128, 256, 512, 1024, 2048, 4096)
DEFAULT_KS = (1, 2, 4, 8)
DEFAULT_DEPTHS = (1, 2)  # halo exchange depths the stencil sweep considers
# per-dispatch fixed cost in issue slots (kernel launch + grid sequencing);
# amortized over the fused chain, which is what makes deep K win at small L
DISPATCH_ISSUE_SLOTS = 5_000.0
# fixed per-exchange latency (collective setup + neighbor sync), the term a
# depth-2 communication-avoiding schedule amortizes over two applications
HALO_EXCHANGE_LATENCY_S = 2e-5


@dataclasses.dataclass
class TuneResult:
    config: dict[str, Any]
    measured_gflops: float
    hlo_bytes_per_site: float
    model_bytes_per_site: float
    vmem_bytes: int
    v5e_bound_gf: float


# ---------------------------------------------------------------------------
# HLO-level accounting
# ---------------------------------------------------------------------------


def hlo_bytes_for_variant(
    variant: str,
    layout: layouts.Layout,
    n_sites: int = 4096,
    tile: int = 512,
    dtype: str = "float32",
    accum_dtype: str = "",
    compression: str = "none",
) -> float:
    """Lower the *physical* plan step through XLA; count HLO bytes per site.

    The operands are packed per the requested layout before lowering (via the
    layout codec), so AOS genuinely streams its 80-word sites and SOA its
    72-word sites — previously the canonical complex operands were lowered
    for every non-Pallas variant and the ``layout`` argument was ignored,
    making the AOS and SOA rows identical.

    ``dtype``/``accum_dtype`` lower the mixed-precision storage plans: a
    bf16-storage / f32-accumulate plan streams 2-byte operands and results,
    so its measured bytes/site land well under the f32 plan's even though
    every FMA runs at f32 (converts are charged at the narrow side — the
    paper-correct streaming cost).

    ``compression="two_row"`` lowers the 12-real gauge plan: the packed
    operand physically carries 48 words/site and the kernel reconstructs the
    third row in-register, so the compressed bytes show up in the counted
    HLO traffic rather than being asserted from the model.
    """
    codec = layouts.make_codec(
        layout, tile=tile, dtype=dtype, accum_dtype=accum_dtype,
        compression=layouts.GaugeCompression(compression),
    )
    entry = registry.get_kernel(variant)
    interpret = True if entry.form == registry.PLANAR else None
    step = make_raw_step(codec, entry, tile=tile, interpret=interpret)
    pad = (-n_sites) % tile
    a = jnp.zeros((n_sites + pad, 4, 3, 3), jnp.complex64)
    a_phys = codec.pack(a)
    b_p = codec.pack_b(jnp.zeros((4, 3, 3), jnp.complex64))
    compiled = jax.jit(step).lower(a_phys, b_p).compile()
    cost = hlo_costs.analyze_hlo(compiled.as_text())
    return cost.bytes / (n_sites + pad)  # bytes per site actually lowered


# ---------------------------------------------------------------------------
# Sweeps
# ---------------------------------------------------------------------------


def tile_sweep(
    tiles: tuple[int, ...] = (128, 256, 512, 1024, 2048, 4096),
    L: int = 8,
    dtype: str = "float32",
    accum_dtype: str = "",
) -> list[dict]:
    """VMEM working set + measured engine time per Pallas tile.

    The working-set bound honors the sweep's dtypes: bf16 storage halves the
    resident tile bytes, while a wider accumulate re-inflates them (the
    upcast tiles are what actually sit in VMEM).

    Exhaustive marginal sweep (every tile at k=1), kept for the CLI and
    diagnostics; production tuning goes through the roofline-pruned joint
    :func:`pipeline_sweep`.
    """
    word_b = layouts.WORD_BYTES[dtype]
    accum_b = layouts.WORD_BYTES[accum_dtype] if accum_dtype else None
    rows = []
    for tile in tiles:
        vmem = su3_matmul.vmem_bytes(tile, word_b, accum_b)
        fits = vmem <= roofline.TPU_V5E.vmem_bytes
        cfg = EngineConfig(L=L, dtype=dtype, variant="pallas", layout=layouts.Layout.SOA,
                           tile=tile, accum_dtype=accum_dtype, iterations=2, warmups=1)
        r = SU3Engine(cfg).run()
        rows.append({
            "tile": tile, "vmem_kib": vmem // 1024, "fits_vmem": fits,
            "measured_gflops": round(r.gflops, 3), "verified": r.verified,
        })
    return rows


def k_sweep(
    ks: tuple[int, ...] = (1, 2, 4, 8),
    L: int = 8,
    dtype: str = "float32",
    tile: int = 512,
    accum_dtype: str = "",
) -> list[dict]:
    """Measured per-multiply GFLOPS of the fused chain at each depth K.

    The fused step amortizes one dispatch (and on TPU one HBM roundtrip) over
    K multiplies, but past some K the chain stops helping — longer in-kernel
    chains grow the straight-line body (or fall to the fori_loop) without
    removing any more overhead.  The knee depends on (backend, L), so it is
    measured, not assumed, and ``best_config`` persists the winner next to
    the tile.
    """
    rows = []
    for k in ks:
        cfg = EngineConfig(L=L, dtype=dtype, variant="pallas", layout=layouts.Layout.SOA,
                           tile=tile, accum_dtype=accum_dtype, iterations=2, warmups=1)
        r = SU3Engine(cfg).run_fused(k=k, reps=2)
        rows.append({
            "k": k, "measured_gflops": round(r.gflops, 3), "verified": r.verified,
        })
    return rows


def layout_sweep(n_sites: int = 4096) -> list[dict]:
    """The paper's AoS->SoA traffic claim, measured at the HLO level.

    The bf16-storage / f32-accumulate row is the MILC-on-KNL reduced-
    precision-storage scheme; the ``two_row`` rows stack the 12-real gauge
    compression on top (48 words/site streamed, third row reconstructed
    in-register), both measured at the HLO level rather than assumed.
    """
    rows = []
    for variant, layout, dtype, accum, comp in (
            ("versionX", layouts.Layout.AOS, "float32", "", "none"),
            ("versionX", layouts.Layout.SOA, "float32", "", "none"),
            ("version_gemm", layouts.Layout.SOA, "float32", "", "none"),
            ("pallas", layouts.Layout.SOA, "float32", "", "none"),
            ("pallas", layouts.Layout.SOA, "bfloat16", "float32", "none"),
            ("pallas", layouts.Layout.SOA, "float32", "", "two_row"),
            ("pallas", layouts.Layout.SOA, "bfloat16", "float32", "two_row")):
        tm = layouts.TrafficModel.for_dtype(
            layout, n_sites, dtype, compression=layouts.GaugeCompression(comp)
        )
        hlo_b = hlo_bytes_for_variant(variant, layout, n_sites,
                                      dtype=dtype, accum_dtype=accum,
                                      compression=comp)
        bound = roofline.TPU_V5E.hbm_bw * tm.arithmetic_intensity / 1e9
        rows.append({
            "variant": variant, "layout": layout.value, "dtype": dtype,
            "accum_dtype": accum or dtype, "compression": comp,
            "model_bytes_per_site": tm.bytes_per_site_rw,
            "hlo_bytes_per_site": round(hlo_b, 1),
            "ai": round(tm.arithmetic_intensity, 3),
            "v5e_bound_gf": round(bound, 1),
        })
    return rows


# ---------------------------------------------------------------------------
# Roofline-pruned pipeline sweep: rank the (tile, fused_k) grid with the
# three-term model, measure only the top fraction.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PipelineCandidate:
    """One point of the joint (Pallas tile, fused chain depth) grid."""

    tile: int
    fused_k: int


def enumerate_candidates(
    tiles: tuple[int, ...] = DEFAULT_TILES,
    ks: tuple[int, ...] = DEFAULT_KS,
    dtype: str = "float32",
    accum_dtype: str = "",
    hw: roofline.HardwareSpec = roofline.TPU_V5E,
) -> list[PipelineCandidate]:
    """The VMEM-fitting (tile, fused_k) grid — the exhaustive candidate set
    the pruner ranks.  Tiles whose resident working set (at the wider of
    storage/accumulate width) exceeds ``hw``'s tile store never become
    candidates."""
    word_b = layouts.WORD_BYTES[dtype]
    accum_b = layouts.WORD_BYTES[accum_dtype] if accum_dtype else None
    return [
        PipelineCandidate(tile, k)
        for tile in tiles
        if su3_matmul.vmem_bytes(tile, word_b, accum_b) <= hw.vmem_bytes
        for k in ks
    ]


_INSTR_MODEL_CACHE: dict[tuple[str, str, int, str], tuple[float, float]] = {}


def kernel_instruction_model(
    dtype: str = "float32", accum_dtype: str = "", tile: int = 256,
    compression: str = "none",
) -> tuple[float, float]:
    """(base, per_multiply) issued-instruction counts of ONE kernel grid step.

    Estimated from the *lowered* kernel's instruction mix, the way the paper
    derives the PIUMA bound from its 12-load/2-store/12-FMA pattern: lower
    the fused planar kernel at chain depths 1 and 2 over a single-tile grid
    and difference the loop-aware HLO instruction counts —

        instructions_per_step(k) ~= base + per_multiply * k

    where ``base`` is the fixed staging cost (tile load/store, bookkeeping)
    and ``per_multiply`` the chained-FMA body.  Instruction counts are
    vector-ISSUE counts: one op however wide its lane payload, which is
    exactly why a larger tile lowers the issue bill per site.
    """
    key = (dtype, accum_dtype, tile, compression)
    if key not in _INSTR_MODEL_CACHE:
        codec = layouts.make_codec(
            Layout.SOA, tile=tile, dtype=dtype, accum_dtype=accum_dtype,
            compression=layouts.GaugeCompression(compression),
        )
        entry = registry.get_kernel("pallas")

        def instrs(k: int) -> float:
            step = make_raw_step(codec, entry, tile=tile, k_iters=k, interpret=True)
            a_p = jnp.zeros((2, codec.planar_rows, tile), codec.word_dtype)
            b_p = jnp.zeros((2, layouts.PLANAR_ROWS), codec.word_dtype)
            compiled = jax.jit(step).lower(a_p, b_p).compile()
            return hlo_costs.analyze_hlo(compiled.as_text()).instructions

        i1, i2 = instrs(1), instrs(2)
        per_mult = max(i2 - i1, 1.0)
        base = max(i1 - per_mult, 0.0)
        _INSTR_MODEL_CACHE[key] = (base, per_mult)
    return _INSTR_MODEL_CACHE[key]


def predict_pipeline(
    cand: PipelineCandidate,
    L: int,
    dtype: str = "float32",
    accum_dtype: str = "",
    hw: roofline.HardwareSpec = roofline.TPU_V5E,
    compression: str = "none",
) -> dict[str, Any]:
    """Three-term per-multiply roofline prediction for one candidate.

    memory_s amortizes the one HBM read + write over the fused chain (the
    chain runs on the VMEM-resident tile), compute_s is the VPU roof, and
    issue_s charges the instruction mix of ``grid_steps`` kernel steps plus
    the per-dispatch launch cost, both amortized over the chain — the three
    rates whose max is the predicted bound.
    """
    n_sites = L**4
    padded = ((n_sites + cand.tile - 1) // cand.tile) * cand.tile
    k = cand.fused_k
    tm = layouts.TrafficModel.for_dtype(
        Layout.SOA, padded, dtype,
        compression=layouts.GaugeCompression(compression),
    )
    # every term charges the PADDED work (what the kernel executes); the
    # predicted throughput credits only the USEFUL flops (what the engine
    # reports), so an oversized tile at small L ranks as badly as it measures
    compute_s = float(tm.flops_per_site) * padded / hw.peak_flops_vpu
    memory_s = tm.total_bytes / k / hw.hbm_bw
    issue_s = 0.0
    if hw.issue_rate:
        base, per_mult = kernel_instruction_model(
            dtype, accum_dtype, compression=compression
        )
        grid_steps = padded // cand.tile
        instrs = grid_steps * (base / k + per_mult) + DISPATCH_ISSUE_SLOTS / k
        issue_s = instrs / hw.issue_rate
    bound_s = max(compute_s, memory_s, issue_s)
    terms = {"compute": compute_s, "memory": memory_s, "issue": issue_s}
    useful_flops = float(tm.flops_per_site) * n_sites  # per multiply
    return {
        "tile": cand.tile,
        "fused_k": k,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "issue_s": issue_s,
        "bound_s": bound_s,
        "dominant": max(terms, key=terms.get),
        "predicted_gflops": round(useful_flops / bound_s / 1e9, 3),
    }


def measure_candidate(
    cand: PipelineCandidate, L: int = 8, dtype: str = "float32",
    accum_dtype: str = "", compression: str = "none",
) -> dict[str, Any]:
    """Measured per-multiply GFLOPS of one (tile, fused_k) candidate — the
    fused chain run exactly as it deploys."""
    word_b = layouts.WORD_BYTES[dtype]
    accum_b = layouts.WORD_BYTES[accum_dtype] if accum_dtype else None
    vmem = su3_matmul.vmem_bytes(cand.tile, word_b, accum_b)
    cfg = EngineConfig(
        L=L, dtype=dtype, variant="pallas", layout=Layout.SOA,
        tile=cand.tile, accum_dtype=accum_dtype, iterations=2, warmups=1,
        compression=compression,
    )
    r = SU3Engine(cfg).run_fused(k=cand.fused_k, reps=2)
    return {
        "tile": cand.tile,
        "fused_k": cand.fused_k,
        "vmem_kib": vmem // 1024,
        "measured_gflops": round(r.gflops, 3),
        "verified": r.verified,
    }


def pipeline_sweep(
    L: int = 8,
    dtype: str = "float32",
    accum_dtype: str = "",
    *,
    compression: str = "none",
    prune: float = DEFAULT_PRUNE,
    tiles: tuple[int, ...] = DEFAULT_TILES,
    ks: tuple[int, ...] = DEFAULT_KS,
    measure_fn: Callable[[PipelineCandidate], dict[str, Any]] | None = None,
    hw: roofline.HardwareSpec = roofline.TPU_V5E,
) -> dict[str, Any]:
    """Rank the candidate grid with the roofline model; measure the top slice.

    Args:
        prune: fraction of the model-ranked candidate set to measure
            (``>= 1`` = exhaustive; the default measures half).  At least
            one candidate is always measured.
        measure_fn: measurement override (tests inject deterministic
            measurements; production uses :func:`measure_candidate`).

    Returns:
        ``{"rows", "candidates_total", "candidates_measured", "prune"}`` —
        each row carries the model prediction (compute/memory/issue seconds,
        predicted GFLOPS, ``predicted_rank``) joined with the measurement.
    """
    cands = enumerate_candidates(tiles, ks, dtype, accum_dtype, hw)
    if not cands:
        raise RuntimeError("no VMEM-fitting pipeline candidate")
    preds = [
        predict_pipeline(c, L, dtype, accum_dtype, hw, compression=compression)
        for c in cands
    ]
    order = sorted(range(len(cands)), key=lambda i: -preds[i]["predicted_gflops"])
    n_meas = len(cands) if prune >= 1 else max(1, math.ceil(prune * len(cands)))
    if measure_fn is None:
        measure_fn = lambda c: measure_candidate(  # noqa: E731
            c, L=L, dtype=dtype, accum_dtype=accum_dtype, compression=compression
        )
    rows = []
    for rank, i in enumerate(order[:n_meas]):
        row = dict(preds[i])
        row.update(measure_fn(cands[i]))
        row["predicted_rank"] = rank
        rows.append(row)
    return {
        "rows": rows,
        "candidates_total": len(cands),
        "candidates_measured": n_meas,
        "prune": prune,
    }


# ---------------------------------------------------------------------------
# Roofline-pruned stencil sweep: rank (tile, overlap) stencil variants with a
# model whose bandwidth term includes the halo exchange, measure the top
# fraction.  The stencil is the first workload where the PR 3 halo model is a
# *schedule* input rather than a price list: overlap on/off changes whether
# halo seconds add to the core roofline bound or hide under it.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StencilCandidate:
    """One point of the stencil variant grid: Pallas site tile x whether the
    interior/boundary overlap schedule is used x halo exchange depth (a
    depth-d exchange ships d ghost rings and runs d stencil applications per
    exchange, recomputing the intermediate ring locally)."""

    tile: int
    overlap: bool
    depth: int = 1


def enumerate_stencil_candidates(
    tiles: tuple[int, ...] = DEFAULT_TILES,
    overlaps: tuple[bool, ...] = (False, True),
    dtype: str = "float32",
    accum_dtype: str = "",
    hw: roofline.HardwareSpec = roofline.TPU_V5E,
    depths: tuple[int, ...] = DEFAULT_DEPTHS,
) -> list[StencilCandidate]:
    """The VMEM-fitting (tile, overlap, depth) grid the stencil pruner ranks.
    The stencil grid step resides U + 8 neighbor + out tiles, so its VMEM
    bound is tighter than the multiply's at the same tile.  Depth > 1 exists
    only on the overlap schedule (the communication-avoiding step-2 path is
    built from the overlap machinery), so (overlap=False, depth=2) is never
    a candidate."""
    word_b = layouts.WORD_BYTES[dtype]
    accum_b = layouts.WORD_BYTES[accum_dtype] if accum_dtype else None
    return [
        StencilCandidate(tile, ov, d)
        for tile in tiles
        if su3_stencil.stencil_vmem_bytes(tile, word_b, accum_b) <= hw.vmem_bytes
        for ov in overlaps
        for d in depths
        if ov or d == 1
    ]


_STENCIL_INSTR_CACHE: dict[tuple[str, str, str], float] = {}
_STENCIL_INSTR_TILE = 256  # fixed lowering tile: issue counts are vector-
# ISSUE counts (one op however wide the lane payload), so per-step cost is
# tile-independent — same convention as kernel_instruction_model


def stencil_instruction_model(
    dtype: str = "float32", accum_dtype: str = "", compression: str = "none"
) -> float:
    """Issued-instruction count of ONE stencil kernel grid step, from the
    lowered kernel's loop-aware instruction mix (same method as
    :func:`kernel_instruction_model`; the stencil has no chain-depth knob, so
    a single lowering at a fixed tile suffices)."""
    key = (dtype, accum_dtype, compression)
    if key not in _STENCIL_INSTR_CACHE:
        tile = _STENCIL_INSTR_TILE
        entry = registry.get_kernel("pallas_stencil")
        wdt = jnp.dtype(dtype)
        kw: dict[str, Any] = {"tile": tile, "interpret": True}
        if accum_dtype:
            kw["accum_dtype"] = accum_dtype
        rows = layouts.PLANAR_ROWS
        if compression == layouts.GaugeCompression.TWO_ROW.value:
            kw["compressed"] = True
            rows = layouts.PLANAR_COMP_ROWS
        u = jnp.zeros((2, rows, tile), wdt)
        vn = jnp.zeros((8, 2, 3, tile), wdt)
        compiled = (
            jax.jit(lambda u, vn: entry.fn(u, vn, **kw)).lower(u, vn).compile()
        )
        _STENCIL_INSTR_CACHE[key] = float(
            hlo_costs.analyze_hlo(compiled.as_text()).instructions
        )
    return _STENCIL_INSTR_CACHE[key]


def _stencil_halo_spec(L: int, hosts: int, word_bytes: int, depth: int = 1):
    """Vector-field HaloSpec for ``hosts`` slabs (0 halo on one host)."""
    from repro.distributed import sharding as dist_sharding

    return dist_sharding.HaloSpec(
        L=L, n_shards=max(hosts, 1), word_bytes=word_bytes,
        words_per_site=dist_sharding.VECTOR_WORDS_PER_SITE, depth=depth,
    )


def predict_stencil(
    cand: StencilCandidate,
    L: int,
    dtype: str = "float32",
    accum_dtype: str = "",
    hosts: int = 1,
    hw: roofline.HardwareSpec = roofline.TPU_V5E,
    compression: str = "none",
) -> dict[str, Any]:
    """Roofline prediction for one stencil variant, halo bytes included.

    Every quantity is PER STENCIL APPLICATION, so depth-1 and depth-2 rows
    compare directly.  The core terms are the usual three (memory streams
    U + 8 neighbor fields + out — 102 words/site when the gauge field is
    two-row compressed, 150 full; VPU compute at 576 flops/site; instruction
    issue per grid step plus per-dispatch launch cost).  The fourth term is
    the halo: one depth-d exchange ships d ghost rings
    (``HaloSpec.halo_bytes_per_exchange`` at 6 words/site) plus pays one
    fixed ``HALO_EXCHANGE_LATENCY_S``, and buys d applications — so the
    per-application halo time divides by depth.  The byte half of that term
    is roughly depth-invariant (d rings / d applications); the LATENCY half
    is what the communication-avoiding schedule actually halves.

    All shards run concurrently, so the wall-clock bound is a PER-SHARD
    quantity: the core terms (computed for the full lattice on one chip)
    scale by ``1/hosts`` before composing with the per-shard halo time.
    Schedule semantics:

    * ``overlap=False`` — compute serializes behind the exchange:
      ``bound = core/hosts + halo``;
    * ``overlap=True``  — the exchange hides under the interior pass and the
      boundary sites are recomputed after it lands; a depth-d schedule
      additionally recomputes the intermediate ghost ring locally, one
      boundary-sized slab per application:
      ``bound = max(core/hosts, halo) + depth * boundary_fraction * core/hosts``
      (``boundary_fraction`` is already shard-relative:
      ``boundary_sites / sites_per_shard``).

    ``bandwidth_bytes`` in the returned row is the full per-application
    bandwidth-term payload — streamed bytes plus the exchanged halo bytes
    amortized over the depth — which is what the benchmark rows persist (the
    acceptance bar: halo bytes are IN the bandwidth term, not a footnote).
    """
    n_sites = L**4
    padded = ((n_sites + cand.tile - 1) // cand.tile) * cand.tile
    wb = layouts.WORD_BYTES[dtype]
    compressed = compression == layouts.GaugeCompression.TWO_ROW.value
    words_site = (su3_stencil.STENCIL_COMP_WORDS_PER_SITE if compressed
                  else su3_stencil.STENCIL_WORDS_PER_SITE)
    stream_bytes = padded * words_site * wb
    compute_s = float(su3_stencil.STENCIL_FLOPS_PER_SITE) * padded / hw.peak_flops_vpu
    memory_s = stream_bytes / hw.hbm_bw
    issue_s = 0.0
    n_dispatches = 3 if (cand.overlap and hosts > 1) else 1
    if hw.issue_rate:
        per_step = stencil_instruction_model(dtype, accum_dtype, compression)
        instrs = (padded // cand.tile) * per_step + DISPATCH_ISSUE_SLOTS * n_dispatches
        issue_s = instrs / hw.issue_rate
    core_s = max(compute_s, memory_s, issue_s)
    # every shard computes 1/hosts of the lattice, all shards concurrently —
    # the wall bound composes the PER-SHARD core with the per-shard halo
    core_shard_s = core_s / max(hosts, 1)
    halo = _stencil_halo_spec(L, hosts, wb, depth=cand.depth)
    halo_s = (
        HALO_EXCHANGE_LATENCY_S + halo.halo_bytes_per_exchange / hw.ici_bw
    ) / cand.depth
    boundary_frac = (  # shard-relative: boundary_sites / sites_per_shard
        halo.boundary_sites / halo.sites_per_shard if hosts > 1 else 0.0
    )
    if hosts == 1:
        bound_s = core_s
    elif cand.overlap:
        bound_s = max(core_shard_s, halo_s) + cand.depth * boundary_frac * core_shard_s
    else:
        bound_s = core_shard_s + halo_s
    useful = float(su3_stencil.STENCIL_FLOPS_PER_SITE) * n_sites
    terms = {"compute": compute_s, "memory": memory_s, "issue": issue_s,
             "halo": halo_s if hosts > 1 else 0.0}
    return {
        "tile": cand.tile,
        "overlap": cand.overlap,
        "depth": cand.depth,
        "compression": compression,
        "hosts": hosts,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "issue_s": issue_s,
        "core_shard_s": core_shard_s,
        "halo_s": halo_s if hosts > 1 else 0.0,
        "bound_s": bound_s,
        "dominant": max(terms, key=terms.get),
        "halo_bytes_per_exchange": halo.halo_bytes_per_exchange,
        "bandwidth_bytes": stream_bytes + halo.halo_bytes_per_exchange // cand.depth,
        "boundary_fraction": round(boundary_frac, 4),
        "predicted_gflops": round(useful / bound_s / 1e9, 3),
    }


def measure_stencil_candidate(
    cand: StencilCandidate, L: int = 8, dtype: str = "float32",
    accum_dtype: str = "", compression: str = "none",
) -> dict[str, Any]:
    """Measured per-application GFLOPS of one stencil variant on the local
    mesh (useful flops = 576/site; a depth-d step runs d applications per
    dispatch, so its wall time divides by d).  Overlap on a single local
    host degenerates to the interior-only schedule — the model's hosts>1
    halo term is what separates the variants; measurement keeps selection
    honest about kernel cost.  Depth-2 candidates are additionally verified
    BITWISE against two reference (depth-1) applications — the
    communication-avoiding schedule must change scheduling only, never
    values."""
    from repro.core.su3.plan import build_plan
    from repro.core.su3.engine import EngineConfig

    word_b = layouts.WORD_BYTES[dtype]
    accum_b = layouts.WORD_BYTES[accum_dtype] if accum_dtype else None
    cfg = EngineConfig(
        L=L, dtype=dtype, variant="pallas", layout=Layout.SOA,
        tile=cand.tile, accum_dtype=accum_dtype, iterations=2, warmups=1,
        compression=compression,
    )
    plan = build_plan(cfg)
    step = plan.stencil_step(overlap=cand.overlap, depth=cand.depth)
    u, v = plan.init_stencil_data()
    out = step(u, v)  # warm/compile; also the output 'verified' judges
    out.block_until_ready()
    import time as _time

    best = float("inf")
    for _ in range(2):
        t0 = _time.perf_counter()
        step(u, v).block_until_ready()
        best = min(best, _time.perf_counter() - t0)
    verified = bool(plan.verify_stencil(out)) if cand.depth == 1 else bool(
        jnp.array_equal(
            out,
            plan.stencil_step(overlap=False, depth=1)(
                u, plan.stencil_step(overlap=False, depth=1)(u, v)
            ),
        )
    )
    gf = cand.depth * su3_stencil.STENCIL_FLOPS_PER_SITE * (L**4) / best / 1e9
    return {
        "tile": cand.tile,
        "overlap": cand.overlap,
        "depth": cand.depth,
        "vmem_kib": su3_stencil.stencil_vmem_bytes(cand.tile, word_b, accum_b) // 1024,
        "measured_gflops": round(gf, 3),
        "verified": verified,
    }


def stencil_sweep(
    L: int = 8,
    dtype: str = "float32",
    accum_dtype: str = "",
    *,
    hosts: int = 1,
    compression: str = "none",
    prune: float = DEFAULT_PRUNE,
    tiles: tuple[int, ...] = DEFAULT_TILES,
    overlaps: tuple[bool, ...] = (False, True),
    depths: tuple[int, ...] = DEFAULT_DEPTHS,
    measure_fn: Callable[[StencilCandidate], dict[str, Any]] | None = None,
    hw: roofline.HardwareSpec = roofline.TPU_V5E,
) -> dict[str, Any]:
    """Rank the stencil (tile, overlap, depth) grid with the halo-charging
    roofline model; measure only the top ``prune`` fraction — the stencil
    analogue of :func:`pipeline_sweep`, with the same return structure and
    the same selection contract (tests gate it at within-5%-of-exhaustive)."""
    cands = enumerate_stencil_candidates(
        tiles, overlaps, dtype, accum_dtype, hw, depths
    )
    if not cands:
        raise RuntimeError("no VMEM-fitting stencil candidate")
    preds = [
        predict_stencil(c, L, dtype, accum_dtype, hosts, hw, compression=compression)
        for c in cands
    ]
    order = sorted(range(len(cands)), key=lambda i: -preds[i]["predicted_gflops"])
    n_meas = len(cands) if prune >= 1 else max(1, math.ceil(prune * len(cands)))
    if measure_fn is None:
        measure_fn = lambda c: measure_stencil_candidate(  # noqa: E731
            c, L=L, dtype=dtype, accum_dtype=accum_dtype, compression=compression
        )
    rows = []
    for rank, i in enumerate(order[:n_meas]):
        row = dict(preds[i])
        row.update(measure_fn(cands[i]))
        row["predicted_rank"] = rank
        rows.append(row)
    return {
        "rows": rows,
        "candidates_total": len(cands),
        "candidates_measured": n_meas,
        "prune": prune,
    }


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------


def cache_dir() -> str:
    return os.environ.get(
        CACHE_ENV, os.path.join(os.path.expanduser("~"), ".cache", "repro_su3")
    )


def cache_key(
    *,
    backend: str,
    device_kind: str,
    layout: str,
    dtype: str,
    L: int,
    n_devices: int,
    compression: str = "none",
    schema: int = SCHEMA_VERSION,
) -> str:
    """Versioned cache key.  The ``v{schema}`` prefix is the invalidation
    mechanism: entries written before the pipeline sweep (v1) or before the
    compression/depth axes (v2) simply never match a v3 lookup and re-measure
    cleanly instead of being read with missing fields.  ``compression`` is a
    key segment, not a suffix on dtype, so an 18-real and a two-row decision
    for the same (dtype, L) never alias."""
    return (
        f"v{schema}|{backend}|{device_kind}|{layout}|{dtype}"
        f"|{compression}|L{L}|d{n_devices}"
    )


def _cache_path(directory: str | None) -> str:
    return os.path.join(directory or cache_dir(), CACHE_FILE)


def load_cache(directory: str | None = None) -> dict[str, Any]:
    path = _cache_path(directory)
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {}


def store_cache_entry(
    key: str, entry: dict[str, Any], directory: str | None = None
) -> None:
    """Read-modify-write the cache file via an atomic rename."""
    path = _cache_path(directory)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    cache = load_cache(directory)
    cache[key] = entry
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(cache, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _device_identity() -> tuple[str, str, int]:
    devs = jax.devices()
    return jax.default_backend(), devs[0].device_kind, len(devs)


# ---------------------------------------------------------------------------
# The tuned production config
# ---------------------------------------------------------------------------


# keys a cached config must carry to be served without re-measuring; entries
# written by older builds (no fused_k; no pipeline block; no compression) or
# truncated by a crashed writer fall through to a fresh sweep instead of
# KeyError-ing every caller.  The versioned cache_key already isolates
# v1/v2 entries — this guard additionally catches a v3-keyed entry written
# incompletely.
_REQUIRED_CONFIG_KEYS = frozenset(
    {"layout", "variant", "tile", "fused_k", "compression", "pipeline"}
)


def _valid_cache_hit(hit: Any) -> dict[str, Any] | None:
    """The cached config dict iff the entry is structurally sound."""
    if not isinstance(hit, dict):
        return None
    config = hit.get("config")
    if not isinstance(config, dict) or not _REQUIRED_CONFIG_KEYS <= config.keys():
        return None
    return config


def best_config(
    L: int = 8,
    dtype: str = "float32",
    *,
    accum_dtype: str = "",
    compression: str = "none",
    cache: bool = True,
    cache_directory: str | None = None,
    refresh: bool = False,
    prune: float = DEFAULT_PRUNE,
    measure_fn: Callable[[PipelineCandidate], dict[str, Any]] | None = None,
) -> dict[str, Any]:
    """The tuned production config: SoA + the (tile, fused_k) pipeline point
    with the best MEASURED GFLOPS among the roofline-ranked top candidates.

    The joint grid is ranked by the three-term model (memory amortized over
    the chain, VPU compute, instruction-issue rate) and only the top
    ``prune`` fraction is measured — selection stays by measured throughput
    among verified, VMEM-fitting candidates, the model just decides what is
    worth timing.  The decision is persisted with its ``pipeline``
    provenance (schema version, candidate counts, the winner's predicted
    rank); later calls (any process) with the same versioned
    (backend, device_kind, layout, dtype, L, n_devices) key do zero
    measurements.  Pre-pipeline (v1) and pre-compression (v2) entries never
    match the v3 key, and corrupt or partial v3 entries (truncated writes,
    missing ``pipeline`` block) are treated as misses and re-measured, never
    crashed on.

    ``accum_dtype`` tunes mixed-precision plans as deployed: the sweep runs
    the f32-accumulate kernel (different VMEM resident set, instruction mix,
    and fused-K knee than the pure storage dtype), and the cache key carries
    the accumulate width so bf16-pure and bf16+f32-accum decisions never
    alias.  ``compression="two_row"`` tunes the 12-real gauge plan the same
    way, under its own key segment.
    """
    backend, device_kind, n_devices = _device_identity()
    dtype_key = f"{dtype}+acc-{accum_dtype}" if accum_dtype else dtype
    key = cache_key(
        backend=backend, device_kind=device_kind, layout="soa",
        dtype=dtype_key, L=L, n_devices=n_devices, compression=compression,
    )
    if cache and not refresh:
        config = _valid_cache_hit(load_cache(cache_directory).get(key))
        if config is not None:
            return dict(config, cached=True)

    sweep = pipeline_sweep(
        L=L, dtype=dtype, accum_dtype=accum_dtype, compression=compression,
        prune=prune, measure_fn=measure_fn,
    )
    rows = [r for r in sweep["rows"] if r["verified"]]
    if not rows:
        raise RuntimeError("no verified pipeline candidate in the measured set")
    winner = max(rows, key=lambda r: r["measured_gflops"])
    config = {
        "layout": "soa", "variant": "pallas",
        "tile": winner["tile"], "fused_k": winner["fused_k"],
        "compression": compression,
        "pipeline": {
            "schema": SCHEMA_VERSION,
            "prune": sweep["prune"],
            "candidates_total": sweep["candidates_total"],
            "candidates_measured": sweep["candidates_measured"],
            "predicted_gflops": winner.get("predicted_gflops", 0.0),
            "predicted_rank": winner.get("predicted_rank", 0),
        },
    }
    if cache:
        store_cache_entry(
            key,
            {"config": config, "measured_gflops": winner["measured_gflops"], "key": key},
            cache_directory,
        )
    return dict(config, cached=False)


# stencil cache entries carry (tile, overlap, depth, stencil provenance)
# instead of the multiply tuple's (tile, fused_k, pipeline); they live under
# their own layout key ("soa-stencil") so the two shapes never alias.
_REQUIRED_STENCIL_KEYS = frozenset(
    {"layout", "variant", "tile", "overlap", "depth", "stencil"}
)


def _valid_stencil_hit(hit: Any) -> dict[str, Any] | None:
    if not isinstance(hit, dict):
        return None
    config = hit.get("config")
    if not isinstance(config, dict) or not _REQUIRED_STENCIL_KEYS <= config.keys():
        return None
    return config


def best_stencil_config(
    L: int = 8,
    dtype: str = "float32",
    *,
    accum_dtype: str = "",
    compression: str = "none",
    hosts: int = 1,
    cache: bool = True,
    cache_directory: str | None = None,
    refresh: bool = False,
    prune: float = DEFAULT_PRUNE,
    measure_fn: Callable[[StencilCandidate], dict[str, Any]] | None = None,
) -> dict[str, Any]:
    """The tuned stencil variant: the (tile, overlap, depth) point with the
    best MEASURED GFLOPS among the halo-aware-roofline-ranked top candidates.

    Same contract as :func:`best_config` — ranked by model, selected by
    measurement among verified candidates, persisted with provenance under a
    versioned key (layout ``soa-stencil``, so multiply and stencil decisions
    never alias) — with ``hosts`` entering both the ranking (the halo term)
    and the cache key (a 1-host and a 4-host schedule tune differently).
    """
    backend, device_kind, n_devices = _device_identity()
    dtype_key = f"{dtype}+acc-{accum_dtype}" if accum_dtype else dtype
    key = cache_key(
        backend=backend, device_kind=device_kind, layout=f"soa-stencil-h{hosts}",
        dtype=dtype_key, L=L, n_devices=n_devices, compression=compression,
    )
    if cache and not refresh:
        config = _valid_stencil_hit(load_cache(cache_directory).get(key))
        if config is not None:
            return dict(config, cached=True)

    sweep = stencil_sweep(
        L=L, dtype=dtype, accum_dtype=accum_dtype, hosts=hosts,
        compression=compression, prune=prune, measure_fn=measure_fn,
    )
    rows = [r for r in sweep["rows"] if r["verified"]]
    if not rows:
        raise RuntimeError("no verified stencil candidate in the measured set")
    # The TILE is decided by measurement; the SCHEDULE axes (overlap, depth)
    # by the halo model.  On the local (single-host) measurement mesh the
    # schedules of a tile compile to near-identical per-application work —
    # overlap degenerates to the interior-only pass — so measured GFLOPS
    # cannot separate them and timer jitter would pick the persisted flags
    # at random.  The model is the only witness of the inter-host halo the
    # flags exist for.
    best_tile = max(rows, key=lambda r: r["measured_gflops"])["tile"]
    same_tile = [r for r in rows if r["tile"] == best_tile]
    # deterministic tie-break: when the model cannot separate the schedules
    # (hosts=1 predicts identical bounds), prefer the simpler serial one and
    # the shallower exchange — never let measured jitter of identical
    # compilations decide
    winner = max(
        same_tile,
        key=lambda r: (r["predicted_gflops"], not r["overlap"], -r.get("depth", 1)),
    )
    config = {
        "layout": "soa", "variant": "pallas_stencil",
        "tile": winner["tile"], "overlap": winner["overlap"],
        "depth": winner.get("depth", 1),
        "stencil": {
            "schema": SCHEMA_VERSION,
            "prune": sweep["prune"],
            "hosts": hosts,
            "compression": compression,
            "candidates_total": sweep["candidates_total"],
            "candidates_measured": sweep["candidates_measured"],
            "predicted_gflops": winner.get("predicted_gflops", 0.0),
            "predicted_rank": winner.get("predicted_rank", 0),
            "halo_bytes_per_exchange": winner.get("halo_bytes_per_exchange", 0),
        },
    }
    if cache:
        store_cache_entry(
            key,
            {"config": config, "measured_gflops": winner["measured_gflops"], "key": key},
            cache_directory,
        )
    return dict(config, cached=False)


# ---------------------------------------------------------------------------
# CG iteration tuning: the solve's hot loop is ONE fused stencil+axpy pass
# plus a shared scalar epilogue per iteration, so its decision axes are the
# Pallas tile and whether to run the fused kernel at all — the fused pass
# saves materializing the search direction p' as a standalone HBM round trip
# but pays a SECOND gathered neighbor field, so which side wins is a
# measured question, not a modeled one.  Decisions persist under their own
# cache key (layout "soa-cg-h{hosts}") so multiply/stencil/CG tuples for the
# same (dtype, L) never alias.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CGCandidate:
    """One point of the CG iteration grid: Pallas site tile x whether the
    iteration body runs the fused stencil+axpy kernel or the composed
    (axpy, stencil, shift) oracle path."""

    tile: int
    fused: bool = True


def enumerate_cg_candidates(
    tiles: tuple[int, ...] = DEFAULT_TILES,
    fused: tuple[bool, ...] = (True, False),
    dtype: str = "float32",
    accum_dtype: str = "",
    hw: roofline.HardwareSpec = roofline.TPU_V5E,
) -> list[CGCandidate]:
    """The VMEM-fitting (tile, fused) grid the CG pruner ranks.  The fused
    grid step resides the stencil tile set PLUS the second gathered field,
    so its VMEM bound is tighter than the stencil's at the same tile; the
    composed path is bounded by the plain stencil step."""
    word_b = layouts.WORD_BYTES[dtype]
    accum_b = layouts.WORD_BYTES[accum_dtype] if accum_dtype else None
    out = []
    for tile in tiles:
        for f in fused:
            bound = (su3_stencil.cg_vmem_bytes(tile, word_b, accum_b) if f
                     else su3_stencil.stencil_vmem_bytes(tile, word_b, accum_b))
            if bound <= hw.vmem_bytes:
                out.append(CGCandidate(tile, f))
    return out


# streamed storage words per site of ONE CG iteration (coarse, for ranking
# only — selection is by measurement).  Fused: the kernel streams U, BOTH
# gathered fields, the two center vectors, and two outputs; composed swaps
# the second gather for a standalone axpy round trip.  Both pay the shared
# epilogue (shift + x/r update + two reductions).
_CG_EPILOGUE_WORDS = 18 + 30 + 12 + 6  # shift, update, <p,Ap>, <r,r>


def _cg_words_per_site(fused: bool, compressed: bool) -> int:
    u_words = 2 * (layouts.PLANAR_COMP_ROWS if compressed else layouts.PLANAR_ROWS)
    if fused:
        body = u_words + 2 * 48 + 2 * 6 + 2 * 6  # u, r/p gathers, r/p, p'/s out
    else:
        body = 18 + (u_words + 48 + 6)  # axpy pass, then stencil pass
    return body + _CG_EPILOGUE_WORDS


def predict_cg(
    cand: CGCandidate,
    L: int,
    dtype: str = "float32",
    accum_dtype: str = "",
    hosts: int = 1,
    hw: roofline.HardwareSpec = roofline.TPU_V5E,
    compression: str = "none",
) -> dict[str, Any]:
    """Roofline prediction for one CG iteration variant.

    Same three core terms as the stencil model (the stencil chain dominates
    the iteration's compute), with the memory stream swapped for the CG word
    count and the per-iteration halo charged like a depth-1 stencil exchange
    — the fused path's overlap schedule ships the ±t ghosts of BOTH fields
    but still pays one exchange per iteration.  Deliberately coarse: the
    model ranks tiles, measurement separates fused from composed.
    """
    n_sites = L**4
    padded = ((n_sites + cand.tile - 1) // cand.tile) * cand.tile
    wb = layouts.WORD_BYTES[dtype]
    compressed = compression == layouts.GaugeCompression.TWO_ROW.value
    stream_bytes = padded * _cg_words_per_site(cand.fused, compressed) * wb
    flops_site = float(su3_stencil.CG_ITER_FLOPS_PER_SITE)
    compute_s = flops_site * padded / hw.peak_flops_vpu
    memory_s = stream_bytes / hw.hbm_bw
    issue_s = 0.0
    n_dispatches = (4 if hosts > 1 else 2) if cand.fused else (5 if hosts > 1 else 3)
    if hw.issue_rate:
        per_step = stencil_instruction_model(dtype, accum_dtype, compression)
        instrs = (padded // cand.tile) * per_step + DISPATCH_ISSUE_SLOTS * n_dispatches
        issue_s = instrs / hw.issue_rate
    core_s = max(compute_s, memory_s, issue_s)
    core_shard_s = core_s / max(hosts, 1)
    halo = _stencil_halo_spec(L, hosts, wb, depth=1)
    halo_s = HALO_EXCHANGE_LATENCY_S + 2 * halo.halo_bytes_per_exchange / hw.ici_bw
    bound_s = core_s if hosts == 1 else max(core_shard_s, halo_s)
    useful = flops_site * n_sites
    return {
        "tile": cand.tile,
        "fused": cand.fused,
        "compression": compression,
        "hosts": hosts,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "issue_s": issue_s,
        "bound_s": bound_s,
        "bandwidth_bytes": stream_bytes,
        "predicted_gflops": round(useful / bound_s / 1e9, 3),
    }


def _cg_measure_problem(L: int, seed: int = 7) -> tuple[Any, Any]:
    """Deterministic convergent CG problem: a constant-per-direction SU(3)
    gauge field (each U_mu constant along mu, so the site-local-adjoint
    stencil is exactly Hermitian) and a unit-scale right-hand side."""
    import numpy as np

    rng = np.random.default_rng(seed)
    a = rng.normal(size=(4, 3, 3)) + 1j * rng.normal(size=(4, 3, 3))
    q, r = np.linalg.qr(a)
    d = np.diagonal(r, axis1=-2, axis2=-1)
    q = q * (d / np.abs(d))[..., None, :]
    q = q / np.linalg.det(q)[..., None, None] ** (1.0 / 3.0)
    n = L**4
    u = np.broadcast_to(q, (n, 4, 3, 3)).astype(np.complex64)
    b = (rng.normal(size=(n, 3)) + 1j * rng.normal(size=(n, 3))).astype(
        np.complex64
    )
    return jnp.asarray(u), jnp.asarray(b)


def measure_cg_candidate(
    cand: CGCandidate, L: int = 8, dtype: str = "float32",
    accum_dtype: str = "", compression: str = "none", iters: int = 4,
) -> dict[str, Any]:
    """Measured per-iteration GFLOPS of one CG variant on the local mesh
    (useful flops = ``CG_ITER_FLOPS_PER_SITE``/site/iteration).  Fused
    candidates are verified against the composed oracle — BITWISE at f32
    storage (the bit-identity contract), within ``plan.verify_tolerance``
    otherwise; the composed candidate is the oracle and verifies by its
    residual actually shrinking."""
    from repro.core.su3.plan import build_plan
    from repro.core.su3.engine import EngineConfig

    word_b = layouts.WORD_BYTES[dtype]
    accum_b = layouts.WORD_BYTES[accum_dtype] if accum_dtype else None
    cfg = EngineConfig(
        L=L, dtype=dtype, variant="pallas", layout=Layout.SOA,
        tile=cand.tile, accum_dtype=accum_dtype, iterations=2, warmups=1,
        compression=compression,
    )
    plan = build_plan(cfg)
    u, b = _cg_measure_problem(L)
    u_phys = plan.pack_gauge(u)
    b_p = plan.pack_rhs(b)

    def run(fused: bool, n: int):
        state = plan.cg_state_init(b_p)
        for _ in range(n):
            state = plan.cg_iterate(u_phys, state, fused=fused)
        jax.block_until_ready(state["rs"])
        return state

    state = run(cand.fused, iters)  # warm/compile; also the verify subject
    import time as _time

    best = float("inf")
    for _ in range(2):
        t0 = _time.perf_counter()
        run(cand.fused, iters)
        best = min(best, _time.perf_counter() - t0)

    b_rs = float(jax.device_get(plan.cg_state_init(b_p)["rs"]))
    rs = float(jax.device_get(state["rs"]))
    if cand.fused:
        oracle = run(False, iters)
        if dtype == "float32":
            verified = bool(jnp.array_equal(state["x"], oracle["x"])) and bool(
                jnp.array_equal(state["r"], oracle["r"])
            )
        else:
            tol = plan.verify_tolerance()
            o_rs = float(jax.device_get(oracle["rs"]))
            verified = abs((rs / b_rs) ** 0.5 - (o_rs / b_rs) ** 0.5) <= tol
    else:
        verified = rs < b_rs  # the oracle must at least be converging
    vmem = (su3_stencil.cg_vmem_bytes(cand.tile, word_b, accum_b) if cand.fused
            else su3_stencil.stencil_vmem_bytes(cand.tile, word_b, accum_b))
    gf = (
        su3_stencil.CG_ITER_FLOPS_PER_SITE * (L**4) * iters / best / 1e9
    )
    return {
        "tile": cand.tile,
        "fused": cand.fused,
        "vmem_kib": vmem // 1024,
        "measured_gflops": round(gf, 3),
        "verified": verified,
    }


def cg_sweep(
    L: int = 8,
    dtype: str = "float32",
    accum_dtype: str = "",
    *,
    hosts: int = 1,
    compression: str = "none",
    prune: float = DEFAULT_PRUNE,
    tiles: tuple[int, ...] = DEFAULT_TILES,
    fused: tuple[bool, ...] = (True, False),
    measure_fn: Callable[[CGCandidate], dict[str, Any]] | None = None,
    hw: roofline.HardwareSpec = roofline.TPU_V5E,
) -> dict[str, Any]:
    """Rank the CG (tile, fused) grid with the coarse iteration roofline;
    measure only the top ``prune`` fraction — same return structure and
    selection contract as :func:`pipeline_sweep` / :func:`stencil_sweep`."""
    cands = enumerate_cg_candidates(tiles, fused, dtype, accum_dtype, hw)
    if not cands:
        raise RuntimeError("no VMEM-fitting CG candidate")
    preds = [
        predict_cg(c, L, dtype, accum_dtype, hosts, hw, compression=compression)
        for c in cands
    ]
    order = sorted(range(len(cands)), key=lambda i: -preds[i]["predicted_gflops"])
    n_meas = len(cands) if prune >= 1 else max(1, math.ceil(prune * len(cands)))
    if measure_fn is None:
        measure_fn = lambda c: measure_cg_candidate(  # noqa: E731
            c, L=L, dtype=dtype, accum_dtype=accum_dtype, compression=compression
        )
    rows = []
    for rank, i in enumerate(order[:n_meas]):
        row = dict(preds[i])
        row.update(measure_fn(cands[i]))
        row["predicted_rank"] = rank
        rows.append(row)
    return {
        "rows": rows,
        "candidates_total": len(cands),
        "candidates_measured": n_meas,
        "prune": prune,
    }


# CG cache entries carry (tile, fused, cg provenance) under their own layout
# key ("soa-cg-h{hosts}") so they never alias multiply or stencil decisions.
_REQUIRED_CG_KEYS = frozenset({"layout", "variant", "tile", "fused", "cg"})


def _valid_cg_hit(hit: Any) -> dict[str, Any] | None:
    if not isinstance(hit, dict):
        return None
    config = hit.get("config")
    if not isinstance(config, dict) or not _REQUIRED_CG_KEYS <= config.keys():
        return None
    return config


def best_cg_config(
    L: int = 8,
    dtype: str = "float32",
    *,
    accum_dtype: str = "",
    compression: str = "none",
    hosts: int = 1,
    cache: bool = True,
    cache_directory: str | None = None,
    refresh: bool = False,
    prune: float = DEFAULT_PRUNE,
    measure_fn: Callable[[CGCandidate], dict[str, Any]] | None = None,
) -> dict[str, Any]:
    """The tuned CG iteration: the (tile, fused) point with the best
    MEASURED per-iteration GFLOPS among the verified candidates.

    Same contract as :func:`best_config` / :func:`best_stencil_config` —
    ranked by model, selected by measurement among verified candidates,
    persisted with provenance under a versioned key (layout
    ``soa-cg-h{hosts}``, so the CG decision never aliases the multiply or
    stencil tuple for the same dtype/L).  ``fused`` is a genuinely measured
    axis: the fused kernel trades a standalone p' round trip for a second
    gathered neighbor field, and which side of that trade wins depends on
    the gather cost of the backend actually serving the solve.
    """
    backend, device_kind, n_devices = _device_identity()
    dtype_key = f"{dtype}+acc-{accum_dtype}" if accum_dtype else dtype
    key = cache_key(
        backend=backend, device_kind=device_kind, layout=f"soa-cg-h{hosts}",
        dtype=dtype_key, L=L, n_devices=n_devices, compression=compression,
    )
    if cache and not refresh:
        config = _valid_cg_hit(load_cache(cache_directory).get(key))
        if config is not None:
            return dict(config, cached=True)

    sweep = cg_sweep(
        L=L, dtype=dtype, accum_dtype=accum_dtype, hosts=hosts,
        compression=compression, prune=prune, measure_fn=measure_fn,
    )
    rows = [r for r in sweep["rows"] if r["verified"]]
    if not rows:
        raise RuntimeError("no verified CG candidate in the measured set")
    winner = max(rows, key=lambda r: r["measured_gflops"])
    config = {
        "layout": "soa", "variant": "pallas_cg",
        "tile": winner["tile"], "fused": winner["fused"],
        "cg": {
            "schema": SCHEMA_VERSION,
            "prune": sweep["prune"],
            "hosts": hosts,
            "compression": compression,
            "candidates_total": sweep["candidates_total"],
            "candidates_measured": sweep["candidates_measured"],
            "predicted_gflops": winner.get("predicted_gflops", 0.0),
            "predicted_rank": winner.get("predicted_rank", 0),
        },
    }
    if cache:
        store_cache_entry(
            key,
            {"config": config, "measured_gflops": winner["measured_gflops"], "key": key},
            cache_directory,
        )
    return dict(config, cached=False)


def tuned_engine_config(
    L: int = 8, dtype: str = "float32", *, cache_directory: str | None = None, **overrides
) -> EngineConfig:
    """EngineConfig built from the (cached) tuned tuple, override-able.

    An ``accum_dtype`` or ``compression`` override also steers the tuning
    itself (such plans are measured as deployed, under their own cache key).
    """
    tuned = best_config(
        L=L, dtype=dtype, accum_dtype=overrides.get("accum_dtype", ""),
        compression=overrides.get("compression", "none"),
        cache_directory=cache_directory,
    )
    base = {
        "L": L, "dtype": dtype, "layout": layouts.Layout(tuned["layout"]),
        "variant": tuned["variant"], "tile": tuned["tile"],
        "compression": tuned.get("compression", "none"),
    }
    base.update(overrides)
    return EngineConfig(**base)


def tuned_fused_k(
    L: int = 8, dtype: str = "float32", *, accum_dtype: str = "",
    compression: str = "none", cache_directory: str | None = None
) -> int:
    """The measured-best fused chain depth for (backend, L) — from the cache.

    Serving and benchmarks call this instead of hardcoding K; the first call
    per device identity pays the sweep, every later process reads the cache.
    """
    return int(best_config(L=L, dtype=dtype, accum_dtype=accum_dtype,
                           compression=compression,
                           cache_directory=cache_directory)["fused_k"])


if __name__ == "__main__":
    print("== tile sweep (VMEM blocking, exhaustive marginal) ==")
    for r in tile_sweep():
        print("  ", r)
    print("== k sweep (fused chain depth, exhaustive marginal) ==")
    for r in k_sweep():
        print("  ", r)
    print("== layout sweep (traffic) ==")
    for r in layout_sweep():
        print("  ", r)
    print("== pipeline sweep (roofline-pruned joint (tile, fused_k)) ==")
    for r in pipeline_sweep()["rows"]:
        print("  ", r)
    print("best:", best_config())

"""SU3 autotune: the paper's §4/§5.4 methodology as a driver, with a cache.

Hillclimbs the SU3 kernel the way the paper does — enumerate candidates
(layout, variant, Pallas tile), napkin-math the expected effect, measure,
keep the winner:

  * layout sweep charges the traffic model (AOS streams 320 B/site vs SoA
    288 B — the paper's streaming-store/padding point) and cross-checks it
    at the HLO level by lowering the *physical* ExecutionPlan step, so the
    packed layout actually shows up in the counted bytes;
  * tile sweep bounds the VMEM working set (the paper's register-blocking
    point re-derived for HBM->VMEM) and measures each candidate;
  * ``best_config`` selects the tile with the best *measured* GFLOPS among
    VMEM-fitting, verified candidates and persists the decision in a JSON
    cache keyed by (backend, device_kind, layout, dtype, L, n_devices) — a
    second call loads the tuned plan with zero measurements, so engines,
    serving, and benchmarks all start from the tuned tuple for free.

Cache location: ``$REPRO_SU3_CACHE_DIR`` or ``~/.cache/repro_su3``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import hlo_costs, roofline
from repro.core.su3 import layouts, registry, variants
from repro.core.su3.engine import EngineConfig, SU3Engine
from repro.core.su3.plan import make_raw_step
from repro.kernels import su3_matmul

CACHE_ENV = "REPRO_SU3_CACHE_DIR"
CACHE_FILE = "su3_autotune.json"


@dataclasses.dataclass
class TuneResult:
    config: dict[str, Any]
    measured_gflops: float
    hlo_bytes_per_site: float
    model_bytes_per_site: float
    vmem_bytes: int
    v5e_bound_gf: float


# ---------------------------------------------------------------------------
# HLO-level accounting
# ---------------------------------------------------------------------------


def hlo_bytes_for_variant(
    variant: str,
    layout: layouts.Layout,
    n_sites: int = 4096,
    tile: int = 512,
    dtype: str = "float32",
    accum_dtype: str = "",
) -> float:
    """Lower the *physical* plan step through XLA; count HLO bytes per site.

    The operands are packed per the requested layout before lowering (via the
    layout codec), so AOS genuinely streams its 80-word sites and SOA its
    72-word sites — previously the canonical complex operands were lowered
    for every non-Pallas variant and the ``layout`` argument was ignored,
    making the AOS and SOA rows identical.

    ``dtype``/``accum_dtype`` lower the mixed-precision storage plans: a
    bf16-storage / f32-accumulate plan streams 2-byte operands and results,
    so its measured bytes/site land well under the f32 plan's even though
    every FMA runs at f32 (converts are charged at the narrow side — the
    paper-correct streaming cost).
    """
    codec = layouts.make_codec(layout, tile=tile, dtype=dtype, accum_dtype=accum_dtype)
    entry = registry.get_kernel(variant)
    interpret = True if entry.form == registry.PLANAR else None
    step = make_raw_step(codec, entry, tile=tile, interpret=interpret)
    pad = (-n_sites) % tile
    a = jnp.zeros((n_sites + pad, 4, 3, 3), jnp.complex64)
    a_phys = codec.pack(a)
    b_p = codec.pack_b(jnp.zeros((4, 3, 3), jnp.complex64))
    compiled = jax.jit(step).lower(a_phys, b_p).compile()
    cost = hlo_costs.analyze_hlo(compiled.as_text())
    return cost.bytes / (n_sites + pad)  # bytes per site actually lowered


# ---------------------------------------------------------------------------
# Sweeps
# ---------------------------------------------------------------------------


def tile_sweep(
    tiles: tuple[int, ...] = (128, 256, 512, 1024, 2048, 4096),
    L: int = 8,
    dtype: str = "float32",
    accum_dtype: str = "",
) -> list[dict]:
    """VMEM working set + measured engine time per Pallas tile.

    The working-set bound honors the sweep's dtypes: bf16 storage halves the
    resident tile bytes, while a wider accumulate re-inflates them (the
    upcast tiles are what actually sit in VMEM).
    """
    word_b = layouts.WORD_BYTES[dtype]
    accum_b = layouts.WORD_BYTES[accum_dtype] if accum_dtype else None
    rows = []
    for tile in tiles:
        vmem = su3_matmul.vmem_bytes(tile, word_b, accum_b)
        fits = vmem <= roofline.TPU_V5E.vmem_bytes
        cfg = EngineConfig(L=L, dtype=dtype, variant="pallas", layout=layouts.Layout.SOA,
                           tile=tile, accum_dtype=accum_dtype, iterations=2, warmups=1)
        r = SU3Engine(cfg).run()
        rows.append({
            "tile": tile, "vmem_kib": vmem // 1024, "fits_vmem": fits,
            "measured_gflops": round(r.gflops, 3), "verified": r.verified,
        })
    return rows


def k_sweep(
    ks: tuple[int, ...] = (1, 2, 4, 8),
    L: int = 8,
    dtype: str = "float32",
    tile: int = 512,
    accum_dtype: str = "",
) -> list[dict]:
    """Measured per-multiply GFLOPS of the fused chain at each depth K.

    The fused step amortizes one dispatch (and on TPU one HBM roundtrip) over
    K multiplies, but past some K the chain stops helping — longer in-kernel
    chains grow the straight-line body (or fall to the fori_loop) without
    removing any more overhead.  The knee depends on (backend, L), so it is
    measured, not assumed, and ``best_config`` persists the winner next to
    the tile.
    """
    rows = []
    for k in ks:
        cfg = EngineConfig(L=L, dtype=dtype, variant="pallas", layout=layouts.Layout.SOA,
                           tile=tile, accum_dtype=accum_dtype, iterations=2, warmups=1)
        r = SU3Engine(cfg).run_fused(k=k, reps=2)
        rows.append({
            "k": k, "measured_gflops": round(r.gflops, 3), "verified": r.verified,
        })
    return rows


def layout_sweep(n_sites: int = 4096) -> list[dict]:
    """The paper's AoS->SoA traffic claim, measured at the HLO level.

    The final row is the bf16-storage / f32-accumulate serving plan: same
    kernel, half the streamed bytes per site, double the bandwidth-bound
    GFLOPS — the MILC-on-KNL reduced-precision-storage scheme measured at
    the HLO level rather than assumed.
    """
    rows = []
    for variant, layout, dtype, accum in (
            ("versionX", layouts.Layout.AOS, "float32", ""),
            ("versionX", layouts.Layout.SOA, "float32", ""),
            ("version_gemm", layouts.Layout.SOA, "float32", ""),
            ("pallas", layouts.Layout.SOA, "float32", ""),
            ("pallas", layouts.Layout.SOA, "bfloat16", "float32")):
        tm = layouts.TrafficModel.for_dtype(layout, n_sites, dtype)
        hlo_b = hlo_bytes_for_variant(variant, layout, n_sites,
                                      dtype=dtype, accum_dtype=accum)
        bound = roofline.TPU_V5E.hbm_bw * tm.arithmetic_intensity / 1e9
        rows.append({
            "variant": variant, "layout": layout.value, "dtype": dtype,
            "accum_dtype": accum or dtype,
            "model_bytes_per_site": tm.bytes_per_site_rw,
            "hlo_bytes_per_site": round(hlo_b, 1),
            "ai": round(tm.arithmetic_intensity, 3),
            "v5e_bound_gf": round(bound, 1),
        })
    return rows


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------


def cache_dir() -> str:
    return os.environ.get(
        CACHE_ENV, os.path.join(os.path.expanduser("~"), ".cache", "repro_su3")
    )


def cache_key(
    *, backend: str, device_kind: str, layout: str, dtype: str, L: int, n_devices: int
) -> str:
    return f"{backend}|{device_kind}|{layout}|{dtype}|L{L}|d{n_devices}"


def _cache_path(directory: str | None) -> str:
    return os.path.join(directory or cache_dir(), CACHE_FILE)


def load_cache(directory: str | None = None) -> dict[str, Any]:
    path = _cache_path(directory)
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {}


def store_cache_entry(
    key: str, entry: dict[str, Any], directory: str | None = None
) -> None:
    """Read-modify-write the cache file via an atomic rename."""
    path = _cache_path(directory)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    cache = load_cache(directory)
    cache[key] = entry
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(cache, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _device_identity() -> tuple[str, str, int]:
    devs = jax.devices()
    return jax.default_backend(), devs[0].device_kind, len(devs)


# ---------------------------------------------------------------------------
# The tuned production config
# ---------------------------------------------------------------------------


# keys a cached config must carry to be served without re-measuring; entries
# written by older builds (no fused_k) or truncated by a crashed writer fall
# through to a fresh sweep instead of KeyError-ing every caller.
_REQUIRED_CONFIG_KEYS = frozenset({"layout", "variant", "tile", "fused_k"})


def _valid_cache_hit(hit: Any) -> dict[str, Any] | None:
    """The cached config dict iff the entry is structurally sound."""
    if not isinstance(hit, dict):
        return None
    config = hit.get("config")
    if not isinstance(config, dict) or not _REQUIRED_CONFIG_KEYS <= config.keys():
        return None
    return config


def best_config(
    L: int = 8,
    dtype: str = "float32",
    *,
    accum_dtype: str = "",
    cache: bool = True,
    cache_directory: str | None = None,
    refresh: bool = False,
) -> dict[str, Any]:
    """The tuned production config: SoA + the tile with the best MEASURED GFLOPS
    + the fused chain depth K with the best measured per-multiply GFLOPS.

    Selection is by measured throughput among VMEM-fitting, verified tiles —
    not the largest fitting tile, which on real devices can sit past the
    occupancy knee.  K is then swept at the winning tile (the knee depends on
    (backend, L)).  The decision is persisted; later calls (any process) with
    the same (backend, device_kind, layout, dtype, L, n_devices) key do zero
    measurements.  Corrupt or partial cache entries (older schema, truncated
    writes) are treated as misses and re-measured, never crashed on.

    ``accum_dtype`` tunes mixed-precision plans as deployed: the sweeps run
    the f32-accumulate kernel (different VMEM resident set and fused-K knee
    than the pure storage dtype), and the cache key carries the accumulate
    width so bf16-pure and bf16+f32-accum decisions never alias.
    """
    backend, device_kind, n_devices = _device_identity()
    dtype_key = f"{dtype}+acc-{accum_dtype}" if accum_dtype else dtype
    key = cache_key(
        backend=backend, device_kind=device_kind, layout="soa",
        dtype=dtype_key, L=L, n_devices=n_devices,
    )
    if cache and not refresh:
        config = _valid_cache_hit(load_cache(cache_directory).get(key))
        if config is not None:
            return dict(config, cached=True)

    rows = [r for r in tile_sweep(L=L, dtype=dtype, accum_dtype=accum_dtype)
            if r["fits_vmem"] and r["verified"]]
    if not rows:
        raise RuntimeError("no VMEM-fitting verified tile candidate")
    winner = max(rows, key=lambda r: r["measured_gflops"])
    krows = [r for r in k_sweep(L=L, dtype=dtype, tile=winner["tile"],
                                accum_dtype=accum_dtype) if r["verified"]]
    kwinner = max(krows, key=lambda r: r["measured_gflops"]) if krows else {"k": 1}
    config = {
        "layout": "soa", "variant": "pallas",
        "tile": winner["tile"], "fused_k": kwinner["k"],
    }
    if cache:
        store_cache_entry(
            key,
            {"config": config, "measured_gflops": winner["measured_gflops"], "key": key},
            cache_directory,
        )
    return dict(config, cached=False)


def tuned_engine_config(
    L: int = 8, dtype: str = "float32", *, cache_directory: str | None = None, **overrides
) -> EngineConfig:
    """EngineConfig built from the (cached) tuned tuple, override-able.

    An ``accum_dtype`` override also steers the tuning itself (mixed-
    precision plans are measured as deployed, under their own cache key).
    """
    tuned = best_config(
        L=L, dtype=dtype, accum_dtype=overrides.get("accum_dtype", ""),
        cache_directory=cache_directory,
    )
    base = {
        "L": L, "dtype": dtype, "layout": layouts.Layout(tuned["layout"]),
        "variant": tuned["variant"], "tile": tuned["tile"],
    }
    base.update(overrides)
    return EngineConfig(**base)


def tuned_fused_k(
    L: int = 8, dtype: str = "float32", *, accum_dtype: str = "",
    cache_directory: str | None = None
) -> int:
    """The measured-best fused chain depth for (backend, L) — from the cache.

    Serving and benchmarks call this instead of hardcoding K; the first call
    per device identity pays the sweep, every later process reads the cache.
    """
    return int(best_config(L=L, dtype=dtype, accum_dtype=accum_dtype,
                           cache_directory=cache_directory)["fused_k"])


if __name__ == "__main__":
    print("== tile sweep (VMEM blocking) ==")
    for r in tile_sweep():
        print("  ", r)
    print("== k sweep (fused chain depth) ==")
    for r in k_sweep():
        print("  ", r)
    print("== layout sweep (traffic) ==")
    for r in layout_sweep():
        print("  ", r)
    print("best:", best_config())

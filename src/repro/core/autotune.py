"""SU3 autotune: the paper's §4/§5.4 methodology as a driver.

Hillclimbs the SU3 kernel the way the paper does — enumerate candidates
(layout, variant, Pallas tile), napkin-math the expected effect, measure,
keep the winner:

  * layout sweep charges the traffic model (AOS streams 320 B/site vs SoA
    288 B — the paper's streaming-store/padding point);
  * tile sweep bounds the VMEM working set (the paper's register-blocking
    point re-derived for HBM->VMEM);
  * variant sweep measures XLA wall time on this host AND the HLO-level
    bytes from the loop-aware cost model (the dry-run profile) so the
    decision is made on the roofline term, not host noise.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import hlo_costs, roofline
from repro.core.su3 import layouts, variants
from repro.core.su3.engine import EngineConfig, SU3Engine
from repro.kernels import su3_matmul


@dataclasses.dataclass
class TuneResult:
    config: dict[str, Any]
    measured_gflops: float
    hlo_bytes_per_site: float
    model_bytes_per_site: float
    vmem_bytes: int
    v5e_bound_gf: float


def hlo_bytes_for_variant(variant: str, layout: layouts.Layout, n_sites: int = 4096) -> float:
    """Lower the variant through XLA and count HLO-level bytes per site."""
    a = jnp.zeros((n_sites, 4, 3, 3), jnp.complex64)
    b = jnp.zeros((4, 3, 3), jnp.complex64)
    if variant == "pallas":
        from repro.kernels import ops

        a_p = layouts.pack_soa(a).reshape(2, su3_matmul.ROWS, n_sites)
        b_p = layouts.to_planar(b).reshape(2, su3_matmul.ROWS)
        fn = lambda x, y: ops.su3_mult_planar(x, y, tile=512, interpret=True)
        compiled = jax.jit(fn).lower(a_p, b_p).compile()
    else:
        fn = variants.get_variant(variant)
        compiled = jax.jit(fn).lower(a, b).compile()
    cost = hlo_costs.analyze_hlo(compiled.as_text())
    return cost.bytes / n_sites


def tile_sweep(tiles: tuple[int, ...] = (128, 256, 512, 1024, 2048, 4096)) -> list[dict]:
    """VMEM working set + measured engine time per Pallas tile."""
    rows = []
    for tile in tiles:
        vmem = su3_matmul.vmem_bytes(tile)
        fits = vmem <= roofline.TPU_V5E.vmem_bytes
        cfg = EngineConfig(L=8, variant="pallas", layout=layouts.Layout.SOA,
                           tile=tile, iterations=2, warmups=1)
        r = SU3Engine(cfg).run()
        rows.append({
            "tile": tile, "vmem_kib": vmem // 1024, "fits_vmem": fits,
            "measured_gflops": round(r.gflops, 3), "verified": r.verified,
        })
    return rows


def layout_sweep(n_sites: int = 4096) -> list[dict]:
    """The paper's AoS->SoA traffic claim, measured at the HLO level."""
    rows = []
    for variant, layout in (("versionX", layouts.Layout.AOS),
                            ("versionX", layouts.Layout.SOA),
                            ("version_gemm", layouts.Layout.SOA),
                            ("pallas", layouts.Layout.SOA)):
        tm = layouts.TrafficModel(layout, n_sites, 4)
        hlo_b = hlo_bytes_for_variant(variant, layout, n_sites)
        bound = roofline.TPU_V5E.hbm_bw * tm.arithmetic_intensity / 1e9
        rows.append({
            "variant": variant, "layout": layout.value,
            "model_bytes_per_site": tm.bytes_per_site_rw,
            "hlo_bytes_per_site": round(hlo_b, 1),
            "ai": round(tm.arithmetic_intensity, 3),
            "v5e_bound_gf": round(bound, 1),
        })
    return rows


def best_config() -> dict[str, Any]:
    """The tuned production config: SoA + largest VMEM-fitting tile."""
    tiles = [r for r in tile_sweep() if r["fits_vmem"] and r["verified"]]
    best_tile = max(tiles, key=lambda r: r["tile"])
    return {"layout": "soa", "variant": "pallas", "tile": best_tile["tile"]}


if __name__ == "__main__":
    print("== tile sweep (VMEM blocking) ==")
    for r in tile_sweep():
        print("  ", r)
    print("== layout sweep (traffic) ==")
    for r in layout_sweep():
        print("  ", r)
    print("best:", best_config())

"""Loop-aware cost analysis over compiled HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body ONCE —
verified empirically: an 8-step scanned matmul reports 1/8 of the unrolled
flops. Every production model here scans over layers (and flash-attention
scans over chunks), so we parse the post-optimization HLO ourselves and
multiply loop bodies by their ``known_trip_count`` backend config.

What we model (per device, since post-SPMD HLO is the per-device program):

  flops   dot ops exactly (2 * numel(result) * contracted dims), elementwise
          arithmetic ~1 flop/elem, transcendentals ~8 flops/elem.
  bytes   materialization-boundary traffic: every top-level instruction in a
          non-fusion computation charges operands + result (fusion internals
          are free — the fusion is the materialization boundary, which is
          XLA's own memory model).
  colls   ring-model link bytes per collective (see core.roofline), with
          loop multipliers applied — a collective inside the layer scan
          counts n_layers times.

This is the TPU analog of the paper's §5.3 exercise: deriving the binding
architectural rate from instruction counts rather than wall-clock.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    # result shape is either a tuple "(...)" (may contain /*index=N*/ comments,
    # hence '=' inside) or a single array shape with optional layout braces.
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^()]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*([\w\-]+)\("
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->.*\{")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_WHILE_REFS_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_COND_BRANCHES_RE = re.compile(r"(?:true_computation|false_computation|branch_computations=\{[^}]*\}|to_apply)=")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_REPLICA_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_REPLICA_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")

_ELEMENTWISE_1 = {
    "add", "subtract", "multiply", "maximum", "minimum", "and", "or", "xor",
    "negate", "abs", "select", "compare", "clamp", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "sign", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "iota", "not",
}
_ELEMENTWISE_8 = {
    "divide", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "sqrt", "rsqrt", "power", "sine", "cosine", "atan2", "erf",
    "logistic", "cbrt", "expm1",
}
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "add-dependency", "domain",
}
_COLLECTIVE_KINDS = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
}
_MEMORY_OPS = {
    "copy", "copy-start", "convert", "reshape", "transpose", "broadcast",
    "concatenate", "pad", "slice", "dynamic-slice", "dynamic-update-slice",
    "gather", "scatter", "bitcast-convert", "reverse",
}
_CONTROL_OPS = {"while", "conditional", "call", "async-start", "fusion", "custom-call"}
_ARITH_OPS = {"dot", "convolution", "reduce", "reduce-window"}


def _instr_class(op: str) -> str:
    """Issue class of one HLO opcode (paper-§5.3 instruction-mix buckets)."""
    kind = op[:-6] if op.endswith("-start") else op
    if kind in _COLLECTIVE_KINDS:
        return "collective"
    if op in _CONTROL_OPS:
        return "control"
    if op in _MEMORY_OPS:
        return "memory"
    if op in _ELEMENTWISE_1 or op in _ELEMENTWISE_8 or op in _ARITH_OPS:
        return "arith"
    return "other"


def _shape_numel_bytes(shape_text: str) -> tuple[float, float]:
    numel_total, bytes_total = 0.0, 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1.0
        if dims:
            for d in dims.split(","):
                n *= int(d)
        numel_total += n
        bytes_total += n * _DTYPE_BYTES[dtype]
    return numel_total, bytes_total


@dataclasses.dataclass
class Instruction:
    name: str
    shape_text: str
    op: str
    line: str

    @property
    def numel(self) -> float:
        return _shape_numel_bytes(self.shape_text)[0]

    @property
    def result_bytes(self) -> float:
        return _shape_numel_bytes(self.shape_text)[1]


@dataclasses.dataclass
class Computation:
    name: str
    instructions: dict[str, Instruction]
    order: list[str]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_link_bytes: float = 0.0
    collective_by_kind: dict[str, float] = dataclasses.field(default_factory=dict)
    # loop-aware instruction counts by issue class — the paper-§5.3 raw
    # material: a pipeline-throughput (issue-rate) bound needs instruction
    # counts, not flops.  Classes: "arith" (FMA-adjacent compute), "memory"
    # (data movement: slices, copies, converts, fusion boundaries),
    # "control" (loops/calls/branches), "other".
    instructions: float = 0.0
    instr_by_class: dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "Cost") -> "Cost":
        self.flops += other.flops
        self.bytes += other.bytes
        self.transcendentals += other.transcendentals
        self.collective_link_bytes += other.collective_link_bytes
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = self.collective_by_kind.get(k, 0.0) + v
        self.instructions += other.instructions
        for k, v in other.instr_by_class.items():
            self.instr_by_class[k] = self.instr_by_class.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(
            self.flops * m,
            self.bytes * m,
            self.transcendentals * m,
            self.collective_link_bytes * m,
            {k: v * m for k, v in self.collective_by_kind.items()},
            self.instructions * m,
            {k: v * m for k, v in self.instr_by_class.items()},
        )

    def count_instr(self, cls: str, n: float = 1.0) -> None:
        self.instructions += n
        self.instr_by_class[cls] = self.instr_by_class.get(cls, 0.0) + n


def parse_computations(hlo_text: str) -> tuple[dict[str, Computation], str, set[str]]:
    """-> (computations, entry_name, fusion-called computation names)."""
    comps: dict[str, Computation] = {}
    entry = ""
    fusion_called: set[str] = set()
    cur: Computation | None = None
    for raw in hlo_text.splitlines():
        m = _COMP_RE.match(raw)
        if m and not raw.startswith(" "):
            cur = Computation(m.group(1), {}, [])
            comps[cur.name] = cur
            if raw.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(raw)
        if mi:
            instr = Instruction(mi.group(1), mi.group(2), mi.group(3), raw)
            cur.instructions[instr.name] = instr
            cur.order.append(instr.name)
            if instr.op == "fusion":
                mc = _CALLS_RE.search(raw)
                if mc:
                    fusion_called.add(mc.group(1))
    return comps, entry, fusion_called


def _group_size(line: str) -> int:
    m = _REPLICA_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _REPLICA_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2  # collective-permute: pairwise


def _collective_link_bytes(kind: str, line: str, result_bytes: float) -> float:
    n = _group_size(line)
    s = result_bytes
    if n <= 1:
        return 0.0
    if kind == "all-gather":
        return (n - 1) / n * s
    if kind == "reduce-scatter":
        return (n - 1) * s
    if kind == "all-reduce":
        return 2 * (n - 1) / n * s
    if kind in ("all-to-all", "ragged-all-to-all"):
        return (n - 1) / n * s
    return float(s)  # collective-permute / broadcast


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.entry, self.fusion_called = parse_computations(hlo_text)
        self._memo: dict[tuple[str, bool], Cost] = {}

    def total(self) -> Cost:
        return self._comp_cost(self.entry, materializing=True)

    # -- internals -----------------------------------------------------------

    def _operand_bytes(
        self, comp: Computation, line: str, instr_name: str, called: str | None = None
    ) -> float:
        """Effective bytes read from operands.

        If this is a fusion and an operand only feeds dynamic-slice ops
        inside the fused computation, it is charged at the sliced size
        (e.g. a (L, B, S, H, D) KV stack sliced per layer inside the layer
        scan reads one layer, not the stack).
        """
        total = 0.0
        call_part = line.split("(", 1)[1] if "(" in line else ""
        call_part = call_part.split("metadata=")[0].split("calls=")[0]
        refs = [r for r in _OPERAND_RE.findall(call_part) if r != instr_name]
        slice_only = self._slice_only_params(called) if called else {}
        for pos, ref in enumerate(refs):
            op_instr = comp.instructions.get(ref)
            if op_instr is None or op_instr.op == "constant":
                continue
            if pos in slice_only:
                total += slice_only[pos]
            else:
                total += op_instr.result_bytes
        return total

    def _slice_only_params(self, called: str) -> dict[int, float]:
        """param position -> sliced bytes, for fusion params consumed only
        by dynamic-slice (or feeding one via bitcast)."""
        comp = self.comps.get(called)
        if comp is None:
            return {}
        out: dict[int, float] = {}
        # map param name -> position
        param_pos: dict[str, int] = {}
        for iname in comp.order:
            ins = comp.instructions[iname]
            if ins.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", ins.line)
                if m:
                    param_pos[iname] = int(m.group(1))
        for pname, pos in param_pos.items():
            consumers = []
            for iname in comp.order:
                ins = comp.instructions[iname]
                if iname == pname:
                    continue
                if re.search(r"%" + re.escape(pname) + r"\b", ins.line.split("=", 1)[-1]):
                    consumers.append(ins)
            if consumers and all(c.op in ("dynamic-slice", "slice") for c in consumers):
                out[pos] = sum(c.result_bytes for c in consumers)
        return out

    def _dot_flops(self, comp: Computation, instr: Instruction) -> float:
        out_numel = instr.numel
        mc = _CONTRACT_RE.search(instr.line)
        contract = 1.0
        if mc and mc.group(1):
            dims = [int(x) for x in mc.group(1).split(",") if x != ""]
            call_part = instr.line.split("(", 1)[1]
            refs = _OPERAND_RE.findall(call_part.split("metadata=")[0])
            if refs:
                lhs = comp.instructions.get(refs[0])
                if lhs is not None:
                    mshape = _SHAPE_RE.search(lhs.shape_text)
                    if mshape and mshape.group(2):
                        lhs_dims = [int(x) for x in mshape.group(2).split(",")]
                        for d in dims:
                            if d < len(lhs_dims):
                                contract *= lhs_dims[d]
        return 2.0 * out_numel * contract

    def _update_operand_bytes(
        self, comp: Computation, line: str, instr_name: str, result_bytes: float
    ) -> float:
        """Size of the update operand of a DUS (2nd operand), fallback small."""
        call_part = line.split("(", 1)[1].split("metadata=")[0]
        refs = _OPERAND_RE.findall(call_part)
        if len(refs) >= 2:
            oi = comp.instructions.get(refs[1])
            if oi is not None:
                return oi.result_bytes
        return result_bytes * 0.01

    def _fusion_is_convert_only(self, mc) -> bool:
        """True for wrapped_convert-style fusions (pure dtype change)."""
        if mc is None:
            return False
        called = self.comps.get(mc.group(1))
        if called is None:
            return False
        kinds = {called.instructions[i].op for i in called.order}
        return kinds <= {"parameter", "convert", "bitcast", "copy", "broadcast"} and "convert" in kinds

    def _fusion_is_inplace_update(self, mc, instr: Instruction) -> bool:
        if mc is None:
            return False
        called = self.comps.get(mc.group(1))
        if called is None:
            return False
        target = instr.result_bytes
        for iname in called.order:
            ins = called.instructions[iname]
            # compare by size, not shape text (layout braces differ)
            if ins.op == "dynamic-update-slice" and abs(ins.result_bytes - target) < 1:
                return True
        return False

    def _comp_cost(self, name: str, materializing: bool) -> Cost:
        key = (name, materializing)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            self._memo[key] = total
            return total
        self._memo[key] = total  # guard against cycles
        for iname in comp.order:
            instr = comp.instructions[iname]
            op = instr.op
            line = instr.line
            if op in _FREE_OPS:
                continue
            if op.endswith("-done"):  # async second halves carry no new work
                continue
            total.count_instr(_instr_class(op))
            kind = op[:-6] if op.endswith("-start") else op
            if kind in _COLLECTIVE_KINDS:
                c = Cost()
                lb = _collective_link_bytes(kind, line, instr.result_bytes)
                c.collective_link_bytes = lb
                c.collective_by_kind = {kind: lb}
                if materializing:
                    c.bytes = instr.result_bytes + self._operand_bytes(comp, line, iname)
                total += c
                continue
            if op == "while":
                m = _WHILE_REFS_RE.search(line)
                trips = 1
                mt = _TRIP_RE.search(line)
                if mt:
                    trips = int(mt.group(1))
                if m:
                    body = self._comp_cost(m.group(2), materializing)
                    cond = self._comp_cost(m.group(1), materializing)
                    inner = Cost()
                    inner += body
                    inner += cond
                    total += inner.scaled(trips)
                continue
            if op == "conditional":
                branches = [
                    self._comp_cost(b, materializing)
                    for b in _CALLS_RE.findall(line)
                ]
                if not branches:
                    refs = re.findall(r"(?:true_computation|false_computation)=%?([\w\.\-]+)", line)
                    branches = [self._comp_cost(b, materializing) for b in refs]
                if branches:
                    total += max(branches, key=lambda c: c.flops + c.bytes)
                if materializing:
                    total += Cost(bytes=instr.result_bytes)
                continue
            if op in ("call", "async-start"):
                # post-opt HLO spells the callee `to_apply=`, older/async
                # forms `calls=` — accept either (the CPU backend wraps its
                # parallel pack/unpack fusions in such calls; dropping them
                # hid all layout-dependent traffic).
                mc = _CALLS_RE.search(line) or _TO_APPLY_RE.search(line)
                if mc:
                    total += self._comp_cost(mc.group(1), materializing)
                continue
            if op == "fusion":
                mc = _CALLS_RE.search(line)
                if mc:
                    # flops from the fused computation; bytes only at boundary
                    total += self._comp_cost(mc.group(1), materializing=False)
                if materializing:
                    opb = self._operand_bytes(
                        comp, line, iname, called=mc.group(1) if mc else None
                    )
                    # in-place scan-stack updates: a fusion whose result
                    # aliases a same-sized operand (DUS-root pattern) only
                    # touches the update region, not the whole stack.
                    if self._fusion_is_inplace_update(mc, instr):
                        others = max(opb - instr.result_bytes, 0.0)
                        total += Cost(bytes=3.0 * max(others, 1.0))
                    elif self._fusion_is_convert_only(mc):
                        total += Cost(bytes=min(instr.result_bytes, opb))
                    else:
                        total += Cost(bytes=instr.result_bytes + opb)
                continue
            if op in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced region, not the whole operand
                if materializing:
                    total += Cost(bytes=2.0 * instr.result_bytes)
                continue
            if op == "dynamic-update-slice":
                # in-place update: read update + read/write touched region
                upd = self._update_operand_bytes(comp, line, iname, instr.result_bytes)
                if materializing:
                    total += Cost(bytes=3.0 * upd)
                continue
            if op == "convert":
                # dtype-normalization: the CPU backend f32-upcasts every bf16
                # dot operand (no native bf16 FMA); on TPU these converts do
                # not exist. Charge the narrow side once.
                if materializing:
                    total += Cost(
                        bytes=min(instr.result_bytes, self._operand_bytes(comp, line, iname))
                    )
                continue
            c = Cost()
            if op == "dot":
                c.flops = self._dot_flops(comp, instr)
            elif op == "convolution":
                # rough: treat like a dot over the kernel volume
                c.flops = 2.0 * instr.numel * 1.0
            elif op in _ELEMENTWISE_1:
                c.flops = instr.numel
            elif op in _ELEMENTWISE_8:
                c.flops = 8.0 * instr.numel
                c.transcendentals = instr.numel
            elif op in ("reduce", "reduce-window"):
                call_part = line.split("(", 1)[1].split("metadata=")[0]
                refs = _OPERAND_RE.findall(call_part)
                in_numel = 0.0
                for r in refs[:1]:
                    oi = comp.instructions.get(r)
                    if oi is not None:
                        in_numel = oi.numel
                c.flops = max(in_numel, instr.numel)
            if materializing:
                c.bytes = instr.result_bytes + self._operand_bytes(comp, line, iname)
            total += c
        self._memo[key] = total
        return total


def analyze_hlo(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).total()

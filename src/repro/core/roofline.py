"""Three-term roofline analyzer — the paper's §5.3 insight as a library.

The paper's PIUMA finding is that two-term (compute, bandwidth) roofline is
insufficient: SU3_Bench on PIUMA is bounded by a *third* architectural rate,
the scalar pipeline's instruction issue rate (12 loads + 2 stores + 12 FMAs
per 24 flops -> 3.6 GF/s/core, below both the flops and bandwidth roofs).

At multi-pod TPU scale the third term is the interconnect: collective bytes
over ICI links. This module derives all three terms from a *compiled* (AOT)
XLA artifact — no hardware required, exactly like the paper derives the PIUMA
bound from instruction counts:

  compute_s    = HLO flops per device       / chip peak flops/s
  memory_s     = HLO bytes per device       / chip HBM bytes/s
  collective_s = sum over collective ops of ring-model time per device
  issue_s      = HLO instructions per device / pipeline issue slots/s
                 (the paper's §5.3 term itself, measurable when the spec
                 carries ``issue_rate`` and the cost model an instruction
                 count — what the roofline-pruned autotuner ranks with)

``cost_analysis()`` on an SPMD executable reports the **per-device** program
(verified empirically: an 8-way sharded matmul reports total/8 flops), so all
terms here are per-device seconds and directly comparable.

Collective bytes are *not* in cost_analysis: we parse the post-partitioning
HLO (``compiled.as_text()``) and apply standard ring-collective cost models
using each op's shape and replica-group size.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Mapping

# ---------------------------------------------------------------------------
# Hardware models.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float  # per chip, dense matmul path (bf16 MXU for TPU)
    peak_flops_vpu: float  # per chip, vector-unit path (fp32) — SU3's honest roof
    hbm_bw: float  # bytes/s per chip
    ici_bw: float  # bytes/s per ICI link
    ici_links: int  # usable links per chip
    hbm_bytes: float  # HBM capacity per chip
    vmem_bytes: float  # VMEM per core (Pallas tile budget)
    # The paper's §5.3 fourth rate: instruction-issue slots per second of the
    # scalar/VLIW pipeline that sequences the kernel (0 = not modeled).  One
    # "instruction" here is one issued op however wide its vector payload —
    # exactly why a wide-lane kernel can be issue-bound long before it is
    # flops- or bandwidth-bound.
    issue_rate: float = 0.0

    @property
    def ridge_flops_per_byte(self) -> float:
        return self.peak_flops / self.hbm_bw


# Assignment-given constants: 197 TFLOP/s bf16; 819 GB/s HBM; ~50 GB/s/link ICI.
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    # 8 VPU lanes x 128 sublanes x 2 flops (FMA) x ~940 MHz ~= 1.9 TF/s fp32.
    peak_flops_vpu=1.9e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    ici_links=4,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=16 * 1024**2,
    # one VPU/VMEM op issued per scalar-core cycle at ~940 MHz; each op covers
    # 8x128 lanes, so issue binds exactly when tiles are small or chains short
    issue_rate=0.94e9,
)

# The paper's two platforms, for the Xeon/PIUMA comparison benchmarks.
XEON_8280_SOCKET = HardwareSpec(
    name="clx8280_socket",  # paper §4: 28 cores, 2x AVX-512 FMA, 105 GB/s
    peak_flops=2420.1e9,
    peak_flops_vpu=2420.1e9,
    hbm_bw=105e9,
    ici_bw=10.4e9,  # one UPI link
    ici_links=3,
    hbm_bytes=96 * 1024**3,
    vmem_bytes=1 * 1024**2,  # L2 as the "tile" store
    issue_rate=3.0e11,  # 28 cores x 4-wide issue x ~2.7 GHz
)

PIUMA_CORE = HardwareSpec(
    name="piuma_core",  # paper §5.3: 8 GF/s FMA peak, BW-bound 4.32 GF/s
    peak_flops=8e9,
    peak_flops_vpu=8e9,
    hbm_bw=6.4e9,  # 4.32 GF/s at AI=0.675 -> 6.4 GB/s effective per core
    ici_bw=6.4e9,  # network bw >= local DRAM bw (paper §3.2)
    ici_links=1,
    hbm_bytes=1 * 1024**3,
    vmem_bytes=256 * 1024,  # SPAD
    # §5.3: 26 issued ops (12 loads + 2 stores + 12 FMAs) per 24 flops bound
    # the core at 3.6 GF/s -> 3.6e9 * 26/24 ~= 3.9e9 issue slots/s
    issue_rate=3.9e9,
)

HARDWARE = {h.name: h for h in (TPU_V5E, XEON_8280_SOCKET, PIUMA_CORE)}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0, "opaque": 0,
}

# one shape, e.g. "bf16[16,4096,512]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\](?:\{[^}]*\})?")
_COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
# `%name = <shape or (tuple)> <kind>(` — post-optimization HLO one-liner form.
_COLLECTIVE_LINE_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+("
    + "|".join(_COLLECTIVE_KINDS)
    + r")(?:-start|-done)?\(",
)
_REPLICA_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_REPLICA_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _shape_bytes(shape_text: str) -> int:
    """Bytes of one HLO shape string or tuple-of-shapes text."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int | None:
    m = _REPLICA_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]<=[total]
    m = _REPLICA_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return None


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int

    @property
    def link_bytes(self) -> float:
        """Ring-model bytes through one device's links.

        all-gather result is the full gathered tensor (per-device output);
        reduce-scatter result is the shard; all-reduce result == operand.
        """
        n = max(self.group_size, 1)
        s = self.result_bytes
        if n == 1:
            return 0.0
        if self.kind == "all-gather":
            return (n - 1) / n * s  # s = full tensor
        if self.kind == "reduce-scatter":
            return (n - 1) * s  # s = shard; (n-1)/n * full = (n-1)*shard
        if self.kind == "all-reduce":
            return 2 * (n - 1) / n * s
        if self.kind == "all-to-all":
            return (n - 1) / n * s
        if self.kind == "collective-permute":
            return float(s)
        return float(s)


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    """Extract every collective op from post-partitioning HLO text."""
    ops: list[CollectiveOp] = []
    seen_started: set[str] = set()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_LINE_RE.search(line)
        if not m:
            continue
        # Avoid double counting async pairs: `-done` carries no replica groups;
        # count `-start` (or the sync form) only.
        if re.search(r"-done\(", line):
            continue
        shape_text, kind = m.group(1), m.group(2)
        result_bytes = _shape_bytes(shape_text)
        group = _group_size(line)
        if group is None:
            group = 2  # collective-permute has no replica_groups; pairwise
        ops.append(CollectiveOp(kind=kind, result_bytes=result_bytes, group_size=group))
    return ops


def collective_bytes_by_kind(ops: list[CollectiveOp]) -> dict[str, float]:
    out: dict[str, float] = {}
    for op in ops:
        out[op.kind] = out.get(op.kind, 0.0) + op.link_bytes
    return out


# ---------------------------------------------------------------------------
# The three-term report.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RooflineReport:
    name: str
    hw: HardwareSpec
    n_chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_link_bytes: float
    collective_by_kind: dict[str, float]
    model_flops: float = 0.0  # 6*N*D useful flops (total, all devices)
    use_vpu_roof: bool = False  # SU3: vector-unit kernels can't see the MXU
    xla_flops_unscaled: float = 0.0  # raw cost_analysis (loop bodies once)
    xla_bytes_unscaled: float = 0.0
    # issued-instruction count per device (loop-aware, from the HLO mix) —
    # feeds the paper's §5.3 pipeline-throughput term; 0 = not measured
    instructions_per_device: float = 0.0
    instr_by_class: dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def peak(self) -> float:
        return self.hw.peak_flops_vpu if self.use_vpu_roof else self.hw.peak_flops

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.peak

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_link_bytes / self.hw.ici_bw

    @property
    def issue_s(self) -> float:
        """Pipeline-throughput term: issued instructions over the issue rate.

        The paper's PIUMA result in model form — SU3_Bench there is bounded
        neither by flops nor by bandwidth but by how fast the pipeline can
        *issue* its 12-load/2-store/12-FMA pattern.  Zero when either side is
        unmeasured/unmodeled, so two-term users are unaffected.
        """
        if not self.hw.issue_rate or not self.instructions_per_device:
            return 0.0
        return self.instructions_per_device / self.hw.issue_rate

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s, self.issue_s)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
            "issue": self.issue_s,
        }
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        total_hlo = self.flops_per_device * self.n_chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """How close the *useful* work runs to the binding roof: the score.

        useful_time_at_roof / bound_s where useful_time_at_roof is the time
        the dominant resource would need for MODEL_FLOPS alone.
        """
        if self.bound_s == 0:
            return 0.0
        useful_per_dev = self.model_flops / max(self.n_chips, 1)
        return (useful_per_dev / self.peak) / self.bound_s

    def as_dict(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "hw": self.hw.name,
            "n_chips": self.n_chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_link_bytes": self.collective_link_bytes,
            "collective_by_kind": self.collective_by_kind,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "issue_s": self.issue_s,
            "instructions_per_device": self.instructions_per_device,
            "instr_by_class": self.instr_by_class,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }

    def summary(self) -> str:
        return (
            f"{self.name}: compute {self.compute_s * 1e3:.3f} ms | "
            f"memory {self.memory_s * 1e3:.3f} ms | "
            f"collective {self.collective_s * 1e3:.3f} ms | "
            f"issue {self.issue_s * 1e3:.3f} ms "
            f"-> {self.dominant}-bound; useful/HLO flops "
            f"{self.useful_flops_ratio:.3f}, roofline frac {self.roofline_fraction:.3f}"
        )


def analyze_compiled(
    name: str,
    compiled: Any,
    *,
    n_chips: int,
    hw: HardwareSpec = TPU_V5E,
    model_flops: float = 0.0,
    use_vpu_roof: bool = False,
    hlo_text: str | None = None,
) -> RooflineReport:
    """Build a RooflineReport from a jax AOT ``compiled`` object.

    Uses the loop-aware HLO cost model (core.hlo_costs) — XLA's built-in
    cost_analysis counts while bodies once, which undercounts every scanned
    layer stack. The raw cost_analysis numbers are kept as a cross-check.
    """
    from repro.core import hlo_costs

    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_costs.analyze_hlo(text)
    raw: Mapping[str, float] = {}
    try:
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax: [per-device dict]
            ca = ca[0] if ca else {}
        raw = ca
    except Exception:
        pass
    return RooflineReport(
        name=name,
        hw=hw,
        n_chips=n_chips,
        flops_per_device=cost.flops,
        bytes_per_device=cost.bytes,
        collective_link_bytes=cost.collective_link_bytes,
        collective_by_kind=dict(cost.collective_by_kind),
        model_flops=model_flops,
        use_vpu_roof=use_vpu_roof,
        xla_flops_unscaled=float(raw.get("flops", 0.0)),
        xla_bytes_unscaled=float(raw.get("bytes accessed", 0.0)),
        instructions_per_device=cost.instructions,
        instr_by_class=dict(cost.instr_by_class),
    )


def analytic_su3_report(
    *,
    n_sites: int,
    word_bytes: int,
    bytes_per_site_rw: int,
    n_chips: int = 1,
    hw: HardwareSpec = TPU_V5E,
) -> RooflineReport:
    """Paper-style analytic roofline for the SU3 kernel (no compile needed)."""
    flops = 864.0 * n_sites
    byts = float(bytes_per_site_rw) * n_sites
    return RooflineReport(
        name=f"su3_analytic_L4={n_sites}",
        hw=hw,
        n_chips=n_chips,
        flops_per_device=flops / n_chips,
        bytes_per_device=byts / n_chips,
        collective_link_bytes=0.0,
        collective_by_kind={},
        model_flops=flops,
        use_vpu_roof=True,
    )

"""ExecutionPlan: the one compiled dispatch path for SU3 work.

The paper's peak numbers come from composing the right *tuple* of
(data layout, kernel formulation, blocking factor, first-touch placement);
getting any element wrong silently costs 2x.  This module makes that tuple a
first-class object instead of re-deriving it ad hoc per call site:

    ┌────────────────────────────────────────────────────────────┐
    │ EngineConfig (L, dtype, layout, variant, tile, placement)  │
    └──────────────────────────┬─────────────────────────────────┘
                               ▼  build_plan() — single construction site
    ┌────────────────────────────────────────────────────────────┐
    │ ExecutionPlan                                              │
    │   codec     LayoutCodec     pack/unpack/planar-view/spec   │
    │   kernel    KernelEntry     unified registry (XLA+Pallas)  │
    │   sharding  NamedSharding   placement-aware out_shardings  │
    │   step      jit(raw_step)   ONE compiled dispatch          │
    │   fused(k)  jit K-chained   one dispatch, K multiplies     │
    └──────────────────────────┬─────────────────────────────────┘
               ┌───────────────┼────────────────────┐
               ▼               ▼                    ▼
        SU3Engine       core.autotune        BatchedLatticeRunner
        (bench loop)    (sweeps + cache)     (B lattices, vmapped)

Everything that used to live in ``SU3Engine._build_step`` / ``_pack`` /
``_unpack`` / ``_unpack_padded`` plus the backend dispatch in
``kernels.ops`` and the candidate enumeration in ``core.autotune`` now flows
through here; benchmarks construct plans (via the thin ``SU3Engine``) rather
than wiring layouts by hand.

Fused multi-iteration stepping
------------------------------
``fused_step(k)`` chains K multiplies (C fed back as A) in ONE dispatch.  On
the Pallas path the chain runs *inside* the kernel grid step on the resident
VMEM tile (``k_iters``), so K iterations cost one HBM read + one HBM write
instead of K of each — the dispatch/HBM-roundtrip overhead that dominates at
small L.  On XLA variants the chain is a ``fori_loop`` under one jit.  This
is a TPU-targeted optimization; in interpret mode on CPU it is merely
no-slower (it still removes K-1 dispatches).

Placement
---------
The three policies reproduce the paper's §4 NUMA/first-touch study:
``sharded`` jits the initializer with sharded out_shardings (every device
first-touches its own shard), ``host_scatter`` materializes on one device and
redistributes (the UPI-storm analog, timed separately), ``replicated`` gives
every device the full lattice.

Multi-host meshes
-----------------
``build_plan`` accepts a :class:`repro.launch.mesh.MeshSpec` (or a concrete
2-D mesh with ``("hosts", "devices")`` axes) in place of the legacy 1-D site
mesh.  The site dimension then shards host-major over BOTH axes (rules in
``repro.distributed.sharding``), so every host owns one contiguous slab of
sites, and:

* ``sharded`` placement materializes each host's slab *on that host* via
  ``jax.make_array_from_callback`` — the fleet-scale form of the paper's
  NUMA-aware object creation (no host ever touches another host's sites);
* ``step`` / ``fused_step`` jit with the same sharding as ``out_shardings``,
  so the K-chained multiply never leaves the devices that hold the shard —
  the chain is device-local end to end (the multiply is site-local; the halo
  model in ``distributed.sharding.halo_spec`` prices what a stencil kernel
  would add).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.su3 import layouts, registry
from repro.core.su3 import variants as _variants  # noqa: F401  (registers XLA kernels)
from repro.core.su3.layouts import Layout, LatticeShape, LayoutCodec
from repro.distributed import sharding as dist_sharding
from repro.kernels import ops as _kops  # noqa: F401  (registers the Pallas kernel)
from repro.launch.mesh import MeshSpec
from repro.chaos.faults import NULL_FAULT_PLAN, corrupt_ghosts
from repro.obs.tracer import NULL_TRACER

PLACEMENTS = ("sharded", "host_scatter", "replicated")


def verify_tolerance(
    dtype: str, accum_dtype: str = "", reconstruct: bool = False
) -> float:
    """THE verification tolerance for a plan's fixed-point checks.

    One rule instead of per-call-site constants, keyed on the full precision
    tuple so a new storage/accumulate/reconstruct combination cannot silently
    inherit a tolerance it never earned:

    * storage rounding dominates: bf16 words quantize at ~2^-8, so any plan
      STORING bf16 verifies at 1e-2 even when it accumulates at f32 (the
      accumulate width fixes the chain, not the stored words);
    * f32 storage verifies at 1e-5 — two-row ``reconstruct`` plans stay at
      the same bound because the in-register cross product is ~1 ulp of
      extra f32 error (documented in ``su3_matmul._expand_tile``), orders of
      magnitude inside it.
    """
    del accum_dtype, reconstruct  # keyed-for-future; today storage decides
    return 1e-2 if dtype == "bfloat16" else 1e-5


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """The tunable tuple. One instance == one ExecutionPlan identity."""

    L: int = 16
    dtype: str = "float32"  # real STORAGE word dtype: float32 | bfloat16
    layout: Layout = Layout.SOA
    variant: str = "pallas"  # any name in registry.kernel_names()
    tile: int = 512  # Pallas site-tile (VMEM blocking) / AoSoA lane
    placement: str = "sharded"  # sharded | host_scatter | replicated
    iterations: int = 10
    warmups: int = 2
    accum_dtype: str = ""  # "" = accumulate at dtype; "float32" = bf16-storage plans
    compression: str = "none"  # gauge storage: "none" (18-real) | "two_row" (12-real)

    @property
    def word_bytes(self) -> int:
        return layouts.WORD_BYTES[self.dtype]

    @property
    def is_compressed(self) -> bool:
        return self.compression == layouts.GaugeCompression.TWO_ROW.value

    @property
    def compute_dtype(self) -> str:
        """The dtype the FMA chain runs at (storage dtype unless overridden)."""
        return self.accum_dtype or self.dtype

    @property
    def is_mixed_precision(self) -> bool:
        return bool(self.accum_dtype) and self.accum_dtype != self.dtype

    @property
    def complex_dtype(self) -> Any:
        return jnp.complex64  # planar kernels use cfg.dtype words

    @property
    def shape(self) -> LatticeShape:
        return LatticeShape(self.L)


def make_site_mesh(devices: list[jax.Device] | None = None) -> jax.sharding.Mesh:
    """1-D mesh over all devices; the lattice shards on the 'sites' axis."""
    devices = devices if devices is not None else jax.devices()
    return jax.sharding.Mesh(np.array(devices), ("sites",))


def resolve_mesh(
    mesh: jax.sharding.Mesh | MeshSpec | None,
) -> jax.sharding.Mesh:
    """Normalize a plan's mesh argument to a concrete ``jax.sharding.Mesh``.

    Args:
        mesh: ``None`` (legacy 1-D site mesh over all devices), a concrete
            mesh (used as-is), or a :class:`~repro.launch.mesh.MeshSpec`
            (resolved to its (host, device) mesh).
    """
    if mesh is None:
        return make_site_mesh()
    if isinstance(mesh, MeshSpec):
        return mesh.resolve()
    return mesh


def init_canonical(n_sites: int) -> tuple[jax.Array, jax.Array]:
    """su3_bench's make_lattice/init_link: A entries (1,0), B entries (1/3,0)."""
    a = jnp.full((n_sites, layouts.LINKS, layouts.SU3, layouts.SU3), 1.0 + 0.0j, jnp.complex64)
    b = jnp.full((layouts.LINKS, layouts.SU3, layouts.SU3), (1.0 / 3.0) + 0.0j, jnp.complex64)
    return a, b


# -- per-host first-touch init (multi-host sharded placement) -----------------
#
# The canonical benchmark lattice is uniform, so a shard's physical values can
# be built directly in host memory without ever materializing the global
# array: each host constructs exactly its slab (numpy, host-local — the
# "first touch") and jax assembles the global array from the per-shard
# pieces.  Only AOS carries site-position-dependent words (the metadata
# block), which is offset to global ids so the result is bit-identical to the
# single-host jit initializer.

_SITE_DIM = {Layout.AOS: 0, Layout.SOA: 2, Layout.AOSOA: 0}  # phys site axis


def _uniform_phys_shard(
    codec: LayoutCodec, n_sites: int, site_offset: int
) -> np.ndarray:
    """The packed physical form of ``n_sites`` canonical A=(1,0) sites.

    ``site_offset`` is the shard's global first-site id (AOS metadata words
    carry global ids; the gauge field is position-independent).
    """
    wdt = np.dtype(codec.word_dtype)
    if codec.layout == Layout.AOS:
        out = np.zeros((n_sites, layouts.SITE_WORDS_AOS), np.float32)
        out[:, 0:layouts.GAUGE_WORDS:2] = 1.0  # re words; im words stay 0
        idx = np.arange(site_offset, site_offset + n_sites, dtype=np.float32)
        for col in range(5):  # x, y, z, t, index — pack_aos carries idx in all
            out[:, layouts.GAUGE_WORDS + col] = idx
        out[:, layouts.GAUGE_WORDS + 5] = idx % 2  # parity
        return out.astype(wdt)
    if codec.layout == Layout.SOA:
        # codec.planar_rows: 36, or 24 for two-row compressed gauge — the
        # stored rows of the uniform lattice are all (1, 0) either way
        out = np.zeros((2, codec.planar_rows, n_sites), np.float32)
        out[0] = 1.0  # re plane
        return out.astype(wdt)
    n_tiles = n_sites // codec.tile
    out = np.zeros((n_tiles, 2, codec.planar_rows, codec.tile), np.float32)
    out[:, 0] = 1.0
    return out.astype(wdt)


def first_touch_init(
    codec: LayoutCodec, sharding: NamedSharding, padded_sites: int
) -> jax.Array:
    """Materialize the canonical lattice shard-by-shard, each on its owner.

    Every addressable shard is built host-locally (numpy) and placed on the
    device that owns it — no global array, no cross-host transfer, no
    redistribution.  This is the multi-host analogue of the paper's
    first-touch fix: in a real multi-controller run each process executes the
    callback only for its own shards.

    Args:
        codec: the plan's layout codec (decides the physical form).
        sharding: the plan's lattice NamedSharding (site axis over the mesh).
        padded_sites: global site count, already padded to the mesh.

    Returns:
        The global physical A array, sharded per ``sharding``, bit-identical
        to ``jit(pack ∘ init_canonical, out_shardings=sharding)()``.
    """
    aval = jax.eval_shape(
        codec.pack,
        jax.ShapeDtypeStruct(
            (padded_sites, layouts.LINKS, layouts.SU3, layouts.SU3), jnp.complex64
        ),
    )
    site_dim = _SITE_DIM[codec.layout]
    sites_per_index = codec.tile if codec.layout == Layout.AOSOA else 1

    def build_shard(index: tuple[slice, ...] | None) -> np.ndarray:
        sl = (index or (slice(None),) * len(aval.shape))[site_dim]
        lo = sl.start or 0
        hi = sl.stop if sl.stop is not None else aval.shape[site_dim]
        return _uniform_phys_shard(
            codec, (hi - lo) * sites_per_index, lo * sites_per_index
        )

    return jax.make_array_from_callback(aval.shape, sharding, build_shard)


def make_raw_step(
    codec: LayoutCodec,
    kernel: registry.KernelEntry,
    *,
    tile: int,
    k_iters: int = 1,
    interpret: bool | None = None,
    alias: bool = False,
) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """Unjitted physical step (a_phys, b_planar) -> c_phys for any kernel form.

    The one place the kernel-form dispatch happens; ExecutionPlan jits this
    and core.autotune lowers it for HLO-level byte accounting.  The codec's
    ``accum_dtype`` (mixed-precision storage plans) flows to planar kernels
    that own their upcast; canonical kernels accumulate in float32 by
    construction (the codec unpacks to complex64).
    """
    if not kernel.supports_layout(codec.layout):
        raise ValueError(
            f"kernel {kernel.name!r} does not support layout {codec.layout.value!r} "
            f"(supported: {[l.value for l in kernel.layouts]})"
        )
    if kernel.form == registry.BATCHED:
        raise ValueError(
            f"kernel {kernel.name!r} is slot-batched; it dispatches through "
            f"ExecutionPlan.fused_batched_step, not a single-lattice step"
        )
    if kernel.form == registry.STENCIL:
        raise ValueError(
            f"kernel {kernel.name!r} is a nearest-neighbor stencil; it "
            f"dispatches through ExecutionPlan.stencil_step, not a multiply step"
        )
    if kernel.form == registry.STENCIL_AXPY:
        raise ValueError(
            f"kernel {kernel.name!r} is a fused CG iteration body; it "
            f"dispatches through ExecutionPlan.cg_solve, not a multiply step"
        )
    if k_iters > 1 and kernel.form == registry.PLANAR and not kernel.supports_fused:
        raise ValueError(f"kernel {kernel.name!r} does not support fused iteration")
    if codec.is_mixed_precision and not kernel.supports_accum_dtype():
        raise ValueError(
            f"kernel {kernel.name!r} cannot accumulate at {codec.accum_dtype!r} "
            f"over {codec.dtype!r} storage (no accum_dtype support)"
        )
    if codec.is_compressed and not kernel.supports_compression():
        raise ValueError(
            f"kernel {kernel.name!r} cannot stream two-row compressed gauge "
            f"(no reconstruct-on-load path)"
        )

    if kernel.form == registry.PLANAR:
        if not codec.supports_planar_view:
            raise ValueError(
                f"planar kernel {kernel.name!r} needs a planar-view layout, "
                f"got {codec.layout.value!r}"
            )

        def raw_step(a_phys: jax.Array, b_p: jax.Array) -> jax.Array:
            a_p = codec.planar_view(a_phys)
            kw: dict[str, Any] = {"tile": tile, "k_iters": k_iters, "alias": alias}
            if codec.is_mixed_precision:
                kw["accum_dtype"] = codec.accum_dtype
            if codec.is_compressed:
                kw["compressed"] = True
            if interpret is not None:
                kw["interpret"] = interpret
            c_p = kernel.fn(a_p, b_p, **kw)
            return codec.from_planar_view(c_p, a_phys)

    else:  # canonical complex kernel wrapped by the codec

        def raw_step(a_phys: jax.Array, b_p: jax.Array) -> jax.Array:
            b = codec.unpack_b(b_p)
            if k_iters == 1:
                return codec.pack(kernel.fn(codec.unpack(a_phys), b))

            def body(_: jax.Array, phys: jax.Array) -> jax.Array:
                return codec.pack(kernel.fn(codec.unpack(phys), b))

            return jax.lax.fori_loop(0, k_iters, body, a_phys)

    return raw_step


MEGAKERNEL_VARIANT = "pallas_megakernel"
STENCIL_VARIANT = "pallas_stencil"
CG_VARIANT = "pallas_cg"

# Default SPD shift of the CG operator A = CG_SHIFT I + S.  Each of the 8
# stencil terms applies one unitary SU(3) row, so ||S|| <= 8; sigma = 16
# keeps the symmetric part positive definite with condition number <= 3
# ((16 + 8) / (16 - 8)), which is what makes the solver a *short*-chain
# serving workload (O(10) iterations to 1e-6) rather than a batch job.
# Note the simplified site-local-adjoint stencil is Hermitian exactly when
# every U_mu is constant along its own direction mu (e.g. uniform or
# per-direction-constant SU(3) fields) — the family the convergence tier
# pins; on general fields A is only near-symmetric and CG is best-effort.
CG_SHIFT = 16.0


# -- stencil neighbor geometry ------------------------------------------------
#
# Site linearization is t-major: site = ((t*L + z)*L + y)*L + x, so the host
# slabs of the lattice sharding are contiguous t-slices and the +-t neighbor
# of site s is (s +- L^3) mod L^4 — the only directions whose access crosses
# slab boundaries.  x/y/z neighbor moves permute sites WITHIN one t-slice and
# therefore never leave a (non-degenerate) slab.


def stencil_neighbor_tables(
    L: int, padded_sites: int, n_shards: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Neighbor index tables for the 8-direction stencil.

    Returns ``(global_idx, local_idx, boundary_idx)``:

    * ``global_idx (8, padded_sites)`` — exact periodic neighbors, direction
      order (+x, +y, +z, +t, -x, -y, -z, -t).  Padding sites (>= L^4) point
      at themselves: their outputs are garbage and are sliced off at unpack.
    * ``local_idx (8, padded_sites)`` — identical except the +-t directions
      wrap WITHIN each of the ``n_shards`` contiguous slabs, so a gather
      through it moves no data between slabs.  It agrees with ``global_idx``
      exactly on every interior site (``HaloSpec.interior_ranges``) — the
      property the overlap schedule's bit-identity rests on.
    * ``boundary_idx (B,)`` — concatenated ``HaloSpec.boundary_ranges`` of
      every shard (empty on one shard): the sites whose +-t neighbors are
      remote, recomputed by the boundary pass after the exchange lands.
    """
    S = L**4
    if n_shards > 1 and S % n_shards:
        raise ValueError(f"L={L} lattice does not shard over {n_shards} slabs")
    idx = np.arange(S, dtype=np.int64)
    pad_id = np.arange(padded_sites, dtype=np.int64)
    glob = np.tile(pad_id, (8, 1))
    for d in range(4):
        stride = L**d
        c = (idx // stride) % L
        glob[d, :S] = idx + (((c + 1) % L) - c) * stride
        glob[4 + d, :S] = idx + (((c - 1) % L) - c) * stride
    local = glob.copy()
    face = L**3
    if n_shards > 1:
        per = S // n_shards
        base = (idx // per) * per
        off = idx - base
        local[3, :S] = base + (off + face) % per
        local[7, :S] = base + (off - face) % per
    spec = dist_sharding.HaloSpec(L=L, n_shards=n_shards)
    ranges = [
        np.arange(a, b, dtype=np.int64)
        for s in range(n_shards)
        for (a, b) in spec.boundary_ranges(s)
    ]
    bidx = np.concatenate(ranges) if ranges else np.empty(0, np.int64)
    return glob.astype(np.int32), local.astype(np.int32), bidx.astype(np.int32)


def init_stencil_canonical(n_sites: int) -> tuple[jax.Array, jax.Array]:
    """Canonical stencil benchmark data: U entries (1, 0), v entries (1/24, 0).

    With uniform inputs every output component is sum over 8 directions of
    3 entries x 1/24 = exactly (1, 0) — the stencil analogue of su3_bench's
    A=(1,0)/B=(1/3,0) fixed-point check, used by ``verify_stencil``.
    """
    a, _ = init_canonical(n_sites)
    v = jnp.full((n_sites, layouts.SU3), (1.0 / 24.0) + 0.0j, jnp.complex64)
    return a, v


# divergence guard: rs blowing past this multiple of ||b||^2 is treated as
# breakdown (relative residual > 1e4), not slow convergence — raise, don't spin
CG_DIVERGENCE_FACTOR = 1e8


class CGError(RuntimeError):
    """Base of every structured ``cg_solve`` failure.

    Raised — never a hang — the Python-level iteration loop is bounded by
    ``max_iters`` and every residual sync is a finite device fetch.
    ``result`` (when not None) carries the best iterate reached as a
    partial :class:`CGResult` (``converged=False``): resume with
    ``cg_solve(..., x0_p=err.result.x_p)`` instead of restarting from zero.
    """

    def __init__(self, message: str, iterations: int, residual: float,
                 tol: float, result: "CGResult | None" = None):
        super().__init__(message)
        self.iterations = iterations
        self.residual = residual
        self.tol = tol
        self.result = result


class CGMaxItersError(CGError):
    """``cg_solve`` exhausted ``max_iters`` without reaching tolerance."""

    def __init__(self, iterations: int, residual: float, tol: float,
                 result: "CGResult | None" = None):
        super().__init__(
            f"CG did not converge: relative residual {residual:.3e} > tol "
            f"{tol:.1e} after {iterations} iterations",
            iterations, residual, tol, result,
        )


class CGDivergedError(CGError):
    """``cg_solve`` hit numerical breakdown: a NaN/Inf residual (poisoned
    operand, corrupted halo) or a residual exploding past
    :data:`CG_DIVERGENCE_FACTOR` x ``||b||^2``.  Structured and immediate —
    a solver fed corrupted data must fail loudly, not iterate forever."""

    def __init__(self, iterations: int, residual: float, tol: float,
                 result: "CGResult | None" = None, reason: str = "diverged"):
        super().__init__(
            f"CG {reason}: relative residual {residual:.3e} (tol {tol:.1e}) "
            f"after {iterations} iterations",
            iterations, residual, tol, result,
        )
        self.reason = reason


@dataclasses.dataclass
class CGResult:
    """One CG solve: the planar solution plus its residual history.

    ``residuals[i]`` is the relative residual ``||r|| / ||b||`` after
    iteration ``i + 1`` — the iterate-by-iterate series the convergence
    tier pins against :func:`cg_reference_solve`.
    """

    x_p: jax.Array
    iterations: int
    residuals: list[float]
    converged: bool
    wall_s: float


def stencil_apply_reference(u: jax.Array, v: jax.Array, L: int) -> jax.Array:
    """Plain-jnp 8-direction stencil on canonical complex arrays.

    ``u (S, 4, 3, 3)`` complex links, ``v (S, 3)`` complex vector field —
    no planar packing, no Pallas, no neighbor-table sharing with the kernel
    path beyond the geometry itself: the independent oracle the CG tier
    pins convergence against.
    """
    S = L**4
    glob, _local, _b = stencil_neighbor_tables(L, S, 1)
    out = jnp.zeros_like(v)
    for mu in range(layouts.LINKS):
        out = out + jnp.einsum("skl,sl->sk", u[:, mu], v[glob[mu]])
        out = out + jnp.einsum("slk,sl->sk", jnp.conj(u[:, mu]), v[glob[4 + mu]])
    return out


def cg_reference_solve(
    u: jax.Array,
    b: jax.Array,
    L: int,
    *,
    tol: float = 1e-6,
    max_iters: int = 200,
    sigma: float = CG_SHIFT,
) -> tuple[jax.Array, list[float], bool]:
    """Plain-jnp CG on the shifted operator ``A = sigma I + S`` — the
    convergence-pinning oracle for :meth:`ExecutionPlan.cg_solve`.

    Complex-arithmetic textbook CG on canonical arrays; returns
    ``(x, relative residuals per iteration, converged)``.  Never raises on
    exhaustion (the oracle reports, the plan enforces).
    """
    apply_j = jax.jit(lambda p: sigma * p + stencil_apply_reference(u, p, L))
    b_rs = float(jnp.sum(jnp.real(b) ** 2 + jnp.imag(b) ** 2))
    if b_rs == 0.0:
        return jnp.zeros_like(b), [], True
    x = jnp.zeros_like(b)
    r = b
    p = b
    rs = jnp.sum(jnp.real(r) ** 2 + jnp.imag(r) ** 2)
    residuals: list[float] = []
    for _ in range(max_iters):
        ap = apply_j(p)
        pap = jnp.real(jnp.vdot(p, ap))
        alpha = rs / pap
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.sum(jnp.real(r) ** 2 + jnp.imag(r) ** 2)
        residuals.append(float(rs_new / b_rs) ** 0.5)
        if residuals[-1] <= tol:
            return x, residuals, True
        p = r + (rs_new / rs) * p
        rs = rs_new
    return x, residuals, False


def make_raw_batched_step(
    codec: LayoutCodec,
    kernel: registry.KernelEntry,
    *,
    tile: int,
    max_k: int,
    interpret: bool | None = None,
    alias: bool = False,
) -> Callable[[jax.Array, jax.Array, jax.Array], jax.Array]:
    """Unjitted slot-batched step (a_batch, b_batch, slot_k) -> c_batch.

    The megakernel analogue of :func:`make_raw_step`: the physical slot table
    ``a_batch (slots, ...)`` flattens to the batched planar view, advances by
    ``slot_k`` chained multiplies per slot in ONE kernel dispatch, and folds
    back into the physical layout.
    """
    if kernel.form != registry.BATCHED:
        raise ValueError(
            f"kernel {kernel.name!r} has form {kernel.form!r}; the batched "
            f"step needs a {registry.BATCHED!r}-form kernel"
        )
    if not kernel.supports_layout(codec.layout):
        raise ValueError(
            f"kernel {kernel.name!r} does not support layout {codec.layout.value!r} "
            f"(supported: {[l.value for l in kernel.layouts]})"
        )
    if not codec.supports_planar_view:
        raise ValueError(
            f"batched kernel {kernel.name!r} needs a planar-view layout, "
            f"got {codec.layout.value!r}"
        )
    if codec.is_mixed_precision and not kernel.supports_accum_dtype():
        raise ValueError(
            f"kernel {kernel.name!r} cannot accumulate at {codec.accum_dtype!r} "
            f"over {codec.dtype!r} storage (no accum_dtype support)"
        )
    if codec.is_compressed and not kernel.supports_compression():
        raise ValueError(
            f"kernel {kernel.name!r} cannot stream two-row compressed gauge "
            f"(no reconstruct-on-load path)"
        )

    def raw_batched(
        a_batch: jax.Array, b_batch: jax.Array, slot_k: jax.Array
    ) -> jax.Array:
        a_p = jax.vmap(codec.planar_view)(a_batch)
        kw: dict[str, Any] = {"tile": tile, "max_k": max_k, "alias": alias}
        if codec.is_mixed_precision:
            kw["accum_dtype"] = codec.accum_dtype
        if codec.is_compressed:
            kw["compressed"] = True
        if interpret is not None:
            kw["interpret"] = interpret
        c_p = kernel.fn(a_p, b_batch, slot_k, **kw)
        return jax.vmap(codec.from_planar_view)(c_p, a_batch)

    return raw_batched


class ExecutionPlan:
    """Compiled execution of one EngineConfig tuple on one mesh.

    Construct via :func:`build_plan` (or ``ExecutionPlan.build``) — the single
    construction site for every layout x variant x placement combination.

    Attributes:
        codec: :class:`~repro.core.su3.layouts.LayoutCodec` — canonical
            (S, 4, 3, 3) complex <-> physical layout conversions.
        kernel: the resolved :class:`~repro.core.su3.registry.KernelEntry`.
        mesh: the concrete mesh; 1-D ``("sites",)`` or 2-D
            ``("hosts", "devices")``.
        site_axes: mesh axes the site dimension shards over (host-major).
        is_multi_host: mesh carries a host axis of size > 1.
        padded_sites: global site count padded so every device shard is a
            whole number of Pallas tiles.
        sharding / replicated: the lattice / scalar NamedShardings.
        step: jitted ``(a_phys, b_planar) -> c_phys`` — ONE dispatch, output
            sharded like the input (the chain stays device-local).
    """

    def __init__(self, cfg: EngineConfig, mesh: jax.sharding.Mesh | MeshSpec):
        self.cfg = cfg
        mesh = resolve_mesh(mesh)
        self.mesh = mesh
        self.n_devices = int(mesh.devices.size)
        self.site_axes = dist_sharding.lattice_site_axes(mesh)
        self.is_multi_host = dist_sharding.lattice_is_multi_host(mesh)
        if cfg.placement not in PLACEMENTS:
            raise ValueError(f"unknown placement {cfg.placement!r}; one of {PLACEMENTS}")
        self.codec = layouts.make_codec(
            cfg.layout,
            tile=cfg.tile,
            dtype=cfg.dtype,
            accum_dtype=cfg.accum_dtype,
            compression=layouts.GaugeCompression(cfg.compression),
        )
        self.kernel = registry.get_kernel(cfg.variant)
        # Lattice padded so every device shard is a whole number of tiles.
        n = cfg.shape.n_sites
        chunk = self.n_devices * cfg.tile
        self.padded_sites = ((n + chunk - 1) // chunk) * chunk
        self.sharding = NamedSharding(
            mesh, dist_sharding.lattice_site_spec(self.codec, mesh)
        )
        self.replicated = NamedSharding(mesh, P())
        self.raw_step = make_raw_step(self.codec, self.kernel, tile=cfg.tile)
        self.step = jax.jit(self.raw_step, out_shardings=self.sharding, donate_argnums=())
        self._fused_steps: dict[int, Callable[[jax.Array, jax.Array], jax.Array]] = {}
        self._batched_steps: dict[
            tuple[int, int], Callable[[jax.Array, jax.Array, jax.Array], jax.Array]
        ] = {}
        self._stencil_steps: dict[
            tuple[bool, int], Callable[[jax.Array, jax.Array], jax.Array]
        ] = {}
        self._stencil_tables: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None
        self._stencil_parts: dict[str, Any] | None = None
        self._cg_help: dict[str, Any] | None = None
        self._cg_applies: dict[tuple[bool, bool], Callable[..., Any]] = {}
        # Phase tracer for the stencil schedule (repro.obs).  Disabled by
        # default: the untraced closures are byte-identical to pre-obs code.
        # When enabled, each schedule phase (exchange / interior / boundary)
        # blocks at its end so the span measures that phase — tracing
        # synchronizes the schedule (the only way to time a phase); the real
        # overlapped wall comes from an untraced run of the same step.
        self.tracer = NULL_TRACER
        # Fault plan for chaos testing (repro.chaos).  Disabled by default:
        # the same one-branch guard style as the tracer, so the fault-free
        # hot path is untouched.  When armed, the overlapped stencil
        # schedules consult the "halo" site after each exchange and apply
        # the drawn corruption to the ghost slabs before the boundary pass.
        self.faults = NULL_FAULT_PLAN

    @classmethod
    def build(
        cls, cfg: EngineConfig, mesh: jax.sharding.Mesh | MeshSpec | None = None
    ) -> "ExecutionPlan":
        return cls(cfg, resolve_mesh(mesh))

    @property
    def n_hosts(self) -> int:
        """Host-axis size of the mesh (1 on the legacy 1-D site mesh)."""
        if dist_sharding.LATTICE_HOST_AXIS in self.mesh.axis_names:
            return int(self.mesh.shape[dist_sharding.LATTICE_HOST_AXIS])
        return 1

    def halo(self) -> dist_sharding.HaloSpec:
        """Boundary geometry of this plan's per-host shards (see
        :func:`repro.distributed.sharding.halo_spec`); n_shards = n_hosts."""
        return dist_sharding.HaloSpec(
            L=self.cfg.L, n_shards=self.n_hosts, word_bytes=self.cfg.word_bytes
        )

    def lattice_batch_sharding(self) -> NamedSharding:
        """Sharding for a LEADING whole-lattice batch axis (request batches,
        megakernel slot tables): the batch axis shards over the mesh's site
        axes — whole lattices per device, host-major — and every physical
        dimension is replicated.  The single owner of the layout ->
        physical-rank mapping for batched forms."""
        phys_ndim = 1 + {Layout.AOS: 2, Layout.SOA: 3, Layout.AOSOA: 4}[
            Layout(self.cfg.layout)
        ]
        axes = self.site_axes
        batch_axis = axes if len(axes) > 1 else axes[0]
        return NamedSharding(
            self.mesh, P(*((batch_axis,) + (None,) * (phys_ndim - 1)))
        )

    # -- fused multi-iteration stepping ---------------------------------------

    def fused_step(self, k: int) -> Callable[[jax.Array, jax.Array], jax.Array]:
        """One dispatch performing K chained multiplies (C fed back as A).

        ``fused_step(k)(a, b)`` equals ``step`` applied k times sequentially.
        On TPU the argument is donated and the Pallas C-tile aliases A's
        buffer, so the chain is a true in-place VMEM-resident update.
        """
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        if k not in self._fused_steps:
            on_tpu = jax.default_backend() == "tpu"
            raw = make_raw_step(
                self.codec, self.kernel, tile=self.cfg.tile, k_iters=k,
                alias=self.kernel.form == registry.PLANAR and on_tpu,
            )
            self._fused_steps[k] = jax.jit(
                raw,
                out_shardings=self.sharding,
                donate_argnums=(0,) if on_tpu else (),
            )
        return self._fused_steps[k]

    def fused_batched_step(
        self, slots: int, max_k: int = 8
    ) -> Callable[[jax.Array, jax.Array, jax.Array], jax.Array]:
        """ONE megakernel dispatch advancing a whole slot table.

        ``fused_batched_step(slots, max_k)(a_batch, b_batch, slot_k)`` equals
        applying ``step`` ``slot_k[s]`` times to slot ``s`` independently —
        bit-identical, but every slot's chain runs inside one pallas_call
        whose grid spans (slots x site tiles), so a serving iteration costs
        one host dispatch however many chains are in flight.  Per-slot depths
        are data (scalar-prefetched), clamped to the static ``max_k``; a slot
        with depth 0 passes through untouched.

        On TPU the slot table is donated and the kernel's C block aliases A's
        buffer, so in-flight slots update in place with zero copies.

        Args:
            slots: slot-table size (the leading axis of ``a_batch``).
            max_k: static in-kernel chain bound; one compiled program serves
                every per-slot depth in ``[0, max_k]``.
        """
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if max_k < 1:
            raise ValueError(f"max_k must be >= 1, got {max_k}")
        key = (slots, max_k)
        if key not in self._batched_steps:
            kernel = registry.get_kernel(MEGAKERNEL_VARIANT)
            on_tpu = jax.default_backend() == "tpu"
            raw = make_raw_batched_step(
                self.codec, kernel, tile=self.cfg.tile, max_k=max_k, alias=on_tpu
            )
            # whole lattices per device (when the table divides the mesh) —
            # the same sharding BatchedLatticeRunner gives request batches
            out_sh = (
                self.lattice_batch_sharding()
                if slots % self.n_devices == 0 else None
            )
            self._batched_steps[key] = jax.jit(
                raw,
                out_shardings=out_sh,
                donate_argnums=(0,) if on_tpu else (),
            )
        return self._batched_steps[key]

    # -- nearest-neighbor stencil (Dslash-style) -------------------------------

    @property
    def vec_sharding(self) -> NamedSharding:
        """Sharding of a planar color-vector field (2, 3, S): site axis over
        the mesh's site axes, components replicated — the vector field lives
        site-aligned with the lattice it belongs to."""
        ax = self.site_axes if len(self.site_axes) > 1 else self.site_axes[0]
        return NamedSharding(self.mesh, P(None, None, ax))

    def stencil_halo(self, depth: int = 1) -> dist_sharding.HaloSpec:
        """Halo spec of the stencil's *vector-field* exchange: same boundary
        geometry as :meth:`halo`, priced at 6 words/site (color 3-vectors
        travel, not gauge links) and at the plan's storage width.

        ``depth=2`` prices the communication-avoiding exchange that feeds two
        :meth:`stencil_step` applications per transfer (twice the ghost zone,
        half as many exchanges)."""
        return dist_sharding.HaloSpec(
            L=self.cfg.L,
            n_shards=self.n_hosts,
            word_bytes=self.cfg.word_bytes,
            words_per_site=dist_sharding.VECTOR_WORDS_PER_SITE,
            depth=depth,
        )

    def _stencil_geometry(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        if self._stencil_tables is None:
            self._stencil_tables = stencil_neighbor_tables(
                self.cfg.L, self.padded_sites, self.n_hosts
            )
        return self._stencil_tables

    def _stencil_kernel_kwargs(
        self, variant: str = STENCIL_VARIANT
    ) -> tuple[registry.KernelEntry, dict[str, Any]]:
        kernel = registry.get_kernel(variant)
        if not kernel.supports_layout(self.codec.layout):
            raise ValueError(
                f"stencil kernel {kernel.name!r} does not support layout "
                f"{self.codec.layout.value!r}"
            )
        if self.codec.is_mixed_precision and not kernel.supports_accum_dtype():
            raise ValueError(
                f"stencil kernel {kernel.name!r} cannot accumulate at "
                f"{self.codec.accum_dtype!r} over {self.codec.dtype!r} storage"
            )
        if self.codec.is_compressed and not kernel.supports_compression():
            raise ValueError(
                f"stencil kernel {kernel.name!r} cannot stream two-row "
                f"compressed gauge (no reconstruct-on-load path)"
            )
        kw: dict[str, Any] = {"tile": self.cfg.tile}
        if self.codec.is_mixed_precision:
            kw["accum_dtype"] = self.codec.accum_dtype
        if self.codec.is_compressed:
            kw["compressed"] = True
        return kernel, kw

    def raw_stencil_reference(self) -> Callable[[jax.Array, jax.Array], jax.Array]:
        """Unjitted reference stencil ``(u_phys, v_p) -> out_p``.

        Gathers all 8 neighbor fields through the exact periodic table and
        runs ONE kernel pass over every site — the bit-identity oracle the
        overlapped schedule is pinned against, and the form the serving
        layer vmaps over request batches.
        """
        kernel, kw = self._stencil_kernel_kwargs()
        glob, _local, _bidx = self._stencil_geometry()
        codec = self.codec

        def reference(u_phys: jax.Array, v_p: jax.Array) -> jax.Array:
            u_p = codec.planar_view(u_phys)
            v_nbr = jnp.moveaxis(v_p[:, :, glob], 2, 0)  # (8, 2, 3, S)
            return kernel.fn(u_p, v_nbr, **kw)

        return reference

    def stencil_reference_step(self) -> Callable[[jax.Array, jax.Array], jax.Array]:
        """Jitted non-overlapped reference stencil — ONE dispatch whose +-t
        neighbor gathers carry the halo traffic inline (compute waits for
        the exchange; the baseline the overlap schedule is measured against
        and pinned bit-identical to)."""
        return self.stencil_step(overlap=False)

    def stencil_step(
        self, overlap: bool | None = None, depth: int = 1
    ) -> Callable[[jax.Array, jax.Array], jax.Array]:
        """The stencil dispatch path: ``step(u_phys, v_p) -> out_p``.

        ``u_phys`` is the plan's physical gauge lattice, ``v_p`` the planar
        (2, 3, padded_sites) vector field (``codec.pack_vec``), and the
        result is the planar output vector field, sharded like ``v_p``.
        ``depth`` is the number of stencil applications the returned callable
        performs (``step(u, v)`` with depth=2 equals two depth-1 steps).

        overlap=False (the pinned reference): one jitted dispatch; neighbor
        gathers through the exact periodic table, kernel over all sites.

        overlap=True (default on multi-host meshes): the interior/boundary
        split schedule —

        1. **exchange** — dispatch the +-t ghost gathers of the boundary
           sites first; the cross-slab transfer is now in flight;
        2. **interior** — dispatch the full-lattice kernel pass whose +-t
           gathers wrap *within* each host slab (no cross-slab dependency,
           so it runs concurrently with the exchange); every interior
           site's result is already exact;
        3. **boundary** — once the ghosts land, recompute only the boundary
           sites with their true remote neighbors and scatter them over the
           interior pass's output.

        Because jax dispatch is asynchronous, step 2 is issued while step
        1's transfer is outstanding — on TPU the collective overlaps the
        interior kernel; on CPU interpret the three dispatches serialize
        (dispatch-order overlap only; see ROADMAP).  The boundary sites are
        computed twice — the classic overlap trade (arXiv:2112.01852) — and
        the result is bit-identical to the reference: same kernel, same
        per-site inputs, same accumulation order.

        depth=2 with overlap (communication avoidance): ONE ±t exchange
        carries the depth-2 ghost payload — the depth-1 ghosts plus every
        ``v`` value the *ring* (the ±t neighbors of the boundary sites)
        reads — and both applications run off it.  Step 2's boundary pass
        needs step 1's result at the ring; instead of a second exchange it
        is recomputed locally from the exchanged ``v`` (same kernel, same
        per-site inputs as the pass that produced it, so the recompute is
        bit-identical and the whole depth-2 step matches two depth-1
        steps).  Halves the exchange count per application at the cost of
        ``2 x ring`` extra boundary-size kernel work — the trade
        ``autotune.predict_stencil`` prices per mesh.
        """
        if depth not in (1, 2):
            raise ValueError(f"stencil exchange depth must be 1 or 2, got {depth}")
        if overlap is None:
            overlap = self.is_multi_host
        key = (bool(overlap), depth)
        if key not in self._stencil_steps:
            self._stencil_steps[key] = self._build_stencil_step(*key)
        return self._stencil_steps[key]

    def _stencil_overlap_parts(self) -> dict[str, Any]:
        """The jitted pieces every overlapped stencil schedule shares.

        One construction site so the depth-2 path reuses the SAME compiled
        interior/boundary programs as depth-1 — the bit-identity argument
        ("same kernel, same per-site inputs") then needs to cover only the
        ring recompute, not a re-derived schedule.
        """
        if self._stencil_parts is not None:
            return self._stencil_parts
        kernel, kw = self._stencil_kernel_kwargs()
        glob, local, bidx = self._stencil_geometry()
        codec, tile = self.codec, self.cfg.tile
        out_sh = self.vec_sharding

        def interior_fn(u_phys: jax.Array, v_p: jax.Array) -> jax.Array:
            # slab-local gathers only: independent of the in-flight exchange
            v_nbr = jnp.moveaxis(v_p[:, :, local], 2, 0)  # (8, 2, 3, S)
            return kernel.fn(codec.planar_view(u_phys), v_nbr, **kw)

        parts: dict[str, Any] = {
            "interior_j": jax.jit(interior_fn, out_shardings=out_sh),
            "n_boundary": int(bidx.size),
        }
        if parts["n_boundary"]:
            n_boundary = parts["n_boundary"]
            # +-t ghosts: the true remote neighbors of the boundary sites
            ghost_fwd_idx, ghost_bwd_idx = glob[3][bidx], glob[7][bidx]
            xyz_idx = glob[(0, 1, 2, 4, 5, 6), :][:, bidx]  # shard-local dirs
            pad = (-n_boundary) % tile

            def exchange_fn(v_p: jax.Array) -> tuple[jax.Array, jax.Array]:
                return v_p[:, :, ghost_fwd_idx], v_p[:, :, ghost_bwd_idx]

            def boundary_fn(
                u_phys: jax.Array,
                v_p: jax.Array,
                ghost_fwd: jax.Array,
                ghost_bwd: jax.Array,
                out_interior: jax.Array,
            ) -> jax.Array:
                u_b = codec.planar_view(u_phys)[:, :, bidx]  # (2, 36|24, B)
                v6 = jnp.moveaxis(v_p[:, :, xyz_idx], 2, 0)  # (6, 2, 3, B)
                v_nbr = jnp.concatenate(
                    [v6[:3], ghost_fwd[None], v6[3:], ghost_bwd[None]], axis=0
                )  # (8, 2, 3, B) in direction order
                if pad:
                    u_b = jnp.pad(u_b, ((0, 0), (0, 0), (0, pad)))
                    v_nbr = jnp.pad(v_nbr, ((0, 0), (0, 0), (0, 0), (0, pad)))
                out_b = kernel.fn(u_b, v_nbr, **kw)[:, :, :n_boundary]
                return out_interior.at[:, :, bidx].set(out_b)

            parts.update(
                exchange_j=jax.jit(exchange_fn),
                boundary_j=jax.jit(boundary_fn, out_shardings=out_sh),
                ghost_fwd_idx=ghost_fwd_idx,
                ghost_bwd_idx=ghost_bwd_idx,
            )
        self._stencil_parts = parts
        return parts

    def _stencil_trace_attrs(self, overlap: bool, depth: int) -> dict[str, Any]:
        """Attrs every ``stencil.step`` span carries — the join key the
        attribution report matches against ``autotune.predict_stencil``."""
        from repro.kernels.su3_stencil import STENCIL_FLOPS_PER_SITE

        cfg = self.cfg
        return {
            "L": cfg.L, "tile": cfg.tile, "dtype": cfg.dtype,
            "compression": cfg.compression, "hosts": self.n_hosts,
            "overlap": bool(overlap), "depth": depth,
            "flops": float(STENCIL_FLOPS_PER_SITE) * cfg.shape.n_sites * depth,
        }

    def _build_stencil_step(
        self, overlap: bool, depth: int = 1
    ) -> Callable[[jax.Array, jax.Array], jax.Array]:
        plan = self  # closures read plan.tracer at CALL time (set post-build)
        if not overlap:
            # ONE body for the reference: the same raw function the serving
            # layer vmaps, so the pinned bit-identity oracle and the served
            # stencil can never silently diverge
            ref = jax.jit(self.raw_stencil_reference(), out_shardings=self.vec_sharding)
            attrs = self._stencil_trace_attrs(False, depth)

            def serial(u_phys: jax.Array, v_p: jax.Array) -> jax.Array:
                tr = plan.tracer
                if not tr.enabled:
                    if depth == 1:
                        return ref(u_phys, v_p)
                    return ref(u_phys, ref(u_phys, v_p))
                with tr.span("stencil.step", **attrs):
                    out = ref(u_phys, v_p)
                    if depth == 2:
                        out = ref(u_phys, out)
                    out = jax.block_until_ready(out)
                return out

            return serial

        parts = self._stencil_overlap_parts()
        interior_j = parts["interior_j"]
        attrs = self._stencil_trace_attrs(True, depth)
        if parts["n_boundary"] == 0:
            # unsharded lattice: local wrap IS the periodic wrap, and there
            # is no exchange to avoid — depth just composes the interior pass

            def local_only(u_phys: jax.Array, v_p: jax.Array) -> jax.Array:
                tr = plan.tracer
                if not tr.enabled:
                    if depth == 1:
                        return interior_j(u_phys, v_p)
                    return interior_j(u_phys, interior_j(u_phys, v_p))
                with tr.span("stencil.step", **attrs):
                    for _ in range(depth):
                        with tr.span("stencil.interior"):
                            v_p = jax.block_until_ready(interior_j(u_phys, v_p))
                return v_p

            return local_only

        exchange_j, boundary_j = parts["exchange_j"], parts["boundary_j"]
        if depth == 1:

            def overlapped(u_phys: jax.Array, v_p: jax.Array) -> jax.Array:
                tr = plan.tracer
                if not tr.enabled:
                    ghosts = exchange_j(v_p)  # issued FIRST: transfer in flight
                    if plan.faults.enabled:
                        f = plan.faults.ask("halo", depth=1)
                        if f is not None:
                            ghosts = corrupt_ghosts(tuple(ghosts), f.action)
                    out_i = interior_j(u_phys, v_p)  # overlaps the exchange
                    return boundary_j(u_phys, v_p, *ghosts, out_i)
                # traced: each phase blocks so its span is a measurement —
                # phase times come from here, the hidden-vs-exposed wall
                # from an untraced run (see benchmarks/stencil.py)
                with tr.span("stencil.step", **attrs):
                    with tr.span("stencil.exchange"):
                        ghosts = jax.block_until_ready(exchange_j(v_p))
                    if plan.faults.enabled:
                        f = plan.faults.ask("halo", depth=1)
                        if f is not None:
                            ghosts = corrupt_ghosts(tuple(ghosts), f.action)
                    with tr.span("stencil.interior"):
                        out_i = jax.block_until_ready(interior_j(u_phys, v_p))
                    with tr.span("stencil.boundary"):
                        out = jax.block_until_ready(
                            boundary_j(u_phys, v_p, *ghosts, out_i))
                return out

            return overlapped

        return self._build_stencil_step2(parts)

    def _build_stencil_step2(
        self, parts: dict[str, Any]
    ) -> Callable[[jax.Array, jax.Array], jax.Array]:
        """The communication-avoiding double step (overlap, depth=2).

        Ring geometry: the ring is ``(+t, -t)`` neighbors of the boundary
        sites — exactly the sites whose step-1 results the second boundary
        pass consumes as ghosts.  ``exchange2`` ships the depth-2 payload in
        one dispatch (depth-1 ghosts + the 8-direction ``v`` neighborhoods of
        the ring); ``ring_j`` then recomputes step-1's output at the ring
        from that payload, so step 2 never exchanges.  A ring site is either
        interior to its owning shard (step 1 computed it through the local
        table, which equals the periodic table there) or a boundary site
        (step 1 computed it from the same glob-derived ghosts) — either way
        the recompute feeds the kernel the same per-site inputs, hence the
        bit-identity with two depth-1 steps.
        """
        kernel, kw = self._stencil_kernel_kwargs()
        glob, _local, _bidx = self._stencil_geometry()
        codec, tile = self.codec, self.cfg.tile
        interior_j, boundary_j = parts["interior_j"], parts["boundary_j"]
        n_boundary = parts["n_boundary"]

        ridx = np.concatenate([parts["ghost_fwd_idx"], parts["ghost_bwd_idx"]])
        ring_nbr_idx = glob[:, ridx]  # (8, 2B): every v site the ring reads
        n_ring = int(ridx.size)
        rpad = (-n_ring) % tile

        def exchange2_fn(
            v_p: jax.Array,
        ) -> tuple[jax.Array, jax.Array, jax.Array]:
            # ONE dispatch shipping the whole depth-2 ghost zone: the
            # depth-1 ghosts (step 1's boundary pass) plus the v values
            # within two faces of the boundary (the ring recompute's reads)
            return (
                v_p[:, :, parts["ghost_fwd_idx"]],
                v_p[:, :, parts["ghost_bwd_idx"]],
                jnp.moveaxis(v_p[:, :, ring_nbr_idx], 2, 0),  # (8, 2, 3, 2B)
            )

        exchange2_j = jax.jit(exchange2_fn)

        def ring_fn(
            u_phys: jax.Array, ring_vnbr: jax.Array
        ) -> tuple[jax.Array, jax.Array]:
            u_r = codec.planar_view(u_phys)[:, :, ridx]  # (2, 36|24, 2B)
            if rpad:
                u_r = jnp.pad(u_r, ((0, 0), (0, 0), (0, rpad)))
                ring_vnbr = jnp.pad(
                    ring_vnbr, ((0, 0), (0, 0), (0, 0), (0, rpad))
                )
            w_r = kernel.fn(u_r, ring_vnbr, **kw)[:, :, :n_ring]
            # step 1's output at (+t, -t) neighbors of the boundary — the
            # ghosts step 2's boundary pass would otherwise exchange
            return w_r[:, :, :n_boundary], w_r[:, :, n_boundary:]

        ring_j = jax.jit(ring_fn)

        plan = self
        attrs = self._stencil_trace_attrs(True, 2)

        def overlapped2(u_phys: jax.Array, v_p: jax.Array) -> jax.Array:
            tr = plan.tracer
            if not tr.enabled:
                g_fwd, g_bwd, ring_vnbr = exchange2_j(v_p)  # ONE exchange, 2 apps
                if plan.faults.enabled:
                    f = plan.faults.ask("halo", depth=2)
                    if f is not None:
                        g_fwd, g_bwd, ring_vnbr = corrupt_ghosts(
                            (g_fwd, g_bwd, ring_vnbr), f.action)
                out_1i = interior_j(u_phys, v_p)  # overlaps the exchange
                w = boundary_j(u_phys, v_p, g_fwd, g_bwd, out_1i)
                ring_w = ring_j(u_phys, ring_vnbr)  # recompute, don't re-exchange
                out_2i = interior_j(u_phys, w)
                return boundary_j(u_phys, w, *ring_w, out_2i)
            with tr.span("stencil.step", **attrs):
                with tr.span("stencil.exchange"):
                    g_fwd, g_bwd, ring_vnbr = jax.block_until_ready(
                        exchange2_j(v_p))
                if plan.faults.enabled:
                    f = plan.faults.ask("halo", depth=2)
                    if f is not None:
                        g_fwd, g_bwd, ring_vnbr = corrupt_ghosts(
                            (g_fwd, g_bwd, ring_vnbr), f.action)
                with tr.span("stencil.interior"):
                    out_1i = jax.block_until_ready(interior_j(u_phys, v_p))
                with tr.span("stencil.boundary"):
                    w = jax.block_until_ready(
                        boundary_j(u_phys, v_p, g_fwd, g_bwd, out_1i))
                with tr.span("stencil.ring"):
                    ring_w = jax.block_until_ready(ring_j(u_phys, ring_vnbr))
                with tr.span("stencil.interior"):
                    out_2i = jax.block_until_ready(interior_j(u_phys, w))
                with tr.span("stencil.boundary"):
                    out = jax.block_until_ready(
                        boundary_j(u_phys, w, *ring_w, out_2i))
            return out

        return overlapped2

    def init_stencil_data(self) -> tuple[jax.Array, jax.Array]:
        """The canonical stencil benchmark inputs under the plan's placement:
        ``(u_phys, v_p)`` with U entries (1, 0) and v entries (1/24, 0) —
        every output component of the 8-direction stencil is then exactly
        (1, 0) (see :func:`init_stencil_canonical`)."""
        a_phys, _b, _init_s, _scatter_s = self.init_data()
        _, v = init_stencil_canonical(self.cfg.shape.n_sites)
        v_p = self.codec.pack_vec(v, self.padded_sites)
        return a_phys, jax.device_put(v_p, self.vec_sharding)

    def unpack_vec(self, out_p: jax.Array) -> jax.Array:
        """Planar stencil output -> canonical complex (n_sites, 3)."""
        return self.codec.unpack_vec(out_p, self.cfg.shape.n_sites)

    def pack_gauge(self, u: jax.Array) -> jax.Array:
        """Canonical complex ``(n_sites, 4, 3, 3)`` gauge field -> physical
        packed layout, zero-padded to ``padded_sites``.  Padding sites
        self-neighbor in the stencil tables and carry zero links, so they
        contribute nothing to any stencil or CG output."""
        n = u.shape[0]
        if n < self.padded_sites:
            u = jnp.concatenate(
                [u, jnp.zeros((self.padded_sites - n,) + u.shape[1:], u.dtype)]
            )
        return self.codec.pack(u)

    def pack_rhs(self, b: jax.Array) -> jax.Array:
        """Canonical complex ``(n_sites, 3)`` vector field -> planar
        ``(2, 3, padded_sites)`` under the plan's vector sharding (zero
        padding keeps every CG reduction over the padded array exact)."""
        return jax.device_put(
            self.codec.pack_vec(b, self.padded_sites), self.vec_sharding
        )

    def verify_stencil(self, out_p: jax.Array) -> bool:
        """Fixed-point check for :meth:`init_stencil_data` inputs: every
        output component must be (1, 0) within the storage dtype's tolerance.

        Two-row compressed plans see a DIFFERENT fixed point: the canonical
        uniform lattice is not SU(3), so the reconstructed third row is
        ``conj(r0 x r1) = 0`` rather than the stored all-ones row, and the
        8-direction sum lands on ``4 (U + U^T) v = (5/6, 5/6, 1/3)`` per
        component (computed here from the reconstructed link, not hardcoded).
        """
        c = self.unpack_vec(jax.device_get(out_p))
        if self.codec.is_compressed:
            u = np.ones((layouts.SU3, layouts.SU3))
            u[2] = 0.0  # reconstructed uniform link: row 2 = conj(r0 x r1) = 0
            expected = jnp.asarray(
                layouts.LINKS * (u + u.T) @ np.full(layouts.SU3, 1.0 / 24.0)
            )
        else:
            expected = jnp.asarray(1.0)
        tol = verify_tolerance(
            self.cfg.dtype, self.cfg.accum_dtype, reconstruct=self.codec.is_compressed
        )
        return bool(
            jnp.max(jnp.abs(jnp.real(c) - expected)) < tol
            and jnp.max(jnp.abs(jnp.imag(c))) < tol
        )

    # -- conjugate-gradient solver (fused stencil+axpy iteration) --------------

    def _cg_helpers(self) -> dict[str, Any]:
        """Jitted scalar/elementwise CG pieces, built once per plan.

        Shared VERBATIM by the fused and composed iteration paths, so the
        fused-vs-composed bit-identity contract reduces to the kernel-level
        argument (same f32 expressions on the same operands): alpha, beta,
        the x/r updates and both global reductions are literally the same
        compiled programs on both paths.
        """
        if self._cg_help is not None:
            return self._cg_help
        vec_sh, rep = self.vec_sharding, self.replicated
        f32 = jnp.float32

        def _rr(v: jax.Array) -> jax.Array:
            v = v.astype(f32)
            return jnp.sum(v * v)

        def _dot(a: jax.Array, b: jax.Array) -> jax.Array:
            return jnp.sum(a.astype(f32) * b.astype(f32))

        def _update(x, r, p, ap, alpha):
            a = alpha.astype(f32)
            return (
                (x.astype(f32) + a * p.astype(f32)).astype(x.dtype),
                (r.astype(f32) - a * ap.astype(f32)).astype(r.dtype),
            )

        def _axpy(r, beta, p):  # composed-path search-direction update
            return (r.astype(f32) + beta.astype(f32) * p.astype(f32)).astype(r.dtype)

        def _shift(p, sigma, s):  # composed-path shifted apply epilogue
            return (
                sigma.astype(f32) * p.astype(f32) + s.astype(f32)
            ).astype(p.dtype)

        def _coef(beta, sigma):
            return jnp.stack(
                [jnp.asarray(beta, f32), jnp.asarray(sigma, f32)]
            ).reshape(1, 2)

        self._cg_help = {
            "rr": jax.jit(_rr, out_shardings=rep),
            "dot": jax.jit(_dot, out_shardings=rep),
            "update": jax.jit(_update, out_shardings=(vec_sh, vec_sh)),
            "axpy": jax.jit(_axpy, out_shardings=vec_sh),
            "shift": jax.jit(_shift, out_shardings=vec_sh),
            "scal": jax.jit(lambda num, den: num / den, out_shardings=rep),
            "coef": jax.jit(_coef, out_shardings=rep),
            "init": jax.jit(
                lambda b: (jnp.zeros_like(b), b, b),
                out_shardings=(vec_sh, vec_sh, vec_sh),
            ),
        }
        return self._cg_help

    def _cg_apply(self, fused: bool, overlap: bool) -> Callable[..., Any]:
        """The per-iteration apply ``(u_phys, r_p, p_p, coefs) -> (p', ap)``
        with ``p' = r + beta p`` and ``ap = sigma p' + S(p')``.

        fused=True: ONE pallas_call per pass — the search-direction axpy is
        formed on the gathered (r, p) neighbor tiles in VMEM and the raw
        apply S(p') lands in the same pass (``registry.STENCIL_AXPY`` form);
        the sigma shift then runs in the SAME shared jitted program as the
        composed path, which is what pins f32 iterates bit-identical
        (an in-kernel shift FMA-contracts differently across programs).
        On a multi-host mesh with ``overlap`` the pass splits into the same
        exchange / interior / boundary schedule as ``stencil_step`` — the
        ±t ghosts of BOTH r and p ship first, the slab-local fused pass
        overlaps the transfer (p' is elementwise, so the interior pass's p'
        is already exact everywhere; only ap needs the boundary scatter).

        fused=False: the composed oracle — the shared jitted axpy, then
        ``stencil_step(overlap)``, then the shared shift epilogue.  At f32
        storage its iterates are pinned bit-identical to the fused path.
        """
        key = (bool(fused), bool(overlap))
        if key in self._cg_applies:
            return self._cg_applies[key]
        plan = self
        h = self._cg_helpers()

        if not fused:
            step = self.stencil_step(overlap=overlap)

            def composed(u_phys, r_p, p_p, coefs):
                beta, sigma = coefs[0, 0], coefs[0, 1]
                p_new = h["axpy"](r_p, beta, p_p)
                return p_new, h["shift"](p_new, sigma, step(u_phys, p_new))

            self._cg_applies[key] = composed
            return composed

        kernel, kw = self._stencil_kernel_kwargs(CG_VARIANT)
        glob, local, bidx = self._stencil_geometry()
        codec, tile = self.codec, self.cfg.tile
        vec_sh = self.vec_sharding
        n_boundary = int(bidx.size)
        gather_idx = local if (overlap and n_boundary) else glob

        def whole_fn(u_phys, r_p, p_p, coefs):
            r_nbr = jnp.moveaxis(r_p[:, :, gather_idx], 2, 0)  # (8, 2, 3, S)
            p_nbr = jnp.moveaxis(p_p[:, :, gather_idx], 2, 0)
            return kernel.fn(
                codec.planar_view(u_phys), r_nbr, p_nbr, r_p, p_p, coefs, **kw
            )

        whole_j = jax.jit(whole_fn, out_shardings=(vec_sh, vec_sh))

        if not (overlap and n_boundary):
            # single shard (or overlap off): the periodic/local gather is one
            # fused pass; nothing to exchange
            def fused_whole(u_phys, r_p, p_p, coefs):
                tr = plan.tracer
                if not tr.enabled:
                    p_new, s = whole_j(u_phys, r_p, p_p, coefs)
                    return p_new, h["shift"](p_new, coefs[0, 1], s)
                with tr.span("cg.interior"):
                    p_new, s = jax.block_until_ready(
                        whole_j(u_phys, r_p, p_p, coefs))
                return p_new, h["shift"](p_new, coefs[0, 1], s)

            self._cg_applies[key] = fused_whole
            return fused_whole

        # overlap schedule: same geometry as _stencil_overlap_parts, but the
        # exchange ships BOTH fields' ±t ghosts (p' at the boundary is
        # r_ghost + beta p_ghost — computed in-kernel, never exchanged);
        # the boundary pass scatters the RAW apply S(p') and the sigma shift
        # runs once on the merged array via the shared epilogue
        ghost_fwd_idx, ghost_bwd_idx = glob[3][bidx], glob[7][bidx]
        xyz_idx = glob[(0, 1, 2, 4, 5, 6), :][:, bidx]
        pad = (-n_boundary) % tile

        def exchange_fn(r_p, p_p):
            return (
                r_p[:, :, ghost_fwd_idx], r_p[:, :, ghost_bwd_idx],
                p_p[:, :, ghost_fwd_idx], p_p[:, :, ghost_bwd_idx],
            )

        def boundary_fn(u_phys, r_p, p_p, r_gf, r_gb, p_gf, p_gb, coefs, s_i):
            u_b = codec.planar_view(u_phys)[:, :, bidx]  # (2, 36|24, B)
            r6 = jnp.moveaxis(r_p[:, :, xyz_idx], 2, 0)  # (6, 2, 3, B)
            p6 = jnp.moveaxis(p_p[:, :, xyz_idx], 2, 0)
            r_nbr = jnp.concatenate(
                [r6[:3], r_gf[None], r6[3:], r_gb[None]], axis=0
            )
            p_nbr = jnp.concatenate(
                [p6[:3], p_gf[None], p6[3:], p_gb[None]], axis=0
            )
            r_b, p_b = r_p[:, :, bidx], p_p[:, :, bidx]
            if pad:
                u_b = jnp.pad(u_b, ((0, 0), (0, 0), (0, pad)))
                r_nbr = jnp.pad(r_nbr, ((0, 0), (0, 0), (0, 0), (0, pad)))
                p_nbr = jnp.pad(p_nbr, ((0, 0), (0, 0), (0, 0), (0, pad)))
                r_b = jnp.pad(r_b, ((0, 0), (0, 0), (0, pad)))
                p_b = jnp.pad(p_b, ((0, 0), (0, 0), (0, pad)))
            _p_new_b, s_b = kernel.fn(u_b, r_nbr, p_nbr, r_b, p_b, coefs, **kw)
            return s_i.at[:, :, bidx].set(s_b[:, :, :n_boundary])

        exchange_j = jax.jit(exchange_fn)
        boundary_j = jax.jit(boundary_fn, out_shardings=vec_sh)

        def fused_overlapped(u_phys, r_p, p_p, coefs):
            tr = plan.tracer
            if not tr.enabled:
                ghosts = exchange_j(r_p, p_p)  # ±t transfer in flight
                p_new, s_i = whole_j(u_phys, r_p, p_p, coefs)  # slab-local
                s = boundary_j(u_phys, r_p, p_p, *ghosts, coefs, s_i)
                return p_new, h["shift"](p_new, coefs[0, 1], s)
            with tr.span("cg.exchange"):
                ghosts = jax.block_until_ready(exchange_j(r_p, p_p))
            with tr.span("cg.interior"):
                p_new, s_i = jax.block_until_ready(whole_j(u_phys, r_p, p_p, coefs))
            with tr.span("cg.boundary"):
                s = jax.block_until_ready(
                    boundary_j(u_phys, r_p, p_p, *ghosts, coefs, s_i))
            return p_new, h["shift"](p_new, coefs[0, 1], s)

        self._cg_applies[key] = fused_overlapped
        return fused_overlapped

    def cg_state_init(
        self,
        b_p: jax.Array,
        x0_p: jax.Array | None = None,
        *,
        u_phys: jax.Array | None = None,
        sigma: float = CG_SHIFT,
        fused: bool = True,
        overlap: bool | None = None,
    ) -> dict[str, Any]:
        """Initial CG state for planar right-hand side ``b_p``: x = 0,
        r = b, p-seed = b, beta = 0 — the first :meth:`cg_iterate` then
        forms ``p_1 = r + 0 p = b``, the textbook start.

        With ``x0_p`` (a prior partial iterate, e.g. ``err.result.x_p`` off
        a :class:`CGError`) this is a CG *restart*: ``r_0 = b - A x_0`` is
        computed with the same apply/epilogue programs as the iterations
        (``u_phys`` is required for that one application), the search
        direction reseeds from ``r_0`` — resumed work is not thrown away,
        only the Krylov history is."""
        h = self._cg_helpers()
        if x0_p is None:
            x, r, p = h["init"](b_p)
            return {
                "x": x, "r": r, "p": p, "rs": h["rr"](r),
                "beta": jnp.float32(0.0), "iterations": 0,
            }
        if u_phys is None:
            raise ValueError("resuming cg_state_init from x0_p needs u_phys "
                             "to form r0 = b - A x0")
        if overlap is None:
            overlap = self.is_multi_host
        apply_fn = self._cg_apply(fused, bool(overlap))
        zeros, _r, _p = h["init"](b_p)
        # beta = 0 makes the apply's p' = x0 exactly, so ap = A x0 comes out
        # of the same compiled pass the iterations use
        _x0, ax0 = apply_fn(u_phys, x0_p, zeros, h["coef"](0.0, sigma))
        # shared update with p = 0, alpha = 1: x stays x0, r = b - A x0
        x, r = h["update"](x0_p, b_p, zeros, ax0, jnp.float32(1.0))
        return {
            "x": x, "r": r, "p": r, "rs": h["rr"](r),
            "beta": jnp.float32(0.0), "iterations": 0,
        }

    def cg_iterate(
        self,
        u_phys: jax.Array,
        state: dict[str, Any],
        *,
        sigma: float = CG_SHIFT,
        fused: bool = True,
        overlap: bool | None = None,
    ) -> dict[str, Any]:
        """Advance the CG state by ONE iteration; everything stays device-
        resident.  The caller decides when to sync on ``state["rs"]`` (the
        global residual reduction): ``cg_solve`` fetches it one iteration
        late, so the reduce's host round trip overlaps the next iteration's
        interior pass; the serving layer syncs per scheduling turn.
        """
        if overlap is None:
            overlap = self.is_multi_host
        h = self._cg_helpers()
        apply_fn = self._cg_apply(fused, bool(overlap))
        coefs = h["coef"](state["beta"], sigma)
        p, ap = apply_fn(u_phys, state["r"], state["p"], coefs)
        alpha = h["scal"](state["rs"], h["dot"](p, ap))
        x, r = h["update"](state["x"], state["r"], p, ap, alpha)
        rs_new = h["rr"](r)
        return {
            "x": x, "r": r, "p": p, "rs": rs_new,
            "beta": h["scal"](rs_new, state["rs"]),
            "iterations": state["iterations"] + 1,
        }

    def cg_solve(
        self,
        u_phys: jax.Array,
        b_p: jax.Array,
        *,
        tol: float = 1e-6,
        max_iters: int = 200,
        sigma: float = CG_SHIFT,
        fused: bool = True,
        overlap: bool | None = None,
        x0_p: jax.Array | None = None,
    ) -> CGResult:
        """Conjugate gradients on ``A = sigma I + S`` to ``||r|| <= tol ||b||``.

        The flagship iterative workload: each iteration is one fused
        stencil+axpy pallas pass (``fused=True``; ``fused=False`` composes
        ``stencil_step`` + the shared axpy — the bit-identity oracle) plus
        the shared scalar updates.  Convergence is checked one iteration
        LATE: iteration ``i+1`` is dispatched before iteration ``i``'s
        residual scalar is pulled to the host, so the global reduction
        (``cg.reduce`` span) overlaps the in-flight interior pass — the CG
        analogue of the stencil's exchange/interior overlap.  At most one
        extra iteration is dispatched past convergence.

        Args:
            u_phys: the plan's physical gauge lattice (``init_data`` /
                ``codec.pack`` form, padded to ``padded_sites``).
            b_p: planar right-hand side ``(2, 3, padded_sites)``
                (``codec.pack_vec``), sharded like :attr:`vec_sharding`.
            tol: relative residual target.
            max_iters: hard bound; exhaustion RAISES :class:`CGMaxItersError`
                (never hangs — the loop is host-bounded).
            sigma: SPD shift (see :data:`CG_SHIFT`).
            fused / overlap: iteration body selection, as above.
            x0_p: optional warm start (a prior partial iterate) — restarts
                from ``r0 = b - A x0`` via :meth:`cg_state_init` instead of
                from zero.

        Raises:
            CGMaxItersError: tolerance not reached within ``max_iters``;
                ``err.result`` carries the best iterate for resume.
            CGDivergedError: NaN/Inf residual or residual blow-up past
                :data:`CG_DIVERGENCE_FACTOR` x ``||b||^2`` — numerical
                breakdown, surfaced immediately with the best iterate.
        """
        tr = self.tracer
        h = self._cg_helpers()
        t0 = time.perf_counter()
        b_rs = float(jax.device_get(h["rr"](b_p)))
        if b_rs == 0.0:
            x, _r, _p = h["init"](b_p)
            return CGResult(x_p=x, iterations=0, residuals=[], converged=True,
                            wall_s=time.perf_counter() - t0)
        if not math.isfinite(b_rs):
            raise CGDivergedError(0, float("nan"), tol,
                                  reason="non-finite right-hand side")
        stop2 = (tol * tol) * b_rs
        state = self.cg_state_init(b_p, x0_p, u_phys=u_phys, sigma=sigma,
                                   fused=fused, overlap=overlap)
        residuals: list[float] = []
        prev: tuple[jax.Array, jax.Array] | None = None  # (x_i, rs_i)
        best: tuple[jax.Array, float, int] | None = None  # (x, rs_host, iter)

        def partial(iterations: int) -> CGResult | None:
            # the best-so-far iterate, packaged for x0_p resume
            if best is None:
                return None
            return CGResult(x_p=best[0], iterations=iterations,
                            residuals=list(residuals), converged=False,
                            wall_s=time.perf_counter() - t0)

        def check(rs_host: float, x: jax.Array, it: int) -> None:
            # NaN/Inf or blow-up means breakdown, not slow convergence
            nonlocal best
            if not math.isfinite(rs_host):
                raise CGDivergedError(
                    it, float("nan"), tol, partial(it),
                    reason="non-finite residual")
            if rs_host > CG_DIVERGENCE_FACTOR * b_rs:
                raise CGDivergedError(
                    it, (rs_host / b_rs) ** 0.5, tol, partial(it))
            if best is None or rs_host < best[1]:
                best = (x, rs_host, it)

        for i in range(1, max_iters + 1):
            if tr.enabled:
                # traced: the iter span blocks so it measures the iteration —
                # tracing synchronizes, as with the stencil schedule spans
                with tr.span("cg.iter", it=i, fused=bool(fused)):
                    state = self.cg_iterate(
                        u_phys, state, sigma=sigma, fused=fused, overlap=overlap)
                    jax.block_until_ready(state["rs"])
            else:
                state = self.cg_iterate(
                    u_phys, state, sigma=sigma, fused=fused, overlap=overlap)
            if prev is not None:
                # lagged check: iteration i is already in flight; this fetch
                # is the previous iteration's global reduce landing
                if tr.enabled:
                    with tr.span("cg.reduce", it=i - 1):
                        rs_host = float(jax.device_get(prev[1]))
                else:
                    rs_host = float(jax.device_get(prev[1]))
                residuals.append((rs_host / b_rs) ** 0.5)
                if rs_host <= stop2:
                    return CGResult(
                        x_p=prev[0], iterations=i - 1, residuals=residuals,
                        converged=True, wall_s=time.perf_counter() - t0)
                check(rs_host, prev[0], i - 1)
            prev = (state["x"], state["rs"])
        rs_host = float(jax.device_get(prev[1]))
        residuals.append((rs_host / b_rs) ** 0.5)
        if rs_host <= stop2:
            return CGResult(x_p=prev[0], iterations=max_iters, residuals=residuals,
                            converged=True, wall_s=time.perf_counter() - t0)
        check(rs_host, prev[0], max_iters)
        raise CGMaxItersError(max_iters, (rs_host / b_rs) ** 0.5, tol,
                              partial(max_iters))

    # -- placement policies ----------------------------------------------------

    def init_data(self) -> tuple[jax.Array, jax.Array, float, float]:
        """Build the benchmark lattice under the plan's placement policy.

        Returns:
            ``(a_phys, b_planar, init_seconds, scatter_seconds)`` — the
            physical A lattice (sharded per the policy), the replicated
            planar B ``(2, 36)``, wall seconds of initialization, and the
            redistribution seconds (``host_scatter`` only; 0.0 otherwise).

        On a multi-host mesh the ``sharded`` policy goes through
        :func:`first_touch_init`: each host materializes only its contiguous
        site slab, host-locally — the fleet form of the paper's NUMA-aware
        object creation.  Single-host meshes keep the jit-with-sharded-
        outputs form (same result, bit-identical).
        """
        cfg = self.cfg

        def build() -> jax.Array:
            a, _ = init_canonical(self.padded_sites)
            return self.codec.pack(a)

        b_planar = self.codec.pack_b(init_canonical(1)[1])
        b_planar = jax.device_put(b_planar, self.replicated)

        t0 = time.perf_counter()
        scatter_s = 0.0
        if cfg.placement == "sharded":
            if self.is_multi_host:
                # Fleet form of the paper's fix: each host builds exactly its
                # slab of sites in host memory and places it on its own
                # devices — no global materialization, no redistribution.
                a_phys = first_touch_init(self.codec, self.sharding, self.padded_sites)
            else:
                # Paper's fix: jit the initializer with sharded outputs —
                # every device first-touches exactly its shard.
                a_phys = jax.jit(build, out_shardings=self.sharding)()
            a_phys.block_until_ready()
        elif cfg.placement == "host_scatter":
            # Failure mode: materialize on one device, then redistribute.
            a_single = jax.jit(build)()  # default device only
            a_single.block_until_ready()
            t1 = time.perf_counter()
            a_phys = jax.device_put(a_single, self.sharding)
            a_phys.block_until_ready()
            scatter_s = time.perf_counter() - t1
        else:  # replicated
            a_phys = jax.jit(build, out_shardings=self.replicated)()
            a_phys.block_until_ready()
        init_s = time.perf_counter() - t0
        return a_phys, b_planar, init_s, scatter_s

    # -- views / checks --------------------------------------------------------

    def unpack(self, c_phys: jax.Array) -> jax.Array:
        """Physical C -> canonical complex, sliced to the live lattice sites."""
        return self.codec.unpack(c_phys, self.cfg.shape.n_sites)

    def verify(self, c_phys: jax.Array) -> bool:
        """su3_bench check: with A=(1,0), B=(1/3,0) every C element is (1,0).

        Two-row compressed plans check the STORED rows only: the canonical
        uniform lattice is not SU(3), so ``unpack``'s reconstructed third row
        is ``conj(r0 x r1) = 0`` by construction — a property of the codec,
        not of the multiply (whose stored output is exact; its rows 0/1
        depend only on A's rows 0/1).
        """
        c = self.unpack(jax.device_get(c_phys))
        if self.codec.is_compressed:
            c = c[:, :, : self.codec.stored_rows, :]
        tol = verify_tolerance(
            self.cfg.dtype, self.cfg.accum_dtype, reconstruct=self.codec.is_compressed
        )
        return bool(
            jnp.max(jnp.abs(jnp.real(c) - 1.0)) < tol
            and jnp.max(jnp.abs(jnp.imag(c))) < tol
        )

    def describe(self) -> str:
        """Compact plan identity for benchmark rows / logs.

        Single-host strings are unchanged from the 1-D-mesh era (bench rows
        stay comparable); multi-host plans append the host count.
        """
        c = self.cfg
        acc = f"+acc-{c.accum_dtype}" if c.is_mixed_precision else ""
        comp = "+two-row" if c.is_compressed else ""
        hosts = f"x{self.n_hosts}h" if self.is_multi_host else ""
        return (
            f"{c.layout.value}/{c.variant}/t{c.tile}/{c.placement}"
            f"@{self.n_devices}dev{hosts}/{c.dtype}{acc}{comp}"
        )


def build_plan(
    cfg: EngineConfig, mesh: jax.sharding.Mesh | MeshSpec | None = None
) -> ExecutionPlan:
    """THE construction site: config tuple -> compiled ExecutionPlan.

    Args:
        cfg: the tunable tuple (layout, variant, tile, placement, dtypes, L).
        mesh: ``None`` (1-D site mesh over every local device), a concrete
            ``jax.sharding.Mesh``, or a :class:`~repro.launch.mesh.MeshSpec`
            describing a (host, device) topology.

    Returns:
        A compiled :class:`ExecutionPlan` whose ``step`` / ``fused_step(k)``
        dispatch with the lattice sharded over the mesh's site axes.
    """
    return ExecutionPlan.build(cfg, mesh)


class BatchedLatticeRunner:
    """Serve B independent lattices through one vmapped, sharded plan step.

    The "many users" scenario: each request carries its own (A, B) lattice
    pair; the runner shards the *batch* axis over the mesh (whole lattices per
    device) and runs every request through the same compiled plan in one
    dispatch — no per-request compilation or per-layout wiring.

    Batches that do not divide the device count are zero-padded and sliced.

    On a (host, device) mesh the *batch* axis shards over the same site axes
    (whole lattices per device, host-major) — one host's requests stay on
    that host's devices, which is what the serving layer's locality routing
    relies on.
    """

    def __init__(
        self, cfg: EngineConfig, mesh: jax.sharding.Mesh | MeshSpec | None = None
    ):
        self.plan = build_plan(cfg, mesh)
        self.cfg = cfg
        self.mesh = self.plan.mesh
        self.n_devices = self.plan.n_devices
        self._sharding = self.plan.lattice_batch_sharding()
        self._steps: dict[int, Callable[[jax.Array, jax.Array], jax.Array]] = {}

    def _batched_step(self, k: int) -> Callable[[jax.Array, jax.Array], jax.Array]:
        if k not in self._steps:
            raw = make_raw_step(
                self.plan.codec, self.plan.kernel, tile=self.cfg.tile, k_iters=k
            )
            self._steps[k] = jax.jit(jax.vmap(raw), out_shardings=self._sharding)
        return self._steps[k]

    def pack_batch(self, a: jax.Array) -> jax.Array:
        """Canonical (B, n_sites, 4, 3, 3) complex -> batched physical form."""
        if a.shape[1] > self.plan.padded_sites:
            raise ValueError(
                f"batch carries {a.shape[1]} sites > plan capacity "
                f"{self.plan.padded_sites} (L={self.cfg.L}, tile={self.cfg.tile})"
            )
        pad = self.plan.padded_sites - a.shape[1]
        if pad:
            a = jnp.concatenate(
                [a, jnp.zeros((a.shape[0], pad) + a.shape[2:], a.dtype)], axis=1
            )
        return jax.vmap(self.plan.codec.pack)(a)

    def unpack_batch(self, c_phys: jax.Array, n_sites: int | None = None) -> jax.Array:
        n = n_sites if n_sites is not None else self.cfg.shape.n_sites
        return jax.vmap(lambda x: self.plan.codec.unpack(x, n))(c_phys)

    def run(self, a_batch: jax.Array, b_batch: jax.Array, k: int = 1) -> jax.Array:
        """Batched physical (B, ...) x planar B (B, 2, 36) -> physical C batch."""
        bsz = a_batch.shape[0]
        pad = (-bsz) % self.n_devices
        if pad:
            zeros = lambda x: jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0
            )
            a_batch, b_batch = zeros(a_batch), zeros(b_batch)
        c = self._batched_step(k)(a_batch, b_batch)
        return c[:bsz] if pad else c

    def multiply(self, a: jax.Array, b: jax.Array, k: int = 1) -> jax.Array:
        """Canonical batched entry: a (B, S, 4, 3, 3), b (B, 4, 3, 3) complex."""
        n_sites = a.shape[1]
        a_phys = self.pack_batch(a)
        b_p = jax.vmap(self.plan.codec.pack_b)(b)
        c_phys = self.run(a_phys, b_p, k=k)
        return self.unpack_batch(c_phys, n_sites)

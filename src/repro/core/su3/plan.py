"""ExecutionPlan: the one compiled dispatch path for SU3 work.

The paper's peak numbers come from composing the right *tuple* of
(data layout, kernel formulation, blocking factor, first-touch placement);
getting any element wrong silently costs 2x.  This module makes that tuple a
first-class object instead of re-deriving it ad hoc per call site:

    ┌────────────────────────────────────────────────────────────┐
    │ EngineConfig (L, dtype, layout, variant, tile, placement)  │
    └──────────────────────────┬─────────────────────────────────┘
                               ▼  build_plan() — single construction site
    ┌────────────────────────────────────────────────────────────┐
    │ ExecutionPlan                                              │
    │   codec     LayoutCodec     pack/unpack/planar-view/spec   │
    │   kernel    KernelEntry     unified registry (XLA+Pallas)  │
    │   sharding  NamedSharding   placement-aware out_shardings  │
    │   step      jit(raw_step)   ONE compiled dispatch          │
    │   fused(k)  jit K-chained   one dispatch, K multiplies     │
    └──────────────────────────┬─────────────────────────────────┘
               ┌───────────────┼────────────────────┐
               ▼               ▼                    ▼
        SU3Engine       core.autotune        BatchedLatticeRunner
        (bench loop)    (sweeps + cache)     (B lattices, vmapped)

Everything that used to live in ``SU3Engine._build_step`` / ``_pack`` /
``_unpack`` / ``_unpack_padded`` plus the backend dispatch in
``kernels.ops`` and the candidate enumeration in ``core.autotune`` now flows
through here; benchmarks construct plans (via the thin ``SU3Engine``) rather
than wiring layouts by hand.

Fused multi-iteration stepping
------------------------------
``fused_step(k)`` chains K multiplies (C fed back as A) in ONE dispatch.  On
the Pallas path the chain runs *inside* the kernel grid step on the resident
VMEM tile (``k_iters``), so K iterations cost one HBM read + one HBM write
instead of K of each — the dispatch/HBM-roundtrip overhead that dominates at
small L.  On XLA variants the chain is a ``fori_loop`` under one jit.  This
is a TPU-targeted optimization; in interpret mode on CPU it is merely
no-slower (it still removes K-1 dispatches).

Placement
---------
The three policies reproduce the paper's §4 NUMA/first-touch study:
``sharded`` jits the initializer with sharded out_shardings (every device
first-touches its own shard), ``host_scatter`` materializes on one device and
redistributes (the UPI-storm analog, timed separately), ``replicated`` gives
every device the full lattice.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.su3 import layouts, registry
from repro.core.su3 import variants as _variants  # noqa: F401  (registers XLA kernels)
from repro.core.su3.layouts import Layout, LatticeShape, LayoutCodec
from repro.kernels import ops as _kops  # noqa: F401  (registers the Pallas kernel)

PLACEMENTS = ("sharded", "host_scatter", "replicated")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """The tunable tuple. One instance == one ExecutionPlan identity."""

    L: int = 16
    dtype: str = "float32"  # real STORAGE word dtype: float32 | bfloat16
    layout: Layout = Layout.SOA
    variant: str = "pallas"  # any name in registry.kernel_names()
    tile: int = 512  # Pallas site-tile (VMEM blocking) / AoSoA lane
    placement: str = "sharded"  # sharded | host_scatter | replicated
    iterations: int = 10
    warmups: int = 2
    accum_dtype: str = ""  # "" = accumulate at dtype; "float32" = bf16-storage plans

    @property
    def word_bytes(self) -> int:
        return layouts.WORD_BYTES[self.dtype]

    @property
    def compute_dtype(self) -> str:
        """The dtype the FMA chain runs at (storage dtype unless overridden)."""
        return self.accum_dtype or self.dtype

    @property
    def is_mixed_precision(self) -> bool:
        return bool(self.accum_dtype) and self.accum_dtype != self.dtype

    @property
    def complex_dtype(self) -> Any:
        return jnp.complex64  # planar kernels use cfg.dtype words

    @property
    def shape(self) -> LatticeShape:
        return LatticeShape(self.L)


def make_site_mesh(devices: list[jax.Device] | None = None) -> jax.sharding.Mesh:
    """1-D mesh over all devices; the lattice shards on the 'sites' axis."""
    devices = devices if devices is not None else jax.devices()
    return jax.sharding.Mesh(np.array(devices), ("sites",))


def init_canonical(n_sites: int) -> tuple[jax.Array, jax.Array]:
    """su3_bench's make_lattice/init_link: A entries (1,0), B entries (1/3,0)."""
    a = jnp.full((n_sites, layouts.LINKS, layouts.SU3, layouts.SU3), 1.0 + 0.0j, jnp.complex64)
    b = jnp.full((layouts.LINKS, layouts.SU3, layouts.SU3), (1.0 / 3.0) + 0.0j, jnp.complex64)
    return a, b


def make_raw_step(
    codec: LayoutCodec,
    kernel: registry.KernelEntry,
    *,
    tile: int,
    k_iters: int = 1,
    interpret: bool | None = None,
    alias: bool = False,
) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """Unjitted physical step (a_phys, b_planar) -> c_phys for any kernel form.

    The one place the kernel-form dispatch happens; ExecutionPlan jits this
    and core.autotune lowers it for HLO-level byte accounting.  The codec's
    ``accum_dtype`` (mixed-precision storage plans) flows to planar kernels
    that own their upcast; canonical kernels accumulate in float32 by
    construction (the codec unpacks to complex64).
    """
    if not kernel.supports_layout(codec.layout):
        raise ValueError(
            f"kernel {kernel.name!r} does not support layout {codec.layout.value!r} "
            f"(supported: {[l.value for l in kernel.layouts]})"
        )
    if k_iters > 1 and kernel.form == registry.PLANAR and not kernel.supports_fused:
        raise ValueError(f"kernel {kernel.name!r} does not support fused iteration")
    if codec.is_mixed_precision and not kernel.supports_accum_dtype():
        raise ValueError(
            f"kernel {kernel.name!r} cannot accumulate at {codec.accum_dtype!r} "
            f"over {codec.dtype!r} storage (no accum_dtype support)"
        )

    if kernel.form == registry.PLANAR:
        if not codec.supports_planar_view:
            raise ValueError(
                f"planar kernel {kernel.name!r} needs a planar-view layout, "
                f"got {codec.layout.value!r}"
            )

        def raw_step(a_phys: jax.Array, b_p: jax.Array) -> jax.Array:
            a_p = codec.planar_view(a_phys)
            kw: dict[str, Any] = {"tile": tile, "k_iters": k_iters, "alias": alias}
            if codec.is_mixed_precision:
                kw["accum_dtype"] = codec.accum_dtype
            if interpret is not None:
                kw["interpret"] = interpret
            c_p = kernel.fn(a_p, b_p, **kw)
            return codec.from_planar_view(c_p, a_phys)

    else:  # canonical complex kernel wrapped by the codec

        def raw_step(a_phys: jax.Array, b_p: jax.Array) -> jax.Array:
            b = codec.unpack_b(b_p)
            if k_iters == 1:
                return codec.pack(kernel.fn(codec.unpack(a_phys), b))

            def body(_: jax.Array, phys: jax.Array) -> jax.Array:
                return codec.pack(kernel.fn(codec.unpack(phys), b))

            return jax.lax.fori_loop(0, k_iters, body, a_phys)

    return raw_step


class ExecutionPlan:
    """Compiled execution of one EngineConfig tuple on one mesh.

    Construct via :func:`build_plan` (or ``ExecutionPlan.build``) — the single
    construction site for every layout x variant x placement combination.
    """

    def __init__(self, cfg: EngineConfig, mesh: jax.sharding.Mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.n_devices = int(mesh.devices.size)
        if cfg.placement not in PLACEMENTS:
            raise ValueError(f"unknown placement {cfg.placement!r}; one of {PLACEMENTS}")
        self.codec = layouts.make_codec(
            cfg.layout, tile=cfg.tile, dtype=cfg.dtype, accum_dtype=cfg.accum_dtype
        )
        self.kernel = registry.get_kernel(cfg.variant)
        # Lattice padded so every device shard is a whole number of tiles.
        n = cfg.shape.n_sites
        chunk = self.n_devices * cfg.tile
        self.padded_sites = ((n + chunk - 1) // chunk) * chunk
        self.sharding = NamedSharding(mesh, self.codec.site_spec())
        self.replicated = NamedSharding(mesh, P())
        self.raw_step = make_raw_step(self.codec, self.kernel, tile=cfg.tile)
        self.step = jax.jit(self.raw_step, out_shardings=self.sharding, donate_argnums=())
        self._fused_steps: dict[int, Callable[[jax.Array, jax.Array], jax.Array]] = {}

    @classmethod
    def build(cls, cfg: EngineConfig, mesh: jax.sharding.Mesh | None = None) -> "ExecutionPlan":
        return cls(cfg, mesh if mesh is not None else make_site_mesh())

    # -- fused multi-iteration stepping ---------------------------------------

    def fused_step(self, k: int) -> Callable[[jax.Array, jax.Array], jax.Array]:
        """One dispatch performing K chained multiplies (C fed back as A).

        ``fused_step(k)(a, b)`` equals ``step`` applied k times sequentially.
        On TPU the argument is donated and the Pallas C-tile aliases A's
        buffer, so the chain is a true in-place VMEM-resident update.
        """
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        if k not in self._fused_steps:
            on_tpu = jax.default_backend() == "tpu"
            raw = make_raw_step(
                self.codec, self.kernel, tile=self.cfg.tile, k_iters=k,
                alias=self.kernel.form == registry.PLANAR and on_tpu,
            )
            self._fused_steps[k] = jax.jit(
                raw,
                out_shardings=self.sharding,
                donate_argnums=(0,) if on_tpu else (),
            )
        return self._fused_steps[k]

    # -- placement policies ----------------------------------------------------

    def init_data(self) -> tuple[jax.Array, jax.Array, float, float]:
        """Returns (a_phys, b_planar, init_seconds, scatter_seconds)."""
        cfg = self.cfg

        def build() -> jax.Array:
            a, _ = init_canonical(self.padded_sites)
            return self.codec.pack(a)

        b_planar = self.codec.pack_b(init_canonical(1)[1])
        b_planar = jax.device_put(b_planar, self.replicated)

        t0 = time.perf_counter()
        scatter_s = 0.0
        if cfg.placement == "sharded":
            # Paper's fix: jit the initializer with sharded outputs — every
            # device first-touches exactly its shard.
            a_phys = jax.jit(build, out_shardings=self.sharding)()
            a_phys.block_until_ready()
        elif cfg.placement == "host_scatter":
            # Failure mode: materialize on one device, then redistribute.
            a_single = jax.jit(build)()  # default device only
            a_single.block_until_ready()
            t1 = time.perf_counter()
            a_phys = jax.device_put(a_single, self.sharding)
            a_phys.block_until_ready()
            scatter_s = time.perf_counter() - t1
        else:  # replicated
            a_phys = jax.jit(build, out_shardings=self.replicated)()
            a_phys.block_until_ready()
        init_s = time.perf_counter() - t0
        return a_phys, b_planar, init_s, scatter_s

    # -- views / checks --------------------------------------------------------

    def unpack(self, c_phys: jax.Array) -> jax.Array:
        """Physical C -> canonical complex, sliced to the live lattice sites."""
        return self.codec.unpack(c_phys, self.cfg.shape.n_sites)

    def verify(self, c_phys: jax.Array) -> bool:
        """su3_bench check: with A=(1,0), B=(1/3,0) every C element is (1,0)."""
        c = self.unpack(jax.device_get(c_phys))
        tol = 1e-2 if self.cfg.dtype == "bfloat16" else 1e-5
        return bool(
            jnp.max(jnp.abs(jnp.real(c) - 1.0)) < tol
            and jnp.max(jnp.abs(jnp.imag(c))) < tol
        )

    def describe(self) -> str:
        """Compact plan identity for benchmark rows / logs."""
        c = self.cfg
        acc = f"+acc-{c.accum_dtype}" if c.is_mixed_precision else ""
        return (
            f"{c.layout.value}/{c.variant}/t{c.tile}/{c.placement}"
            f"@{self.n_devices}dev/{c.dtype}{acc}"
        )


def build_plan(cfg: EngineConfig, mesh: jax.sharding.Mesh | None = None) -> ExecutionPlan:
    """THE construction site: config tuple -> compiled ExecutionPlan."""
    return ExecutionPlan.build(cfg, mesh)


class BatchedLatticeRunner:
    """Serve B independent lattices through one vmapped, sharded plan step.

    The "many users" scenario: each request carries its own (A, B) lattice
    pair; the runner shards the *batch* axis over the mesh (whole lattices per
    device) and runs every request through the same compiled plan in one
    dispatch — no per-request compilation or per-layout wiring.

    Batches that do not divide the device count are zero-padded and sliced.
    """

    def __init__(self, cfg: EngineConfig, mesh: jax.sharding.Mesh | None = None):
        self.plan = build_plan(cfg, mesh)
        self.cfg = cfg
        self.mesh = self.plan.mesh
        self.n_devices = self.plan.n_devices
        phys_ndim = 1 + {"aos": 2, "soa": 3, "aosoa": 4}[cfg.layout.value]
        batch_spec = P(*(("sites",) + (None,) * (phys_ndim - 1)))
        self._sharding = NamedSharding(self.mesh, batch_spec)
        self._steps: dict[int, Callable[[jax.Array, jax.Array], jax.Array]] = {}

    def _batched_step(self, k: int) -> Callable[[jax.Array, jax.Array], jax.Array]:
        if k not in self._steps:
            raw = make_raw_step(
                self.plan.codec, self.plan.kernel, tile=self.cfg.tile, k_iters=k
            )
            self._steps[k] = jax.jit(jax.vmap(raw), out_shardings=self._sharding)
        return self._steps[k]

    def pack_batch(self, a: jax.Array) -> jax.Array:
        """Canonical (B, n_sites, 4, 3, 3) complex -> batched physical form."""
        if a.shape[1] > self.plan.padded_sites:
            raise ValueError(
                f"batch carries {a.shape[1]} sites > plan capacity "
                f"{self.plan.padded_sites} (L={self.cfg.L}, tile={self.cfg.tile})"
            )
        pad = self.plan.padded_sites - a.shape[1]
        if pad:
            a = jnp.concatenate(
                [a, jnp.zeros((a.shape[0], pad) + a.shape[2:], a.dtype)], axis=1
            )
        return jax.vmap(self.plan.codec.pack)(a)

    def unpack_batch(self, c_phys: jax.Array, n_sites: int | None = None) -> jax.Array:
        n = n_sites if n_sites is not None else self.cfg.shape.n_sites
        return jax.vmap(lambda x: self.plan.codec.unpack(x, n))(c_phys)

    def run(self, a_batch: jax.Array, b_batch: jax.Array, k: int = 1) -> jax.Array:
        """Batched physical (B, ...) x planar B (B, 2, 36) -> physical C batch."""
        bsz = a_batch.shape[0]
        pad = (-bsz) % self.n_devices
        if pad:
            zeros = lambda x: jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0
            )
            a_batch, b_batch = zeros(a_batch), zeros(b_batch)
        c = self._batched_step(k)(a_batch, b_batch)
        return c[:bsz] if pad else c

    def multiply(self, a: jax.Array, b: jax.Array, k: int = 1) -> jax.Array:
        """Canonical batched entry: a (B, S, 4, 3, 3), b (B, 4, 3, 3) complex."""
        n_sites = a.shape[1]
        a_phys = self.pack_batch(a)
        b_p = jax.vmap(self.plan.codec.pack_b)(b)
        c_phys = self.run(a_phys, b_p, k=k)
        return self.unpack_batch(c_phys, n_sites)

"""The paper's SU3_Bench implementation variants, re-expressed in JAX.

The OpenMP study compares Versions 0–3 (different pragma/collapse strategies),
VersionX (plain ``parallel for``), and an explicitly unrolled GEMM. Pragmas
have no JAX analogue — what *does* transfer is how each variant expresses the
computation to the compiler and what layout it streams:

  version0        loop-nest faithful: per-site fori_loop over links with
                  dynamic indexing — the "trust the compiler" shape. XLA, like
                  icc on the collapsed pragmas, does poorly here.
  version3        fully-collapsed analog: one flat work-item axis
                  (site*link*row), gathered operands — models the paper's
                  worst performer (collapse(4)) whose index arithmetic defeats
                  vectorization; here the gathers defeat fusion.
  versionX        the "simplest parallel" shape: one einsum over canonical
                  complex data. XLA's equivalent of ``#pragma omp parallel for``.
  version_gemm    paper §4 "explicit GEMM + FMA": planar SoA operands, the
                  3x3x3 complex product fully unrolled into real FMA chains
                  over site-lane vectors. This is also what the Pallas kernel
                  implements on TPU (kernels/su3_matmul.py).
  version_blocked paper §5.4 blocked GEMM: version_gemm applied per AoSoA
                  site tile (register/VMEM-pressure blocking).

All variants take/return the *canonical* complex form so they are directly
interchangeable and testable against ``kernels.ref.su3_mult_ref``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.su3 import layouts, registry
from repro.core.su3.layouts import Layout
from repro.kernels import ref as kref

Variant = Callable[[jax.Array, jax.Array], jax.Array]


def register(
    name: str, *, variant_layouts: tuple[Layout, ...] = (Layout.AOS, Layout.SOA, Layout.AOSOA)
) -> Callable[[Variant], Variant]:
    """Register an XLA variant in the unified kernel registry (canonical form)."""
    return registry.register_kernel(
        name, layouts=variant_layouts, backends=("xla",), form=registry.CANONICAL
    )


def get_variant(name: str) -> Variant:
    entry = registry.get_kernel(name)
    if entry.form != registry.CANONICAL:
        raise KeyError(f"{name!r} is not a canonical XLA variant")
    return entry.fn


def variant_names() -> list[str]:
    """Names of the canonical (XLA) variants — excludes the Pallas path."""
    return registry.kernel_names(backend="xla", form=registry.CANONICAL)


@register("version0")
def version0(a: jax.Array, b: jax.Array) -> jax.Array:
    """Loop-nest faithful: scan over links with dynamic slicing per link."""

    def per_link(j: jax.Array) -> jax.Array:
        aj = jax.lax.dynamic_index_in_dim(a, j, axis=1, keepdims=False)
        bj = jax.lax.dynamic_index_in_dim(b, j, axis=0, keepdims=False)
        return jnp.einsum("skl,lm->skm", aj, bj)

    c = jax.lax.map(per_link, jnp.arange(layouts.LINKS))  # (4, s, 3, 3)
    return jnp.moveaxis(c, 0, 1)


@register("version3")
def version3(a: jax.Array, b: jax.Array) -> jax.Array:
    """Fully-collapsed work-item analog (paper's worst performer).

    Flattens (site, link, row, col) into one axis and gathers operand rows —
    mirroring Version 2/3's manual index reconstruction from work-item ids.
    """
    n_sites = a.shape[0]
    s_idx, j_idx, k_idx, m_idx = jnp.unravel_index(
        jnp.arange(n_sites * layouts.LINKS * layouts.SU3 * layouts.SU3),
        (n_sites, layouts.LINKS, layouts.SU3, layouts.SU3),
    )
    a_rows = a[s_idx, j_idx, k_idx, :]  # (work, 3)
    b_cols = b[j_idx, :, m_idx]  # (work, 3)
    c_flat = jnp.sum(a_rows * b_cols, axis=-1)
    return c_flat.reshape(n_sites, layouts.LINKS, layouts.SU3, layouts.SU3)


@register("versionX")
def version_x(a: jax.Array, b: jax.Array) -> jax.Array:
    """The paper's VersionX: simplest parallel formulation — one einsum."""
    return kref.su3_mult_ref(a, b)


def _gemm_planar_unrolled(a_p: jax.Array, b_p: jax.Array) -> jax.Array:
    """Fully unrolled 3x3x3 complex product over planar site-vectors.

    a_p: (2, 4, 3, 3, S) — SoA; b_p: (2, 4, 3, 3). Emits 432 real FMA-shaped
    ops per site over (S,) lane vectors; the k/l/m loops are Python-unrolled
    exactly like the paper's hand-written GEMM.
    """
    ar, ai = a_p[0], a_p[1]
    br, bi = b_p[0], b_p[1]
    out_r = [[[None] * layouts.SU3 for _ in range(layouts.SU3)] for _ in range(layouts.LINKS)]
    out_i = [[[None] * layouts.SU3 for _ in range(layouts.SU3)] for _ in range(layouts.LINKS)]
    for j in range(layouts.LINKS):
        for k in range(layouts.SU3):
            for m in range(layouts.SU3):
                cr = ar[j, k, 0] * br[j, 0, m] - ai[j, k, 0] * bi[j, 0, m]
                ci = ar[j, k, 0] * bi[j, 0, m] + ai[j, k, 0] * br[j, 0, m]
                for l in range(1, layouts.SU3):
                    cr = cr + ar[j, k, l] * br[j, l, m] - ai[j, k, l] * bi[j, l, m]
                    ci = ci + ar[j, k, l] * bi[j, l, m] + ai[j, k, l] * br[j, l, m]
                out_r[j][k][m] = cr
                out_i[j][k][m] = ci
    stack = lambda o: jnp.stack(
        [jnp.stack([jnp.stack(row, 0) for row in link], 0) for link in o], 0
    )
    return jnp.stack([stack(out_r), stack(out_i)], axis=0)


@register("version_gemm")
def version_gemm(a: jax.Array, b: jax.Array) -> jax.Array:
    """Paper §4: explicit unrolled GEMM with FMAs on planar SoA data."""
    a_p = layouts.pack_soa(a)
    b_p = layouts.to_planar(b)
    c_p = _gemm_planar_unrolled(a_p, b_p)
    return layouts.unpack_soa(c_p, a.dtype)


@register("version_blocked")
def version_blocked(a: jax.Array, b: jax.Array, *, lane: int = layouts.LANE) -> jax.Array:
    """Paper §5.4: blocked GEMM — unrolled product per AoSoA site tile."""
    n_sites = a.shape[0]
    t = layouts.pack_aosoa(a, lane=lane)  # (tiles, 2, 4, 3, 3, lane)
    b_p = layouts.to_planar(b)
    c_t = jax.lax.map(lambda tile: _gemm_planar_unrolled(tile, b_p), t)
    return layouts.unpack_aosoa(c_t, n_sites, a.dtype)

"""SU3 benchmark engine: timed multiply loop + validation over an ExecutionPlan.

The paper's Xeon story in framework form.  All layout/kernel/placement wiring
lives in :mod:`repro.core.su3.plan` — ``SU3Engine`` owns only the measurement
protocol, which mirrors the su3_bench driver: W warmup + I timed iterations of
``C = A (x) B`` (paper's -W/-I flags), reporting GF/s (useful flops =
864/site) and GB/s (layout traffic model).

Two stepping modes:

  ``run()``        the classic loop — I separately dispatched single steps,
                   each timed (paper-faithful; what Tables 2/3 report).
  ``run_fused(k)`` one fused dispatch chaining k multiplies inside the kernel
                   (plan.fused_step); per-multiply seconds are reported so the
                   two modes are directly comparable.  This quantifies the
                   dispatch/HBM-roundtrip overhead that dominates at small L.

Validation follows su3_bench: with A entries = (1,0) and B entries = (1/3,0),
every element of C must equal (1,0) — a fixed point of the multiply, so
chained fused steps validate identically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

import jax

from repro.core.su3.layouts import GaugeCompression, TrafficModel
from repro.core.su3.plan import (  # noqa: F401  (re-exported for compatibility)
    EngineConfig,
    ExecutionPlan,
    build_plan,
    init_canonical as _init_canonical,
    make_site_mesh,
)


@dataclasses.dataclass
class BenchResult:
    config: EngineConfig
    n_devices: int
    init_seconds: float
    scatter_seconds: float  # host_scatter redistribution cost (0 otherwise)
    iter_seconds: list[float]  # per-multiply seconds (fused runs pre-divide by k)
    verified: bool
    fused_k: int = 1  # multiplies chained per dispatch (1 = classic loop)
    plan_id: str = ""

    @property
    def best_seconds(self) -> float:
        return min(self.iter_seconds)

    @property
    def mean_seconds(self) -> float:
        return float(np.mean(self.iter_seconds))

    @property
    def traffic(self) -> TrafficModel:
        return TrafficModel(
            self.config.layout,
            self.config.shape.n_sites,
            self.config.word_bytes,
            compression=GaugeCompression(self.config.compression),
        )

    @property
    def gflops(self) -> float:
        """Useful GF/s, the paper's reported figure (864 flops/site)."""
        return self.traffic.flops_per_site * self.config.shape.n_sites / self.best_seconds / 1e9

    @property
    def gbytes(self) -> float:
        """Effective GB/s from the layout traffic model (paper's GBYTES column)."""
        return self.traffic.total_bytes / self.best_seconds / 1e9

    def row(self) -> dict[str, Any]:
        return {
            "L": self.config.L,
            "layout": self.config.layout.value,
            "variant": self.config.variant,
            "placement": self.config.placement,
            "dtype": self.config.dtype,
            "compression": self.config.compression,
            "devices": self.n_devices,
            "GFLOPS": round(self.gflops, 3),
            "GBYTES": round(self.gbytes, 3),
            "bytes_per_site": self.traffic.bytes_per_site_rw,
            "best_s": self.best_seconds,
            "mean_s": self.mean_seconds,
            "init_s": self.init_seconds,
            "scatter_s": self.scatter_seconds,
            "verified": self.verified,
            "fused_k": self.fused_k,
            "plan": self.plan_id,
        }


class SU3Engine:
    """Paper-faithful benchmark runner over a compiled ExecutionPlan.

    ``mesh`` may be a concrete ``jax.sharding.Mesh``, a
    ``repro.launch.mesh.MeshSpec`` (multi-host plans — how the fig7
    multi-controller dryrun drives the engine), or None (1-D site mesh).
    """

    def __init__(self, cfg: EngineConfig, mesh: "jax.sharding.Mesh | Any" = None):
        self.plan = build_plan(cfg, mesh)
        self.cfg = cfg
        self.mesh = self.plan.mesh
        self.n_devices = self.plan.n_devices
        self.padded = self.plan.padded_sites
        self._step = self.plan.step

    def init_data(self) -> tuple[jax.Array, jax.Array, float, float]:
        return self.plan.init_data()

    def verify(self, c_phys: jax.Array) -> bool:
        return self.plan.verify(c_phys)

    def _result(self, init_s, scatter_s, times, verified, fused_k=1) -> BenchResult:
        return BenchResult(
            config=self.cfg,
            n_devices=self.n_devices,
            init_seconds=init_s,
            scatter_seconds=scatter_s,
            iter_seconds=times,
            verified=verified,
            fused_k=fused_k,
            plan_id=self.plan.describe(),
        )

    def run(self) -> BenchResult:
        """W warmups + I timed single-step dispatches (the paper's loop)."""
        cfg = self.cfg
        a_phys, b_p, init_s, scatter_s = self.init_data()
        for _ in range(cfg.warmups):
            c_phys = self._step(a_phys, b_p)
            c_phys.block_until_ready()
        times: list[float] = []
        for _ in range(cfg.iterations):
            t0 = time.perf_counter()
            c_phys = self._step(a_phys, b_p)
            c_phys.block_until_ready()
            times.append(time.perf_counter() - t0)
        verified = self.verify(c_phys)
        return self._result(init_s, scatter_s, times, verified)

    def compare_fused(self, k: int, reps: int = 10) -> dict[str, Any]:
        """Block-time K dispatched single steps vs ONE fused(K) dispatch.

        Both sides chain C back into A (identical semantics and flop count);
        medians over ``reps`` blocks keep the statistic stable at small L.
        This is the honest form of the fused-stepping claim: the fused path
        removes K-1 dispatches and (on TPU) K-1 HBM roundtrips.
        """
        import jax.numpy as jnp

        a_phys, b_p, init_s, scatter_s = self.init_data()
        step, fstep = self._step, self.plan.fused_step(k)
        # The fused step donates its argument on TPU: give the fused chain its
        # own buffer and always rebind (y = fstep(y, ...)), never reuse a
        # donated array. The dispatched step never donates, so a_phys is safe.
        y = jnp.copy(a_phys)
        for _ in range(max(1, self.cfg.warmups)):
            step(a_phys, b_p).block_until_ready()
            y = fstep(y, b_p)
            y.block_until_ready()
        disp, fused = [], []
        for _ in range(reps):
            t0 = time.perf_counter()
            x = a_phys
            for _ in range(k):
                x = step(x, b_p)
            x.block_until_ready()
            disp.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            y = fstep(y, b_p)
            y.block_until_ready()
            fused.append(time.perf_counter() - t0)
        result = self._result(
            init_s, scatter_s, [t / k for t in fused], self.verify(y), fused_k=k
        )
        return {
            "k": k,
            "dispatched_s": float(np.median(disp)),
            "fused_s": float(np.median(fused)),
            "dispatched_min_s": min(disp),
            "fused_min_s": min(fused),
            "fused_speedup": float(np.median(disp) / np.median(fused)),
            "result": result,
        }

    def run_fused(self, k: int | None = None, reps: int = 3) -> BenchResult:
        """One dispatch chaining k multiplies; timed ``reps`` times.

        ``iter_seconds`` holds per-multiply seconds (wall / k) so the result
        is directly comparable to ``run()``.  The loop rebinds A to the
        produced C, which is what donation on TPU requires and is a no-op for
        the benchmark's fixed-point lattice data.
        """
        cfg = self.cfg
        k = cfg.iterations if k is None else k
        fstep = self.plan.fused_step(k)
        a_phys, b_p, init_s, scatter_s = self.init_data()
        x = a_phys
        for _ in range(max(1, cfg.warmups)):
            x = fstep(x, b_p)
            x.block_until_ready()
        times: list[float] = []
        for _ in range(reps):
            t0 = time.perf_counter()
            x = fstep(x, b_p)
            x.block_until_ready()
            times.append((time.perf_counter() - t0) / k)
        verified = self.verify(x)
        return self._result(init_s, scatter_s, times, verified, fused_k=k)

"""SU3 lattice engine: placement-aware init, timed multiply loop, validation.

The paper's Xeon story in framework form. The three placement policies map
the paper's §4 findings onto JAX/TPU:

  ``sharded``       paper's fix (empty constructor + parallel init): data is
                    materialized *directly sharded* by jit-ing the initializer
                    with sharded out_shardings — each device first-touches its
                    own shard, no redistribution traffic ever happens.
  ``host_scatter``  the failure mode (default constructor touches everything
                    on socket 0): arrays are materialized on host / device 0
                    and then redistributed with device_put; the scatter is the
                    UPI-storm analog and is timed separately.
  ``replicated``    every device holds the full lattice (what naive
                    ``device_put`` without sharding gives you at pod scale) —
                    memory blowup measured, B's policy by design.

The iteration loop mirrors the benchmark driver: W warmup + I timed
iterations of ``C = A (x) B`` with the same A and B (paper's -W/-I flags),
reporting GF/s (useful flops = 864/site) and GB/s (layout traffic model).

Validation follows su3_bench: with A entries = (1,0) and B entries = (1/3,0),
every element of C must equal (1,0); we check sum and pointwise.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.su3 import layouts, variants
from repro.core.su3.layouts import Layout, LatticeShape, TrafficModel
from repro.kernels import ops as kops
from repro.kernels import su3_matmul


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    L: int = 16
    dtype: str = "float32"  # real word dtype: float32 | bfloat16
    layout: Layout = Layout.SOA
    variant: str = "pallas"  # 'pallas' or a name in variants.variant_names()
    tile: int = 512  # Pallas site-tile (VMEM blocking)
    placement: str = "sharded"  # sharded | host_scatter | replicated
    iterations: int = 10
    warmups: int = 2

    @property
    def word_bytes(self) -> int:
        return {"float32": 4, "bfloat16": 2, "float64": 8}[self.dtype]

    @property
    def complex_dtype(self) -> Any:
        return jnp.complex64  # planar kernels use cfg.dtype words

    @property
    def shape(self) -> LatticeShape:
        return LatticeShape(self.L)


@dataclasses.dataclass
class BenchResult:
    config: EngineConfig
    n_devices: int
    init_seconds: float
    scatter_seconds: float  # host_scatter redistribution cost (0 otherwise)
    iter_seconds: list[float]
    verified: bool

    @property
    def best_seconds(self) -> float:
        return min(self.iter_seconds)

    @property
    def mean_seconds(self) -> float:
        return float(np.mean(self.iter_seconds))

    @property
    def traffic(self) -> TrafficModel:
        return TrafficModel(
            self.config.layout, self.config.shape.n_sites, self.config.word_bytes
        )

    @property
    def gflops(self) -> float:
        """Useful GF/s, the paper's reported figure (864 flops/site)."""
        return self.traffic.flops_per_site * self.config.shape.n_sites / self.best_seconds / 1e9

    @property
    def gbytes(self) -> float:
        """Effective GB/s from the layout traffic model (paper's GBYTES column)."""
        return self.traffic.total_bytes / self.best_seconds / 1e9

    def row(self) -> dict[str, Any]:
        return {
            "L": self.config.L,
            "layout": self.config.layout.value,
            "variant": self.config.variant,
            "placement": self.config.placement,
            "dtype": self.config.dtype,
            "devices": self.n_devices,
            "GFLOPS": round(self.gflops, 3),
            "GBYTES": round(self.gbytes, 3),
            "best_s": self.best_seconds,
            "mean_s": self.mean_seconds,
            "init_s": self.init_seconds,
            "scatter_s": self.scatter_seconds,
            "verified": self.verified,
        }


def make_site_mesh(devices: list[jax.Device] | None = None) -> jax.sharding.Mesh:
    """1-D mesh over all devices; the lattice shards on the 'sites' axis."""
    devices = devices if devices is not None else jax.devices()
    return jax.sharding.Mesh(np.array(devices), ("sites",))


def _init_canonical(n_sites: int) -> tuple[jax.Array, jax.Array]:
    """su3_bench's make_lattice/init_link: A entries (1,0), B entries (1/3,0)."""
    a = jnp.full((n_sites, layouts.LINKS, layouts.SU3, layouts.SU3), 1.0 + 0.0j, jnp.complex64)
    b = jnp.full((layouts.LINKS, layouts.SU3, layouts.SU3), (1.0 / 3.0) + 0.0j, jnp.complex64)
    return a, b


class SU3Engine:
    """Paper-faithful benchmark engine with TPU-native layout/placement knobs."""

    def __init__(self, cfg: EngineConfig, mesh: jax.sharding.Mesh | None = None):
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else make_site_mesh()
        self.n_devices = self.mesh.devices.size
        n = cfg.shape.n_sites
        # Lattice padded so every device shard is a whole number of tiles.
        self.padded = ((n + self.n_devices * cfg.tile - 1) // (self.n_devices * cfg.tile)) * (
            self.n_devices * cfg.tile
        )
        self._step = self._build_step()

    # -- physical state ------------------------------------------------------

    def _site_spec(self) -> P:
        if self.cfg.layout == Layout.AOS:
            return P("sites", None)  # (sites, 80)
        if self.cfg.layout == Layout.SOA:
            return P(None, None, "sites")  # (2, 36, S)
        return P("sites", None, None, None)  # (tiles, 2, 36, lane)

    def _pack(self, a: jax.Array) -> jax.Array:
        """Canonical complex (padded_sites, 4, 3, 3) -> physical layout."""
        wdt = jnp.dtype(self.cfg.dtype)
        if self.cfg.layout == Layout.AOS:
            return layouts.pack_aos(a).astype(wdt)
        if self.cfg.layout == Layout.SOA:
            return layouts.pack_soa(a).reshape(2, su3_matmul.ROWS, -1).astype(wdt)
        t = layouts.pack_aosoa(a, lane=self.cfg.tile)
        return t.reshape(t.shape[0], 2, su3_matmul.ROWS, self.cfg.tile).astype(wdt)

    def _unpack(self, phys: jax.Array) -> jax.Array:
        n = self.cfg.shape.n_sites
        if self.cfg.layout == Layout.AOS:
            return layouts.unpack_aos(phys.astype(jnp.float32))[:n]
        if self.cfg.layout == Layout.SOA:
            p = phys.astype(jnp.float32).reshape(2, layouts.LINKS, layouts.SU3, layouts.SU3, -1)
            return layouts.unpack_soa(p)[:n]
        t = phys.astype(jnp.float32).reshape(
            phys.shape[0], 2, layouts.LINKS, layouts.SU3, layouts.SU3, self.cfg.tile
        )
        return layouts.unpack_aosoa(t, n)

    # -- placement policies ----------------------------------------------------

    def init_data(self) -> tuple[jax.Array, jax.Array, float, float]:
        """Returns (a_phys, b_planar, init_seconds, scatter_seconds)."""
        cfg = self.cfg
        sharding = NamedSharding(self.mesh, self._site_spec())
        replicated = NamedSharding(self.mesh, P())

        def build() -> jax.Array:
            a, _ = _init_canonical(self.padded)
            return self._pack(a)

        b_planar = layouts.to_planar(_init_canonical(1)[1]).reshape(2, su3_matmul.ROWS)
        b_planar = jax.device_put(b_planar.astype(jnp.dtype(cfg.dtype)), replicated)

        t0 = time.perf_counter()
        scatter_s = 0.0
        if cfg.placement == "sharded":
            # Paper's fix: jit the initializer with sharded outputs — every
            # device first-touches exactly its shard.
            a_phys = jax.jit(build, out_shardings=sharding)()
            a_phys.block_until_ready()
        elif cfg.placement == "host_scatter":
            # Failure mode: materialize on one device, then redistribute.
            a_single = jax.jit(build)()  # default device only
            a_single.block_until_ready()
            t1 = time.perf_counter()
            a_phys = jax.device_put(a_single, sharding)
            a_phys.block_until_ready()
            scatter_s = time.perf_counter() - t1
        elif cfg.placement == "replicated":
            a_phys = jax.jit(build, out_shardings=replicated)()
            a_phys.block_until_ready()
        else:
            raise ValueError(f"unknown placement {cfg.placement!r}")
        init_s = time.perf_counter() - t0
        return a_phys, b_planar, init_s, scatter_s

    # -- the kernel step -------------------------------------------------------

    def _build_step(self) -> Callable[[jax.Array, jax.Array], jax.Array]:
        cfg = self.cfg
        sharding = NamedSharding(self.mesh, self._site_spec())

        if cfg.variant == "pallas":
            if cfg.layout == Layout.SOA:

                def step(a_p: jax.Array, b_p: jax.Array) -> jax.Array:
                    return kops.su3_mult_planar(a_p, b_p, tile=cfg.tile)

            elif cfg.layout == Layout.AOSOA:

                def step(a_t: jax.Array, b_p: jax.Array) -> jax.Array:
                    a_p = jnp.moveaxis(a_t, 0, -1).reshape(2, su3_matmul.ROWS, -1)
                    c_p = kops.su3_mult_planar(a_p, b_p, tile=cfg.tile)
                    c_t = c_p.reshape(2, su3_matmul.ROWS, a_t.shape[0], cfg.tile)
                    return jnp.moveaxis(c_t, 2, 0)

            else:
                raise ValueError("pallas variant requires SOA or AOSOA layout")
        else:
            fn = variants.get_variant(cfg.variant)

            def step(a_phys: jax.Array, b_p: jax.Array) -> jax.Array:
                a = self._unpack_padded(a_phys)
                b = layouts.from_planar(
                    b_p.astype(jnp.float32).reshape(2, layouts.LINKS, layouts.SU3, layouts.SU3)
                )
                c = fn(a, b)
                return self._pack(c)

        return jax.jit(step, out_shardings=sharding, donate_argnums=())

    def _unpack_padded(self, phys: jax.Array) -> jax.Array:
        if self.cfg.layout == Layout.AOS:
            return layouts.unpack_aos(phys.astype(jnp.float32))
        if self.cfg.layout == Layout.SOA:
            p = phys.astype(jnp.float32).reshape(2, layouts.LINKS, layouts.SU3, layouts.SU3, -1)
            return layouts.unpack_soa(p)
        t = phys.astype(jnp.float32).reshape(
            phys.shape[0], 2, layouts.LINKS, layouts.SU3, layouts.SU3, self.cfg.tile
        )
        return layouts.unpack_aosoa(t, phys.shape[0] * self.cfg.tile)

    # -- the benchmark loop ------------------------------------------------------

    def run(self) -> BenchResult:
        cfg = self.cfg
        a_phys, b_p, init_s, scatter_s = self.init_data()
        for _ in range(cfg.warmups):
            c_phys = self._step(a_phys, b_p)
            c_phys.block_until_ready()
        times: list[float] = []
        for _ in range(cfg.iterations):
            t0 = time.perf_counter()
            c_phys = self._step(a_phys, b_p)
            c_phys.block_until_ready()
            times.append(time.perf_counter() - t0)
        verified = self.verify(c_phys)
        return BenchResult(
            config=cfg,
            n_devices=self.n_devices,
            init_seconds=init_s,
            scatter_seconds=scatter_s,
            iter_seconds=times,
            verified=verified,
        )

    def verify(self, c_phys: jax.Array) -> bool:
        """su3_bench check: with A=(1,0), B=(1/3,0) every C element is (1,0)."""
        c = self._unpack(jax.device_get(c_phys))
        tol = 1e-2 if self.cfg.dtype == "bfloat16" else 1e-5
        return bool(
            jnp.max(jnp.abs(jnp.real(c) - 1.0)) < tol
            and jnp.max(jnp.abs(jnp.imag(c))) < tol
        )

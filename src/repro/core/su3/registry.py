"""Unified SU3 kernel registry.

Before the ExecutionPlan refactor the repo had *two* kernel namespaces: the
XLA variant table (``variants._REGISTRY``) and the hardcoded ``"pallas"``
string special-cased by the engine's step builder.  Both now live here, with
enough metadata for a plan to validate and wire a kernel without per-kernel
``if/elif`` chains:

  ``form``
      ``"canonical"`` — fn(a, b) on canonical complex arrays
      (a: (S, 4, 3, 3), b: (4, 3, 3)); the plan wraps it with the layout
      codec's unpack/pack.
      ``"planar"`` — fn(a_p, b_p, *, tile, k_iters, interpret) on the
      flattened planar view (a_p: (2, 36, S), b_p: (2, 36)); the plan feeds
      it the codec's planar view directly (zero-copy for SoA).
      ``"batched"`` — fn(a_p, b_p, slot_k, *, tile, max_k, interpret) on a
      slot-batched planar view (a_p: (slots, 2, 36, S), b_p: (slots, 2, 36),
      slot_k: (slots,) int32); ONE dispatch advances every slot by its own
      chain depth.  Consumed only by ``ExecutionPlan.fused_batched_step`` —
      a batched kernel cannot serve as a plan's single-lattice ``step``.
      ``"stencil"`` — fn(u_p, v_nbr, *, tile, interpret, accum_dtype?) on the
      planar link view plus direction-major shifted neighbor vectors
      (u_p: (2, 36, S), v_nbr: (8, 2, 3, S) -> out (2, 3, S)); the
      nearest-neighbor Dslash-style operator.  Consumed only by
      ``ExecutionPlan.stencil_step`` / ``stencil_reference_step`` — a
      stencil kernel cannot serve as a plan's multiply ``step``.
      ``"stencil_axpy"`` — fn(u_p, r_nbr, p_nbr, r_p, p_p, coefs, *, tile,
      interpret, accum_dtype?) -> (p_new, s): one fused conjugate-gradient
      iteration body — the search-direction axpy ``p' = r + beta p`` formed
      on the resident neighbor tiles plus the raw stencil apply ``S(p')``
      in the same pallas_call.  The sigma shift ``ap = sigma p' + S(p')``
      runs in the plan's shared epilogue program (bit-identity contract).
      Consumed only by ``ExecutionPlan.cg_solve`` / ``cg_iterate``.
  ``layouts``
      which physical layouts the kernel can be planned with.
  ``backends``
      ``"xla"`` | ``"pallas"`` — what lowers the kernel body.
  ``supports_fused``
      whether fn accepts ``k_iters`` and chains K multiplies in one dispatch.
  ``supports_accum``
      whether fn accepts ``accum_dtype`` and can accumulate at a wider
      precision than the storage words it streams (bf16-storage/f32-accumulate
      plans).  Canonical-form kernels get this for free — the layout codec
      unpacks to float32 complex before they run — so the flag only gates the
      planar path, where the kernel itself owns the upcast.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

from repro.core.su3.layouts import Layout

CANONICAL = "canonical"
PLANAR = "planar"
BATCHED = "batched"
STENCIL = "stencil"
STENCIL_AXPY = "stencil_axpy"


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """One registered SU3 kernel plus the metadata a plan validates against.

    Attributes:
        name: registry key (``EngineConfig.variant``).
        fn: the kernel callable.  Canonical form:
            ``fn(a, b) -> c`` with ``a/c: (S, 4, 3, 3)`` complex64 and
            ``b: (4, 3, 3)`` complex64.  Planar form:
            ``fn(a_p, b_p, *, tile, k_iters, alias, interpret?,
            accum_dtype?) -> c_p`` with ``a_p/c_p: (2, 36, S)`` and
            ``b_p: (2, 36)`` real words in the storage dtype.
        layouts: physical layouts the kernel can be planned with.
        backends: ``"xla"`` / ``"pallas"`` — what lowers the body.
        form: ``"canonical"`` or ``"planar"`` (module constants).
        supports_fused: fn accepts ``k_iters`` and chains K multiplies in
            one dispatch.
        supports_accum: fn accepts ``accum_dtype`` (planar mixed-precision).
        supports_compressed: fn accepts ``compressed`` and can stream two-row
            (24-planar-row) gauge blocks, reconstructing row 2 in-register.
    """

    name: str
    fn: Callable
    layouts: tuple[Layout, ...]
    backends: tuple[str, ...]
    form: str = CANONICAL
    supports_fused: bool = False
    supports_accum: bool = False
    supports_compressed: bool = False

    def supports_layout(self, layout: Layout) -> bool:
        """Whether this kernel can be planned with ``layout`` (accepts the
        enum or its string value)."""
        return Layout(layout) in self.layouts

    def supports_accum_dtype(self) -> bool:
        """Mixed-precision capable: planar kernels must opt in; canonical
        kernels always accumulate in float32 (the codec unpacks to c64)."""
        return self.supports_accum or self.form == CANONICAL

    def supports_compression(self) -> bool:
        """Two-row gauge capable: planar-view kernels must opt in; canonical
        kernels get it for free — the codec's unpack reconstructs row 2
        before they ever see the data (they just don't save the bytes)."""
        return self.supports_compressed or self.form == CANONICAL


_KERNELS: dict[str, KernelEntry] = {}


def register_kernel(
    name: str,
    *,
    layouts: Iterable[Layout] = (Layout.AOS, Layout.SOA, Layout.AOSOA),
    backends: Iterable[str] = ("xla",),
    form: str = CANONICAL,
    supports_fused: bool = False,
    supports_accum: bool = False,
    supports_compressed: bool = False,
) -> Callable[[Callable], Callable]:
    """Decorator registering ``fn`` as kernel ``name``; returns fn unchanged.

    Args:
        name: registry key; later registrations under the same name replace
            earlier ones (tests use this for stand-ins).
        layouts: physical layouts the kernel accepts (default: all three).
        backends: lowering backends (``"xla"`` and/or ``"pallas"``).
        form: ``CANONICAL`` (codec-wrapped complex) or ``PLANAR`` (direct
            planar view) — see :class:`KernelEntry` for the fn signatures.
        supports_fused: fn accepts ``k_iters`` (in-kernel chained multiply).
        supports_accum: fn accepts ``accum_dtype`` (planar kernels that own
            their upcast; canonical kernels get mixed precision for free).
        supports_compressed: fn accepts ``compressed`` (two-row gauge blocks
            with in-register row-2 reconstruction).

    Raises:
        ValueError: on an unknown ``form``.
    """
    if form not in (CANONICAL, PLANAR, BATCHED, STENCIL, STENCIL_AXPY):
        raise ValueError(f"unknown kernel form {form!r}")

    def deco(fn: Callable) -> Callable:
        _KERNELS[name] = KernelEntry(
            name=name,
            fn=fn,
            layouts=tuple(Layout(l) for l in layouts),
            backends=tuple(backends),
            form=form,
            supports_fused=supports_fused,
            supports_accum=supports_accum,
            supports_compressed=supports_compressed,
        )
        return fn

    return deco


def get_kernel(name: str) -> KernelEntry:
    """The registered entry for ``name``.

    Raises:
        KeyError: naming the known kernels, when ``name`` is unregistered.
    """
    try:
        return _KERNELS[name]
    except KeyError:
        raise KeyError(
            f"unknown SU3 kernel {name!r}; registered: {sorted(_KERNELS)}"
        ) from None


def kernel_names(
    *, backend: str | None = None, layout: Layout | None = None, form: str | None = None
) -> list[str]:
    """Sorted registered kernel names, optionally filtered.

    Args:
        backend: keep kernels lowered by this backend (``"xla"``/``"pallas"``).
        layout: keep kernels plannable with this physical layout.
        form: keep kernels of this form (``CANONICAL``/``PLANAR``).
    """
    out = []
    for name, entry in _KERNELS.items():
        if backend is not None and backend not in entry.backends:
            continue
        if layout is not None and not entry.supports_layout(layout):
            continue
        if form is not None and entry.form != form:
            continue
        out.append(name)
    return sorted(out)

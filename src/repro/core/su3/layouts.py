"""Lattice data layouts for the SU3 kernel.

The paper's central Xeon lesson is that the *physical layout* of the ``site``
struct determines achievable bandwidth:

  * the original MILC-derived AoS ``site`` struct is 320 B (fp32) per site, of
    which only 288 B (4 links x 72 B) are the gauge field — the x/y/z/t/index/
    parity/pad words are dead weight that (a) inflates streamed traffic by
    320/288 = 1.11x and (b) leaves gaps that defeat streaming stores;
  * ``B`` is accessed column-major (non-unit stride) and is better transposed
    into a thread-local copy.

On TPU the analogous axes are VPU lanes (128-wide) and VMEM tiles:

  * ``AOS``       — faithful paper layout: (n_sites, 80) fp32 words per site
                    (72 gauge + 8 metadata/pad). Charged in the traffic model.
  * ``SOA``       — planar structure-of-arrays: (2, 4, 3, 3, n_sites); complex
                    split re/im (TPU has no complex MXU/VPU path), site index
                    innermost → unit-stride lane vectors, no padding traffic.
  * ``AOSOA``     — site-tiled SoA: (n_tiles, 2, 4, 3, 3, lane) with lane=128;
                    one tile is one VPU-lane-aligned working set. This is the
                    paper's "blocked GEMM fits the register file" re-derived
                    for the HBM→VMEM→VREG hierarchy.

Canonical (logical) form everywhere else in the library is complex:
  A : (n_sites, 4, 3, 3) complex   B : (4, 3, 3) complex.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any

import jax
import jax.numpy as jnp

LINKS = 4  # links per site (the j loop)
SU3 = 3  # SU(3) matrix dimension
GAUGE_WORDS = LINKS * SU3 * SU3 * 2  # 72 real words of gauge field per site
SITE_PAD_WORDS = 8  # x, y, z, t, index, parity(+align), pad[2]  (PRECISION==1)
SITE_WORDS_AOS = GAUGE_WORDS + SITE_PAD_WORDS  # 80 words = 320 B fp32, paper-faithful
LANE = 128  # TPU VPU lane width


class Layout(str, enum.Enum):
    AOS = "aos"
    SOA = "soa"
    AOSOA = "aosoa"


@dataclasses.dataclass(frozen=True)
class LatticeShape:
    """Lattice of dimension L^4, matching the paper's ``total_sites = L**4``."""

    L: int

    @property
    def n_sites(self) -> int:
        return self.L**4

    def padded_sites(self, lane: int = LANE) -> int:
        return ((self.n_sites + lane - 1) // lane) * lane


# ---------------------------------------------------------------------------
# Canonical <-> physical layout converters.
# ---------------------------------------------------------------------------


def _real_dtype(complex_dtype: Any) -> Any:
    return jnp.float64 if complex_dtype == jnp.complex128 else jnp.float32


def to_planar(a: jax.Array) -> jax.Array:
    """complex (..., ) -> stacked planar (2, ...) real array (re, im)."""
    return jnp.stack([jnp.real(a), jnp.imag(a)], axis=0)


def from_planar(p: jax.Array) -> jax.Array:
    return jax.lax.complex(p[0], p[1])


def pack_aos(a: jax.Array, site_meta: jax.Array | None = None) -> jax.Array:
    """Canonical A (n_sites, 4, 3, 3) complex -> paper-faithful AoS (n_sites, 80).

    Words [0:72] are interleaved (re, im) gauge entries in link-major order —
    exactly MILC's ``site.link[4]``; words [72:80] are the metadata/pad block.
    """
    n_sites = a.shape[0]
    dt = _real_dtype(a.dtype)
    gauge = jnp.stack([jnp.real(a), jnp.imag(a)], axis=-1)  # (s, 4, 3, 3, 2)
    gauge = gauge.reshape(n_sites, GAUGE_WORDS).astype(dt)
    if site_meta is None:
        # x, y, z, t, index, parity, pad, pad — populated like the benchmark's
        # make_lattice(): index = linear site id; coords from L is unknown here
        # so carry the linear index in all coordinate words (metadata is dead
        # weight for the kernel either way; that is the point of this layout).
        idx = jnp.arange(n_sites, dtype=dt)[:, None]
        site_meta = jnp.concatenate(
            [idx, idx, idx, idx, idx, idx % 2, jnp.zeros((n_sites, 2), dt)], axis=1
        )
    return jnp.concatenate([gauge, site_meta.astype(dt)], axis=1)


def unpack_aos(aos: jax.Array, complex_dtype: Any = jnp.complex64) -> jax.Array:
    n_sites = aos.shape[0]
    gauge = aos[:, :GAUGE_WORDS].reshape(n_sites, LINKS, SU3, SU3, 2)
    return jax.lax.complex(gauge[..., 0], gauge[..., 1]).astype(complex_dtype)


def pack_soa(a: jax.Array) -> jax.Array:
    """Canonical (n_sites, 4, 3, 3) complex -> SoA planar (2, 4, 3, 3, n_sites)."""
    return to_planar(jnp.moveaxis(a, 0, -1))


def unpack_soa(soa: jax.Array, complex_dtype: Any = jnp.complex64) -> jax.Array:
    return jnp.moveaxis(from_planar(soa), -1, 0).astype(complex_dtype)


def pack_aosoa(a: jax.Array, lane: int = LANE) -> jax.Array:
    """Canonical -> (n_tiles, 2, 4, 3, 3, lane). Pads site count up to lane."""
    n_sites = a.shape[0]
    pad = (-n_sites) % lane
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0)
    n_tiles = a.shape[0] // lane
    # (tiles, lane, 4, 3, 3) -> (tiles, 4, 3, 3, lane) -> planar
    t = jnp.moveaxis(a.reshape(n_tiles, lane, LINKS, SU3, SU3), 1, -1)
    return jnp.stack([jnp.real(t), jnp.imag(t)], axis=1)


def unpack_aosoa(
    t: jax.Array, n_sites: int, complex_dtype: Any = jnp.complex64
) -> jax.Array:
    c = jax.lax.complex(t[:, 0], t[:, 1])  # (tiles, 4, 3, 3, lane)
    c = jnp.moveaxis(c, -1, 1).reshape(-1, LINKS, SU3, SU3)
    return c[:n_sites].astype(complex_dtype)


# ---------------------------------------------------------------------------
# Traffic model — charges each layout the bytes it actually streams.
# This is the quantitative form of the paper's 288/320 streaming-store point.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrafficModel:
    """Bytes moved per kernel invocation for a given layout/dtype.

    read(A) + write(C); B is cache/VMEM-resident after first read (paper §3.1:
    "B could stay in the cache and can be reused") and charged once, which is
    negligible, so it is excluded exactly as in the paper's AI computation.
    """

    layout: Layout
    n_sites: int
    word_bytes: int  # 4 for fp32, 2 for bf16, 8 for fp64

    @property
    def words_per_site(self) -> int:
        if self.layout == Layout.AOS:
            return SITE_WORDS_AOS  # 80: pads are streamed too
        return GAUGE_WORDS  # 72: SoA/AoSoA carry no metadata

    @property
    def bytes_per_site_rw(self) -> int:
        return 2 * self.words_per_site * self.word_bytes  # read A + write C

    @property
    def total_bytes(self) -> int:
        return self.n_sites * self.bytes_per_site_rw

    @property
    def flops_per_site(self) -> int:
        # 4 links x (3x3x3 complex MACs) x (4 mul + 4 add) = 864 (paper §3.1)
        return LINKS * SU3 * SU3 * SU3 * 8

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_site / self.bytes_per_site_rw


def paper_arithmetic_intensity(word_bytes: int = 4) -> float:
    """AI = 864 / (320 * 2) = 1.35 fp32 / 0.675 fp64 — paper §3.1 exactly."""
    return TrafficModel(Layout.AOS, 1, word_bytes).arithmetic_intensity

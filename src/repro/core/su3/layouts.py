"""Lattice data layouts for the SU3 kernel.

The paper's central Xeon lesson is that the *physical layout* of the ``site``
struct determines achievable bandwidth:

  * the original MILC-derived AoS ``site`` struct is 320 B (fp32) per site, of
    which only 288 B (4 links x 72 B) are the gauge field — the x/y/z/t/index/
    parity/pad words are dead weight that (a) inflates streamed traffic by
    320/288 = 1.11x and (b) leaves gaps that defeat streaming stores;
  * ``B`` is accessed column-major (non-unit stride) and is better transposed
    into a thread-local copy.

On TPU the analogous axes are VPU lanes (128-wide) and VMEM tiles:

  * ``AOS``       — faithful paper layout: (n_sites, 80) fp32 words per site
                    (72 gauge + 8 metadata/pad). Charged in the traffic model.
  * ``SOA``       — planar structure-of-arrays: (2, 4, 3, 3, n_sites); complex
                    split re/im (TPU has no complex MXU/VPU path), site index
                    innermost → unit-stride lane vectors, no padding traffic.
  * ``AOSOA``     — site-tiled SoA: (n_tiles, 2, 4, 3, 3, lane) with lane=128;
                    one tile is one VPU-lane-aligned working set. This is the
                    paper's "blocked GEMM fits the register file" re-derived
                    for the HBM→VMEM→VREG hierarchy.

Canonical (logical) form everywhere else in the library is complex:
  A : (n_sites, 4, 3, 3) complex   B : (4, 3, 3) complex.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any

import jax
import jax.numpy as jnp

LINKS = 4  # links per site (the j loop)
SU3 = 3  # SU(3) matrix dimension
GAUGE_WORDS = LINKS * SU3 * SU3 * 2  # 72 real words of gauge field per site
SITE_PAD_WORDS = 8  # x, y, z, t, index, parity(+align), pad[2]  (PRECISION==1)
SITE_WORDS_AOS = GAUGE_WORDS + SITE_PAD_WORDS  # 80 words = 320 B fp32, paper-faithful
LANE = 128  # TPU VPU lane width


class Layout(str, enum.Enum):
    AOS = "aos"
    SOA = "soa"
    AOSOA = "aosoa"


class GaugeCompression(str, enum.Enum):
    """How many rows of each SU(3) link the physical form stores.

    ``TWO_ROW`` is the staggered-Dslash-on-KNL trick (arXiv:1411.2087): an
    SU(3) matrix is determined by its first two rows — the third is the
    unitarity cross product ``row2 = conj(row0 x row1)`` — so storage drops
    from 18 to 12 reals per link (72 -> 48 words per site) and the consumer
    reconstructs row 2 in registers.  Exact only on SU(3); for arbitrary
    matrices the reconstruction error is bounded by the distance to the
    nearest unitary (the codec round-trip property tests pin this).
    """

    NONE = "none"
    TWO_ROW = "two_row"


@dataclasses.dataclass(frozen=True)
class LatticeShape:
    """Lattice of dimension L^4, matching the paper's ``total_sites = L**4``."""

    L: int

    @property
    def n_sites(self) -> int:
        return self.L**4

    def padded_sites(self, lane: int = LANE) -> int:
        return ((self.n_sites + lane - 1) // lane) * lane


# ---------------------------------------------------------------------------
# Canonical <-> physical layout converters.
# ---------------------------------------------------------------------------


def _real_dtype(complex_dtype: Any) -> Any:
    return jnp.float64 if complex_dtype == jnp.complex128 else jnp.float32


def to_planar(a: jax.Array) -> jax.Array:
    """complex (..., ) -> stacked planar (2, ...) real array (re, im)."""
    return jnp.stack([jnp.real(a), jnp.imag(a)], axis=0)


def from_planar(p: jax.Array) -> jax.Array:
    return jax.lax.complex(p[0], p[1])


def pack_aos(a: jax.Array, site_meta: jax.Array | None = None) -> jax.Array:
    """Canonical A (n_sites, 4, 3, 3) complex -> paper-faithful AoS (n_sites, 80).

    Words [0:72] are interleaved (re, im) gauge entries in link-major order —
    exactly MILC's ``site.link[4]``; words [72:80] are the metadata/pad block.
    """
    n_sites = a.shape[0]
    dt = _real_dtype(a.dtype)
    gauge = jnp.stack([jnp.real(a), jnp.imag(a)], axis=-1)  # (s, 4, 3, 3, 2)
    gauge = gauge.reshape(n_sites, GAUGE_WORDS).astype(dt)
    if site_meta is None:
        # x, y, z, t, index, parity, pad, pad — populated like the benchmark's
        # make_lattice(): index = linear site id; coords from L is unknown here
        # so carry the linear index in all coordinate words (metadata is dead
        # weight for the kernel either way; that is the point of this layout).
        idx = jnp.arange(n_sites, dtype=dt)[:, None]
        site_meta = jnp.concatenate(
            [idx, idx, idx, idx, idx, idx % 2, jnp.zeros((n_sites, 2), dt)], axis=1
        )
    return jnp.concatenate([gauge, site_meta.astype(dt)], axis=1)


def unpack_aos(aos: jax.Array, complex_dtype: Any = jnp.complex64) -> jax.Array:
    n_sites = aos.shape[0]
    gauge = aos[:, :GAUGE_WORDS].reshape(n_sites, LINKS, SU3, SU3, 2)
    return jax.lax.complex(gauge[..., 0], gauge[..., 1]).astype(complex_dtype)


def pack_soa(a: jax.Array) -> jax.Array:
    """Canonical (n_sites, 4, 3, 3) complex -> SoA planar (2, 4, 3, 3, n_sites)."""
    return to_planar(jnp.moveaxis(a, 0, -1))


def unpack_soa(soa: jax.Array, complex_dtype: Any = jnp.complex64) -> jax.Array:
    return jnp.moveaxis(from_planar(soa), -1, 0).astype(complex_dtype)


def pack_aosoa(a: jax.Array, lane: int = LANE) -> jax.Array:
    """Canonical -> (n_tiles, 2, 4, 3, 3, lane). Pads site count up to lane."""
    n_sites = a.shape[0]
    pad = (-n_sites) % lane
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0)
    n_tiles = a.shape[0] // lane
    # (tiles, lane, 4, 3, 3) -> (tiles, 4, 3, 3, lane) -> planar
    t = jnp.moveaxis(a.reshape(n_tiles, lane, LINKS, SU3, SU3), 1, -1)
    return jnp.stack([jnp.real(t), jnp.imag(t)], axis=1)


def unpack_aosoa(
    t: jax.Array, n_sites: int, complex_dtype: Any = jnp.complex64
) -> jax.Array:
    c = jax.lax.complex(t[:, 0], t[:, 1])  # (tiles, 4, 3, 3, lane)
    c = jnp.moveaxis(c, -1, 1).reshape(-1, LINKS, SU3, SU3)
    return c[:n_sites].astype(complex_dtype)


# ---------------------------------------------------------------------------
# LayoutCodec — pack/unpack/shard as a first-class object.
#
# Historically the engine re-derived the canonical<->physical conversion (and
# its padded twin) per layout in three separate if/elif chains; the codec is
# the single owner of that logic.  A codec knows:
#   * the physical array produced from canonical complex (S, 4, 3, 3) data,
#   * how to restore canonical data (optionally sliced to the live sites),
#   * the PartitionSpec that shards the physical form over a 1-D site mesh,
#   * the planar "kernel view" (2, 36, S) the Pallas path consumes.
# ---------------------------------------------------------------------------

PLANAR_ROWS = LINKS * SU3 * SU3  # 36 complex entries per site

# Two-row compressed planar form: 4 links x 2 stored rows x 3 cols = 24
# complex entries per site (48 real words).  Row order is the full form's
# with every k=2 row deleted, so COMP_ROW_INDICES gathers the compressed
# rows out of a full 36-row planar array (and is the store-side "drop row
# 2" map the kernels use).
PLANAR_COMP_ROWS = LINKS * 2 * SU3  # 24
GAUGE_COMP_WORDS = PLANAR_COMP_ROWS * 2  # 48 real words per site
COMP_ROW_INDICES = tuple(
    (j * SU3 + k) * SU3 + l
    for j in range(LINKS)
    for k in range(2)
    for l in range(SU3)
)


def reconstruct_third_row(r0: jax.Array, r1: jax.Array) -> jax.Array:
    """row2 = conj(row0 x row1) — the SU(3) unitarity reconstruction.

    ``r0``/``r1`` are complex arrays with the color index last (..., 3).
    Expanded in *real* arithmetic with the exact operand grouping of the
    kernels' in-register reconstruction (``su3_matmul._expand_tile``), NOT
    via complex primitives — same formula, same f32 precision; values agree
    with the in-kernel reconstruction to ~1 ulp (LLVM FMA contraction can
    round mul+add pairs differently across compiled programs, so bitwise
    equality across *different* programs is not guaranteed — see
    ``_expand_tile`` for what is exactly pinned).  Computed at the input
    precision; callers wanting f32 reconstruction from narrower storage
    upcast first.
    """
    a_r, a_i = jnp.real(r0), jnp.imag(r0)
    b_r, b_i = jnp.real(r1), jnp.imag(r1)

    def _comp(i: int, j: int) -> jax.Array:
        # conj(r0[i]*r1[j] - r0[j]*r1[i]), grouped as in _expand_tile
        xr = (a_r[..., i] * b_r[..., j] - a_i[..., i] * b_i[..., j]) - (
            a_r[..., j] * b_r[..., i] - a_i[..., j] * b_i[..., i]
        )
        xi = (a_r[..., i] * b_i[..., j] + a_i[..., i] * b_r[..., j]) - (
            a_r[..., j] * b_i[..., i] + a_i[..., j] * b_r[..., i]
        )
        return jax.lax.complex(xr, -xi)

    return jnp.stack([_comp(1, 2), _comp(2, 0), _comp(0, 1)], axis=-1)


@dataclasses.dataclass(frozen=True)
class LayoutCodec:
    """Canonical <-> physical converter for one (layout, tile, word dtype).

    ``tile`` is the AoSoA lane width / Pallas site-tile; AOS and SOA ignore it
    for shape purposes but carry it so a codec fully identifies the physical
    form used by an :class:`repro.core.su3.plan.ExecutionPlan`.

    ``accum_dtype`` ("" = same as ``dtype``) records the *compute* width of
    mixed-precision plans: storage words stream at ``dtype`` (what pack emits
    and the traffic model charges) while the kernel accumulates at
    ``accum_dtype`` — the bf16-storage / f32-accumulate serving scheme.

    ``compression`` selects the stored-row set of each link.  TWO_ROW keeps
    rows 0 and 1 only (24 planar rows instead of 36); the codec itself never
    materializes row 2 in the physical array — ``pack`` drops it, kernels
    reconstruct it in registers, and only ``unpack`` (the canonical escape
    hatch) rebuilds it, in f32, via :func:`reconstruct_third_row`.
    """

    layout: Layout
    tile: int = LANE
    dtype: str = "float32"
    accum_dtype: str = ""  # "" => accumulate at the storage dtype
    compression: GaugeCompression = GaugeCompression.NONE

    @property
    def word_dtype(self) -> Any:
        return jnp.dtype(self.dtype)

    @property
    def is_compressed(self) -> bool:
        return self.compression == GaugeCompression.TWO_ROW

    @property
    def planar_rows(self) -> int:
        """Planar gauge rows of the physical form: 36 full, 24 two-row."""
        return PLANAR_COMP_ROWS if self.is_compressed else PLANAR_ROWS

    @property
    def stored_rows(self) -> int:
        """SU(3) matrix rows present in storage (3 full, 2 compressed)."""
        return 2 if self.is_compressed else SU3

    @property
    def compute_dtype(self) -> str:
        """The dtype FMAs run at: accum_dtype when set, else the word dtype."""
        return self.accum_dtype or self.dtype

    @property
    def is_mixed_precision(self) -> bool:
        return bool(self.accum_dtype) and self.accum_dtype != self.dtype

    # -- canonical <-> physical ------------------------------------------------

    def pack(self, a: jax.Array) -> jax.Array:
        """Canonical complex (n_sites, 4, 3, 3) -> physical layout array.

        TWO_ROW drops each link's third row before laying out — the stored
        form is (2, 24, S) / (tiles, 2, 24, lane); row 2 never exists
        physically.
        """
        wdt = self.word_dtype
        if self.layout == Layout.AOS:
            return pack_aos(a).astype(wdt)  # (S, 80)
        if self.is_compressed:
            a = a[:, :, :2, :]  # (S, 4, 2, 3): keep rows 0, 1
        rows = self.planar_rows
        if self.layout == Layout.SOA:
            return to_planar(jnp.moveaxis(a, 0, -1)).reshape(2, rows, -1).astype(wdt)
        # AoSoA: pad sites to the lane, tile-major site order
        n_sites = a.shape[0]
        pad = (-n_sites) % self.tile
        if pad:
            a = jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0)
        n_tiles = a.shape[0] // self.tile
        t = jnp.moveaxis(a.reshape((n_tiles, self.tile) + a.shape[1:]), 1, -1)
        p = jnp.stack([jnp.real(t), jnp.imag(t)], axis=1)
        return p.reshape(n_tiles, 2, rows, self.tile).astype(wdt)

    def unpack(self, phys: jax.Array, n_sites: int | None = None) -> jax.Array:
        """Physical -> canonical complex; slice to ``n_sites`` when given.

        For TWO_ROW storage the third row is reconstructed here, in f32, via
        the unitarity cross product — bit-identical to what the kernels
        rebuild in registers (same formula, same precision).
        """
        f32 = phys.astype(jnp.float32)
        sr = self.stored_rows
        if self.layout == Layout.AOS:
            c = unpack_aos(f32)
        elif self.layout == Layout.SOA:
            c = unpack_soa(f32.reshape(2, LINKS, sr, SU3, -1))
        else:
            t = f32.reshape(phys.shape[0], 2, LINKS, sr, SU3, self.tile)
            cc = jax.lax.complex(t[:, 0], t[:, 1])  # (tiles, 4, sr, 3, lane)
            cc = jnp.moveaxis(cc, -1, 1).reshape(-1, LINKS, sr, SU3)
            c = cc.astype(jnp.complex64)
        if self.is_compressed:
            r2 = reconstruct_third_row(c[:, :, 0, :], c[:, :, 1, :])
            c = jnp.concatenate([c, r2[:, :, None, :]], axis=2)
        return c if n_sites is None else c[:n_sites]

    def pack_b(self, b: jax.Array) -> jax.Array:
        """Canonical B (4, 3, 3) complex -> planar (2, 36) in the word dtype."""
        return to_planar(b).reshape(2, PLANAR_ROWS).astype(self.word_dtype)

    def unpack_b(self, b_p: jax.Array) -> jax.Array:
        return from_planar(b_p.astype(jnp.float32).reshape(2, LINKS, SU3, SU3))

    # -- color-vector fields (the stencil workload's v) ------------------------
    #
    # The vector field is planar (2, 3, S) in every layout — it has no AoS
    # metadata and no per-layout physical form; only the word dtype (and the
    # site padding the caller applies) varies.  Site order matches the
    # lattice's linear site ids, i.e. the planar view's site axis.

    def pack_vec(self, v: jax.Array, padded_sites: int | None = None) -> jax.Array:
        """Canonical vector field (n_sites, 3) complex -> planar (2, 3, S)
        in the word dtype, zero-padded to ``padded_sites`` when given."""
        p = to_planar(jnp.moveaxis(v, 0, -1))  # (2, 3, n_sites)
        if padded_sites is not None and padded_sites > v.shape[0]:
            p = jnp.pad(p, ((0, 0), (0, 0), (0, padded_sites - v.shape[0])))
        return p.astype(self.word_dtype)

    def unpack_vec(self, v_p: jax.Array, n_sites: int | None = None) -> jax.Array:
        """Planar (2, 3, S) -> canonical complex (n_sites, 3)."""
        c = jnp.moveaxis(from_planar(v_p.astype(jnp.float32)), -1, 0)
        return c if n_sites is None else c[:n_sites]

    # -- sharding --------------------------------------------------------------

    def site_spec(
        self, site_axes: tuple[str, ...] = ("sites",)
    ) -> "jax.sharding.PartitionSpec":
        """PartitionSpec sharding the physical site axis over ``site_axes``.

        Args:
            site_axes: mesh axis names the site dimension shards over, major
                first — ``("sites",)`` on the legacy 1-D mesh,
                ``("hosts", "devices")`` on a (host, device) mesh (see
                ``repro.distributed.sharding.lattice_site_axes``).

        Returns:
            The layout's PartitionSpec with every non-site dimension
            replicated: ``(sites, 80)`` for AOS, ``(2, 36, S)`` for SOA
            (site axis last), ``(tiles, 2, 36, lane)`` for AoSoA (the tile
            axis is the site axis).
        """
        P = jax.sharding.PartitionSpec
        ax = site_axes if len(site_axes) > 1 else site_axes[0]
        if self.layout == Layout.AOS:
            return P(ax, None)  # (sites, 80)
        if self.layout == Layout.SOA:
            return P(None, None, ax)  # (2, 36, S)
        return P(ax, None, None, None)  # (tiles, 2, 36, lane)

    # -- the Pallas kernel's planar view --------------------------------------

    @property
    def supports_planar_view(self) -> bool:
        return self.layout in (Layout.SOA, Layout.AOSOA)

    def planar_view(self, phys: jax.Array) -> jax.Array:
        """Physical -> flattened planar (2, 36, S) without changing dtype.

        Tile-major site order (s = tile_idx * lane + lane_idx), the exact
        inverse of :meth:`from_planar_view` and consistent with
        ``pack_aosoa``'s site numbering.  (The pre-codec engine used a
        lane-major flatten here with a tile-major unflatten — a site
        permutation masked by the benchmark's uniform lattice data.)
        """
        if self.layout == Layout.SOA:
            return phys
        if self.layout == Layout.AOSOA:
            return jnp.moveaxis(phys, 0, 2).reshape(2, self.planar_rows, -1)
        raise ValueError(f"{self.layout} has no planar kernel view")

    def from_planar_view(self, c_p: jax.Array, like: jax.Array) -> jax.Array:
        """Planar (2, rows, S) -> physical, shaped like ``like``."""
        if self.layout == Layout.SOA:
            return c_p
        if self.layout == Layout.AOSOA:
            c_t = c_p.reshape(2, self.planar_rows, like.shape[0], self.tile)
            return jnp.moveaxis(c_t, 2, 0)
        raise ValueError(f"{self.layout} has no planar kernel view")


def make_codec(
    layout: Layout,
    tile: int = LANE,
    dtype: str = "float32",
    accum_dtype: str = "",
    compression: GaugeCompression | str = GaugeCompression.NONE,
) -> LayoutCodec:
    """The one construction site for layout codecs."""
    comp = GaugeCompression(compression)
    if comp != GaugeCompression.NONE and Layout(layout) == Layout.AOS:
        # The AoS layout exists to reproduce the paper's 320 B site struct
        # verbatim; a compressed variant of it is not a form the paper (or
        # any kernel here) defines.
        raise ValueError("gauge compression is only defined for SOA/AoSoA layouts")
    return LayoutCodec(
        layout=Layout(layout),
        tile=tile,
        dtype=dtype,
        accum_dtype=accum_dtype,
        compression=comp,
    )


# ---------------------------------------------------------------------------
# Traffic model — charges each layout the bytes it actually streams.
# This is the quantitative form of the paper's 288/320 streaming-store point.
# ---------------------------------------------------------------------------


WORD_BYTES = {"float32": 4, "bfloat16": 2, "float64": 8}


@dataclasses.dataclass(frozen=True)
class TrafficModel:
    """Bytes moved per kernel invocation for a given layout/dtype.

    read(A) + write(C); B is cache/VMEM-resident after first read (paper §3.1:
    "B could stay in the cache and can be reused") and charged once, which is
    negligible, so it is excluded exactly as in the paper's AI computation.

    Mixed-precision plans are charged at *storage* width: a bf16-storage /
    f32-accumulate plan streams 2-byte words over HBM (the accumulate happens
    on the VMEM-resident tile and never hits memory), so ``word_bytes`` is
    always the storage dtype's width.
    """

    layout: Layout
    n_sites: int
    word_bytes: int  # 4 for fp32, 2 for bf16, 8 for fp64 — STORAGE width
    compression: GaugeCompression = GaugeCompression.NONE

    @classmethod
    def for_dtype(
        cls,
        layout: Layout,
        n_sites: int,
        dtype: str,
        compression: GaugeCompression | str = GaugeCompression.NONE,
    ) -> "TrafficModel":
        return cls(layout, n_sites, WORD_BYTES[dtype], GaugeCompression(compression))

    @property
    def words_per_site(self) -> int:
        if self.layout == Layout.AOS:
            return SITE_WORDS_AOS  # 80: pads are streamed too
        if self.compression == GaugeCompression.TWO_ROW:
            return GAUGE_COMP_WORDS  # 48: two stored rows per link
        return GAUGE_WORDS  # 72: SoA/AoSoA carry no metadata

    @property
    def bytes_per_site_rw(self) -> int:
        return 2 * self.words_per_site * self.word_bytes  # read A + write C

    @property
    def total_bytes(self) -> int:
        return self.n_sites * self.bytes_per_site_rw

    @property
    def flops_per_site(self) -> int:
        # 4 links x (3x3x3 complex MACs) x (4 mul + 4 add) = 864 (paper §3.1)
        return LINKS * SU3 * SU3 * SU3 * 8

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_site / self.bytes_per_site_rw


def paper_arithmetic_intensity(word_bytes: int = 4) -> float:
    """AI = 864 / (320 * 2) = 1.35 fp32 / 0.675 fp64 — paper §3.1 exactly."""
    return TrafficModel(Layout.AOS, 1, word_bytes).arithmetic_intensity

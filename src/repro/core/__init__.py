"""Core: the paper contribution (SU3 lattice engine + roofline methodology)."""

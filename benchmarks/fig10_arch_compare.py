"""Paper Fig. 10 analog: cross-architecture comparison for SU3_Bench.

The paper compares PIUMA vs Xeon cores (PIUMA wins 1.5x at 32 cores via
bandwidth). We put four platforms on the same three-term roofline:
paper-Xeon socket, paper-PIUMA core cluster, TPU v5e chip (this work's
target), and this container's CPU (measured). Per-chip bandwidth-bound
GF/s at the fp32 SoA arithmetic intensity (864/576 = 1.5)."""
from __future__ import annotations

from repro.core import roofline
from repro.core.su3.engine import EngineConfig, SU3Engine

AI_SOA = 864 / 576
AI_AOS = 864 / 640


def run(L: int = 8) -> list[dict]:
    rows = []
    for hw, cores in ((roofline.XEON_8280_SOCKET, 1), (roofline.PIUMA_CORE, 32),
                      (roofline.TPU_V5E, 1)):
        bw = hw.hbm_bw * cores
        peak = hw.peak_flops_vpu * cores
        # PIUMA third term (paper §5.3): issue rate 3.6 GF/s/core dot-product,
        # 4.8 GF/s/core blocked-GEMM
        issue = 4.8e9 * cores if hw is roofline.PIUMA_CORE else float("inf")
        bound = min(bw * AI_SOA, peak, issue)
        rows.append({
            "name": f"fig10_{hw.name}_x{cores}",
            "bw_gbs": round(bw / 1e9, 1),
            "compute_gf": round(peak / 1e9, 1),
            "issue_gf": None if issue == float("inf") else round(issue / 1e9, 1),
            "bound_gf": round(bound / 1e9, 2),
            "bound_term": (
                "issue" if bound == issue else
                "bandwidth" if bound == bw * AI_SOA else "compute"
            ),
        })
    # measured on this container (relative only) — one ExecutionPlan row
    eng = SU3Engine(EngineConfig(L=L, variant="versionX", iterations=3, warmups=1,
                                 tile=128))
    r = eng.run()
    rows.append({
        "name": "fig10_container_cpu_measured",
        "bw_gbs": round(r.gbytes, 2),
        "compute_gf": None, "issue_gf": None,
        "bound_gf": round(r.gflops, 2), "bound_term": "measured",
        "plan": eng.plan.describe(),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

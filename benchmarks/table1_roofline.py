"""Paper Table 1 analog: theoretical roofline ladders for SU3_Bench.

Reproduces the paper's Xeon CLX-8280 SIMD-utilization ladder exactly
(2.7 GHz x 2 SIMD x 8 lanes x 2 flops), and derives the equivalent ladder
for the TPU v5e VPU (8x128 lanes x FMA x ~940 MHz) — the honest compute
roof for a vector (non-MXU) kernel, which is SU3's PIUMA moment on TPU.
"""
from __future__ import annotations

from repro.core import roofline
from repro.core.su3 import layouts

GHZ = 2.7
CORES = 28
BW_SOCKET = 105.0  # GB/s


def xeon_ladder() -> list[dict]:
    rows = []
    for units, fma in ((2, True), (1, True), (1, False)):
        for simd in range(8, 0, -1):
            core = GHZ * units * simd * (2 if fma else 1)
            socket_peak = core * CORES
            # bandwidth roof at AI=1.35 (fp32)
            bw_roof = BW_SOCKET * layouts.paper_arithmetic_intensity(4)
            rows.append({
                "name": f"xeon_units{units}_fma{int(fma)}_simd{simd}",
                "core_gf": round(core, 1),
                "socket_gf": round(min(socket_peak, bw_roof), 1),
                "bw_bound_gf": round(bw_roof, 1),
            })
    return rows


def v5e_ladder() -> list[dict]:
    hw = roofline.TPU_V5E
    rows = []
    ai_aos = layouts.paper_arithmetic_intensity(4)  # 1.35
    ai_soa = 864 / 576  # padding removed
    for name, ai in (("aos", ai_aos), ("soa", ai_soa)):
        bw_roof = hw.hbm_bw * ai / 1e9
        rows.append({
            "name": f"v5e_{name}",
            "vpu_roof_gf": round(hw.peak_flops_vpu / 1e9, 1),
            "mxu_roof_gf": round(hw.peak_flops / 1e9, 1),
            "bw_bound_gf": round(bw_roof, 1),
            "binding": "bandwidth" if bw_roof < hw.peak_flops_vpu / 1e9 else "vpu",
        })
    return rows


def run() -> list[dict]:
    return xeon_ladder()[:3] + v5e_ladder()  # headline rows


if __name__ == "__main__":
    for r in xeon_ladder() + v5e_ladder():
        print(r)

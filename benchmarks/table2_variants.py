"""Paper Table 2 analog: baseline performance of the implementation
variants (versions 0/3/X/gemm/blocked/pallas) with -I/-W iteration sweeps.

Every row is an ExecutionPlan (layout, kernel, tile, placement) run by the
thin SU3Engine loop; the ``plan`` column records the exact tuple.  On top of
the paper's grid this adds the fused-stepping comparison: for the Pallas
plan, ``table2_pallas_fused_I{K}`` chains K multiplies in ONE dispatch
(plan.fused_step) against the K separately dispatched steps of
``table2_pallas_I{K}``, and reports the speedup.  On TPU this removes K-1
HBM roundtrips; in interpret mode on CPU it merely removes K-1 dispatches
(documented as a TPU-targeted optimization — the acceptance bar here is
"no slower").

CPU-measured numbers are for *relative* comparison between variants (this
container is the dev host, not the target); the v5e projection column uses
the roofline bandwidth bound with each variant's layout traffic.
"""
from __future__ import annotations

from repro.core import roofline
from repro.core.su3.engine import EngineConfig, SU3Engine
from repro.core.su3.layouts import Layout

VARIANTS = [
    ("version0", Layout.SOA),
    ("version3", Layout.SOA),
    ("versionX", Layout.SOA),
    ("version_gemm", Layout.SOA),
    ("version_blocked", Layout.AOSOA),
    ("pallas", Layout.SOA),
]


def run(L: int = 8, iters: tuple[int, ...] = (1, 5)) -> list[dict]:
    rows = []
    for variant, layout in VARIANTS:
        for n_iter in iters:
            cfg = EngineConfig(L=L, layout=layout, variant=variant,
                               iterations=n_iter, warmups=1, tile=128)
            r = SU3Engine(cfg).run()
            tm = r.traffic
            v5e_gf = roofline.TPU_V5E.hbm_bw * tm.arithmetic_intensity / 1e9
            row = r.row()
            row.update(name=f"table2_{variant}_I{n_iter}",
                       v5e_bw_bound_gf=round(v5e_gf, 1))
            rows.append(row)
    # Two-row compressed-gauge rows: same Pallas kernel, 48 words/site
    # streamed for A/C instead of 72 (row 2 reconstructed in-register), with
    # and without the bf16-storage stack.  ``bytes_per_site`` in the row is
    # what the acceptance gate diffs against the 18-real rows above.
    for dtype, accum in (("float32", ""), ("bfloat16", "float32")):
        cfg = EngineConfig(L=L, layout=Layout.SOA, variant="pallas",
                           dtype=dtype, accum_dtype=accum,
                           compression="two_row",
                           iterations=max(iters), warmups=1, tile=128)
        r = SU3Engine(cfg).run()
        tm = r.traffic
        v5e_gf = roofline.TPU_V5E.hbm_bw * tm.arithmetic_intensity / 1e9
        row = r.row()
        acc_tag = f"_acc-{accum}" if accum else ""
        row.update(name=f"table2_pallas_two_row_{dtype}{acc_tag}",
                   v5e_bw_bound_gf=round(v5e_gf, 1))
        rows.append(row)
    # Fused multi-iteration stepping: block-time K dispatched single steps
    # against ONE fused(K) dispatch on the same engine (median over repeated
    # blocks — individually-timed iterations at L=4 are pure noise). One
    # measurement pass supplies both the comparison and the result row.
    for n_iter in iters:
        if n_iter < 2:
            continue
        cfg = EngineConfig(L=L, layout=Layout.SOA, variant="pallas",
                           iterations=n_iter, warmups=2, tile=128)
        cmp = SU3Engine(cfg).compare_fused(k=n_iter, reps=10)
        row = cmp["result"].row()
        row.update(
            name=f"table2_pallas_fused_I{n_iter}",
            dispatched_block_s=round(cmp["dispatched_s"], 6),
            fused_block_s=round(cmp["fused_s"], 6),
            fused_speedup=round(cmp["fused_speedup"], 3),
        )
        rows.append(row)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Paper Table 2 analog: baseline performance of the implementation
variants (versions 0/3/X/gemm/blocked/pallas) with -I/-W iteration sweeps.

CPU-measured numbers are for *relative* comparison between variants (this
container is the dev host, not the target); the v5e projection column uses
the roofline bandwidth bound with each variant's layout traffic.
"""
from __future__ import annotations

from repro.core import roofline
from repro.core.su3.engine import EngineConfig, SU3Engine
from repro.core.su3.layouts import Layout

VARIANTS = [
    ("version0", Layout.SOA),
    ("version3", Layout.SOA),
    ("versionX", Layout.SOA),
    ("version_gemm", Layout.SOA),
    ("version_blocked", Layout.AOSOA),
    ("pallas", Layout.SOA),
]


def run(L: int = 8, iters: tuple[int, ...] = (1, 5)) -> list[dict]:
    rows = []
    for variant, layout in VARIANTS:
        for n_iter in iters:
            cfg = EngineConfig(L=L, layout=layout, variant=variant,
                               iterations=n_iter, warmups=1, tile=128)
            r = SU3Engine(cfg).run()
            tm = r.traffic
            v5e_gf = roofline.TPU_V5E.hbm_bw * tm.arithmetic_intensity / 1e9
            row = r.row()
            row.update(name=f"table2_{variant}_I{n_iter}",
                       v5e_bw_bound_gf=round(v5e_gf, 1))
            rows.append(row)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Traffic benchmark for the SU3 serving subsystem (the ``serve`` section).

Load models over ``repro.serve.su3.SU3Service``:

  open loop    Poisson arrivals (exponential inter-arrival gaps) with a mixed
               (L, k) request population, replayed against the wall clock.
               The arrival rate is derived from a measured warm dispatch time
               (offered load ~= OVERLOAD x service capacity), so the queue
               genuinely builds and the batcher's coalescing shows up as
               batch occupancy > 1 — machine-speed independent.
  closed loop  U concurrent users, each submit -> await -> resubmit for R
               rounds: the sustained-throughput view with a fixed population.
  continuous   the SAME mixed-k open-loop schedule served batch-per-step vs
               continuous-batching vs megakernel at a FIXED slot count.
               Batch-per-step fragments the stream into per-(L, k) buckets —
               every chain depth dispatches separately, each padded to the
               slot count — while the continuous path merges all depths of
               an L into one in-flight chain and admits at iteration
               boundaries, so its dispatched slots run measurably fuller
               (the acceptance bar: continuous occupancy > batch occupancy
               under open-loop load).  The megakernel path additionally
               collapses host dispatches to ONE per iteration at no-worse
               occupancy (second acceptance bar, same row).
  dispatch     per-chain continuous vs megakernel on a MIXED-L stream: the
               chain path pays one dispatch per (host, L) per iteration,
               the slot table pays 1 — dispatch counts and sustained GFLOPS
               recorded (the paper's §5.3 pipeline-throughput tax, measured
               at the serving layer).
  bf16 row     the same request stream served by a bf16-storage /
               f32-accumulate plan pool vs the f32 pool: measured HLO
               bytes/site must drop, results must agree within 1e-2.
  solve row    one CG solve (data-dependent scheduling-turn count) mixed
               with a multiply stream on the same service: multiplies keep
               completing while the solve is in flight (kind alternation),
               the solve retires mid-stream on its residual test, per-kind
               iteration metrics split the work, and the served solution
               matches the plain-jnp reference solver.
  traced row   ONE Poisson stream replayed tracer-off vs tracer-on
               (``repro.obs``): sustained-GFLOPS delta, full request
               lifecycle + stencil exchange/interior/boundary phase
               coverage, trace exported as JSONL + Chrome trace-event
               JSON (``artifacts/serve_trace.jsonl`` /
               ``artifacts/serve_trace.chrome.json``).

Rows land in ``BENCH_su3.json`` under ``serve`` via ``benchmarks.run``;
standalone CLI:

    PYTHONPATH=src python -m benchmarks.serve_traffic --quick
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.su3.layouts import Layout
from repro.serve.su3 import BatcherConfig, ServiceConfig, SU3Service

OVERLOAD = 4.0  # offered load multiple of one-dispatch service capacity
TILE = 128  # explicit tile for the fixed-plan (non-autotuned) pools

# prefixed with an `L, tile, reps = ...` line by traced_serving; runs the
# 2-host overlap schedule under an enabled tracer (warm pass untraced, so
# only steady-state phases land in the records) and prints the span records
_PHASES_SUBPROC = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
from repro.core.su3.plan import EngineConfig, build_plan
from repro.launch.mesh import MeshSpec
from repro.obs import Tracer

plan = build_plan(EngineConfig(L=L, tile=tile, iterations=1, warmups=0),
                  MeshSpec(hosts=2, devices_per_host=1))
u, v = plan.init_stencil_data()
step = plan.stencil_step(overlap=True)
step(u, v).block_until_ready()  # compile + warm untraced
plan.tracer = Tracer(enabled=True, capacity=4096)
for _ in range(reps):
    step(u, v)
print(json.dumps([s.as_dict() for s in plan.tracer.spans()]))
"""


def _random_request(rng: np.random.Generator, n_sites: int):
    """One user's canonical complex (A, B) pair from a seeded host RNG."""
    a = rng.standard_normal((n_sites, 4, 3, 3, 2)).astype(np.float32)
    b = rng.standard_normal((4, 3, 3, 2)).astype(np.float32)
    return (
        jnp.asarray(a[..., 0] + 1j * a[..., 1], jnp.complex64),
        jnp.asarray(b[..., 0] + 1j * b[..., 1], jnp.complex64),
    )


def _service(dtype: str = "float32", accum: str = "", use_autotune: bool = False,
             max_queue_depth: int = 256) -> SU3Service:
    return SU3Service(ServiceConfig(
        dtype=dtype, accum_dtype=accum, autotune=use_autotune, tile=TILE,
        batcher=BatcherConfig(
            max_batch=8, warm_batch_sizes=(1, 2, 4, 8),
            max_queue_depth=max_queue_depth,
        ),
    ))


def _measure_step_s(svc: SU3Service, L: int, k: int, batch: int,
                    rng: np.random.Generator) -> float:
    """Warm median dispatch seconds for the (L, k, batch) shape."""
    n_sites = L**4
    times = []
    for _ in range(3):
        for _ in range(batch):
            a, b = _random_request(rng, n_sites)
            svc.submit(a, b, k=k)
        t0 = time.perf_counter()
        svc.step()
        times.append(time.perf_counter() - t0)
        svc.pop_ready()
    return float(np.median(times))


def open_loop(
    n_requests: int, Ls: tuple[int, ...], ks: tuple[int, ...], seed: int,
    use_autotune: bool = False,
) -> dict:
    """Poisson-arrival replay: submit per the schedule, step when work waits."""
    rng = np.random.default_rng(seed)
    svc = _service(use_autotune=use_autotune)
    svc.warm(Ls, ks=ks, batch_sizes=svc.cfg.batcher.warm_batch_sizes)

    # Offered rate: OVERLOAD x one-dispatch service capacity.  A warm
    # full batch of the slowest shape serves max_batch requests per
    # ref_step_s seconds, so capacity ~= max_batch / ref_step_s.
    max_batch = svc.cfg.batcher.max_batch
    ref_step_s = _measure_step_s(svc, max(Ls), max(ks), max_batch, rng)
    rate = OVERLOAD * max_batch / ref_step_s  # requests/sec
    gaps = rng.exponential(1.0 / rate, n_requests)
    arrivals = np.cumsum(gaps)
    # pre-generate the mixed population outside the timed loop
    population = []
    for i in range(n_requests):
        L = int(rng.choice(Ls))
        k = int(rng.choice(ks))
        population.append((L, k) + _random_request(rng, L**4))

    svc.metrics.reset()  # report the replay only, not the warmup
    t0 = time.perf_counter()
    submitted = 0
    while svc.metrics.completed + svc.metrics.rejected < n_requests:
        now = time.perf_counter() - t0
        while submitted < n_requests and arrivals[submitted] <= now:
            L, k, a, b = population[submitted]
            svc.submit(a, b, k=k)
            submitted += 1
        if svc.pending():
            svc.step()
            svc.pop_ready()  # deliver: don't accumulate C lattices on device
        elif submitted < n_requests:
            time.sleep(min(arrivals[submitted] - now, 0.01))
    wall = time.perf_counter() - t0

    row = dict(svc.metrics.snapshot())
    row.update(
        name="serve_open_loop",
        load="poisson",
        n_requests=n_requests,
        offered_rate_rps=round(rate, 2),
        replay_wall_s=round(wall, 3),
        mix_L=list(Ls),
        mix_k=list(ks),
        # pool keys are (host, L, dtype, layout, tile)
        pool=[f"h{key[0]}/L{key[1]}/{key[2]}/t{key[4]}" for key in svc.pool_keys()],
    )
    return row


def closed_loop(
    users: int, rounds: int, L: int, k: int | None, seed: int,
    use_autotune: bool = False,
) -> dict:
    """Fixed population: U users submit -> drain -> resubmit, R rounds."""
    rng = np.random.default_rng(seed)
    svc = _service(use_autotune=use_autotune)
    n_sites = L**4
    if k is None:
        k = svc.default_k_for(L)  # the autotuned fused depth, not a constant
    svc.warm((L,), ks=(k,), batch_sizes=(min(8, users),))
    svc.metrics.reset()
    for _ in range(rounds):
        ids = []
        for _ in range(users):
            a, b = _random_request(rng, n_sites)
            ids.append(svc.submit(a, b, k=k))
        svc.run_until_drained()
        for rid in ids:
            svc.pop_result(rid)
    row = dict(svc.metrics.snapshot())
    row.update(
        name="serve_closed_loop", load="closed", users=users, rounds=rounds,
        L=L, k=k,
    )
    return row


def _make_slot_service(slots: int, continuous: bool, megakernel: bool = False,
                       horizon: int = 1, tracer=None) -> SU3Service:
    """Fixed-slot service (every dispatch padded to ``slots``) so occupancy
    is directly comparable across batch / continuous / megakernel modes."""
    return SU3Service(ServiceConfig(
        autotune=False, tile=TILE, continuous=continuous,
        megakernel=megakernel, chain_horizon=horizon, chain_slots=slots,
        batcher=BatcherConfig(
            max_batch=slots, warm_batch_sizes=(slots,), max_queue_depth=256,
        ),
    ), tracer=tracer)


def _replay_open_loop(
    svc: SU3Service, Ls: tuple[int, ...], ks: tuple[int, ...],
    n_requests: int, rate: float, seed: int, slots: int,
) -> dict:
    """Replay ONE Poisson (L, k) stream (identical per seed) against ``svc``."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, n_requests)
    arrivals = np.cumsum(gaps)
    population = [
        (int(rng.choice(Ls)), int(rng.choice(ks)))
        for _ in range(n_requests)
    ]
    population = [
        (L, k) + _random_request(rng, L**4) for L, k in population
    ]
    svc.warm(tuple(sorted(set(Ls))), ks=ks, batch_sizes=(slots,))
    svc.metrics.reset()
    t0 = time.perf_counter()
    submitted = 0
    while svc.metrics.completed + svc.metrics.rejected < n_requests:
        now = time.perf_counter() - t0
        while submitted < n_requests and arrivals[submitted] <= now:
            _L, k, a, b = population[submitted]
            svc.submit(a, b, k=k)
            submitted += 1
        if svc.pending():
            svc.step()
            svc.pop_ready()
        elif submitted < n_requests:
            time.sleep(min(arrivals[submitted] - now, 0.01))
    return svc.metrics.snapshot()


def continuous_comparison(
    L: int = 2, n_requests: int = 24, seed: int = 0, slots: int = 4,
    ks: tuple[int, ...] = (1, 2, 4),
) -> dict:
    """Batch-per-step vs continuous vs megakernel on one mixed-k stream.

    All three services pad every dispatch to ``slots`` (one warm batch size /
    ``chain_slots``), so ``mean_batch_occupancy`` — live slots over
    dispatched slots — is directly comparable.  The stream mixes chain
    depths ``ks`` at one lattice size; arrivals are Poisson at an offered
    rate of ~1.5 requests per measured warm iteration.  The megakernel
    acceptance bar rides on this row: host dispatches collapse to ONE per
    iteration with occupancy no worse than the per-chain continuous path.
    """
    n_sites = L**4
    probe = _make_slot_service(slots, continuous=False)
    rng = np.random.default_rng(seed)
    probe.warm((L,), ks=ks, batch_sizes=(slots,))
    iter_s = _measure_step_s(probe, L, 1, slots, rng)
    rate = 1.5 / max(iter_s, 1e-5)  # ~1.5 arrivals per iteration time

    def replay(svc: SU3Service) -> dict:
        return _replay_open_loop(svc, (L,), ks, n_requests, rate, seed, slots)

    batch_snap = replay(_make_slot_service(slots, continuous=False))
    cont_snap = replay(_make_slot_service(slots, continuous=True))
    mega_snap = replay(_make_slot_service(slots, continuous=True, megakernel=True))
    return {
        "name": "serve_continuous_vs_batch",
        "L": L,
        "mix_k": list(ks),
        "n_requests": n_requests,
        "slots": slots,
        "offered_rate_rps": round(rate, 2),
        "occupancy_batch": batch_snap["mean_batch_occupancy"],
        "occupancy_continuous": cont_snap["mean_batch_occupancy"],
        "occupancy_megakernel": mega_snap["mean_batch_occupancy"],
        "occupancy_gain": round(
            cont_snap["mean_batch_occupancy"]
            / max(batch_snap["mean_batch_occupancy"], 1e-9), 3
        ),
        "continuous_higher_occupancy": (
            cont_snap["mean_batch_occupancy"] > batch_snap["mean_batch_occupancy"]
        ),
        "megakernel_occupancy_no_worse": (
            mega_snap["mean_batch_occupancy"]
            >= 0.95 * cont_snap["mean_batch_occupancy"]
        ),
        "midchain_admits": cont_snap["midchain_admits"],
        "midchain_admits_megakernel": mega_snap["midchain_admits"],
        "latency_p50_ms_batch": batch_snap["latency_p50_ms"],
        "latency_p50_ms_continuous": cont_snap["latency_p50_ms"],
        "latency_p50_ms_megakernel": mega_snap["latency_p50_ms"],
        "dispatches_batch": batch_snap["dispatches"],
        "dispatches_continuous": cont_snap["dispatches"],
        "dispatches_megakernel": mega_snap["dispatches"],
        "dispatches_per_iteration_megakernel": mega_snap["dispatches_per_iteration"],
        "megakernel_single_dispatch_per_iteration": (
            mega_snap["dispatches_per_iteration"] <= 1.0
        ),
        "sustained_gflops_busy": cont_snap["sustained_gflops_busy"],
    }


def traced_serving(
    L: int = 2, n_requests: int = 16, seed: int = 0, slots: int = 4,
    ks: tuple[int, ...] = (1, 2), n_stencil: int = 4,
    stencil_L: int = 4, trace_prefix: str = "artifacts/serve_trace",
) -> dict:
    """Tracing-overhead and lifecycle/phase-coverage row (``repro.obs``).

    Replays ONE Poisson mixed-k stream twice — tracer disabled (the
    production default: every hot-path site is one ``tracer.enabled``
    predicate) and enabled (flight-recorder ring) — and reports the
    sustained-GFLOPS delta between the two.  The traced service then
    serves a short stencil stream, and the 2-host overlap schedule runs
    under the SAME tracer (oversubscribed on the local device), so one
    exported trace covers the full request lifecycle (admit -> queue ->
    seat -> dispatch -> complete, multiply AND stencil kinds) plus the
    stencil exchange/interior/boundary phases.  The row asserts both
    coverages and names the trace files (``{trace_prefix}.jsonl`` and
    ``{trace_prefix}.chrome.json`` — the latter loads in
    chrome://tracing / Perfetto and carries the provenance block in
    ``otherData``).
    """
    from repro.obs import Tracer, attribution_report, provenance_block

    probe = _make_slot_service(slots, continuous=False)
    rng = np.random.default_rng(seed)
    probe.warm((L,), ks=ks, batch_sizes=(slots,))
    iter_s = _measure_step_s(probe, L, 1, slots, rng)
    rate = 1.5 / max(iter_s, 1e-5)

    # min-of-N walls: the first continuous-mode replay pays the chain jit
    # compiles and every replay carries scheduler/sleep jitter; the min
    # discards both while any persistent per-span tracer cost survives
    def best_replay(tracer, reps=3):
        best, svc = None, None
        for _ in range(reps):
            svc = _make_slot_service(slots, continuous=True, tracer=tracer)
            snap = _replay_open_loop(svc, (L,), ks, n_requests, rate, seed, slots)
            if best is None or snap["wall_s"] < best["wall_s"]:
                best = snap
        return best, svc

    off_snap, _ = best_replay(None)
    tracer = Tracer(enabled=True, capacity=1 << 16)
    on_snap, svc = best_replay(tracer)

    # a short stencil stream through the SAME service + tracer (request
    # lifecycle of the second workload kind)
    n_sites = L**4
    for _ in range(n_stencil):
        u, _ = _random_request(rng, n_sites)
        vv = rng.standard_normal((n_sites, 3, 2)).astype(np.float32)
        svc.submit_stencil(u, jnp.asarray(vv[..., 0] + 1j * vv[..., 1],
                                          jnp.complex64))
    svc.run_until_drained()
    svc.pop_ready()

    # the overlap schedule's three phases need a real 2-host mesh; the
    # forced device count locks at first jax init, so (exactly like the
    # stencil benchmark's identity rows) a subprocess runs the traced
    # schedule and its span records merge into THIS trace via absorb()
    from benchmarks.stencil import _subprocess_json
    code = (f"L, tile, reps = {stencil_L}, {min(64, stencil_L**3)}, 2\n"
            + _PHASES_SUBPROC)
    phase_records, phase_err = _subprocess_json(code)
    if phase_records:
        tracer.absorb(phase_records, lane_offset=200)

    names = {s.name for s in tracer.spans()}
    lifecycle = {"admit", "seat", "dispatch", "request"}
    phases = {"stencil.exchange", "stencil.interior", "stencil.boundary"}
    jsonl_path = f"{trace_prefix}.jsonl"
    chrome_path = f"{trace_prefix}.chrome.json"
    trace_dir = os.path.dirname(trace_prefix)
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)  # gitignored artifacts/ home
    n_records = tracer.to_jsonl(jsonl_path)
    tracer.to_chrome_trace(chrome_path, metadata=provenance_block())
    # tracing cost shows up in the replay wall of the identical Poisson
    # schedule (busy_s can NOT see it: spans are recorded outside the timed
    # dispatch region by design); at quick scale the delta is noise-level —
    # which is the acceptance point
    row = {
        "name": "serve_traced",
        "L": L, "mix_k": list(ks), "n_requests": n_requests, "slots": slots,
        "n_stencil_requests": n_stencil, "stencil_hosts": 2,
        "stencil_L": stencil_L,
        "gflops_untraced": off_snap["sustained_gflops_wall"],
        "gflops_traced": on_snap["sustained_gflops_wall"],
        "wall_s_untraced": off_snap["wall_s"],
        "wall_s_traced": on_snap["wall_s"],
        "tracing_overhead_frac": round(
            on_snap["wall_s"] / max(off_snap["wall_s"], 1e-9) - 1.0, 4),
        "spans_recorded": n_records,
        "spans_dropped": tracer.dropped,
        "lifecycle_covered": lifecycle <= names,
        "phases_covered": phases <= names,
        "span_names": sorted(names),
        "attribution_rows": len(attribution_report(tracer.spans())),
        "trace_jsonl": jsonl_path,
        "trace_chrome": chrome_path,
    }
    if phase_err:
        row["phase_subprocess_error"] = phase_err
    return row


def dispatch_overhead(
    Ls: tuple[int, ...] = (2, 3), n_requests: int = 16, seed: int = 0,
    slots: int = 4, ks: tuple[int, ...] = (1, 2),
) -> dict:
    """Per-chain continuous vs megakernel dispatch bill on a MIXED-L stream.

    With two lattice sizes in flight the per-chain path pays one dispatch
    per (host, L) chain per iteration; the megakernel slot table pays ONE.
    This row records the dispatch counts, dispatches/iteration, and
    sustained GFLOPS of both paths on an identical Poisson stream — the
    serving-side measurement of the paper's §5.3 pipeline-throughput tax.
    """
    probe = _make_slot_service(slots, continuous=False)
    rng = np.random.default_rng(seed)
    probe.warm((min(Ls),), ks=(1,), batch_sizes=(slots,))
    iter_s = _measure_step_s(probe, min(Ls), 1, slots, rng)
    rate = 1.5 / max(iter_s, 1e-5)

    chain_snap = _replay_open_loop(
        _make_slot_service(slots, continuous=True),
        Ls, ks, n_requests, rate, seed, slots)
    mega_snap = _replay_open_loop(
        _make_slot_service(slots, continuous=True, megakernel=True),
        Ls, ks, n_requests, rate, seed, slots)
    return {
        "name": "serve_dispatch_overhead",
        "mix_L": list(Ls),
        "mix_k": list(ks),
        "n_requests": n_requests,
        "slots": slots,
        "offered_rate_rps": round(rate, 2),
        "dispatches_chains": chain_snap["dispatches"],
        "dispatches_megakernel": mega_snap["dispatches"],
        "dispatch_ratio": round(
            chain_snap["dispatches"] / max(mega_snap["dispatches"], 1), 3
        ),
        "dispatches_per_iteration_chains": chain_snap["dispatches_per_iteration"],
        "dispatches_per_iteration_megakernel": mega_snap["dispatches_per_iteration"],
        "megakernel_fewer_dispatches": (
            mega_snap["dispatches"] < chain_snap["dispatches"]
        ),
        "occupancy_chains": chain_snap["mean_batch_occupancy"],
        "occupancy_megakernel": mega_snap["mean_batch_occupancy"],
        "gflops_busy_chains": chain_snap["sustained_gflops_busy"],
        "sustained_gflops_busy": mega_snap["sustained_gflops_busy"],
    }


def bf16_plan_comparison(L: int, seed: int) -> dict:
    """bf16-storage/f32-accumulate pool vs f32 pool on one request stream.

    The serving form of the ROADMAP's bf16 item: storage bytes drop at the
    HLO level (measured, not modeled) while results stay within 1e-2 of the
    f32 path and the canonical su3_bench verification still passes.
    """
    rng = np.random.default_rng(seed)
    n_sites = L**4
    f32 = _service()
    bf16 = _service(dtype="bfloat16", accum="float32")
    reqs = [_random_request(rng, n_sites) for _ in range(4)]
    ids32 = [f32.submit(a, b, k=2) for a, b in reqs]
    ids16 = [bf16.submit(a, b, k=2) for a, b in reqs]
    f32.run_until_drained()
    bf16.run_until_drained()
    errs = []
    for i32, i16 in zip(ids32, ids16):
        c32, c16 = f32.pop_result(i32), bf16.pop_result(i16)
        errs.append(
            float(jnp.max(jnp.abs(c16 - c32)))
            / max(float(jnp.max(jnp.abs(c32))), 1.0)
        )
    err = max(errs)

    # canonical verification through the bf16 plan itself
    plan16 = bf16.runner_for(L).plan
    a_phys, b_p, _, _ = plan16.init_data()
    verified = plan16.verify(plan16.step(a_phys, b_p))

    hlo_f32 = autotune.hlo_bytes_for_variant(
        "pallas", Layout.SOA, n_sites=1024, tile=TILE)
    hlo_bf16 = autotune.hlo_bytes_for_variant(
        "pallas", Layout.SOA, n_sites=1024, tile=TILE,
        dtype="bfloat16", accum_dtype="float32")
    return {
        "name": "serve_bf16_vs_f32",
        "L": L,
        "hlo_bytes_per_site_f32": round(hlo_f32, 1),
        "hlo_bytes_per_site_bf16": round(hlo_bf16, 1),
        "bf16_bytes_ratio": round(hlo_bf16 / hlo_f32, 3),
        "bf16_fewer_bytes": hlo_bf16 < hlo_f32,
        "model_bytes_per_site_f32": 2 * 72 * 4,
        "model_bytes_per_site_bf16": 2 * 72 * 2,
        "max_rel_err_vs_f32": round(err, 5),
        "within_1e-2": err < 1e-2,
        "bf16_verified": bool(verified),
        "plan": plan16.describe(),
    }


def solve_mix(L: int = 2, n_multiply: int = 6, seed: int = 0,
              iters_per_step: int = 2) -> dict:
    """Mixed solve + multiply traffic: the data-dependent-length request kind.

    One CG solve (unknown-many scheduling turns: it retires on a residual
    test, not a known chain depth) rides the SAME service as a stream of
    multiply requests.  The acceptance points this row records:

      * kind alternation keeps the multiplies flowing WHILE the solve is in
        flight (``multiplies_done_mid_solve`` > 0 — no starvation either way);
      * the solve retires mid-stream the moment its residual crosses tol —
        not at a padded max_iters — freeing its host budget
        (``solve_iterations`` < max_iters);
      * per-kind iteration metrics split the work
        (``kind_iterations['solve']`` == solve iterations dispatched);
      * the served solution matches the plain-jnp :func:`cg_reference_solve`
        oracle on the identical problem.
    """
    from benchmarks.cg_solve import _problem
    from repro.core.su3.plan import CG_SHIFT, cg_reference_solve

    rng = np.random.default_rng(seed)
    n_sites = L**4
    svc = SU3Service(ServiceConfig(
        autotune=False, tile=min(TILE, n_sites),
        solve_iters_per_step=iters_per_step,
        batcher=BatcherConfig(
            max_batch=4, warm_batch_sizes=(1, 2, 4), max_queue_depth=64,
        ),
    ))
    u, b = _problem(L)
    tol = 1e-6
    max_iters = 64
    solve_id = svc.submit_solve(u, b, tol=tol, max_iters=max_iters)
    mult_ids = [svc.submit(*_random_request(rng, n_sites), k=1)
                for _ in range(n_multiply)]

    solve_x = None
    solve_done_step = None
    mult_done_mid_solve = 0
    steps = 0
    t0 = time.perf_counter()
    while svc.pending():
        steps += 1
        svc.step()
        for rid, out in svc.pop_ready().items():
            if rid == solve_id:
                solve_done_step = steps
                solve_x = out
            elif solve_done_step is None:
                mult_done_mid_solve += 1
    wall = time.perf_counter() - t0

    x_ref, _, _ = cg_reference_solve(u, b, L, sigma=CG_SHIFT, tol=tol,
                                     max_iters=max_iters)
    err = float(jnp.max(jnp.abs(solve_x - x_ref))) / max(
        float(jnp.max(jnp.abs(x_ref))), 1e-30)
    snap = svc.metrics.snapshot()
    kind_iters = snap.get("kind_iterations", {})
    solve_iters = kind_iters.get("solve", 0)
    return {
        "name": "serve_solve_mix",
        "L": L,
        "n_multiply": n_multiply,
        "solve_iters_per_step": iters_per_step,
        "tol": tol,
        "max_iters": max_iters,
        "steps": steps,
        "wall_s": round(wall, 3),
        "solve_retired_step": solve_done_step,
        "solve_iterations": solve_iters,
        "solve_retired_early": 0 < solve_iters < max_iters,
        "multiplies_done_mid_solve": mult_done_mid_solve,
        "kinds_interleaved": mult_done_mid_solve > 0,
        "kind_iterations": kind_iters,
        "completed": snap["completed"],
        "solve_max_rel_err_vs_reference": round(err, 9),
        "solve_matches_reference": err < 1e-5,
    }


def run(quick: bool = True, seed: int = 0, use_autotune: bool = False) -> list[dict]:
    """The ``serve`` benchmark section (wired into benchmarks.run)."""
    if quick:
        Ls, ks, n_req, users, rounds = (2, 4), (1, 2), 32, 8, 2
    else:
        Ls, ks, n_req, users, rounds = (2, 4), (1, 2, 4), 96, 8, 4
    rows = [
        open_loop(n_req, Ls, ks, seed, use_autotune=use_autotune),
        closed_loop(users, rounds, max(Ls), None if use_autotune else max(ks),
                    seed, use_autotune=use_autotune),
        continuous_comparison(min(Ls), n_requests=16 if quick else 48, seed=seed),
        dispatch_overhead(Ls, n_requests=12 if quick else 32, seed=seed),
        bf16_plan_comparison(max(Ls), seed),
        traced_serving(min(Ls), n_requests=12 if quick else 32, seed=seed),
        solve_mix(min(Ls), n_multiply=4 if quick else 8, seed=seed),
    ]
    return rows


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--autotune", action="store_true",
                    help="build pools through the persistent autotune cache "
                         "(first run pays the tile+K sweeps)")
    args = ap.parse_args(argv)
    rows = run(quick=args.quick, seed=args.seed, use_autotune=args.autotune)
    ok = True
    for r in rows:
        print(r)
        if r["name"] == "serve_open_loop" and r["mean_live_batch"] <= 1.0:
            print("FAIL: open-loop batch occupancy did not exceed 1", file=sys.stderr)
            ok = False
        if r["name"] == "serve_continuous_vs_batch" and not r["continuous_higher_occupancy"]:
            print("FAIL: continuous batching did not beat batch-per-step "
                  "occupancy under open-loop load", file=sys.stderr)
            ok = False
        if r["name"] == "serve_continuous_vs_batch" and not (
            r["megakernel_single_dispatch_per_iteration"]
            and r["megakernel_occupancy_no_worse"]
        ):
            print("FAIL: megakernel did not hold 1 dispatch/host/iteration "
                  "at no-worse occupancy", file=sys.stderr)
            ok = False
        if r["name"] == "serve_dispatch_overhead" and not r["megakernel_fewer_dispatches"]:
            print("FAIL: megakernel did not reduce mixed-L dispatch count",
                  file=sys.stderr)
            ok = False
        if r["name"] == "serve_bf16_vs_f32" and not (
            r["bf16_fewer_bytes"] and r["within_1e-2"] and r["bf16_verified"]
        ):
            print("FAIL: bf16-storage plan acceptance", file=sys.stderr)
            ok = False
        if r["name"] == "serve_solve_mix" and not (
            r["solve_retired_early"] and r["kinds_interleaved"]
            and r["solve_matches_reference"]
        ):
            print("FAIL: solve-mix acceptance (early retire / interleave / "
                  "reference match)", file=sys.stderr)
            ok = False
        if r["name"] == "serve_traced" and not (
            r["lifecycle_covered"] and r["phases_covered"]
        ):
            print("FAIL: trace did not cover the request lifecycle and the "
                  "stencil exchange/interior/boundary phases", file=sys.stderr)
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""CG solver benchmark: residual-vs-time + per-iteration GFLOPS (``cg``).

The flagship iterative workload: the shifted staggered solve
``(sigma I + S) x = b`` through ``ExecutionPlan.cg_solve``.  Three row
families land in ``BENCH_su3.json`` under ``cg``:

  headline row   ``cg_residual_vs_time`` — the fused f32 solve on the
                 reference constant-per-direction SU(3) problem, one
                 ``(t_ms, rel_res)`` sample per iteration (each iteration
                 synced so the samples are honest walls), with
                 ``iters_to_tol`` at tol=1e-6.  ``scripts/bench_diff.py``
                 gates on this row: a diff that needs >10% more iterations
                 to the same tol than the committed artifact fails.
  grid rows      ``cg_iter_L{L}_{layout}_{dtype}[_acc][_two_row]_{fused|
                 composed}`` — per-iteration GFLOPS (useful flops =
                 ``CG_ITER_FLOPS_PER_SITE``/site) across the layout x dtype
                 x compression grid, fused vs composed.  ``verified`` means
                 fused matched composed BITWISE at f32 storage (the
                 bit-identity contract) / within ``plan.verify_tolerance``
                 at bf16.
  tuned row      ``cg_tuned`` — the ``autotune.best_cg_config`` decision
                 (tile, fused) with its provenance; persisted under the
                 dedicated ``soa-cg-h{hosts}`` cache key so the CG tuple
                 never aliases the multiply or stencil decisions.

Standalone CLI:  PYTHONPATH=src python -m benchmarks.cg_solve --quick
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.su3.layouts import Layout
from repro.core.su3.plan import (
    CG_SHIFT,
    CGMaxItersError,
    EngineConfig,
    build_plan,
    verify_tolerance,
)
from repro.kernels.su3_stencil import CG_ITER_FLOPS_PER_SITE

TOL_F32 = 1e-6
TOL_BF16 = 2e-2  # bf16 storage stalls near its rounding floor (~1e-2)
MAX_ITERS = 64


def _problem(L: int, seed: int = 7):
    """The deterministic convergent solve problem (same construction the
    autotuner measures on): constant-per-direction SU(3) links — exactly
    Hermitian under the site-local-adjoint stencil — and a unit-scale b."""
    return autotune._cg_measure_problem(L, seed)


def _build(L: int, layout: Layout, dtype: str, accum: str, compression: str,
           tile: int):
    cfg = EngineConfig(
        L=L, dtype=dtype, accum_dtype=accum, layout=layout, tile=tile,
        iterations=1, warmups=0, compression=compression,
    )
    plan = build_plan(cfg)
    u, b = _problem(L)
    return plan, plan.pack_gauge(u), plan.pack_rhs(b)


def residual_vs_time_row(L: int, tile: int, tol: float = TOL_F32) -> dict:
    """The headline row: per-iteration (wall, relative residual) samples of
    the fused f32 solve.  Each iteration is synced before the clock is read,
    so the series is a true residual-vs-time curve, not a dispatch queue."""
    plan, u_phys, b_p = _build(L, Layout.SOA, "float32", "", "none", tile)
    # warm/compile one throwaway solve so the curve measures iterations,
    # not the first-call compile
    plan.cg_solve(u_phys, b_p, tol=tol, max_iters=MAX_ITERS)

    state = plan.cg_state_init(b_p)
    b_rs = float(jax.device_get(state["rs"]))
    stop2 = tol * tol * b_rs
    series: list[tuple[float, float]] = []
    t0 = time.perf_counter()
    iters = 0
    while iters < MAX_ITERS:
        state = plan.cg_iterate(u_phys, state)
        rs = float(jax.device_get(state["rs"]))  # syncs the iteration
        iters += 1
        series.append(
            (round((time.perf_counter() - t0) * 1e3, 4),
             float((rs / b_rs) ** 0.5))
        )
        if rs <= stop2:
            break
    wall = time.perf_counter() - t0
    n_sites = L**4
    return {
        "name": "cg_residual_vs_time",
        "us_per_call": round(wall / iters * 1e6, 1),
        "L": L, "tile": tile, "dtype": "float32", "fused": True,
        "sigma": CG_SHIFT, "tol": tol,
        "iters_to_tol": iters,
        "converged": series[-1][1] <= tol,
        "final_rel_residual": series[-1][1],
        "residual_vs_time_ms": series,
        "GFLOPS": round(
            CG_ITER_FLOPS_PER_SITE * n_sites * iters / wall / 1e9, 3),
        "flops_per_site_per_iter": CG_ITER_FLOPS_PER_SITE,
    }


def _grid_row(L: int, layout: Layout, dtype: str, accum: str,
              compression: str, tile: int, fused: bool) -> dict:
    tol = TOL_BF16 if dtype == "bfloat16" else TOL_F32
    plan, u_phys, b_p = _build(L, layout, dtype, accum, compression, tile)
    acc_tag = f"_acc-{accum}" if accum else ""
    comp_tag = "_two_row" if compression == "two_row" else ""
    name = (f"cg_iter_L{L}_{layout.value}_{dtype}{acc_tag}{comp_tag}_"
            f"{'fused' if fused else 'composed'}")
    try:
        plan.cg_solve(u_phys, b_p, tol=tol, max_iters=MAX_ITERS, fused=fused)
        res = plan.cg_solve(u_phys, b_p, tol=tol, max_iters=MAX_ITERS,
                            fused=fused)
        converged, iters, final = True, res.iterations, res.residuals[-1]
        x = res.x_p
        wall = res.wall_s
    except CGMaxItersError as e:
        # bf16 can stall above a too-ambitious tol; the row still reports
        # the measured iteration throughput
        converged, iters, final, x, wall = False, e.iterations, e.residual, None, 0.0
    if not wall:
        # re-time a fixed iteration count when the solve path didn't
        t0 = time.perf_counter()
        state = plan.cg_state_init(b_p)
        for _ in range(iters):
            state = plan.cg_iterate(u_phys, state, fused=fused)
        jax.block_until_ready(state["rs"])
        wall = time.perf_counter() - t0
        x = state["x"]
    verified = True
    if fused:
        try:
            oracle = plan.cg_solve(u_phys, b_p, tol=tol, max_iters=MAX_ITERS,
                                   fused=False)
            if dtype == "float32":
                verified = bool(jnp.array_equal(x, oracle.x_p))
            else:
                verified = abs(final - oracle.residuals[-1]) <= verify_tolerance(
                    dtype, accum, reconstruct=compression == "two_row")
        except CGMaxItersError:
            verified = not converged  # both paths stalled the same way
    n_sites = L**4
    return {
        "name": name,
        "us_per_call": round(wall / max(iters, 1) * 1e6, 1),
        "L": L, "layout": layout.value, "dtype": dtype,
        "accum_dtype": accum or dtype, "compression": compression,
        "tile": tile, "fused": fused, "tol": tol,
        "iterations": iters, "converged": converged,
        "final_rel_residual": float(final),
        "GFLOPS": round(
            CG_ITER_FLOPS_PER_SITE * n_sites * max(iters, 1) / wall / 1e9, 3),
        "verified": verified,
    }


def tuned_row(L: int, quick: bool) -> dict:
    """The persisted CG tuning decision (its own cache key segment)."""
    cfg = autotune.best_cg_config(
        L=L,
        measure_fn=lambda c: autotune.measure_cg_candidate(
            c, L=L, iters=2 if quick else 4),
    )
    return {
        "name": "cg_tuned",
        "L": L, "tile": cfg["tile"], "fused": cfg["fused"],
        "variant": cfg["variant"], "cached": cfg.get("cached", False),
        "cache_layout_segment": f"soa-cg-h{cfg['cg'].get('hosts', 1)}",
        **{f"cg_{k}": v for k, v in cfg["cg"].items()},
    }


def run(quick: bool = True) -> list[dict]:
    L = 4 if quick else 8
    tile = min(128, L**3)
    rows = [residual_vs_time_row(L, tile)]
    grid = [
        (Layout.SOA, "float32", "", "none"),
        (Layout.SOA, "bfloat16", "float32", "none"),
        (Layout.SOA, "float32", "", "two_row"),
        (Layout.AOSOA, "float32", "", "none"),
    ]
    for layout, dtype, accum, compression in grid:
        for fused in (True, False):
            rows.append(_grid_row(L, layout, dtype, accum, compression,
                                  tile, fused))
    rows.append(tuned_row(L, quick))
    return rows


if __name__ == "__main__":
    for r in run(quick="--quick" in sys.argv[1:]):
        print({k: v for k, v in r.items() if k != "residual_vs_time_ms"})

"""Chaos verification benchmark (the ``chaos`` section): a seeded fault
storm over a mixed request population, gated on the robustness contract.

One :func:`repro.chaos.storm` plan (dispatch fail/delay + kernel NaN/Inf
poison + warm-pool build failures, bounded by ``max_fires`` so the storm
*ends* and recovery is observable) is driven over a closed-loop mix of
multiply requests plus one CG solve, against the same service config that
serves the clean baseline.  The row records — and ``main``/
``scripts/bench_diff.py`` gate on — the ISSUE 9 acceptance points:

  zero lost requests    every submitted request resolves: a result, a
                        structured error (RetriesExhausted / CGDiverged),
                        or a structured timeout — nothing hangs, nothing
                        silently drops;
  bitwise clean         every request that *succeeded* under the storm
                        returns a result bitwise identical to the
                        fault-free baseline (retried dispatches re-run
                        the same compiled path on the same inputs);
  bounded p99           the storm may inflate tail latency by retries and
                        backoff, but only boundedly (default ceiling
                        ``P99_INFLATION_CEILING`` x the clean p99);
  recovery              seconds from each injected fault to the next
                        completed request — the storm's max_fires bound
                        makes "the service came back" a measurable number;
  same-seed reproduction  the identical replay under ``FaultPlan.reset()``
                        (same seed, same specs) fires the same faults in
                        the same per-site order — a chaos failure is a
                        bug report, not a shrug.

Fault provenance rides in the row: ``plan.describe()`` (seed + per-site
schedule) plus the full fired log, so any artifact number produced under
injection names the exact faults behind it.

Standalone CLI:

    PYTHONPATH=src python -m benchmarks.serve_chaos --quick
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

import jax.numpy as jnp

from repro.chaos import FaultPlan, storm
from repro.serve.su3 import BatcherConfig, ServiceConfig, SU3Service
from repro.serve.su3.robustness import RequestFailure, RetryPolicy

TILE = 128
P99_INFLATION_CEILING = 25.0  # chaos p99 may cost retries, not a meltdown
# Backoffs far below one dispatch time: retries rejoin the queue by the
# next step, so the storm's ask schedule (and therefore its fired-fault
# log) is reproducible run-to-run — the same-seed gate depends on it.
RETRY = RetryPolicy(max_retries=6, base_s=1e-6, cap_s=1e-5, jitter=0.2,
                    budget=512)


def _random_request(rng: np.random.Generator, n_sites: int):
    a = rng.standard_normal((n_sites, 4, 3, 3, 2)).astype(np.float32)
    b = rng.standard_normal((4, 3, 3, 2)).astype(np.float32)
    return (
        jnp.asarray(a[..., 0] + 1j * a[..., 1], jnp.complex64),
        jnp.asarray(b[..., 0] + 1j * b[..., 1], jnp.complex64),
    )


def _service(L: int, faults: FaultPlan | None) -> SU3Service:
    return SU3Service(ServiceConfig(
        autotune=False, tile=min(TILE, L**4), faults=faults, retry=RETRY,
        solve_iters_per_step=4,
        batcher=BatcherConfig(
            max_batch=4, warm_batch_sizes=(1, 2, 4), max_queue_depth=256,
        ),
    ))


def _replay(svc: SU3Service, population: list, solve_problem, tol: float,
            max_iters: int) -> dict:
    """Submit the whole mix up-front, drain, and account every request.

    Returns resolved results keyed by submission index (arrays or
    structured failure objects), per-fault recovery samples, and the
    service metrics snapshot.  Closed-loop submission keeps the dispatch
    schedule deterministic, which is what makes the same-seed fired-log
    comparison an end-to-end gate rather than a unit test.
    """
    ids = []
    if solve_problem is not None:
        u, b = solve_problem
        ids.append(("solve", svc.submit_solve(u, b, tol=tol,
                                              max_iters=max_iters)))
    for a, b in population:
        ids.append(("multiply", svc.submit(a, b, k=2)))

    resolved: dict[int, object] = {}
    pending_fault_t: list[float] = []
    recovery: list[float] = []
    t0 = time.perf_counter()
    steps = 0
    while svc.pending() and steps < 20_000:
        steps += 1
        n_faults0 = svc.metrics.faults_injected
        svc.step()
        now = time.perf_counter()
        pending_fault_t.extend([now] * (svc.metrics.faults_injected - n_faults0))
        ready = svc.pop_ready()
        if ready:
            resolved.update(ready)
            if pending_fault_t:
                recovery.extend(now - t for t in pending_fault_t)
                pending_fault_t.clear()
    resolved.update(svc.pop_ready())
    wall = time.perf_counter() - t0
    return {
        "ids": ids,
        "resolved": resolved,
        "recovery_s": recovery,
        "unrecovered_faults": len(pending_fault_t),
        "wall_s": wall,
        "snapshot": svc.metrics.snapshot(),
    }


def _storm_plan(seed: int) -> FaultPlan:
    return storm(seed, dispatch_p=0.35, kernel_p=0.35, pool_p=0.5,
                 max_fires=4, delay_s=0.002)


def _log_key(entry: dict) -> tuple:
    # ctx is call-site metadata (host ids, kinds) and seq is the global
    # interleave; the determinism contract is per-site: same seed + same
    # per-site ask schedule => same (site, action, site_seq) sequence
    return (entry["site"], entry["action"], entry["site_seq"])


def fault_storm(L: int = 2, n_multiply: int = 20, seed: int = 0) -> dict:
    """The ``serve_chaos`` row: baseline replay, storm replay, repro replay."""
    from benchmarks.cg_solve import _problem

    rng = np.random.default_rng(seed)
    n_sites = L**4
    population = [_random_request(rng, n_sites) for _ in range(n_multiply)]
    solve_problem = _problem(L)
    tol, max_iters = 1e-6, 64

    def run_one(faults: FaultPlan | None) -> tuple[dict, SU3Service]:
        svc = _service(L, faults)
        svc.warm((L,), ks=(2,), batch_sizes=svc.cfg.batcher.warm_batch_sizes)
        svc.metrics.reset()
        return _replay(svc, population, solve_problem, tol, max_iters), svc

    base, _ = run_one(None)
    plan = _storm_plan(seed)
    chaos, chaos_svc = run_one(plan)
    replay_plan = plan.reset()
    rerun, _ = run_one(replay_plan)

    # -- zero lost: every id resolved as a result or a structured failure --
    def account(run: dict) -> tuple[int, int, dict[str, int], bool]:
        ok = failed = 0
        by_type: dict[str, int] = {}
        lost = False
        for _kind, rid in run["ids"]:
            out = run["resolved"].get(rid, None)
            if out is None:
                lost = True
            elif isinstance(out, Exception):
                if not isinstance(out, (RequestFailure, RuntimeError)):
                    lost = True  # an unstructured escape is a lost request
                failed += 1
                t = type(out).__name__
                by_type[t] = by_type.get(t, 0) + 1
            else:
                ok += 1
        return ok, failed, by_type, lost

    ok_n, failed_n, failed_by_type, lost = account(chaos)
    ok2, failed2, _, lost2 = account(rerun)
    zero_lost = (not lost) and (not lost2)

    # -- bitwise identity: chaos successes vs the fault-free baseline ------
    clean_bitwise = True
    compared = 0
    for (_k, rid_b), (_k2, rid_c) in zip(base["ids"], chaos["ids"]):
        out_b = base["resolved"].get(rid_b)
        out_c = chaos["resolved"].get(rid_c)
        if isinstance(out_b, Exception) or isinstance(out_c, Exception):
            continue
        if out_b is None or out_c is None:
            continue
        compared += 1
        if not bool(jnp.array_equal(out_b, out_c)):
            clean_bitwise = False

    # -- same-seed reproduction: fired logs agree per site -----------------
    log1 = [_log_key(e) for e in plan.log()]
    log2 = [_log_key(e) for e in replay_plan.log()]
    same_seed = sorted(log1) == sorted(log2) and len(log1) > 0

    p99_base = base["snapshot"]["latency_p99_ms"]
    p99_chaos = chaos["snapshot"]["latency_p99_ms"]
    inflation = p99_chaos / max(p99_base, 1e-9)
    recovery = chaos["recovery_s"]
    snap = chaos["snapshot"]
    return {
        "name": "serve_chaos",
        "L": L,
        "seed": seed,
        "n_multiply": n_multiply,
        "n_solve": 1,
        "tol": tol,
        "max_iters": max_iters,
        "storm": plan.describe(),
        "faults_fired": plan.fired,
        "fired_by_site": plan.fired_by_site(),
        "fault_log": plan.log(),
        "completed_ok": ok_n,
        "failed_structured": failed_n,
        "failed_by_type": failed_by_type,
        "zero_lost": zero_lost,
        "compared_results": compared,
        "clean_results_bitwise": clean_bitwise,
        "latency_p99_ms_baseline": p99_base,
        "latency_p99_ms_chaos": p99_chaos,
        "p99_inflation": round(inflation, 3),
        "p99_inflation_bounded": inflation <= P99_INFLATION_CEILING,
        "recovery_max_s": round(max(recovery), 6) if recovery else 0.0,
        "recovery_mean_s": round(float(np.mean(recovery)), 6) if recovery else 0.0,
        "recovered_faults": len(recovery),
        "unrecovered_faults": chaos["unrecovered_faults"],
        "same_seed_reproduces": same_seed,
        "rerun_completed_ok": ok2,
        "rerun_failed_structured": failed2,
        "retries": snap["retries"],
        "retries_exhausted": snap["retries_exhausted"],
        "timeouts": snap["timeouts"],
        "shed": snap["shed"],
        "quarantines": snap["quarantines"],
        "degraded_dispatches": snap["degraded_dispatches"],
        "wall_s_baseline": round(base["wall_s"], 3),
        "wall_s_chaos": round(chaos["wall_s"], 3),
        "health": chaos_svc.health.snapshot(),
    }


def gate_problems(row: dict) -> list[str]:
    """The acceptance checks ``main`` and bench_diff's chaos gate share."""
    problems = []
    if row.get("error"):
        return [f"serve_chaos: row errored: {row['error']}"]
    if row.get("faults_fired", 0) <= 0:
        problems.append("serve_chaos: the storm fired no faults — the row "
                        "proves nothing")
    if row.get("zero_lost") is not True:
        problems.append("serve_chaos: LOST REQUESTS — a submitted request "
                        "resolved as neither result nor structured failure")
    if row.get("clean_results_bitwise") is not True:
        problems.append("serve_chaos: a request that succeeded under the "
                        "storm is NOT bitwise identical to the fault-free "
                        "baseline")
    if row.get("same_seed_reproduces") is not True:
        problems.append("serve_chaos: the same seed did NOT reproduce the "
                        "same fault sequence")
    if row.get("p99_inflation_bounded") is not True:
        problems.append(
            f"serve_chaos: p99 inflation {row.get('p99_inflation')}x exceeds "
            f"the {P99_INFLATION_CEILING}x ceiling")
    return problems


def run(quick: bool = True, seed: int = 0) -> list[dict]:
    """The ``chaos`` benchmark section (wired into benchmarks.run)."""
    n = 12 if quick else 32
    return [fault_storm(L=2, n_multiply=n, seed=seed)]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    rows = run(quick=args.quick, seed=args.seed)
    ok = True
    for r in rows:
        print(r)
        for p in gate_problems(r):
            print(f"FAIL: {p}", file=sys.stderr)
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Paper Fig. 7 analog: strong scaling of VersionX over (host, device) meshes.

Historically each point ran in its own child process (device count locks at
first jax init, so a fresh process per count).  Now the whole curve is ONE
multi-controller dry-run launch through ``repro.launch.dryrun --su3-fig7``:

  * one launch spawns N identical controller processes over a forced device
    pool covering ``max(device_counts)``;
  * inside each controller every point slices its mesh from that pool via
    ``repro.launch.mesh.MeshSpec`` — the real ``build_plan`` (host, device)
    path with per-host first-touch init, not a bespoke benchmark harness;
  * the launcher byte-compares every point's result lattice against the
    single-host reference across ALL controllers and fails the launch on
    divergence.

Both placement policies are measured — the paper's with/without-empty-
constructor pair.  Row names stay ``fig7_{placement}_d{n}`` so the
``scripts/bench_diff.py`` trajectory is unbroken.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def run(
    L: int = 8,
    device_counts: tuple[int, ...] = (1, 2, 4),
    hosts: int = 2,
    controllers: int = 2,
) -> list[dict]:
    """One multi-controller launch; returns controller 0's benchmark rows.

    Args:
        L: lattice extent per point.
        device_counts: mesh sizes to sweep (each sliced from one pool).
        hosts: host-axis size of each point's MeshSpec (capped at the
            point's device count; d1 stays the legacy single-host mesh).
        controllers: identical controller processes to launch and
            divergence-check.

    Returns:
        Rows named ``fig7_{placement}_d{n}`` (error row on launch failure).
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun", "--su3-fig7",
        "--L", str(L),
        "--device-counts", ",".join(str(n) for n in device_counts),
        "--hosts", str(hosts),
        "--controllers", str(controllers),
    ]
    out = subprocess.run(
        cmd, capture_output=True, text=True, env=env, timeout=900, cwd=ROOT,
    )
    if out.returncode != 0:
        return [{
            "name": "fig7_launch_error",
            "error": (out.stderr or out.stdout)[-300:],
        }]
    # rows are the last JSON line on stdout (workers' chatter goes to stderr)
    last = out.stdout.strip().splitlines()[-1]
    return json.loads(last)


if __name__ == "__main__":
    for r in run():
        print(r)

"""Paper Fig. 7 analog: strong scaling of VersionX over device counts.

Each point runs in a subprocess with XLA_FLAGS host-device-count (device
count locks at first jax init). Both placement policies are measured —
the paper's with/without-empty-constructor pair.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
from repro.core.su3.engine import EngineConfig, SU3Engine
cfg = EngineConfig(L=int(sys.argv[3]), variant="versionX", placement=sys.argv[2],
                   iterations=3, warmups=1, tile=128)
r = SU3Engine(cfg).run()
print(json.dumps(r.row()))
"""


def run(L: int = 8, device_counts: tuple[int, ...] = (1, 2, 4)) -> list[dict]:
    rows = []
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    for n in device_counts:
        for placement in ("sharded", "host_scatter"):
            out = subprocess.run(
                [sys.executable, "-c", _CHILD, str(n), placement, str(L)],
                capture_output=True, text=True, env=env, timeout=300,
            )
            if out.returncode != 0:
                rows.append({"name": f"fig7_{placement}_d{n}", "error": out.stderr[-200:]})
                continue
            row = json.loads(out.stdout.strip().splitlines()[-1])
            row["name"] = f"fig7_{placement}_d{n}"
            rows.append(row)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

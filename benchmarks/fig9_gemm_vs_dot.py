"""Paper Fig. 9 analog: explicit GEMM vs compiler-autovectorized dot.

The paper's §4 finding: hand-unrolled FMA GEMM wins 1.6-1.8x at low core
counts and the gap closes once bandwidth saturates. Here: versionX
(compiler does everything) vs version_gemm (explicit unroll) vs the pallas
kernel across lattice sizes."""
from __future__ import annotations

from repro.core.su3.engine import EngineConfig, SU3Engine
from repro.core.su3.layouts import Layout


def run(sizes: tuple[int, ...] = (4, 8)) -> list[dict]:
    rows = []
    for L in sizes:
        for variant, layout in (("versionX", Layout.SOA), ("version_gemm", Layout.SOA),
                                ("pallas", Layout.SOA)):
            cfg = EngineConfig(L=L, variant=variant, layout=layout,
                               iterations=3, warmups=1, tile=128)
            r = SU3Engine(cfg).run()
            row = r.row()
            row["name"] = f"fig9_{variant}_L{L}"
            rows.append(row)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Stencil benchmark: GFLOPS + overlap efficiency of the Dslash-style path.

The first workload in this repo where halo traffic actually moves.  Four
row families land in ``BENCH_su3.json`` under ``stencil``:

  measured rows   ``stencil_L{L}_{dtype}[_acc][_two_row]_{overlap|serial}`` —
                  wall-time GFLOPS (useful flops = 576/site) of the
                  overlapped vs non-overlapped ``ExecutionPlan.stencil_step``
                  on the local mesh, verified against the uniform fixed
                  point.  ``_two_row`` rows stream the 12-real compressed
                  gauge field (102 words/site instead of 150) and carry the
                  smaller ``bytes_per_site`` — the acceptance bar's
                  bandwidth reduction is read straight off these rows.
  roofline rows   ``stencil_roofline_h{hosts}_{serial|overlap|overlap_d2}
                  [_two_row]`` — the halo-charging model
                  (autotune.predict_stencil) at 1/2/4 hosts across the
                  (overlap, depth) schedule grid.  The bandwidth term
                  INCLUDES the vector-field halo bytes amortized over the
                  exchange depth (``bandwidth_bytes = streamed +
                  halo/depth``).
  overlap row     ``stencil_overlap_identity`` — a forced-device 2-host
                  subprocess runs both schedules on a real sharded mesh and
                  reports bit-identity plus the measured overlap efficiency
                  (t_serial / t_overlap).  On CPU interpret the three
                  dispatches serialize, so efficiency ~<= 1 here; the
                  schedule claim on CPU is dispatch-ORDER only — see
                  ROADMAP for the TPU validation item.
  attribution     ``stencil_phase_attribution_h{hosts}_d{depth}`` — the
                  traced schedule's per-phase seconds (exchange / interior
                  / boundary spans, ``repro.obs``) joined against
                  ``predict_stencil`` at the SAME (overlap, depth, hosts)
                  config: measured-vs-modeled delta and which term
                  dominates.  The identity row additionally carries
                  ``overlap_efficiency_measured = sum_phases /
                  t_overlap_untraced`` — the phase-accounted form of the
                  efficiency the untraced walls can only infer.
  depth-2 rows    ``stencil_depth2_identity_h{hosts}`` — a forced-device
                  subprocess builds 1/2/4-host meshes and checks the
                  communication-avoiding depth-2 step (ONE widened exchange,
                  TWO stencil applications, intermediate ghost ring
                  recomputed locally) bit-identical to two depth-1 steps,
                  for both the 18-real and two-row compressed plans.

Standalone CLI:  PYTHONPATH=src python -m benchmarks.stencil --quick
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from repro.core import autotune
from repro.core.su3.layouts import WORD_BYTES, Layout
from repro.core.su3.plan import EngineConfig, build_plan
from repro.kernels.su3_stencil import (
    STENCIL_COMP_WORDS_PER_SITE,
    STENCIL_FLOPS_PER_SITE,
    STENCIL_WORDS_PER_SITE,
)

# prefixed with an `L, tile, reps = ...` line by _overlap_identity_row (the
# template itself contains JSON braces, so str.format is off the table)
_OVERLAP_SUBPROC = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np, jax
from repro.core.su3.plan import EngineConfig, build_plan
from repro.launch.mesh import MeshSpec

cfg = EngineConfig(L=L, tile=tile, iterations=1, warmups=0)
plan = build_plan(cfg, MeshSpec(hosts=2, devices_per_host=1))
u, v = plan.init_stencil_data()
serial, overlap = plan.stencil_step(overlap=False), plan.stencil_step(overlap=True)
r_s, r_o = serial(u, v), overlap(u, v)  # warm both
r_s.block_until_ready(); r_o.block_until_ready()
identical = bool(np.array_equal(np.asarray(jax.device_get(r_s)),
                                np.asarray(jax.device_get(r_o))))
def best(step):
    t = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter(); step(u, v).block_until_ready()
        t = min(t, time.perf_counter() - t0)
    return t
t_serial, t_overlap = best(serial), best(overlap)
# traced passes AFTER the untraced timings: per-phase spans synchronize at
# phase boundaries (repro.obs), so they measure the phases, not the hiding
from repro.obs import Tracer
plan.tracer = Tracer(enabled=True, capacity=4096)
for _ in range(reps):
    overlap(u, v)
print(json.dumps({
    "identical": identical, "verified": bool(plan.verify_stencil(r_o)),
    "t_serial_s": t_serial, "t_overlap_s": t_overlap,
    "halo": plan.stencil_halo().as_dict(),
    "spans": [s.as_dict() for s in plan.tracer.spans()],
}))
"""

# prefixed with `L, tile, reps = ...`; 4 forced devices cover 1/2/4-host
# meshes in one process (the forced count locks at first jax init)
_DEPTH2_SUBPROC = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.core.su3.plan import EngineConfig, build_plan
from repro.launch.mesh import MeshSpec

rows = []
for hosts in (1, 2, 4):
    for compression in ("none", "two_row"):
        cfg = EngineConfig(L=L, tile=tile, iterations=1, warmups=0,
                           compression=compression)
        mesh = None if hosts == 1 else MeshSpec(hosts=hosts, devices_per_host=1)
        plan = build_plan(cfg, mesh)
        u, v = plan.init_stencil_data()
        step1 = plan.stencil_step(overlap=hosts > 1, depth=1)
        step2 = plan.stencil_step(overlap=hosts > 1, depth=2)
        two = step1(u, step1(u, v)); two.block_until_ready()
        one = step2(u, v); one.block_until_ready()
        identical = bool(np.array_equal(np.asarray(jax.device_get(one)),
                                        np.asarray(jax.device_get(two))))
        def best(fn):
            t = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter(); fn().block_until_ready()
                t = min(t, time.perf_counter() - t0)
            return t
        t2x1 = best(lambda: step1(u, step1(u, v)))
        t1x2 = best(lambda: step2(u, v))
        rows.append({
            "hosts": hosts, "compression": compression,
            "identical": identical,
            "t_two_depth1_s": t2x1, "t_one_depth2_s": t1x2,
            "halo_d2": plan.stencil_halo(depth=2).as_dict(),
        })
print(json.dumps(rows))
"""


def _stencil_bytes_per_site(dtype: str, compression: str) -> int:
    words = (STENCIL_COMP_WORDS_PER_SITE if compression == "two_row"
             else STENCIL_WORDS_PER_SITE)
    return words * WORD_BYTES[dtype]


def _measure_row(L: int, dtype: str, accum: str, overlap: bool, tile: int,
                 reps: int, compression: str = "none") -> dict:
    cfg = EngineConfig(L=L, dtype=dtype, accum_dtype=accum, layout=Layout.SOA,
                       tile=tile, iterations=1, warmups=0,
                       compression=compression)
    plan = build_plan(cfg)
    step = plan.stencil_step(overlap=overlap)
    u, v = plan.init_stencil_data()
    out = step(u, v)
    out.block_until_ready()  # warm/compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        step(u, v).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    n_sites = L**4
    acc_tag = f"_acc-{accum}" if accum else ""
    comp_tag = "_two_row" if compression == "two_row" else ""
    sched = "overlap" if overlap else "serial"
    return {
        "name": f"stencil_L{L}_{dtype}{acc_tag}{comp_tag}_{sched}",
        "us_per_call": round(best * 1e6, 1),
        "L": L, "dtype": dtype, "accum_dtype": accum or dtype,
        "compression": compression,
        "overlap": overlap, "tile": tile,
        "GFLOPS": round(STENCIL_FLOPS_PER_SITE * n_sites / best / 1e9, 3),
        "bytes_per_site": _stencil_bytes_per_site(dtype, compression),
        "bandwidth_bytes": _stencil_bytes_per_site(dtype, compression) * n_sites,
        "verified": plan.verify_stencil(out),
        "plan": plan.describe(),
    }


def _roofline_rows(L: int, dtype: str) -> list[dict]:
    rows = []
    for compression in ("none", "two_row"):
        comp_tag = "_two_row" if compression == "two_row" else ""
        for hosts in (1, 2, 4):
            for overlap, depth in ((False, 1), (True, 1), (True, 2)):
                pred = autotune.predict_stencil(
                    autotune.StencilCandidate(
                        tile=min(256, L**3), overlap=overlap, depth=depth),
                    L=L, dtype=dtype, hosts=hosts, compression=compression,
                )
                sched = ("overlap_d2" if depth == 2
                         else "overlap" if overlap else "serial")
                rows.append({
                    "name": f"stencil_roofline_h{hosts}_{sched}{comp_tag}",
                    "bytes_per_site": _stencil_bytes_per_site(dtype, compression),
                    **pred,
                })
    return rows


def _subprocess_json(code: str, timeout: int = 600) -> tuple[dict | list | None, str]:
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=timeout, cwd=root,
    )
    if proc.returncode != 0:
        return None, proc.stderr.strip()[-300:]
    return json.loads(proc.stdout.strip().splitlines()[-1]), ""


def _overlap_identity_row(L: int, tile: int, reps: int) -> dict:
    """Forced-device 2-host schedule comparison (subprocess: the forced
    device count locks at first jax init, exactly like the fig7 dryrun)."""
    code = f"L, tile, reps = {L}, {tile}, {reps}\n" + _OVERLAP_SUBPROC
    payload, err = _subprocess_json(code)
    if payload is None:
        return {"name": "stencil_overlap_identity", "error": err}
    eff = payload["t_serial_s"] / payload["t_overlap_s"]
    row = {
        "name": "stencil_overlap_identity",
        "hosts": 2, "L": L, "tile": tile,
        "identical": payload["identical"],
        "verified": payload["verified"],
        "t_serial_us": round(payload["t_serial_s"] * 1e6, 1),
        "t_overlap_us": round(payload["t_overlap_s"] * 1e6, 1),
        "overlap_efficiency": round(eff, 3),
        # CPU interpret serializes the three dispatches: the schedule here is
        # dispatch-order only; real hiding needs TPU (ROADMAP open item)
        "dispatch_order_only": True,
        **payload["halo"],
    }
    # phase-level accounting (repro.obs): traced spans give the per-phase
    # seconds; dividing their sum by the UNTRACED overlapped wall measures
    # what the schedule actually hides (traced walls can't — each phase
    # blocks so it can be timed at all)
    from repro.obs.attribution import (
        overlap_efficiency, overlap_efficiency_from_spans,
    )
    acct = overlap_efficiency_from_spans(payload.get("spans", []))
    if acct:
        row.update(
            phase_us={k: round(v * 1e6, 1) for k, v in acct["phase_s"].items()},
            sum_phases_us=round(acct["sum_phases_s"] * 1e6, 1),
            overlap_efficiency_measured=round(overlap_efficiency(
                acct["sum_phases_s"], payload["t_overlap_s"]), 3),
            dominant_phase=(max(acct["phase_s"], key=acct["phase_s"].get)
                            if acct["phase_s"] else None),
        )
    row["_spans"] = payload.get("spans", [])  # popped by run(); not a column
    return row


def _phase_attribution_rows(payload_spans: list[dict]) -> list[dict]:
    """Model-vs-measured rows for the traced schedule configs: the paper's
    attribution method (which roofline term binds, and by how much the
    model misses) applied to the stencil overlap schedule."""
    from repro.obs.attribution import attribution_report

    rows = []
    for arow in attribution_report(payload_spans):
        if arow["workload"] != "stencil_schedule":
            continue
        sched = f"h{arow['hosts']}_d{arow['depth']}"
        rows.append({
            "name": f"stencil_phase_attribution_{sched}",
            "L": arow["L"], "tile": arow["tile"], "hosts": arow["hosts"],
            "depth": arow["depth"], "overlap": arow["overlap"],
            "n_steps": arow["n_spans"],
            "measured_us_per_app": round(arow["measured_unit_s"] * 1e6, 1),
            "predicted_us_per_app": (
                round(arow["predicted_s"] * 1e6, 1)
                if arow["predicted_s"] is not None else None),
            "delta_frac": (round(arow["delta_frac"], 3)
                           if arow["delta_frac"] is not None else None),
            "model_dominant": arow["model_dominant"],
            "measured_dominant_phase": arow["measured_dominant_phase"],
            "phase_us": {k: round(v * 1e6, 1)
                         for k, v in arow["phase_s"].items()},
            # the model is the TPU-v5e roofline; CPU-measured deltas are
            # large and expected — the row's value is the phase breakdown
            # and WHICH term dominates, not the absolute seconds
            "model_hw": "tpu_v5e",
        })
    return rows


def _depth2_identity_rows(L: int, tile: int, reps: int) -> list[dict]:
    """Forced-device 1/2/4-host depth-2 bit-identity: ONE widened exchange +
    two applications vs two depth-1 exchange/apply rounds, 18-real and
    two-row plans, all in one subprocess."""
    code = f"L, tile, reps = {L}, {tile}, {reps}\n" + _DEPTH2_SUBPROC
    payload, err = _subprocess_json(code)
    if payload is None:
        return [{"name": "stencil_depth2_identity_h1", "error": err}]
    rows = []
    for p in payload:
        comp_tag = "_two_row" if p["compression"] == "two_row" else ""
        rows.append({
            "name": f"stencil_depth2_identity_h{p['hosts']}{comp_tag}",
            "L": L, "tile": tile, "depth": 2,
            "hosts": p["hosts"], "compression": p["compression"],
            "identical": p["identical"],
            "t_two_depth1_us": round(p["t_two_depth1_s"] * 1e6, 1),
            "t_one_depth2_us": round(p["t_one_depth2_s"] * 1e6, 1),
            # exchanges per two applications: 2 at depth 1, 1 at depth 2
            "exchanges_saved_per_2apps": 1,
            **{f"halo_{k}": v for k, v in p["halo_d2"].items()},
        })
    return rows


def run(quick: bool = True) -> list[dict]:
    L = 4 if quick else 8
    tile = min(128, L**3)
    reps = 2 if quick else 5
    rows = []
    for dtype, accum in (("float32", ""), ("bfloat16", "float32")):
        for compression in ("none", "two_row"):
            for overlap in (False, True):
                rows.append(_measure_row(
                    L, dtype, accum, overlap, tile, reps,
                    compression=compression))
    rows.extend(_roofline_rows(L, "float32"))
    overlap_row = _overlap_identity_row(L, tile=min(64, L**3), reps=reps)
    spans = overlap_row.pop("_spans", [])
    rows.append(overlap_row)
    rows.extend(_phase_attribution_rows(spans))
    rows.extend(_depth2_identity_rows(
        2 if quick else 4, tile=min(16, L**3), reps=reps))
    return rows


if __name__ == "__main__":
    for r in run(quick="--quick" in sys.argv[1:]):
        print(r)

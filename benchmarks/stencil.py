"""Stencil benchmark: GFLOPS + overlap efficiency of the Dslash-style path.

The first workload in this repo where halo traffic actually moves.  Three
row families land in ``BENCH_su3.json`` under ``stencil``:

  measured rows   ``stencil_L{L}_{dtype}[_acc]_{overlap|serial}`` — wall-time
                  GFLOPS (useful flops = 576/site) of the overlapped vs
                  non-overlapped ``ExecutionPlan.stencil_step`` on the local
                  mesh, verified against the (1/24)-uniform fixed point.
  roofline rows   ``stencil_roofline_h{hosts}_{overlap|serial}`` — the
                  halo-charging model (autotune.predict_stencil) at 1/2/4
                  hosts.  The bandwidth term INCLUDES the vector-field halo
                  bytes (``bandwidth_bytes = streamed + halo``): the PR 3
                  halo price list is now a schedule input.
  overlap row     ``stencil_overlap_identity`` — a forced-device 2-host
                  subprocess runs both schedules on a real sharded mesh and
                  reports bit-identity plus the measured overlap efficiency
                  (t_serial / t_overlap).  On CPU interpret the three
                  dispatches serialize, so efficiency ~<= 1 here (the
                  boundary recompute is visible, the hidden transfer is
                  not); the schedule claim on CPU is dispatch-ORDER only —
                  see ROADMAP for the TPU validation item.

Standalone CLI:  PYTHONPATH=src python -m benchmarks.stencil --quick
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from repro.core import autotune
from repro.core.su3.layouts import Layout
from repro.core.su3.plan import EngineConfig, build_plan
from repro.kernels.su3_stencil import STENCIL_FLOPS_PER_SITE

# prefixed with an `L, tile, reps = ...` line by _overlap_identity_row (the
# template itself contains JSON braces, so str.format is off the table)
_OVERLAP_SUBPROC = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np, jax
from repro.core.su3.plan import EngineConfig, build_plan
from repro.launch.mesh import MeshSpec

cfg = EngineConfig(L=L, tile=tile, iterations=1, warmups=0)
plan = build_plan(cfg, MeshSpec(hosts=2, devices_per_host=1))
u, v = plan.init_stencil_data()
serial, overlap = plan.stencil_step(overlap=False), plan.stencil_step(overlap=True)
r_s, r_o = serial(u, v), overlap(u, v)  # warm both
r_s.block_until_ready(); r_o.block_until_ready()
identical = bool(np.array_equal(np.asarray(jax.device_get(r_s)),
                                np.asarray(jax.device_get(r_o))))
def best(step):
    t = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter(); step(u, v).block_until_ready()
        t = min(t, time.perf_counter() - t0)
    return t
t_serial, t_overlap = best(serial), best(overlap)
print(json.dumps({
    "identical": identical, "verified": bool(plan.verify_stencil(r_o)),
    "t_serial_s": t_serial, "t_overlap_s": t_overlap,
    "halo": plan.stencil_halo().as_dict(),
}))
"""


def _measure_row(L: int, dtype: str, accum: str, overlap: bool, tile: int,
                 reps: int) -> dict:
    cfg = EngineConfig(L=L, dtype=dtype, accum_dtype=accum, layout=Layout.SOA,
                       tile=tile, iterations=1, warmups=0)
    plan = build_plan(cfg)
    step = plan.stencil_step(overlap=overlap)
    u, v = plan.init_stencil_data()
    out = step(u, v)
    out.block_until_ready()  # warm/compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        step(u, v).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    n_sites = L**4
    acc_tag = f"_acc-{accum}" if accum else ""
    return {
        "name": f"stencil_L{L}_{dtype}{acc_tag}_{'overlap' if overlap else 'serial'}",
        "us_per_call": round(best * 1e6, 1),
        "L": L, "dtype": dtype, "accum_dtype": accum or dtype,
        "overlap": overlap, "tile": tile,
        "GFLOPS": round(STENCIL_FLOPS_PER_SITE * n_sites / best / 1e9, 3),
        "verified": plan.verify_stencil(out),
        "plan": plan.describe(),
    }


def _roofline_rows(L: int, dtype: str) -> list[dict]:
    rows = []
    for hosts in (1, 2, 4):
        for overlap in (False, True):
            pred = autotune.predict_stencil(
                autotune.StencilCandidate(tile=min(256, L**3), overlap=overlap),
                L=L, dtype=dtype, hosts=hosts,
            )
            rows.append({
                "name": f"stencil_roofline_h{hosts}_{'overlap' if overlap else 'serial'}",
                **pred,
            })
    return rows


def _overlap_identity_row(L: int, tile: int, reps: int) -> dict:
    """Forced-device 2-host schedule comparison (subprocess: the forced
    device count locks at first jax init, exactly like the fig7 dryrun)."""
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    code = f"L, tile, reps = {L}, {tile}, {reps}\n" + _OVERLAP_SUBPROC
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=600, cwd=root,
    )
    if proc.returncode != 0:
        return {"name": "stencil_overlap_identity",
                "error": proc.stderr.strip()[-300:]}
    payload = json.loads(proc.stdout.strip().splitlines()[-1])
    eff = payload["t_serial_s"] / payload["t_overlap_s"]
    return {
        "name": "stencil_overlap_identity",
        "hosts": 2, "L": L, "tile": tile,
        "identical": payload["identical"],
        "verified": payload["verified"],
        "t_serial_us": round(payload["t_serial_s"] * 1e6, 1),
        "t_overlap_us": round(payload["t_overlap_s"] * 1e6, 1),
        "overlap_efficiency": round(eff, 3),
        # CPU interpret serializes the three dispatches: the schedule here is
        # dispatch-order only; real hiding needs TPU (ROADMAP open item)
        "dispatch_order_only": True,
        **payload["halo"],
    }


def run(quick: bool = True) -> list[dict]:
    L = 4 if quick else 8
    tile = min(128, L**3)
    reps = 2 if quick else 5
    rows = []
    for dtype, accum in (("float32", ""), ("bfloat16", "float32")):
        for overlap in (False, True):
            rows.append(_measure_row(L, dtype, accum, overlap, tile, reps))
    rows.extend(_roofline_rows(L, "float32"))
    rows.append(_overlap_identity_row(L, tile=min(64, L**3), reps=reps))
    return rows


if __name__ == "__main__":
    for r in run(quick="--quick" in sys.argv[1:]):
        print(r)

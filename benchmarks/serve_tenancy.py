"""Multi-tenant SLO benchmark (the ``tenancy`` section): an adversarial
tenant mix under overload, gated on the fairness + isolation contract.

Three tenants share one service: ``steady`` submits a fixed stream of
latency-class multiplies (the interactive tenant whose tail the gate
protects), ``burst`` floods the bulk lane with several times the queue's
fair share plus one CG solve, and ``drip`` submits a small bulk batch that
a FIFO scheduler would starve behind the flood.  The same population is
replayed four ways — unloaded latency baseline, loaded clean run, loaded
run under a seeded :func:`repro.chaos.storm`, and a same-seed storm replay
— and the row records the ISSUE 10 acceptance points:

  bounded latency tail  the steady tenant's latency-class p99 under the
                        bulk burst stays within ``LATENCY_P99_CEILING`` x
                        its unloaded p99 (deficit-weighted turns + seat
                        preemption are what make this hold);
  fairness              Jain's index over per-bulk-tenant delivered
                        completions, sampled the moment the smaller bulk
                        tenant finishes — a fair scheduler serves both at
                        the same rate however lopsided the backlogs, a
                        FIFO drain scores well under ``JAIN_FLOOR``;
  brownout provenance   the flood must actually climb the ladder (>= 1
                        transition) and the same seed must reproduce the
                        exact transition log (turn, from, to) under the
                        storm replay;
  zero lost             every submission resolves: a result, a structured
                        failure, or a deterministic front-door rejection
                        (quota / queue budget) — nothing hangs;
  bitwise clean         multiplies that succeed under the storm match the
                        clean loaded run bit for bit (solve results are
                        excluded: rung-2 degradation may legitimately
                        re-chunk the iteration schedule).

Quota provenance rides in the row (``quota_rejected_by_tenant``): the
burst tenant's token bucket is sized below its submission count, so the
front door provably meters — with ``rate_per_s=0`` the budget is pure
burst and the rejection count is deterministic.

Standalone CLI:

    PYTHONPATH=src python -m benchmarks.serve_tenancy --quick
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

import jax.numpy as jnp

from repro.chaos import FaultPlan, storm
from repro.serve.su3 import (
    AutoscaleConfig, BatcherConfig, BrownoutConfig, ServiceConfig,
    SU3Service, TenantQuota,
)
from repro.serve.su3.robustness import RequestFailure, RetryPolicy

TILE = 128
LATENCY_P99_CEILING = 2.0  # loaded latency p99 vs unloaded (the SLO)
JAIN_FLOOR = 0.8  # min Jain index over per-bulk-tenant delivered work
# Same rationale as serve_chaos: backoffs far below one dispatch time keep
# the retry schedule (and the fired-fault log) reproducible run-to-run.
RETRY = RetryPolicy(max_retries=6, base_s=1e-6, cap_s=1e-5, jitter=0.2,
                    budget=512)

TENANT_LATENCY = "steady"
TENANT_BURST = "burst"
TENANT_DRIP = "drip"


def _random_request(rng: np.random.Generator, n_sites: int):
    a = rng.standard_normal((n_sites, 4, 3, 3, 2)).astype(np.float32)
    b = rng.standard_normal((4, 3, 3, 2)).astype(np.float32)
    return (
        jnp.asarray(a[..., 0] + 1j * a[..., 1], jnp.complex64),
        jnp.asarray(b[..., 0] + 1j * b[..., 1], jnp.complex64),
    )


def _service(L: int, faults: FaultPlan | None, max_queue_depth: int,
             quota_burst: int) -> SU3Service:
    return SU3Service(ServiceConfig(
        autotune=False, tile=min(TILE, L**4), faults=faults, retry=RETRY,
        hosts=2, solve_iters_per_step=4,
        quotas={TENANT_BURST: TenantQuota(rate_per_s=0.0,
                                          burst=float(quota_burst))},
        autoscale=AutoscaleConfig(enabled=True, min_hosts=1, grow_turns=2,
                                  shrink_turns=6),
        brownout=BrownoutConfig(enter_pressure=0.5, exit_pressure=0.2,
                                sustain_turns=2, exit_turns=4),
        batcher=BatcherConfig(
            max_batch=4, warm_batch_sizes=(1, 2, 4),
            max_queue_depth=max_queue_depth,
        ),
    ))


def _bulk_count(svc: SU3Service, tenant: str) -> int:
    res = svc.metrics.latencies_by_class.get(f"{tenant}/bulk")
    return res.count if res is not None else 0


def _replay(svc: SU3Service, submit_mix, checkpoint_at: int) -> dict:
    """Submit the whole mix up-front, drain, and account every request.

    ``submit_mix(svc)`` returns the submission ledger
    ``[(kind, tenant, req_id-or-None)]`` — a None id is a deterministic
    front-door rejection (quota or queue budget), accounted separately
    from in-system requests.  The fairness checkpoint samples per-bulk-
    tenant completion counts the first time the drip tenant has
    ``checkpoint_at`` completions — i.e. while the burst backlog is still
    contending — which is the window where fair and FIFO schedules differ.
    """
    ids = submit_mix(svc)
    resolved: dict[int, object] = {}
    checkpoint: dict[str, int] | None = None
    t0 = time.perf_counter()
    steps = 0
    while svc.pending() and steps < 20_000:
        steps += 1
        svc.step()
        ready = svc.pop_ready()
        if ready:
            resolved.update(ready)
        if checkpoint is None and _bulk_count(svc, TENANT_DRIP) >= checkpoint_at:
            checkpoint = {t: _bulk_count(svc, t)
                          for t in (TENANT_BURST, TENANT_DRIP)}
    resolved.update(svc.pop_ready())
    if checkpoint is None:  # drip never finished — score the final counts
        checkpoint = {t: _bulk_count(svc, t)
                      for t in (TENANT_BURST, TENANT_DRIP)}
    return {
        "ids": ids,
        "resolved": resolved,
        "checkpoint": checkpoint,
        "steps": steps,
        "wall_s": time.perf_counter() - t0,
        "snapshot": svc.metrics.snapshot(),
        "brownout_signature": [list(t) for t in svc._brownout.signature()],
    }


def _storm_plan(seed: int) -> FaultPlan:
    return storm(seed, dispatch_p=0.3, kernel_p=0.3, pool_p=0.5,
                 max_fires=3, delay_s=0.001)


def _log_key(entry: dict) -> tuple:
    # per-site determinism contract, same as serve_chaos
    return (entry["site"], entry["action"], entry["site_seq"])


def jain_index(xs: list[float]) -> float:
    """Jain's fairness index: 1.0 = perfectly even, 1/n = one tenant
    took everything.  0.0 when nothing was delivered at all."""
    total = float(sum(xs))
    if total <= 0.0:
        return 0.0
    return total * total / (len(xs) * float(sum(x * x for x in xs)))


def tenancy_mix(L: int = 2, n_latency: int = 8, n_burst: int = 24,
                quota_burst: int = 20, n_drip: int = 6,
                max_queue_depth: int = 48, seed: int = 0) -> dict:
    """The ``serve_tenancy`` row: unloaded baseline, loaded clean run,
    loaded storm run, same-seed storm replay."""
    from benchmarks.cg_solve import _problem

    rng = np.random.default_rng(seed)
    n_sites = L**4
    latency_pop = [_random_request(rng, n_sites) for _ in range(n_latency)]
    burst_pop = [_random_request(rng, n_sites) for _ in range(n_burst)]
    drip_pop = [_random_request(rng, n_sites) for _ in range(n_drip)]
    solve_problem = _problem(L)
    tol, max_iters = 1e-6, 64

    def submit_loaded(svc: SU3Service) -> list:
        # the steady tenant is in residence when the flood arrives
        ids = [("multiply", TENANT_LATENCY,
                svc.submit(a, b, k=2, tenant=TENANT_LATENCY, slo="latency"))
               for a, b in latency_pop]
        u, bb = solve_problem
        ids.append(("solve", TENANT_BURST,
                    svc.submit_solve(u, bb, tol=tol, max_iters=max_iters,
                                     tenant=TENANT_BURST, slo="bulk")))
        ids.extend(("multiply", TENANT_BURST,
                    svc.submit(a, b, k=2, tenant=TENANT_BURST))
                   for a, b in burst_pop)
        ids.extend(("multiply", TENANT_DRIP,
                    svc.submit(a, b, k=2, tenant=TENANT_DRIP))
                   for a, b in drip_pop)
        return ids

    def submit_unloaded(svc: SU3Service) -> list:
        return [("multiply", TENANT_LATENCY,
                 svc.submit(a, b, k=2, tenant=TENANT_LATENCY, slo="latency"))
                for a, b in latency_pop]

    def run_one(faults: FaultPlan | None, submit_mix) -> dict:
        svc = _service(L, faults, max_queue_depth, quota_burst)
        svc.warm((L,), ks=(2,), batch_sizes=svc.cfg.batcher.warm_batch_sizes)
        # compile the solve path before timing: its one-off jit cost would
        # otherwise land on whichever latency requests are queued behind it
        u, bb = solve_problem
        rid = svc.submit_solve(u, bb, tol=1e-2, max_iters=8,
                               tenant=TENANT_BURST, slo="bulk")
        steps = 0
        while svc.pending() and steps < 1_000:
            steps += 1
            svc.step()
        svc.pop_ready()
        svc.metrics.reset()
        return _replay(svc, submit_mix, checkpoint_at=n_drip)

    unloaded = run_one(None, submit_unloaded)
    loaded = run_one(None, submit_loaded)
    plan = _storm_plan(seed)
    stormed = run_one(plan, submit_loaded)
    replay_plan = plan.reset()
    rerun = run_one(replay_plan, submit_loaded)

    # -- zero lost: every in-system id resolves; None ids are deterministic
    #    front-door rejections (quota / queue budget), counted separately --
    def account(run: dict) -> dict:
        ok = failed = rejected = 0
        lost = False
        for _kind, _tenant, rid in run["ids"]:
            if rid is None:
                rejected += 1
                continue
            out = run["resolved"].get(rid, None)
            if out is None:
                lost = True
            elif isinstance(out, Exception):
                if not isinstance(out, (RequestFailure, RuntimeError)):
                    lost = True  # an unstructured escape is a lost request
                failed += 1
            else:
                ok += 1
        return {"ok": ok, "failed": failed, "rejected": rejected,
                "lost": lost}
    acct_loaded = account(loaded)
    acct_storm = account(stormed)
    acct_rerun = account(rerun)
    zero_lost = not (acct_loaded["lost"] or acct_storm["lost"]
                     or acct_rerun["lost"])

    # -- bitwise: storm-run multiply successes vs the clean loaded run -----
    clean_bitwise = True
    compared = 0
    for (kind, _t, rid_a), (_k2, _t2, rid_b) in zip(loaded["ids"],
                                                    stormed["ids"]):
        if kind != "multiply" or rid_a is None or rid_b is None:
            continue
        out_a = loaded["resolved"].get(rid_a)
        out_b = stormed["resolved"].get(rid_b)
        if isinstance(out_a, Exception) or isinstance(out_b, Exception):
            continue
        if out_a is None or out_b is None:
            continue
        compared += 1
        if not bool(jnp.array_equal(out_a, out_b)):
            clean_bitwise = False

    # -- same-seed: fault log AND brownout transition log reproduce --------
    log1 = [_log_key(e) for e in plan.log()]
    log2 = [_log_key(e) for e in replay_plan.log()]
    same_seed = sorted(log1) == sorted(log2) and len(log1) > 0
    sig_reproduced = (stormed["brownout_signature"] == rerun["brownout_signature"]
                      and len(stormed["brownout_signature"]) > 0)

    # -- fairness + latency SLO --------------------------------------------
    jain = jain_index([float(v) for v in loaded["checkpoint"].values()])
    lat_key = f"{TENANT_LATENCY}/latency"
    p99_unloaded = unloaded["snapshot"]["latency_by_class_ms"].get(
        lat_key, {}).get("p99", 0.0)
    p99_loaded = loaded["snapshot"]["latency_by_class_ms"].get(
        lat_key, {}).get("p99", 0.0)
    inflation = p99_loaded / max(p99_unloaded, 1e-9)

    snap = loaded["snapshot"]
    return {
        "name": "serve_tenancy",
        "L": L,
        "seed": seed,
        "tenants": {
            TENANT_LATENCY: {"slo": "latency", "n": n_latency},
            TENANT_BURST: {"slo": "bulk", "n": n_burst + 1,
                           "quota_burst": quota_burst},
            TENANT_DRIP: {"slo": "bulk", "n": n_drip},
        },
        "max_queue_depth": max_queue_depth,
        "latency_p99_ms_unloaded": p99_unloaded,
        "latency_p99_ms_loaded": p99_loaded,
        "latency_inflation": round(inflation, 3),
        "latency_bounded": inflation <= LATENCY_P99_CEILING,
        "jain_fairness": round(jain, 4),
        "fairness_ok": jain >= JAIN_FLOOR,
        "fairness_checkpoint": loaded["checkpoint"],
        "per_class_latency_ms": snap["latency_by_class_ms"],
        "admitted_by_class": snap["admitted_by_class"],
        "shed_by_class": snap["shed_by_class"],
        "quota_rejected": snap["quota_rejected"],
        "quota_rejected_by_tenant": snap["quota_rejected_by_tenant"],
        "preemptions": snap["preemptions"],
        "scale_ups": snap["scale_ups"],
        "scale_downs": snap["scale_downs"],
        "brownout_rung_turns": snap["brownout_rung_turns"],
        "brownout_transitions": snap["brownout_transitions"],
        "brownout_signature": loaded["brownout_signature"],
        "brownout_signature_reproduced": sig_reproduced,
        "brownout_degraded_solve_turns": snap["brownout_degraded_solve_turns"],
        "completed_ok": acct_loaded["ok"],
        "failed_structured": acct_loaded["failed"],
        "rejected_front_door": acct_loaded["rejected"],
        "storm_completed_ok": acct_storm["ok"],
        "storm_failed_structured": acct_storm["failed"],
        "faults_fired": plan.fired,
        "fired_by_site": plan.fired_by_site(),
        "storm": plan.describe(),
        "zero_lost": zero_lost,
        "compared_results": compared,
        "clean_results_bitwise": clean_bitwise,
        "same_seed_reproduces": same_seed,
        "wall_s_unloaded": round(unloaded["wall_s"], 3),
        "wall_s_loaded": round(loaded["wall_s"], 3),
        "wall_s_storm": round(stormed["wall_s"], 3),
    }


def gate_problems(row: dict) -> list[str]:
    """The acceptance checks ``main`` and bench_diff's tenancy gate share."""
    problems = []
    if row.get("error"):
        return [f"serve_tenancy: row errored: {row['error']}"]
    if row.get("zero_lost") is not True:
        problems.append("serve_tenancy: LOST REQUESTS — a submitted request "
                        "resolved as neither result, structured failure, "
                        "nor deterministic front-door rejection")
    if row.get("latency_bounded") is not True:
        problems.append(
            f"serve_tenancy: latency-class p99 under the bulk burst is "
            f"{row.get('latency_inflation')}x the unloaded p99 — exceeds "
            f"the {LATENCY_P99_CEILING}x ceiling (tenant isolation broke)")
    if row.get("fairness_ok") is not True:
        problems.append(
            f"serve_tenancy: Jain fairness {row.get('jain_fairness')} over "
            f"delivered bulk work is under the {JAIN_FLOOR} floor — the "
            f"burst tenant starved the drip tenant")
    if not row.get("brownout_transitions", 0):
        problems.append("serve_tenancy: the flood never climbed the "
                        "brownout ladder — the row proves nothing about "
                        "overload control")
    if row.get("brownout_signature_reproduced") is not True:
        problems.append("serve_tenancy: the same seed did NOT reproduce "
                        "the brownout transition log")
    if row.get("same_seed_reproduces") is not True:
        problems.append("serve_tenancy: the same seed did NOT reproduce "
                        "the same fault sequence")
    if row.get("clean_results_bitwise") is not True:
        problems.append("serve_tenancy: a multiply that succeeded under "
                        "the storm is NOT bitwise identical to the clean "
                        "loaded run")
    return problems


def run(quick: bool = True, seed: int = 0) -> list[dict]:
    """The ``tenancy`` benchmark section (wired into benchmarks.run)."""
    if quick:
        return [tenancy_mix(L=2, n_latency=8, n_burst=24, quota_burst=20,
                            n_drip=6, max_queue_depth=48, seed=seed)]
    return [tenancy_mix(L=2, n_latency=12, n_burst=36, quota_burst=32,
                        n_drip=8, max_queue_depth=64, seed=seed)]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    rows = run(quick=args.quick, seed=args.seed)
    ok = True
    for r in rows:
        print(r)
        for p in gate_problems(r):
            print(f"FAIL: {p}", file=sys.stderr)
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())

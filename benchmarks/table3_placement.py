"""Paper Table 3/5/6 analog: placement policy study (the NUMA/first-touch
lesson). ``sharded`` = paper's empty-constructor + parallel init fix;
``host_scatter`` = default-constructor first-touch on socket 0 (data built
on one device, then redistributed); ``replicated`` = the memory-blowup
failure. Reports init/scatter time and per-device bytes."""
from __future__ import annotations

import jax

from repro.core.su3.engine import EngineConfig, SU3Engine


def run(L: int = 8) -> list[dict]:
    rows = []
    for placement in ("sharded", "host_scatter", "replicated"):
        cfg = EngineConfig(L=L, placement=placement, iterations=2, warmups=1, tile=128)
        eng = SU3Engine(cfg)
        r = eng.run()
        row = r.row()
        row["name"] = f"table3_{placement}"
        row["devices"] = eng.n_devices
        rows.append(row)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

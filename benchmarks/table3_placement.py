"""Paper Table 3/5/6 analog: placement policy study (the NUMA/first-touch
lesson). ``sharded`` = paper's empty-constructor + parallel init fix;
``host_scatter`` = default-constructor first-touch on socket 0 (data built
on one device, then redistributed); ``replicated`` = the memory-blowup
failure. Each policy is just a different ExecutionPlan (same codec/kernel
tuple, different out_shardings at init) — the ``plan`` column records it.
Reports init/scatter time and per-device bytes."""
from __future__ import annotations

from repro.core.su3.engine import EngineConfig, SU3Engine
from repro.core.su3.plan import PLACEMENTS


def run(L: int = 8) -> list[dict]:
    rows = []
    for placement in PLACEMENTS:
        cfg = EngineConfig(L=L, placement=placement, iterations=2, warmups=1, tile=128)
        r = SU3Engine(cfg).run()
        row = r.row()
        row["name"] = f"table3_{placement}"
        rows.append(row)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

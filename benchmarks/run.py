"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract.
``us_per_call`` is the best iteration time where measured (engine rows) and
empty for analytic tables; ``derived`` carries the table-specific payload.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json [PATH]] [--seed N]

``--seed`` re-keys the seeded sections (the chaos fault storm and the
tenancy mix) so their deterministic schedules can be varied without
touching the timing tables.

``--json`` additionally writes a machine-readable ``BENCH_su3.json`` (all
rows, grouped per table, with GFLOPS/GBYTES where measured) so the perf
trajectory is tracked across PRs; ``scripts/smoke.sh`` wires it into the
quick-mode smoke run.

Every artifact carries a ``provenance`` block (git sha, jax/jaxlib
versions, backend, device kind, XLA flags, autotune cache schema —
``repro.obs.provenance_block``): numbers without the environment that
produced them are not comparable, and ``scripts/bench_diff.py`` refuses a
diff whose current side lacks the block or whose jax/backend pair changed
without a re-baseline note (``REPRO_BENCH_REBASELINE="why"``).
"""
from __future__ import annotations

import json
import sys

DEFAULT_JSON = "BENCH_su3.json"


def _emit(rows: list[dict], collected: dict[str, list[dict]], table: str) -> None:
    collected[table] = [dict(r) for r in rows]
    for r in rows:
        r = dict(r)
        name = r.pop("name", "unnamed")
        us = r.pop("us_per_call", None)
        if us is None and "best_s" in r:
            us = round(r["best_s"] * 1e6, 1)
        derived = json.dumps(r, default=str)
        print(f"{name},{us if us is not None else ''},{derived}")


def main(argv: list[str] | None = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    quick = "--quick" in argv
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        nxt = argv[i + 1] if i + 1 < len(argv) else None
        json_path = nxt if nxt and not nxt.startswith("--") else DEFAULT_JSON
    seed = 0  # seeded sections (chaos storm, tenancy mix) key off this
    if "--seed" in argv:
        i = argv.index("--seed")
        if i + 1 < len(argv):
            seed = int(argv[i + 1])

    from benchmarks import (
        cg_solve, fig7_strong_scaling, fig9_gemm_vs_dot, fig10_arch_compare,
        lm_step, serve_chaos, serve_tenancy, serve_traffic, stencil,
        table1_roofline, table2_variants, table3_placement,
    )

    collected: dict[str, list[dict]] = {}
    tables = [
        ("table1_roofline", lambda: table1_roofline.run()),
        ("table2_variants", lambda: table2_variants.run(
            L=8 if not quick else 4, iters=(1, 5) if not quick else (1, 4))),
        ("table3_placement", lambda: table3_placement.run(L=8 if not quick else 4)),
        ("fig7_strong_scaling", lambda: fig7_strong_scaling.run(
            L=8 if not quick else 4,
            device_counts=(1, 2, 4) if not quick else (1, 2))),
        ("fig9_gemm_vs_dot", lambda: fig9_gemm_vs_dot.run(
            sizes=(4, 8) if not quick else (4,))),
        ("fig10_arch_compare", lambda: fig10_arch_compare.run(L=8 if not quick else 4)),
        ("lm_step", lambda: lm_step.run()),
        ("serve", lambda: serve_traffic.run(quick=quick)),
        ("chaos", lambda: serve_chaos.run(quick=quick, seed=seed)),
        ("tenancy", lambda: serve_tenancy.run(quick=quick, seed=seed)),
        ("stencil", lambda: stencil.run(quick=quick)),
        ("cg", lambda: cg_solve.run(quick=quick)),
    ]
    for table, fn in tables:
        # one broken table must not take the other rows or the JSON
        # artifact down with it
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            rows = [{"name": f"{table}_error", "error": f"{type(e).__name__}: {e}"[:300]}]
        _emit(rows, collected, table)

    if json_path:
        from repro.obs import provenance_block

        payload = {
            "schema": "su3-bench-rows/v1",
            "quick": quick,
            "provenance": provenance_block(),
            "tables": collected,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, default=str)
        print(f"# wrote {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()

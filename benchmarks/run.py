"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract.
``us_per_call`` is the best iteration time where measured (engine rows) and
empty for analytic tables; ``derived`` carries the table-specific payload.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import json
import sys


def _emit(rows: list[dict]) -> None:
    for r in rows:
        name = r.pop("name", "unnamed")
        us = r.pop("us_per_call", None)
        if us is None and "best_s" in r:
            us = round(r["best_s"] * 1e6, 1)
        derived = json.dumps(r, default=str)
        print(f"{name},{us if us is not None else ''},{derived}")


def main() -> None:
    quick = "--quick" in sys.argv
    from benchmarks import (
        fig7_strong_scaling, fig9_gemm_vs_dot, fig10_arch_compare,
        lm_step, table1_roofline, table2_variants, table3_placement,
    )

    _emit(table1_roofline.run())
    _emit(table2_variants.run(L=8 if not quick else 4, iters=(1, 5) if not quick else (1,)))
    _emit(table3_placement.run(L=8 if not quick else 4))
    _emit(fig7_strong_scaling.run(L=8 if not quick else 4,
                                  device_counts=(1, 2, 4) if not quick else (1, 2)))
    _emit(fig9_gemm_vs_dot.run(sizes=(4, 8) if not quick else (4,)))
    _emit(fig10_arch_compare.run(L=8 if not quick else 4))
    _emit(lm_step.run())


if __name__ == "__main__":
    main()

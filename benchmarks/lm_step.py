"""LM-side microbenchmarks: measured reduced-config step times on CPU plus
pointers into the dry-run roofline table for the full configs."""
from __future__ import annotations

import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import registry
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import make_train_step

ARCHS = ("qwen3-4b", "granite-moe-1b-a400m", "zamba2-1.2b", "xlstm-125m")


def run(seq: int = 64, batch: int = 4, reps: int = 3) -> list[dict]:
    rows = []
    shape = ShapeConfig("bench", seq, batch, "train")
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        api = registry.get(cfg)
        params = api.init(jax.random.PRNGKey(0), cfg)
        batch_data = registry.make_inputs(cfg, shape, jax.random.PRNGKey(1))
        step = jax.jit(
            make_train_step(cfg, AdamWConfig(), q_chunk=min(64, seq), kv_chunk=min(64, seq)),
            donate_argnums=(0, 1),
        )
        from repro.optim import adamw

        opt = adamw.init(params, AdamWConfig())
        params, opt, _ = step(params, opt, batch_data)  # compile+warm
        jax.block_until_ready(params)
        t0 = time.perf_counter()
        for _ in range(reps):
            params, opt, m = step(params, opt, batch_data)
        jax.block_until_ready(params)
        dt = (time.perf_counter() - t0) / reps
        rows.append({
            "name": f"lm_step_{arch}_reduced",
            "us_per_call": round(dt * 1e6, 1),
            "tokens_per_s": round(seq * batch / dt, 1),
            "loss": float(m["loss"]),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Render the §Dry-run / §Roofline tables from experiments/dryrun/*.json."""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"

ARCH_ORDER = [
    "internvl2-26b", "granite-34b", "qwen3-4b", "minitron-8b", "yi-6b",
    "zamba2-1.2b", "deepseek-v3-671b", "granite-moe-1b-a400m",
    "xlstm-125m", "whisper-tiny",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str) -> dict[tuple[str, str], dict]:
    out = {}
    for p in sorted(DRYRUN.glob(f"*__{mesh}.json")):  # tagged variants excluded
        d = json.loads(p.read_text())
        if d.get("status") != "ok":
            continue
        out[(d["arch"], d["shape"])] = d
    return out


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_table(mesh: str) -> str:
    cells = load(mesh)
    lines = [
        f"### Roofline baselines — mesh `{mesh}` "
        f"({'(2,16,16)=512' if mesh == 'multi' else '(16,16)=256'} chips, v5e model)",
        "",
        "| arch | shape | compute | memory | collective | bound | useful/HLO | roofline frac | GiB/dev (analytic) | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = cells.get((arch, shape))
            if d is None:
                lines.append(f"| {arch} | {shape} | — | — | — | skipped (long-context inapplicable) | | | | |")
                continue
            r = d["roofline"]
            m = d["memory_analytic"]
            lines.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** "
                f"| {r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.3f} "
                f"| {m['total_bytes'] / 2**30:.2f} | {'Y' if m['fits_v5e_16g'] else 'N'} |"
            )
    return "\n".join(lines)


def dryrun_table(mesh: str) -> str:
    cells = load(mesh)
    lines = [
        f"### Dry-run compile record — mesh `{mesh}`",
        "",
        "| arch | shape | compile s | HLO GFLOPs/dev | HLO GB/dev | coll GB/dev (link) | top collectives | XLA GiB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = cells.get((arch, shape))
            if d is None:
                continue
            r = d["roofline"]
            by = r.get("collective_by_kind", {})
            top = ", ".join(
                f"{k}:{v / 1e9:.1f}G" for k, v in
                sorted(by.items(), key=lambda kv: -kv[1])[:2]
            ) or "none"
            lines.append(
                f"| {arch} | {shape} | {d['compile_s']} | {r['flops_per_device'] / 1e9:.0f} "
                f"| {r['bytes_per_device'] / 1e9:.1f} | {r['collective_link_bytes'] / 1e9:.2f} "
                f"| {top} | {d['memory']['total_bytes_per_device'] / 2**30:.2f} |"
            )
    return "\n".join(lines)


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    for mesh in ("single", "multi"):
        if which in ("all", "roofline"):
            print(roofline_table(mesh))
            print()
        if which in ("all", "dryrun"):
            print(dryrun_table(mesh))
            print()


if __name__ == "__main__":
    main()

#!/usr/bin/env python
"""Dispatch profiler: count + per-dispatch overhead rows (``dispatch`` table).

The paper's PIUMA conclusion (§5.3) is that SU3_Bench's ceiling is pipeline
throughput — how fast work can be ISSUED, not how fast it runs.  On the
serving stack the analogous tax is the kernel dispatch: every launch pays a
fixed host-side cost that dominates at quick-mode lattice sizes.  This tool
measures that tax directly and lands it in ``BENCH_su3.json`` so the
trajectory is gated like every other row:

  dispatch_overhead_L{L}
      K single-step dispatches vs ONE fused(K) dispatch of the same K
      multiplies; the wall difference over K-1 is the per-dispatch overhead.
  megakernel_amortization_L{L}
      a SLOTS-slot table advanced one iteration as SLOTS single-lattice
      dispatches (the per-chain continuous path) vs ONE batched megakernel
      dispatch — the dispatch-count collapse the slot-table serving mode
      banks every iteration.

All timing runs through the ``repro.obs`` tracer — every rep is a
``profile.dispatch`` span on the same monotonic clock and span schema the
serving stack emits, and the table rows are derived from those spans
(``--trace PATH`` exports them as flat JSONL for ``scripts/trace_report.py``
or, with a ``.json`` suffix, as Chrome trace-event JSON).

Usage (wired into scripts/smoke.sh quick mode):

    PYTHONPATH=src python scripts/profile_dispatch.py --quick --json BENCH_su3.json
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys

import numpy as np

import jax.numpy as jnp

from repro.core.su3.engine import EngineConfig, SU3Engine
from repro.core.su3.layouts import Layout
from repro.obs import Tracer

SLOTS = 4
FUSED_K = 4
TILE = 128

# One clock, one span schema: every timed rep is a span on this tracer, and
# the table rows below are reductions over those spans.
TRACER = Tracer(enabled=True, capacity=4096)


def _median_wall(fn, reps: int, label: str, **attrs) -> float:
    spans = []
    for _ in range(reps):
        with TRACER.span("profile.dispatch", label=label, **attrs) as sp:
            fn()
        spans.append(sp)
    return float(statistics.median(s.dur_s for s in spans))


def dispatch_overhead_row(L: int, k: int = FUSED_K, reps: int = 5) -> dict:
    """K dispatched single steps vs one fused(K) dispatch (engine protocol)."""
    cfg = EngineConfig(L=L, dtype="float32", variant="pallas",
                       layout=Layout.SOA, tile=TILE, iterations=1, warmups=1)
    engine = SU3Engine(cfg)
    cmp = engine.compare_fused(k=k, reps=reps)
    per_dispatch_s = max(cmp["dispatched_s"] - cmp["fused_s"], 0.0) / (k - 1)
    return {
        "name": f"dispatch_overhead_L{L}",
        "L": L,
        "k": k,
        "dispatches_chained": k,
        "dispatches_fused": 1,
        "chained_s": round(cmp["dispatched_s"], 6),
        "fused_s": round(cmp["fused_s"], 6),
        "per_dispatch_overhead_us": round(per_dispatch_s * 1e6, 1),
        "fused_speedup": round(cmp["fused_speedup"], 3),
        "GFLOPS": cmp["result"].row()["GFLOPS"],  # fused per-multiply GF/s
        "verified": cmp["result"].verified,
    }


def megakernel_amortization_row(L: int, slots: int = SLOTS, reps: int = 5) -> dict:
    """SLOTS single-lattice dispatches vs ONE megakernel dispatch per
    iteration, on identical slot data — the serving-layer collapse."""
    cfg = EngineConfig(L=L, dtype="float32", variant="pallas",
                       layout=Layout.SOA, tile=TILE, iterations=1, warmups=1)
    plan = SU3Engine(cfg).plan
    rng = np.random.default_rng(0)
    S = plan.padded_sites
    a = rng.standard_normal((slots, S, 4, 3, 3, 2)).astype(np.float32)
    b = rng.standard_normal((slots, 4, 3, 3, 2)).astype(np.float32)
    import jax
    a_phys = jax.vmap(plan.codec.pack)(
        jnp.asarray(a[..., 0] + 1j * a[..., 1], jnp.complex64))
    b_p = jax.vmap(plan.codec.pack_b)(
        jnp.asarray(b[..., 0] + 1j * b[..., 1], jnp.complex64))
    ones = jnp.ones((slots,), jnp.int32)
    mega = plan.fused_batched_step(slots, max_k=1)

    def per_chain():
        outs = [plan.step(a_phys[s], b_p[s]) for s in range(slots)]
        outs[-1].block_until_ready()

    def megakernel():
        mega(a_phys, b_p, ones).block_until_ready()

    per_chain()  # warm both compiled shapes before timing
    megakernel()
    chain_s = _median_wall(per_chain, reps, "per_chain", L=L, slots=slots)
    mega_s = _median_wall(megakernel, reps, "megakernel", L=L, slots=slots)
    useful_flops = 864.0 * (L**4) * slots
    return {
        "name": f"megakernel_amortization_L{L}",
        "L": L,
        "slots": slots,
        "dispatches_per_iter_chains": slots,
        "dispatches_per_iter_megakernel": 1,
        "chains_s": round(chain_s, 6),
        "megakernel_s": round(mega_s, 6),
        "dispatch_amortization_speedup": round(chain_s / max(mega_s, 1e-9), 3),
        "per_dispatch_overhead_us": round(
            max(chain_s - mega_s, 0.0) / (slots - 1) * 1e6, 1),
        "GFLOPS": round(useful_flops / mega_s / 1e9, 3),
    }


def run(quick: bool = True) -> list[dict]:
    Ls = (2, 4) if quick else (4, 8)
    rows = []
    for L in Ls:
        rows.append(dispatch_overhead_row(L))
        rows.append(megakernel_amortization_row(L))
    return rows


def merge_into_artifact(rows: list[dict], path: str) -> None:
    """Land the ``dispatch`` table inside the benchmark artifact (creating a
    minimal payload when the harness has not run yet).  The provenance block
    is stamped if absent so a standalone profiler artifact still passes the
    bench_diff provenance gate."""
    payload = {"schema": "su3-bench-rows/v1", "tables": {}}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload.setdefault("tables", {})["dispatch"] = rows
    if "provenance" not in payload:
        from repro.obs import provenance_block

        payload["provenance"] = provenance_block()
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="",
                    help="merge rows into this BENCH_su3.json artifact")
    ap.add_argument("--trace", default="",
                    help="export the profiling spans (.jsonl flat / "
                         ".json Chrome trace-event)")
    args = ap.parse_args(argv)
    rows = run(quick=args.quick)
    for r in rows:
        print(r)
    if args.json:
        merge_into_artifact(rows, args.json)
        print(f"# merged dispatch table into {args.json}", file=sys.stderr)
    if args.trace:
        if args.trace.endswith(".jsonl"):
            n = TRACER.to_jsonl(args.trace)
        else:
            n = TRACER.to_chrome_trace(args.trace)
        print(f"# wrote {n} spans to {args.trace}", file=sys.stderr)
    bad = [r for r in rows if "verified" in r and not r["verified"]]
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())

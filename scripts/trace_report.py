#!/usr/bin/env python
"""Render a repro.obs trace: span tree + model-vs-measured attribution.

Reads either export format the tracer writes — flat JSONL (one record per
line) or Chrome trace-event JSON (``{"traceEvents": [...]}``) — and prints:

  1. the span tree, aggregated by name-path (count, total, mean), so the
     request lifecycle (admit -> seat -> dispatch -> request) and the
     stencil phase nesting (stencil.step > exchange/interior/boundary)
     read at a glance;
  2. counters, if any were recorded;
  3. overlap-phase accounting when the trace holds overlapped
     ``stencil.step`` spans (per-phase seconds; the real efficiency needs
     an untraced wall — see ``benchmarks.stencil``);
  4. the attribution table: every traced (tile, fused_k, compression,
     depth) config joined against the pipeline/stencil roofline
     (``repro.obs.attribution``).  On a jax-less machine the model side
     degrades to ``-`` and the measured columns still render.

    PYTHONPATH=src python scripts/trace_report.py  # artifacts/serve_trace.jsonl
    python scripts/trace_report.py artifacts/serve_trace.chrome.json  # same report

Exit code 0 iff the report rendered (used by scripts/smoke.sh to assert a
traced serving run produced a readable trace).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

try:
    from repro.obs import attribution_report, render_attribution
    from repro.obs.attribution import overlap_efficiency_from_spans
    from repro.obs.tracer import load_jsonl
except ImportError:  # direct invocation without PYTHONPATH=src
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    from repro.obs import attribution_report, render_attribution
    from repro.obs.attribution import overlap_efficiency_from_spans
    from repro.obs.tracer import load_jsonl


def load_records(path: str) -> tuple[list[dict], dict]:
    """(records, metadata) from a JSONL or Chrome trace-event file."""
    try:
        with open(path) as fh:
            payload = json.load(fh)
    except json.JSONDecodeError:  # multiple lines -> flat JSONL
        return load_jsonl(path), {}
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        # a single-record JSONL file parses as one object
        return ([payload] if isinstance(payload, dict) else []), {}
    records = []
    for ev in payload.get("traceEvents", []):
        args = dict(ev.get("args") or {})
        records.append({
            "type": "span",
            "name": ev.get("name", ""),
            "ts_s": ev.get("ts", 0.0) / 1e6,
            "dur_s": ev.get("dur", 0.0) / 1e6,
            "span_id": args.pop("span_id", None),
            "parent_id": args.pop("parent_id", None),
            "lane": ev.get("tid", 0),
            "attrs": args,
        })
    meta = dict(payload.get("otherData") or {})
    for name, value in (meta.pop("counters", None) or {}).items():
        records.append({"type": "counter", "name": name, "value": value})
    return records, meta


def _fmt_s(v: float) -> str:
    if v >= 1.0:
        return f"{v:.3f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.2f}ms"
    return f"{v * 1e6:.1f}us"


def span_tree(records: list[dict]) -> list[str]:
    """Aggregate spans by name-path (parent chain) -> indented table."""
    spans = [r for r in records if r.get("type", "span") == "span"]
    by_id = {s["span_id"]: s for s in spans if s.get("span_id") is not None}

    def path(s: dict) -> tuple[str, ...]:
        names, seen = [], set()
        while s is not None and s["span_id"] not in seen:
            seen.add(s["span_id"])
            names.append(s["name"])
            s = by_id.get(s.get("parent_id"))
        return tuple(reversed(names))

    agg: dict[tuple[str, ...], list[float]] = {}
    for s in spans:
        agg.setdefault(path(s), []).append(float(s.get("dur_s", 0.0)))
    lines = []
    width = max((2 * (len(p) - 1) + len(p[-1]) for p in agg), default=4)
    header = f"{'span':<{width}}  {'count':>5}  {'total':>9}  {'mean':>9}"
    lines.append(header)
    lines.append("-" * len(header))
    for p in sorted(agg):
        durs = agg[p]
        label = "  " * (len(p) - 1) + p[-1]
        lines.append(
            f"{label:<{width}}  {len(durs):>5}  {_fmt_s(sum(durs)):>9}  "
            f"{_fmt_s(sum(durs) / len(durs)):>9}")
    return lines


def report(path: str) -> str:
    records, meta = load_records(path)
    spans = [r for r in records if r.get("type", "span") == "span"]
    counters = [r for r in records if r.get("type") == "counter"]
    out = [f"trace: {path}  ({len(spans)} spans)"]
    if meta:
        prov = ", ".join(
            f"{k}={meta[k]}" for k in
            ("git_sha", "jax_version", "backend", "device_kind")
            if k in meta)
        if prov:
            out.append(f"provenance: {prov}")
        if meta.get("dropped_spans"):
            out.append(f"WARNING: flight recorder dropped "
                       f"{meta['dropped_spans']} spans (ring capacity)")
    out.append("")
    out.extend(span_tree(records) if spans else ["(no spans)"])
    if counters:
        out.append("")
        out.append("counters:")
        for c in counters:
            out.append(f"  {c['name']} = {c['value']}")
    acct = overlap_efficiency_from_spans(records)
    if acct:
        out.append("")
        out.append(
            f"overlap schedule ({acct['n_steps']} steps): "
            + "  ".join(f"{k}={_fmt_s(v)}" for k, v in acct["phase_s"].items())
            + f"  sum={_fmt_s(acct['sum_phases_s'])}"
            + f"  traced_wall={_fmt_s(acct['traced_wall_s'])}")
        out.append("  (efficiency = sum_phases / UNTRACED wall; traced walls "
                   "serialize at phase boundaries and cannot witness hiding)")
    out.append("")
    out.append("attribution (measured vs roofline):")
    out.append(render_attribution(attribution_report(records)))
    return "\n".join(out)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="render a repro.obs trace (span tree + attribution)")
    ap.add_argument("trace", nargs="?", default="artifacts/serve_trace.jsonl",
                    help="path to a .jsonl or .chrome.json trace "
                         "(default: %(default)s — where the traced serve "
                         "benchmark row exports)")
    args = ap.parse_args(argv)
    if not os.path.exists(args.trace):
        print(f"trace_report: no trace at {args.trace!r}", file=sys.stderr)
        return 1
    print(report(args.trace))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

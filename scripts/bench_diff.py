#!/usr/bin/env python
"""Diff BENCH_su3.json throughput rows against the previous PR's artifact.

The ROADMAP's regression tripwire: every PR commits a fresh quick-mode
``BENCH_su3.json``; this tool compares the measured throughput rows of a new
run against the committed baseline and exits non-zero when any shared row
regresses by more than the threshold (default 15%).

Rows compared by (table, name):
  * engine rows        ``GFLOPS`` (best-iteration useful GF/s)
  * serve rows         ``sustained_gflops_busy`` (useful flops / kernel wall)

Baselines can be a file path or a git blob (``git:REV`` reads
``REV:BENCH_su3.json``), so the default compares working-tree results
against the last commit:

    PYTHONPATH=src python scripts/bench_diff.py              # vs git:HEAD
    python scripts/bench_diff.py --baseline old.json --current new.json
    python scripts/bench_diff.py --threshold 0.25            # looser gate

A missing baseline (first PR, artifact not committed at REV) is a clean
exit — there is nothing to regress against.

Note on noise: quick-mode rows on a loaded CPU dev host can swing past 15%
in either direction (single-iteration L=4 timings are the worst).  The gate
therefore RE-MEASURES flagged rows before failing: the benchmark harness is
re-run twice more and each flagged row is judged on the MEDIAN of its three
observations — a row that recovers is timer noise, not a regression, and
passes without human retry.  ``--no-retry`` keeps the old single-pass
behavior (CI contexts that re-run the whole job themselves).  On the real
TPU target the variance is far below the threshold.

Besides the throughput diff, the gate checks the CURRENT artifact's
compression and wide-halo rows (no baseline needed — bytes/site is a
deterministic model quantity, so there is no retry either):

  * ``table2_pallas_two_row_*`` rows must exist for f32 and bf16, declare
    ``compression=two_row``, and stream <= 70% of the matching 18-real
    pallas row's bytes/site (true compressed ratio: 96/144 words = 67%).
  * measured ``stencil_*_two_row_*`` rows must exist and stream <= 85% of
    their 18-real siblings (true ratio: 102/126 words = 81% — the gauge
    field is only 72 of the 126 streamed words/site).
  * ``stencil_depth2_identity_h{1,2,4}[_two_row]`` rows must all report
    ``identical: true`` (depth-2 exchange bit-equals two depth-1 steps).

A silent fallback to the 18-real layout fails all three ways: the row
keeps the full bytes/site, loses its ``compression`` tag, or vanishes.
``--no-compression-gate`` skips this block (pre-compression artifacts).

The CG convergence gate pins the SOLVER'S iteration count, not just its
throughput: the current artifact's ``cg_residual_vs_time`` row must exist
and report convergence, every fused ``cg_iter_*`` row must carry
``verified: true`` (the fused/composed bit-identity contract), and — when
the committed baseline measured the same tol — the fresh run may not need
more than 10% more iterations to reach it.  Iteration counts are
deterministic (fixed seed, fixed problem), so like the compression gate
there is no noise retry: more iterations means the numerics changed.
``--no-cg-gate`` skips the block (pre-solver artifacts).

The chaos gate checks the robustness contract on the CURRENT artifact's
``serve_chaos`` row (``benchmarks/serve_chaos.py``): the seeded fault
storm must have fired, every request must resolve (zero lost), successes
must be bitwise identical to the fault-free baseline, the same seed must
reproduce the same fault sequence, and p99 inflation must stay bounded.
These are determinism/accounting properties, not timings — no noise
retry.  ``--no-chaos-gate`` skips the block (pre-chaos artifacts).

The tenancy gate checks the multi-tenant SLO contract on the CURRENT
artifact's ``serve_tenancy`` row (``benchmarks/serve_tenancy.py``): the
steady tenant's latency-class p99 under the bulk flood must stay within
the published ceiling of its unloaded p99, Jain fairness over delivered
bulk work must clear the floor, the flood must actually climb the
brownout ladder and the same seed must reproduce its transition log,
and every submission must resolve (zero lost).  The latency/fairness
verdicts are computed against the row's own unloaded baseline (same
process, same warm state), so they are paired measurements rather than
absolute timings — no noise retry.  ``--no-tenancy-gate`` skips the
block (pre-tenancy artifacts).

The gate also verifies run PROVENANCE (``repro.obs.provenance_block``):
a harness artifact without a provenance block fails, as does a diff whose
jax/jaxlib/backend/device identity changed between baseline and current
without a re-baseline note — environment swaps masquerading as perf wins
(or losses) are the oldest benchmark lie.  Notes come from
``REPRO_BENCH_REBASELINE="why"`` at generation time or
``--rebaseline-note "why"`` here; ``--no-provenance-gate`` skips the block
(pre-provenance artifacts).
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
import tempfile

try:
    from repro.obs.provenance import provenance_problems
except ImportError:  # direct invocation without PYTHONPATH=src
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    from repro.obs.provenance import provenance_problems

DEFAULT_ARTIFACT = "BENCH_su3.json"
RETRY_RUNS = 2  # re-measurements per flagged gate (median of 1 + RETRY_RUNS)
# (metric key, minimum absolute baseline value worth gating on) — rows below
# the floor are pure timer noise at CPU quick-mode sizes.
_METRICS = (("GFLOPS", 0.05), ("sustained_gflops_busy", 0.01))
# bytes/site ceilings for the compression gate, as a fraction of the 18-real
# row.  Both sit between the true compressed ratio and 1.0, so a silent
# fallback to the full layout (ratio 1.0) fails while the honest compressed
# stream passes with margin.
MULTIPLY_BYTES_RATIO = 0.70   # true: 96/144 words = 0.667
STENCIL_BYTES_RATIO = 0.85    # true: 102/126 words = 0.810
CG_ITERS_HEADROOM = 0.10      # >10% more iterations to the same tol fails
DEPTH2_HOSTS = (1, 2, 4)
_WORD_BYTES = {"float32": 4, "bfloat16": 2, "float64": 8}


def collect_rows(
    payload: dict, *, apply_floor: bool = True
) -> dict[tuple[str, str], float]:
    """-> {(table, row name): throughput} for every measured row.

    The noise floor gates the BASELINE side only: a baseline row below the
    floor is timer noise not worth diffing, but a *current* row must be
    collected however small — a collapse from above-floor to ~zero is the
    exact regression the gate exists to catch.
    """
    out: dict[tuple[str, str], float] = {}
    for table, rows in payload.get("tables", {}).items():
        if not isinstance(rows, list):
            continue
        for row in rows:
            if not isinstance(row, dict):
                continue
            name = row.get("name")
            if not name:
                continue
            for metric, floor in _METRICS:
                val = row.get(metric)
                if isinstance(val, (int, float)):
                    if not apply_floor or val >= floor:
                        out[(table, str(name))] = float(val)
                    break  # first present metric decides the row
    return out


def load_baseline(spec: str) -> dict | None:
    """Baseline payload from a path or ``git:REV`` blob; None when absent."""
    if spec.startswith("git:"):
        rev = spec[len("git:"):] or "HEAD"
        try:
            text = subprocess.run(
                ["git", "show", f"{rev}:{DEFAULT_ARTIFACT}"],
                capture_output=True, text=True, check=True,
            ).stdout
        except (subprocess.CalledProcessError, FileNotFoundError):
            return None
        return json.loads(text)
    try:
        with open(spec) as f:
            return json.load(f)
    except FileNotFoundError:
        return None


def diff(
    baseline: dict, current: dict, threshold: float
) -> tuple[list[dict], list[dict]]:
    """-> (all compared rows, the regressions among them)."""
    base_rows = collect_rows(baseline)
    cur_rows = collect_rows(current, apply_floor=False)
    compared, regressions = [], []
    for key in sorted(base_rows.keys() & cur_rows.keys()):
        base, cur = base_rows[key], cur_rows[key]
        drop = (base - cur) / base if base > 0 else 0.0
        entry = {
            "table": key[0], "name": key[1],
            "baseline": round(base, 3), "current": round(cur, 3),
            "delta_pct": round(-drop * 100, 1),
        }
        compared.append(entry)
        if drop > threshold:
            regressions.append(entry)
    return compared, regressions


def asymmetric_rows(
    baseline: dict, current: dict
) -> tuple[list[tuple[str, str]], list[tuple[str, str]]]:
    """-> (rows only in baseline, rows only in current), named and sorted.

    A row present in the committed artifact but missing from the fresh run
    is a *dropped measurement* — historically skipped silently, which let a
    batch of new rows (e.g. a fresh stencil table) mask the disappearance
    of an old one.  Both directions are reported by name so the gate's
    output always accounts for every row it did NOT compare.

    Presence is judged WITHOUT the noise floor on either side: the floor
    decides what is worth *gating*, not what exists — a below-floor
    baseline row that vanishes is still a dropped measurement, and must
    not be misreported as the current side's "new" row.
    """
    base_rows = collect_rows(baseline, apply_floor=False)
    cur_rows = collect_rows(current, apply_floor=False)
    only_base = sorted(base_rows.keys() - cur_rows.keys())
    only_cur = sorted(cur_rows.keys() - base_rows.keys())
    return only_base, only_cur


def remeasure_rows(
    keys: set[tuple[str, str]], runs: int = RETRY_RUNS, quick: bool = True,
) -> dict[tuple[str, str], list[float]]:
    """Re-run the benchmark harness ``runs`` times; collect the flagged rows.

    Each run regenerates the artifact in a temp dir at the SAME mode
    (quick/full) that produced the one under test — the rows are not
    independently runnable, the harness is the measurement unit — and only
    the flagged (table, name) values are kept.  Rows in the ``dispatch``
    table come from ``scripts/profile_dispatch.py``, so that profiler is
    re-run (merging into the same temp artifact) whenever a dispatch row is
    flagged.  A run that fails or omits a row contributes nothing for it;
    the median is taken over whatever observations exist.
    """
    mode = ["--quick"] if quick else []
    profiler = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "profile_dispatch.py")
    need_dispatch = any(table == "dispatch" for table, _name in keys)
    out: dict[tuple[str, str], list[float]] = {key: [] for key in keys}
    for _ in range(runs):
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "bench_remeasure.json")
            subprocess.run(
                [sys.executable, "-m", "benchmarks.run"] + mode
                + ["--json", path],
                capture_output=True, text=True,
            )
            if need_dispatch:
                subprocess.run(
                    [sys.executable, profiler] + mode + ["--json", path],
                    capture_output=True, text=True,
                )
            if not os.path.exists(path):
                continue
            with open(path) as f:
                rows = collect_rows(json.load(f), apply_floor=False)
            for key in keys:
                if key in rows:
                    out[key].append(rows[key])
    return out


def retry_regressions(
    regressions: list[dict], threshold: float,
    remeasure_fn=None,
) -> tuple[list[dict], list[dict]]:
    """Median-of-3 verdict on flagged rows: (still regressed, recovered).

    Each flagged row's single-pass current value is pooled with the
    re-measured observations; the row fails only if the MEDIAN still drops
    past the threshold.
    """
    if remeasure_fn is None:
        remeasure_fn = remeasure_rows
    keys = {(r["table"], r["name"]) for r in regressions}
    extra = remeasure_fn(keys)
    still, recovered = [], []
    for r in regressions:
        vals = [r["current"]] + extra.get((r["table"], r["name"]), [])
        med = float(statistics.median(vals))
        base = r["baseline"]
        drop = (base - med) / base if base > 0 else 0.0
        verdict = dict(
            r, current_median=round(med, 3), observations=len(vals),
            delta_pct=round(-drop * 100, 1),
        )
        (still if drop > threshold else recovered).append(verdict)
    return still, recovered


def _rows_by_name(payload: dict, table: str) -> dict[str, dict]:
    out: dict[str, dict] = {}
    rows = payload.get("tables", {}).get(table, [])
    if isinstance(rows, list):
        for row in rows:
            if isinstance(row, dict) and row.get("name"):
                out[str(row["name"])] = row
    return out


def _bytes_verdict(name: str, row: dict, base_name: str, base_bps: float,
                   ceiling: float, problems: list[str]) -> None:
    """One compressed row's bytes/site report line + ceiling check."""
    bps = row.get("bytes_per_site")
    if not isinstance(bps, (int, float)) or bps <= 0:
        problems.append(f"{name}: bytes_per_site missing — cannot prove the "
                        f"compressed stream")
        return
    ratio = bps / base_bps
    gf = row.get("GFLOPS")
    gf_txt = f" at {gf:.3f} GF/s" if isinstance(gf, (int, float)) else ""
    print(f"  {name}: {bps:.0f} B/site vs {base_bps:.0f} ({base_name}) "
          f"= {(ratio - 1) * 100:+.1f}%{gf_txt}")
    if ratio > ceiling:
        problems.append(
            f"{name}: bytes/site {bps:.0f} is {ratio:.0%} of the 18-real "
            f"{base_name} ({base_bps:.0f}) — above the {ceiling:.0%} ceiling; "
            f"looks like a silent fallback to the uncompressed layout")


def compression_gate(current: dict) -> list[str]:
    """Presence + bytes/site checks on the compressed and depth-2 rows of
    the CURRENT artifact; -> list of problems (empty = gate passes).

    Bytes/site is a deterministic layout quantity, so unlike the throughput
    diff there is no noise retry: any violation is a real code-path change.
    """
    problems: list[str] = []
    t2 = _rows_by_name(current, "table2_variants")
    st = _rows_by_name(current, "stencil")

    # -- multiply rows: f32 + bf16 compressed vs the 18-real pallas rows ----
    mult_base: dict[str, dict] = {}
    for name in sorted(t2):
        row = t2[name]
        if (row.get("variant") == "pallas"
                and row.get("compression", "none") == "none"
                and isinstance(row.get("bytes_per_site"), (int, float))):
            mult_base.setdefault(str(row.get("dtype")), row)
    comp_rows = {n: r for n, r in t2.items() if "_two_row" in n}
    for dtype in ("float32", "bfloat16"):
        if not any(r.get("dtype") == dtype for r in comp_rows.values()):
            problems.append(f"table2: no table2_pallas_two_row_* row for "
                            f"{dtype} — compressed multiply not measured")
    for name in sorted(comp_rows):
        row = comp_rows[name]
        if row.get("compression") != "two_row":
            problems.append(f"{name}: row does not declare compression="
                            f"two_row — silent fallback to 18-real")
            continue
        dtype = str(row.get("dtype", "float32"))
        if dtype in mult_base:
            base = mult_base[dtype]
            base_bps, base_name = float(base["bytes_per_site"]), base["name"]
        elif "float32" in mult_base:
            # no uncompressed row at this dtype: scale the f32 one by the
            # storage-word width (the word COUNT is dtype-independent)
            scale = _WORD_BYTES.get(dtype, 4) / _WORD_BYTES["float32"]
            base_bps = float(mult_base["float32"]["bytes_per_site"]) * scale
            base_name = f"{mult_base['float32']['name']} scaled to {dtype}"
        else:
            problems.append(f"{name}: no 18-real pallas row in table2 to "
                            f"diff bytes/site against")
            continue
        _bytes_verdict(name, row, str(base_name), base_bps,
                       MULTIPLY_BYTES_RATIO, problems)

    # -- measured stencil rows: sibling = same name minus the _two_row tag --
    st_comp = {n: r for n, r in st.items()
               if "_two_row" in n and n.startswith("stencil_L")}
    for dtype in ("float32", "bfloat16"):
        if not any(r.get("dtype") == dtype for r in st_comp.values()):
            problems.append(f"stencil: no measured stencil_L*_two_row_* row "
                            f"for {dtype}")
    for name in sorted(st_comp):
        row = st_comp[name]
        if row.get("compression") != "two_row":
            problems.append(f"{name}: row does not declare compression="
                            f"two_row — silent fallback to 18-real")
            continue
        sibling = name.replace("_two_row", "")
        base = st.get(sibling)
        if not base or not isinstance(base.get("bytes_per_site"), (int, float)):
            problems.append(f"{name}: 18-real sibling row {sibling!r} "
                            f"missing — cannot diff bytes/site")
            continue
        _bytes_verdict(name, row, sibling, float(base["bytes_per_site"]),
                       STENCIL_BYTES_RATIO, problems)

    # -- depth-2 identity: every host count, both layouts, bit-identical ----
    for hosts in DEPTH2_HOSTS:
        for tag in ("", "_two_row"):
            name = f"stencil_depth2_identity_h{hosts}{tag}"
            row = st.get(name)
            if row is None:
                problems.append(f"stencil: {name} row missing — depth-2 "
                                f"halo path not exercised at {hosts} host(s)")
            elif row.get("error"):
                problems.append(f"{name}: subprocess failed: {row['error']}")
            elif row.get("identical") is not True:
                problems.append(f"{name}: depth-2 step NOT bit-identical to "
                                f"two depth-1 steps")
            else:
                d1 = row.get("t_two_depth1_us")
                d2 = row.get("t_one_depth2_us")
                timing = (f" ({d1:.0f}us -> {d2:.0f}us)"
                          if isinstance(d1, (int, float))
                          and isinstance(d2, (int, float)) else "")
                print(f"  {name}: identical, 1 exchange saved per 2 "
                      f"applications{timing}")
    return problems


def cg_gate(current: dict, baseline: dict | None) -> list[str]:
    """Convergence checks on the CG solver rows; -> problems (empty = pass).

    Iteration counts on the fixed-seed reference problem are deterministic,
    so there is no noise retry: a solve that needs more iterations to the
    same tolerance changed numerically, full stop.
    """
    problems: list[str] = []
    cur = _rows_by_name(current, "cg")
    row = cur.get("cg_residual_vs_time")
    if row is None:
        problems.append("cg: cg_residual_vs_time row missing — solver "
                        "convergence not measured")
        return problems
    if row.get("converged") is not True:
        problems.append(f"cg_residual_vs_time: solve did NOT converge to "
                        f"tol={row.get('tol')} within the iteration budget")
    # fused grid rows must carry their verification verdict (bitwise vs the
    # composed oracle at f32 storage, verify_tolerance at bf16)
    for name in sorted(cur):
        r = cur[name]
        if (name.startswith("cg_iter_") and r.get("fused")
                and r.get("verified") is not True):
            problems.append(f"{name}: fused path failed verification "
                            f"against the composed oracle")
    iters = row.get("iters_to_tol")
    if not isinstance(iters, (int, float)) or iters <= 0:
        problems.append("cg_residual_vs_time: iters_to_tol missing")
        return problems
    base_row = (_rows_by_name(baseline, "cg").get("cg_residual_vs_time")
                if baseline else None)
    if base_row is None:
        print(f"  cg_residual_vs_time: {int(iters)} iterations to "
              f"tol={row.get('tol')} (no committed baseline — the count "
              f"gates from the next artifact on)")
        return problems
    base_iters = base_row.get("iters_to_tol")
    if (base_row.get("tol") != row.get("tol")
            or not isinstance(base_iters, (int, float)) or base_iters <= 0):
        print("  cg_residual_vs_time: baseline measured a different tol — "
              "iteration counts not comparable")
        return problems
    ceiling = base_iters * (1.0 + CG_ITERS_HEADROOM)
    print(f"  cg_residual_vs_time: {int(iters)} iterations to "
          f"tol={row.get('tol')} vs baseline {int(base_iters)} "
          f"(ceiling {ceiling:.1f})")
    if iters > ceiling:
        problems.append(
            f"cg_residual_vs_time: {int(iters)} iterations to "
            f"tol={row.get('tol')} vs {int(base_iters)} in the committed "
            f"artifact (>{CG_ITERS_HEADROOM:.0%} more) — solver "
            f"convergence regressed")
    return problems


def chaos_gate(current: dict) -> list[str]:
    """Robustness checks on the ``serve_chaos`` row; -> problems (empty =
    pass).

    Zero-lost / bitwise / same-seed are determinism and accounting
    properties of the fixed-seed storm, so like the compression gate
    there is no noise retry: a violation is a real robustness break.
    The verdicts are computed by the benchmark itself (it holds both the
    storm and the baseline); this gate checks the flags so the tool stays
    importable without the jax stack.
    """
    row = _rows_by_name(current, "chaos").get("serve_chaos")
    if row is None:
        return ["chaos: serve_chaos row missing — the fault storm did not "
                "run (or the chaos table was dropped)"]
    if row.get("error"):
        return [f"serve_chaos: row errored: {row['error']}"]
    problems = []
    if not row.get("faults_fired", 0):
        problems.append("serve_chaos: the storm fired no faults — the row "
                        "proves nothing")
    for flag, what in (
        ("zero_lost", "LOST REQUESTS — a submitted request resolved as "
                      "neither result nor structured failure"),
        ("clean_results_bitwise", "a request that succeeded under the storm "
                                  "is NOT bitwise identical to the "
                                  "fault-free baseline"),
        ("same_seed_reproduces", "the same seed did NOT reproduce the same "
                                 "fault sequence"),
        ("p99_inflation_bounded", f"p99 inflation "
                                  f"{row.get('p99_inflation')}x exceeds the "
                                  f"ceiling"),
    ):
        if row.get(flag) is not True:
            problems.append(f"serve_chaos: {what}")
    if not problems:
        print(f"  serve_chaos: {row.get('faults_fired')} faults "
              f"({row.get('fired_by_site')}), "
              f"{row.get('completed_ok')} ok + "
              f"{row.get('failed_structured')} structured failures, "
              f"0 lost; p99 x{row.get('p99_inflation')}, recovery max "
              f"{row.get('recovery_max_s')}s, same-seed reproduced")
    return problems


def tenancy_gate(current: dict) -> list[str]:
    """Multi-tenant SLO checks on the ``serve_tenancy`` row; -> problems
    (empty = pass).

    Fairness / latency-isolation / brownout-replay verdicts are computed
    by the benchmark itself against its own in-process unloaded baseline
    (paired measurements, not absolute timings), so like the chaos gate
    this tool only checks the flags and stays importable without the jax
    stack.  No noise retry: a violation is a real scheduling break.
    """
    row = _rows_by_name(current, "tenancy").get("serve_tenancy")
    if row is None:
        return ["tenancy: serve_tenancy row missing — the adversarial "
                "tenant mix did not run (or the tenancy table was dropped)"]
    if row.get("error"):
        return [f"serve_tenancy: row errored: {row['error']}"]
    problems = []
    if row.get("zero_lost") is not True:
        problems.append("serve_tenancy: LOST REQUESTS — a submitted request "
                        "resolved as neither result, structured failure, nor "
                        "deterministic front-door rejection")
    if row.get("latency_bounded") is not True:
        problems.append(f"serve_tenancy: latency-class p99 under the bulk "
                        f"burst is {row.get('latency_inflation')}x the "
                        f"unloaded p99 — exceeds the ceiling (tenant "
                        f"isolation broke)")
    if row.get("fairness_ok") is not True:
        problems.append(f"serve_tenancy: Jain fairness "
                        f"{row.get('jain_fairness')} over delivered bulk "
                        f"work is under the floor — the burst tenant "
                        f"starved the drip tenant")
    if not row.get("brownout_transitions", 0):
        problems.append("serve_tenancy: the flood never climbed the "
                        "brownout ladder — the row proves nothing about "
                        "overload control")
    if row.get("brownout_signature_reproduced") is not True:
        problems.append("serve_tenancy: the same seed did NOT reproduce "
                        "the brownout transition log")
    if row.get("same_seed_reproduces") is not True:
        problems.append("serve_tenancy: the same seed did NOT reproduce "
                        "the same fault sequence")
    if row.get("clean_results_bitwise") is not True:
        problems.append("serve_tenancy: a multiply that succeeded under the "
                        "storm is NOT bitwise identical to the clean "
                        "loaded run")
    if not problems:
        print(f"  serve_tenancy: latency p99 x{row.get('latency_inflation')}"
              f" under flood, Jain {row.get('jain_fairness')}, brownout "
              f"{row.get('brownout_transitions')} transition(s) "
              f"{row.get('brownout_signature')}, "
              f"{row.get('quota_rejected')} quota-rejected, 0 lost, "
              f"same-seed reproduced")
    return problems


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default=DEFAULT_ARTIFACT,
                    help="freshly generated artifact (default: %(default)s)")
    ap.add_argument("--baseline", default="git:HEAD",
                    help="path or git:REV of the committed artifact "
                         "(default: %(default)s)")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated fractional GFLOPS drop "
                         "(default: %(default)s)")
    ap.add_argument("--no-retry", action="store_true",
                    help="fail flagged rows immediately instead of "
                         "re-measuring them (median of 3)")
    ap.add_argument("--no-compression-gate", action="store_true",
                    help="skip the compressed-gauge/depth-2 row checks "
                         "(pre-compression artifacts)")
    ap.add_argument("--no-cg-gate", action="store_true",
                    help="skip the CG iterations-to-tolerance checks "
                         "(pre-solver artifacts)")
    ap.add_argument("--no-chaos-gate", action="store_true",
                    help="skip the serve_chaos robustness checks "
                         "(pre-chaos artifacts)")
    ap.add_argument("--no-tenancy-gate", action="store_true",
                    help="skip the serve_tenancy multi-tenant SLO checks "
                         "(pre-tenancy artifacts)")
    ap.add_argument("--no-provenance-gate", action="store_true",
                    help="skip the provenance-block checks "
                         "(pre-provenance artifacts)")
    ap.add_argument("--rebaseline-note", default="",
                    help="acknowledge a changed jax/backend environment "
                         "(required when the identity keys drift between "
                         "baseline and current)")
    args = ap.parse_args(argv)

    try:
        with open(args.current) as f:
            current = json.load(f)
    except FileNotFoundError:
        print(f"bench_diff: current artifact {args.current!r} missing", file=sys.stderr)
        return 2

    # baseline-free checks on the fresh artifact itself — these run (and can
    # fail) even on a first PR with nothing committed to regress against.
    # Only full harness artifacts carry the gated tables (benchmarks.run
    # emits them even on error, as ``{table}_error`` rows); ad-hoc payloads
    # without them have nothing to prove.
    tables = current.get("tables", {})
    gate_applies = "table2_variants" in tables or "stencil" in tables
    baseline = load_baseline(args.baseline)

    problems: list[str] = []
    if not args.no_provenance_gate and gate_applies:
        prov_problems = provenance_problems(
            current, baseline, rebaseline_note=args.rebaseline_note)
        if prov_problems:
            for p in prov_problems:
                print(f"  FAIL provenance: {p}", file=sys.stderr)
            problems.extend(prov_problems)
        else:
            prov = current.get("provenance", {})
            print(f"bench_diff: provenance ok — jax {prov.get('jax_version')}"
                  f"/{prov.get('jaxlib_version')} on {prov.get('backend')}"
                  f" ({prov.get('device_kind')}), git "
                  f"{str(prov.get('git_sha'))[:12]}, autotune schema "
                  f"v{prov.get('autotune_cache_schema')}")
    if not args.no_compression_gate and gate_applies:
        print("bench_diff: compression / depth-2 gate (current artifact):")
        comp_problems = compression_gate(current)
        for p in comp_problems:
            print(f"  FAIL {p}", file=sys.stderr)
        problems.extend(comp_problems)
    if not args.no_cg_gate and gate_applies:
        print("bench_diff: CG convergence gate (iterations to tolerance):")
        cg_problems = cg_gate(current, baseline)
        for p in cg_problems:
            print(f"  FAIL {p}", file=sys.stderr)
        problems.extend(cg_problems)
    if not args.no_chaos_gate and gate_applies:
        print("bench_diff: chaos gate (fault storm robustness contract):")
        chaos_problems = chaos_gate(current)
        for p in chaos_problems:
            print(f"  FAIL {p}", file=sys.stderr)
        problems.extend(chaos_problems)
    if not args.no_tenancy_gate and gate_applies:
        print("bench_diff: tenancy gate (multi-tenant SLO contract):")
        tenancy_problems = tenancy_gate(current)
        for p in tenancy_problems:
            print(f"  FAIL {p}", file=sys.stderr)
        problems.extend(tenancy_problems)

    if baseline is None:
        print(f"bench_diff: no baseline at {args.baseline!r}; nothing to diff")
        if problems:
            print(f"bench_diff: artifact gate failed "
                  f"({len(problems)} problem(s))", file=sys.stderr)
            return 1
        return 0

    only_base, only_cur = asymmetric_rows(baseline, current)
    for table, name in only_base:
        print(f"bench_diff: WARNING row {table}/{name} present in the "
              f"baseline but MISSING from the current run (dropped "
              f"measurement — not compared)", file=sys.stderr)
    for table, name in only_cur:
        print(f"bench_diff: WARNING row {table}/{name} is new in the current "
              f"run (no baseline — not compared; it gates from the next "
              f"committed artifact on)", file=sys.stderr)

    compared, regressions = diff(baseline, current, args.threshold)
    if not compared:
        print("bench_diff: no shared measured rows between baseline and current")
        if problems:
            print(f"bench_diff: compression gate failed "
                  f"({len(problems)} problem(s))", file=sys.stderr)
            return 1
        return 0
    width = max(len(f"{c['table']}/{c['name']}") for c in compared)
    for c in compared:
        flag = "  << REGRESSION" if c in regressions else ""
        print(f"{c['table'] + '/' + c['name']:<{width}}  "
              f"{c['baseline']:>10.3f} -> {c['current']:>10.3f} GF/s  "
              f"({c['delta_pct']:+6.1f}%){flag}")
    if regressions and not args.no_retry:
        print(f"\nbench_diff: {len(regressions)} flagged row(s); re-measuring "
              f"(median of {1 + RETRY_RUNS}) before failing the gate...")
        quick = bool(current.get("quick", True))  # re-measure at the same mode
        regressions, recovered = retry_regressions(
            regressions, args.threshold,
            remeasure_fn=lambda keys: remeasure_rows(keys, quick=quick),
        )
        for r in recovered:
            print(f"  recovered {r['table']}/{r['name']}: median "
                  f"{r['current_median']:.3f} over {r['observations']} runs "
                  f"({r['delta_pct']:+.1f}%) — timer noise, not a regression")
        for r in regressions:
            print(f"  CONFIRMED {r['table']}/{r['name']}: median "
                  f"{r['current_median']:.3f} over {r['observations']} runs "
                  f"({r['delta_pct']:+.1f}%)", file=sys.stderr)
    if regressions:
        print(f"\nbench_diff: {len(regressions)}/{len(compared)} rows regressed "
              f">{args.threshold:.0%}", file=sys.stderr)
        return 1
    if problems:
        print(f"\nbench_diff: compression gate failed "
              f"({len(problems)} problem(s))", file=sys.stderr)
        return 1
    print(f"\nbench_diff: OK — {len(compared)} rows within {args.threshold:.0%}"
          + ("; compression/depth-2 rows verified"
             if gate_applies and not args.no_compression_gate else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

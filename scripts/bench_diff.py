#!/usr/bin/env python
"""Diff BENCH_su3.json throughput rows against the previous PR's artifact.

The ROADMAP's regression tripwire: every PR commits a fresh quick-mode
``BENCH_su3.json``; this tool compares the measured throughput rows of a new
run against the committed baseline and exits non-zero when any shared row
regresses by more than the threshold (default 15%).

Rows compared by (table, name):
  * engine rows        ``GFLOPS`` (best-iteration useful GF/s)
  * serve rows         ``sustained_gflops_busy`` (useful flops / kernel wall)

Baselines can be a file path or a git blob (``git:REV`` reads
``REV:BENCH_su3.json``), so the default compares working-tree results
against the last commit:

    PYTHONPATH=src python scripts/bench_diff.py              # vs git:HEAD
    python scripts/bench_diff.py --baseline old.json --current new.json
    python scripts/bench_diff.py --threshold 0.25            # looser gate

A missing baseline (first PR, artifact not committed at REV) is a clean
exit — there is nothing to regress against.

Note on noise: quick-mode rows on a loaded CPU dev host can swing past 15%
in either direction (single-iteration L=4 timings are the worst).  The gate
therefore RE-MEASURES flagged rows before failing: the benchmark harness is
re-run twice more and each flagged row is judged on the MEDIAN of its three
observations — a row that recovers is timer noise, not a regression, and
passes without human retry.  ``--no-retry`` keeps the old single-pass
behavior (CI contexts that re-run the whole job themselves).  On the real
TPU target the variance is far below the threshold.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
import tempfile

DEFAULT_ARTIFACT = "BENCH_su3.json"
RETRY_RUNS = 2  # re-measurements per flagged gate (median of 1 + RETRY_RUNS)
# (metric key, minimum absolute baseline value worth gating on) — rows below
# the floor are pure timer noise at CPU quick-mode sizes.
_METRICS = (("GFLOPS", 0.05), ("sustained_gflops_busy", 0.01))


def collect_rows(
    payload: dict, *, apply_floor: bool = True
) -> dict[tuple[str, str], float]:
    """-> {(table, row name): throughput} for every measured row.

    The noise floor gates the BASELINE side only: a baseline row below the
    floor is timer noise not worth diffing, but a *current* row must be
    collected however small — a collapse from above-floor to ~zero is the
    exact regression the gate exists to catch.
    """
    out: dict[tuple[str, str], float] = {}
    for table, rows in payload.get("tables", {}).items():
        if not isinstance(rows, list):
            continue
        for row in rows:
            if not isinstance(row, dict):
                continue
            name = row.get("name")
            if not name:
                continue
            for metric, floor in _METRICS:
                val = row.get(metric)
                if isinstance(val, (int, float)):
                    if not apply_floor or val >= floor:
                        out[(table, str(name))] = float(val)
                    break  # first present metric decides the row
    return out


def load_baseline(spec: str) -> dict | None:
    """Baseline payload from a path or ``git:REV`` blob; None when absent."""
    if spec.startswith("git:"):
        rev = spec[len("git:"):] or "HEAD"
        try:
            text = subprocess.run(
                ["git", "show", f"{rev}:{DEFAULT_ARTIFACT}"],
                capture_output=True, text=True, check=True,
            ).stdout
        except (subprocess.CalledProcessError, FileNotFoundError):
            return None
        return json.loads(text)
    try:
        with open(spec) as f:
            return json.load(f)
    except FileNotFoundError:
        return None


def diff(
    baseline: dict, current: dict, threshold: float
) -> tuple[list[dict], list[dict]]:
    """-> (all compared rows, the regressions among them)."""
    base_rows = collect_rows(baseline)
    cur_rows = collect_rows(current, apply_floor=False)
    compared, regressions = [], []
    for key in sorted(base_rows.keys() & cur_rows.keys()):
        base, cur = base_rows[key], cur_rows[key]
        drop = (base - cur) / base if base > 0 else 0.0
        entry = {
            "table": key[0], "name": key[1],
            "baseline": round(base, 3), "current": round(cur, 3),
            "delta_pct": round(-drop * 100, 1),
        }
        compared.append(entry)
        if drop > threshold:
            regressions.append(entry)
    return compared, regressions


def asymmetric_rows(
    baseline: dict, current: dict
) -> tuple[list[tuple[str, str]], list[tuple[str, str]]]:
    """-> (rows only in baseline, rows only in current), named and sorted.

    A row present in the committed artifact but missing from the fresh run
    is a *dropped measurement* — historically skipped silently, which let a
    batch of new rows (e.g. a fresh stencil table) mask the disappearance
    of an old one.  Both directions are reported by name so the gate's
    output always accounts for every row it did NOT compare.

    Presence is judged WITHOUT the noise floor on either side: the floor
    decides what is worth *gating*, not what exists — a below-floor
    baseline row that vanishes is still a dropped measurement, and must
    not be misreported as the current side's "new" row.
    """
    base_rows = collect_rows(baseline, apply_floor=False)
    cur_rows = collect_rows(current, apply_floor=False)
    only_base = sorted(base_rows.keys() - cur_rows.keys())
    only_cur = sorted(cur_rows.keys() - base_rows.keys())
    return only_base, only_cur


def remeasure_rows(
    keys: set[tuple[str, str]], runs: int = RETRY_RUNS, quick: bool = True,
) -> dict[tuple[str, str], list[float]]:
    """Re-run the benchmark harness ``runs`` times; collect the flagged rows.

    Each run regenerates the artifact in a temp dir at the SAME mode
    (quick/full) that produced the one under test — the rows are not
    independently runnable, the harness is the measurement unit — and only
    the flagged (table, name) values are kept.  Rows in the ``dispatch``
    table come from ``scripts/profile_dispatch.py``, so that profiler is
    re-run (merging into the same temp artifact) whenever a dispatch row is
    flagged.  A run that fails or omits a row contributes nothing for it;
    the median is taken over whatever observations exist.
    """
    mode = ["--quick"] if quick else []
    profiler = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "profile_dispatch.py")
    need_dispatch = any(table == "dispatch" for table, _name in keys)
    out: dict[tuple[str, str], list[float]] = {key: [] for key in keys}
    for _ in range(runs):
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "bench_remeasure.json")
            subprocess.run(
                [sys.executable, "-m", "benchmarks.run"] + mode
                + ["--json", path],
                capture_output=True, text=True,
            )
            if need_dispatch:
                subprocess.run(
                    [sys.executable, profiler] + mode + ["--json", path],
                    capture_output=True, text=True,
                )
            if not os.path.exists(path):
                continue
            with open(path) as f:
                rows = collect_rows(json.load(f), apply_floor=False)
            for key in keys:
                if key in rows:
                    out[key].append(rows[key])
    return out


def retry_regressions(
    regressions: list[dict], threshold: float,
    remeasure_fn=None,
) -> tuple[list[dict], list[dict]]:
    """Median-of-3 verdict on flagged rows: (still regressed, recovered).

    Each flagged row's single-pass current value is pooled with the
    re-measured observations; the row fails only if the MEDIAN still drops
    past the threshold.
    """
    if remeasure_fn is None:
        remeasure_fn = remeasure_rows
    keys = {(r["table"], r["name"]) for r in regressions}
    extra = remeasure_fn(keys)
    still, recovered = [], []
    for r in regressions:
        vals = [r["current"]] + extra.get((r["table"], r["name"]), [])
        med = float(statistics.median(vals))
        base = r["baseline"]
        drop = (base - med) / base if base > 0 else 0.0
        verdict = dict(
            r, current_median=round(med, 3), observations=len(vals),
            delta_pct=round(-drop * 100, 1),
        )
        (still if drop > threshold else recovered).append(verdict)
    return still, recovered


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default=DEFAULT_ARTIFACT,
                    help="freshly generated artifact (default: %(default)s)")
    ap.add_argument("--baseline", default="git:HEAD",
                    help="path or git:REV of the committed artifact "
                         "(default: %(default)s)")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated fractional GFLOPS drop "
                         "(default: %(default)s)")
    ap.add_argument("--no-retry", action="store_true",
                    help="fail flagged rows immediately instead of "
                         "re-measuring them (median of 3)")
    args = ap.parse_args(argv)

    baseline = load_baseline(args.baseline)
    if baseline is None:
        print(f"bench_diff: no baseline at {args.baseline!r}; nothing to gate")
        return 0
    try:
        with open(args.current) as f:
            current = json.load(f)
    except FileNotFoundError:
        print(f"bench_diff: current artifact {args.current!r} missing", file=sys.stderr)
        return 2

    only_base, only_cur = asymmetric_rows(baseline, current)
    for table, name in only_base:
        print(f"bench_diff: WARNING row {table}/{name} present in the "
              f"baseline but MISSING from the current run (dropped "
              f"measurement — not compared)", file=sys.stderr)
    for table, name in only_cur:
        print(f"bench_diff: WARNING row {table}/{name} is new in the current "
              f"run (no baseline — not compared; it gates from the next "
              f"committed artifact on)", file=sys.stderr)

    compared, regressions = diff(baseline, current, args.threshold)
    if not compared:
        print("bench_diff: no shared measured rows between baseline and current")
        return 0
    width = max(len(f"{c['table']}/{c['name']}") for c in compared)
    for c in compared:
        flag = "  << REGRESSION" if c in regressions else ""
        print(f"{c['table'] + '/' + c['name']:<{width}}  "
              f"{c['baseline']:>10.3f} -> {c['current']:>10.3f} GF/s  "
              f"({c['delta_pct']:+6.1f}%){flag}")
    if regressions and not args.no_retry:
        print(f"\nbench_diff: {len(regressions)} flagged row(s); re-measuring "
              f"(median of {1 + RETRY_RUNS}) before failing the gate...")
        quick = bool(current.get("quick", True))  # re-measure at the same mode
        regressions, recovered = retry_regressions(
            regressions, args.threshold,
            remeasure_fn=lambda keys: remeasure_rows(keys, quick=quick),
        )
        for r in recovered:
            print(f"  recovered {r['table']}/{r['name']}: median "
                  f"{r['current_median']:.3f} over {r['observations']} runs "
                  f"({r['delta_pct']:+.1f}%) — timer noise, not a regression")
        for r in regressions:
            print(f"  CONFIRMED {r['table']}/{r['name']}: median "
                  f"{r['current_median']:.3f} over {r['observations']} runs "
                  f"({r['delta_pct']:+.1f}%)", file=sys.stderr)
    if regressions:
        print(f"\nbench_diff: {len(regressions)}/{len(compared)} rows regressed "
              f">{args.threshold:.0%}", file=sys.stderr)
        return 1
    print(f"\nbench_diff: OK — {len(compared)} rows within {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

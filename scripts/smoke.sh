#!/usr/bin/env bash
# Quick-mode smoke: fast-tier suite + machine-readable benchmark rows.
#
#   scripts/smoke.sh            # fast tests (-m "not slow") + benchmarks
#   scripts/smoke.sh --full     # also run the slow tier (serving/megakernel/
#                               # e2e tests — the ~12-minute tail)
#   scripts/smoke.sh --no-bench # tests only
#
# The tier-1 gate (`python -m pytest -x -q`, no marker filter) still runs
# everything; smoke iterations default to the fast tier so the slow serving
# suites no longer gate every edit loop.
#
# Writes BENCH_su3.json in the repo root so the perf trajectory is
# comparable across PRs (schema: su3-bench-rows/v1).  The stencil table
# (benchmarks/stencil.py) rides in benchmarks.run alongside the rest.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

RUN_FULL=0
RUN_BENCH=1
for arg in "$@"; do
  case "$arg" in
    --full) RUN_FULL=1 ;;
    --no-bench) RUN_BENCH=0 ;;
    *) echo "unknown flag: $arg" >&2; exit 2 ;;
  esac
done

echo "== compressed-gauge spot check (reconstruct accuracy + depth-2 identity) =="
# Seconds, not minutes: ONE reconstruct bit-accuracy check and ONE
# depth-2-vs-two-exchange identity check so a broken compression path
# surfaces before the full fast tier spins up.  The exhaustive sweeps
# (layout x dtype x tile property tests, multi-host subprocess identity,
# autotune sweeps) stay in the fast/slow pytest tiers below.
python -m pytest -x -q \
  tests/test_compression.py::test_compressed_multiply_matches_full_kernel_on_su3 \
  "tests/test_compression.py::test_stencil_depth2_single_host_bit_identical[two_row]"

echo "== chaos spot check (storm zero-lost + same-seed fault reproduction) =="
# Seconds, not minutes: ONE seeded fault-storm run through the serving
# stack (every request must resolve, retried results bitwise clean) and
# ONE FaultPlan determinism check, so a broken robustness seam surfaces
# before the full tiers.  The full chaos matrix (-m chaos) rides in the
# fast tier below.
python -m pytest -x -q \
  tests/test_robustness.py::test_storm_zero_lost_and_bitwise_clean \
  tests/test_chaos.py::test_same_seed_reproduces_fault_log

echo "== tenancy spot check (deficit-fair turns + brownout replay determinism) =="
# Seconds, not minutes: ONE service-level fairness check (two tenants'
# bulk queues earn equal DRR turns however lopsided the backlogs) and ONE
# brownout-ladder signature replay check, so a broken scheduler or a
# non-deterministic overload ladder surfaces before the full tiers.  The
# full multi-tenant matrix (-m tenancy) rides in the fast tier below.
python -m pytest -x -q \
  tests/test_tenancy.py::test_deficit_fair_turns_across_tenants_in_service \
  tests/test_tenancy.py::test_brownout_signature_is_replay_deterministic

echo "== CG solver spot check (convergence pin + fused bit-identity) =="
# The flagship solve, in seconds: ONE end-to-end convergence check against
# the independent oracle and ONE fused-vs-composed bit-identity check, so
# a numerically broken solver surfaces before the full tiers and the
# benchmark harness spin up.  The full grid (layout x dtype x compression
# property tests, multi-host subprocess identity, serving mixes) stays in
# the pytest tiers below.
python -m pytest -x -q \
  tests/test_cg_solve.py::test_cg_converges_and_solves_the_system \
  tests/test_cg_solve.py::test_fused_composed_bit_identical_f32

echo "== fast tier (-m 'not slow') =="
python -m pytest -x -q -m "not slow"

if [[ "$RUN_FULL" == 1 ]]; then
  echo "== slow tier (-m slow: serving/megakernel/e2e) =="
  python -m pytest -x -q -m slow
fi

if [[ "$RUN_BENCH" == 1 ]]; then
  echo "== fig7 multi-controller dryrun (2 controllers, divergence gate) =="
  # Two identical controller processes run the strong-scaling curve through
  # the real (host, device) MeshSpec plan path; the launcher exits non-zero
  # if any point's result lattice diverges from the single-host reference
  # on any controller.
  python -m repro.launch.dryrun --su3-fig7 \
    --L 4 --device-counts 1,2 --hosts 2 --controllers 2 --iterations 1 \
    > /dev/null

  echo "== quick benchmarks incl. stencil table (BENCH_su3.json) =="
  python -m benchmarks.run --quick --json BENCH_su3.json
  echo "== dispatch profiler (dispatch table -> BENCH_su3.json) =="
  python scripts/profile_dispatch.py --quick --json BENCH_su3.json
  echo "== trace report (artifacts/serve_trace from the traced serve row) =="
  # benchmarks.run's serve section exported the trace pair into the
  # gitignored artifacts/ dir; the report must render (span tree +
  # attribution) or the obs layer broke
  python scripts/trace_report.py artifacts/serve_trace.jsonl > /dev/null
  python scripts/trace_report.py artifacts/serve_trace.chrome.json | tail -8
  echo "== bench diff vs last committed artifact (>15% GFLOPS drop fails) =="
  # BENCH_DIFF_THRESHOLD loosens the gate on noisy shared dev hosts; flagged
  # rows are re-measured (median of 3) by scripts/bench_diff.py before the
  # gate fails, so residual failures are real regressions, not timer noise.
  # Rows present on only one side are named WARNINGs, never silent skips.
  # The CG gate rides in the same call: cg_residual_vs_time must converge,
  # and may not need >10% more iterations to the committed tol.  The chaos
  # gate does too: the serve_chaos storm row must report zero lost
  # requests, bitwise-clean successes, and same-seed fault reproduction.
  # So does the tenancy gate: the serve_tenancy row must hold the latency
  # p99 ceiling under the bulk flood, clear the Jain fairness floor, and
  # reproduce its brownout transition log from the same seed.
  python scripts/bench_diff.py --current BENCH_su3.json --baseline git:HEAD \
    --threshold "${BENCH_DIFF_THRESHOLD:-0.15}"
fi

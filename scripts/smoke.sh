#!/usr/bin/env bash
# Quick-mode smoke: tier-1 suite + machine-readable benchmark rows.
#
#   scripts/smoke.sh            # pytest + benchmarks --quick --json
#   scripts/smoke.sh --no-bench # tests only
#
# Writes BENCH_su3.json in the repo root so the perf trajectory is
# comparable across PRs (schema: su3-bench-rows/v1).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 suite =="
python -m pytest -x -q

if [[ "${1:-}" != "--no-bench" ]]; then
  echo "== fig7 multi-controller dryrun (2 controllers, divergence gate) =="
  # Two identical controller processes run the strong-scaling curve through
  # the real (host, device) MeshSpec plan path; the launcher exits non-zero
  # if any point's result lattice diverges from the single-host reference
  # on any controller.
  python -m repro.launch.dryrun --su3-fig7 \
    --L 4 --device-counts 1,2 --hosts 2 --controllers 2 --iterations 1 \
    > /dev/null

  echo "== quick benchmarks (BENCH_su3.json) =="
  python -m benchmarks.run --quick --json BENCH_su3.json
  echo "== dispatch profiler (dispatch table -> BENCH_su3.json) =="
  python scripts/profile_dispatch.py --quick --json BENCH_su3.json
  echo "== bench diff vs last committed artifact (>15% GFLOPS drop fails) =="
  # BENCH_DIFF_THRESHOLD loosens the gate on noisy shared dev hosts; flagged
  # rows are re-measured (median of 3) by scripts/bench_diff.py before the
  # gate fails, so residual failures are real regressions, not timer noise.
  python scripts/bench_diff.py --current BENCH_su3.json --baseline git:HEAD \
    --threshold "${BENCH_DIFF_THRESHOLD:-0.15}"
fi

"""Reproduce the paper's experiment structure end-to-end (CPU-scaled).

Walks the paper's §4 narrative: baseline variants (Table 2), placement
policies (Table 3/5/6), VersionX, explicit GEMM (Fig 9), and prints the
three-term rooflines for Xeon / PIUMA / v5e (Table 1, §5.3, Fig 10).

    PYTHONPATH=src python examples/su3_paper_repro.py [--L 8]
"""
import argparse

from benchmarks import (
    fig9_gemm_vs_dot, fig10_arch_compare, table1_roofline,
    table2_variants, table3_placement,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--L", type=int, default=8)
    args = ap.parse_args()

    print("== Table 1: roofline ladders (Xeon + v5e) ==")
    for r in table1_roofline.xeon_ladder()[:3] + table1_roofline.v5e_ladder():
        print("  ", r)
    print("== Table 2: variant baselines ==")
    for r in table2_variants.run(L=args.L, iters=(1, 5)):
        print("  ", {k: r[k] for k in ("name", "GFLOPS", "GBYTES", "verified")})
    print("== Table 3: placement (NUMA/first-touch analog) ==")
    for r in table3_placement.run(L=args.L):
        print("  ", {k: r[k] for k in ("name", "GFLOPS", "init_s", "scatter_s")})
    print("== Fig 9: explicit GEMM vs compiler dot ==")
    for r in fig9_gemm_vs_dot.run(sizes=(args.L,)):
        print("  ", {k: r[k] for k in ("name", "GFLOPS", "GBYTES")})
    print("== Fig 10: cross-architecture bound ==")
    for r in fig10_arch_compare.run(L=args.L):
        print("  ", r)


if __name__ == "__main__":
    main()

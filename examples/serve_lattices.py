"""Batched SU3 lattice serving through the SU3Service front door.

Each request carries its own (A, B) lattice pair.  Requests flow through the
dynamic batcher ((L, k) buckets, warm-size padding, admission control) into a
warm pool of vmapped ExecutionPlan runners — no per-request compilation, no
per-layout wiring, and (with ``--bf16``) bf16-storage / f32-accumulate plans
that stream half the HBM bytes.  The plan tuple (layout, kernel, tile) and
the default chain depth come from the persistent autotune cache, so the
first run on a device measures once and every later process starts tuned.

    PYTHONPATH=src python examples/serve_lattices.py --batch 8 --L 4 --chain 3
    PYTHONPATH=src python examples/serve_lattices.py --batch 8 --bf16
    PYTHONPATH=src python examples/serve_lattices.py --batch 8 --autotune
"""
import argparse
import time

import jax
import numpy as np

from repro.serve.su3 import BatcherConfig, ServiceConfig, SU3Service, request_flops


def _random_requests(batch: int, n_sites: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (batch, n_sites, 4, 3, 3, 2))
    b = jax.random.normal(kb, (batch, 4, 3, 3, 2))
    return jax.lax.complex(a[..., 0], a[..., 1]), jax.lax.complex(b[..., 0], b[..., 1])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8, help="independent user lattices")
    ap.add_argument("--L", type=int, default=4)
    ap.add_argument("--chain", type=int, default=0,
                    help="multiplies chained per request "
                         "(0 = the autotuned fused depth from the cache)")
    ap.add_argument("--tile", type=int, default=0,
                    help="explicit tile; overrides --autotune (no point paying "
                         "the sweep just to discard its tile)")
    ap.add_argument("--bf16", action="store_true",
                    help="bf16-storage / f32-accumulate serving plans")
    ap.add_argument("--autotune", action="store_true",
                    help="build the pool through the persistent autotune cache "
                         "(first run measures once, later runs start tuned)")
    args = ap.parse_args()

    svc = SU3Service(ServiceConfig(
        dtype="bfloat16" if args.bf16 else "float32",
        accum_dtype="float32" if args.bf16 else "",
        autotune=args.autotune and not args.tile,
        tile=args.tile,
        batcher=BatcherConfig(
            max_batch=max(8, args.batch),
            warm_batch_sizes=(1, 2, 4, 8, max(8, args.batch)),
            max_queue_depth=4 * max(8, args.batch),
        ),
    ))

    n_sites = args.L**4
    a, b = _random_requests(args.batch, n_sites)
    k = args.chain or None  # None => tuned_fused_k (autotune) / service default

    # Warm pass: pay plan build + jit outside the timed window (a real
    # deployment does this at rollout, not inside a user's request).
    ids = [svc.submit(a[i], b[i], k=k) for i in range(args.batch)]
    svc.run_until_drained()
    resolved_k = args.chain or svc.default_k_for(args.L)
    for rid in ids:
        svc.pop_result(rid)
    svc.metrics.reset()

    t0 = time.perf_counter()
    ids = [svc.submit(a[i], b[i], k=k) for i in range(args.batch)]
    served = svc.run_until_drained()
    wall = time.perf_counter() - t0
    c = [svc.pop_result(rid) for rid in ids]

    ecfg = svc.runner_for(args.L).cfg
    print(f"plan: layout={ecfg.layout.value} variant={ecfg.variant} "
          f"tile={ecfg.tile} dtype={ecfg.dtype}"
          + (f" accum={ecfg.accum_dtype}" if ecfg.is_mixed_precision else "")
          + f" chain_k={resolved_k}")
    flops = args.batch * request_flops(n_sites, resolved_k)
    print(f"served {served} lattices (L={args.L}, {n_sites} sites, "
          f"chain={resolved_k}) on {svc.runner_for(args.L).n_devices} device(s) "
          f"in {wall*1e3:.1f} ms -> {flops / wall / 1e9:.2f} GF/s aggregate")
    snap = svc.metrics.snapshot()
    print(f"metrics: p50={snap['latency_p50_ms']} ms "
          f"p99={snap['latency_p99_ms']} ms "
          f"occupancy={snap['mean_batch_occupancy']} "
          f"live/batch={snap['mean_live_batch']} "
          f"dispatches={snap['dispatches']}")
    print("sample C[0,0,0]:", np.asarray(jax.device_get(c[0]))[0, 0, 0])


if __name__ == "__main__":
    main()

"""Batched SU3 lattice serving: the "many users" scenario.

Each request carries its own (A, B) lattice pair; the BatchedLatticeRunner
pushes the whole batch through ONE vmapped, sharded ExecutionPlan step — no
per-request compilation, no per-layout wiring.  The plan tuple (layout,
kernel, tile) comes from the persistent autotune cache, so the first run on
a device measures once and every later process starts tuned.

    PYTHONPATH=src python examples/serve_lattices.py --batch 8 --L 4 --chain 3
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune
from repro.core.su3.layouts import Layout
from repro.core.su3.plan import BatchedLatticeRunner, EngineConfig


def _random_requests(batch: int, n_sites: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (batch, n_sites, 4, 3, 3, 2))
    b = jax.random.normal(kb, (batch, 4, 3, 3, 2))
    return jax.lax.complex(a[..., 0], a[..., 1]), jax.lax.complex(b[..., 0], b[..., 1])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8, help="independent user lattices")
    ap.add_argument("--L", type=int, default=4)
    ap.add_argument("--chain", type=int, default=1,
                    help="multiplies chained per request (fused when >1)")
    ap.add_argument("--tile", type=int, default=0,
                    help="override the autotuned tile (0 = use the cache)")
    args = ap.parse_args()

    if args.tile:
        # explicit tile: no point paying the autotune sweep just to discard it
        cfg = EngineConfig(L=args.L, layout=Layout.SOA, variant="pallas", tile=args.tile)
    else:
        cfg = autotune.tuned_engine_config(L=args.L)  # measures once, then cached
    print(f"tuned plan: layout={cfg.layout.value} variant={cfg.variant} tile={cfg.tile}")

    runner = BatchedLatticeRunner(cfg)
    n_sites = cfg.shape.n_sites
    a, b = _random_requests(args.batch, n_sites)

    t0 = time.perf_counter()
    c = runner.multiply(a, b, k=args.chain)
    c.block_until_ready()
    wall = time.perf_counter() - t0

    flops = args.batch * args.chain * 864 * n_sites
    print(f"served {args.batch} lattices (L={args.L}, {n_sites} sites, "
          f"chain={args.chain}) on {runner.n_devices} device(s) "
          f"in {wall*1e3:.1f} ms -> {flops / wall / 1e9:.2f} GF/s aggregate")
    print("sample C[0,0,0]:", np.asarray(jax.device_get(c))[0, 0, 0, 0])


if __name__ == "__main__":
    main()

"""End-to-end training driver: train a reduced LM for a few hundred steps
on CPU with checkpointing, resume, and loss tracking.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-4b --steps 300
    PYTHONPATH=src python examples/train_lm.py --arch granite-moe-1b-a400m --steps 200
"""
import argparse

from repro.configs import ALL_ARCHS, get_config
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch {args.arch} (reduced: {cfg.n_layers}L d{cfg.d_model}, "
          f"~{cfg.n_params() / 1e6:.1f}M params)")
    tcfg = TrainConfig(
        steps=args.steps, seq_len=args.seq_len, global_batch=args.batch,
        checkpoint_dir=args.checkpoint_dir, log_every=20,
        opt=AdamWConfig(peak_lr=args.lr, warmup_steps=args.steps // 10,
                        total_steps=args.steps),
    )
    out = train(cfg, tcfg)
    first, last = out["losses"][0], out["losses"][-1]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first - 0.2 else 'check hyperparams'})")


if __name__ == "__main__":
    main()

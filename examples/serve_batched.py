"""Batched serving example: prefill a prompt batch, decode N tokens.

    PYTHONPATH=src python examples/serve_batched.py --arch yi-6b --tokens 16
"""
import argparse

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.models import registry
from repro.serve.engine import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    api = registry.get(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(
        cfg, params,
        ServeConfig(max_len=args.prompt_len + args.tokens + 8,
                    temperature=args.temperature),
    )
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32
    )
    extras = {}
    if cfg.n_patches:
        extras["patches"] = jax.random.normal(
            jax.random.PRNGKey(9), (args.batch, cfg.n_patches, cfg.d_model)
        )
    if cfg.is_encoder_decoder:
        extras["frames"] = jax.random.normal(
            jax.random.PRNGKey(10), (args.batch, cfg.encoder_len, cfg.d_model)
        )
    out = engine.generate(prompts, args.tokens, extras=extras or None)
    print(f"arch {args.arch}: generated {out.shape} "
          f"(batch {args.batch}, {args.tokens} new tokens each)")
    print("continuations:")
    for row in out[:, args.prompt_len:]:
        print("  ", row.tolist())


if __name__ == "__main__":
    main()

"""Quickstart: the SU3 engine (the paper's workload) through the public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.su3_bench import SMOKE_L8
from repro.core import roofline
from repro.core.su3.engine import SU3Engine
from repro.kernels import ops, ref


def main() -> None:
    print(f"devices: {jax.devices()}")

    # 1. the kernel, canonical complex form, vs the oracle
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (1024, 4, 3, 3, 2))
    a = jax.lax.complex(a[..., 0], a[..., 1])
    b = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 3, 2))
    b = jax.lax.complex(b[..., 0], b[..., 1])
    c = ops.su3_mult(a, b)  # Pallas kernel (interpret mode on CPU)
    err = float(abs(c - ref.su3_mult_ref(a, b)).max())
    print(f"pallas vs oracle max err: {err:.2e}")

    # 2. the paper's benchmark loop (L=8 smoke config)
    result = SU3Engine(SMOKE_L8).run()
    print(f"engine: {result.row()}")

    # 3. the three-term roofline for the paper's L=32 on TPU v5e
    rep = roofline.analytic_su3_report(
        n_sites=32**4, word_bytes=4, bytes_per_site_rw=576, n_chips=1
    )
    print(rep.summary())
    print(f"v5e bandwidth-bound GF/s (SoA): "
          f"{roofline.TPU_V5E.hbm_bw * (864 / 576) / 1e9:.0f}")


if __name__ == "__main__":
    main()

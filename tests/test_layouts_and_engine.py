"""Layout roundtrips (hypothesis), traffic model vs the paper's numbers,
and the SU3 engine end-to-end on every placement/layout/variant combo."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.su3 import layouts
from repro.core.su3.engine import EngineConfig, SU3Engine
from repro.core.su3.layouts import Layout, TrafficModel


@hypothesis.settings(deadline=None, max_examples=25)
@hypothesis.given(n_sites=st.integers(1, 500), seed=st.integers(0, 2**31 - 1))
def test_layout_roundtrips(n_sites, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (n_sites, 4, 3, 3, 2))
    a = jax.lax.complex(a[..., 0], a[..., 1])
    for pack, unpack, args in [
        (layouts.pack_aos, layouts.unpack_aos, ()),
        (layouts.pack_soa, layouts.unpack_soa, ()),
    ]:
        rt = unpack(pack(a), *args) if not args else None
        np.testing.assert_allclose(np.asarray(rt), np.asarray(a), rtol=1e-6)
    rt = layouts.unpack_aosoa(layouts.pack_aosoa(a), n_sites)
    np.testing.assert_allclose(np.asarray(rt), np.asarray(a), rtol=1e-6)


def test_paper_arithmetic_intensity():
    # §3.1: AI = 864/(320*2) = 1.35 fp32; 0.675 fp64
    assert layouts.paper_arithmetic_intensity(4) == pytest.approx(1.35)
    assert layouts.paper_arithmetic_intensity(8) == pytest.approx(0.675)


def test_traffic_model_layouts():
    aos = TrafficModel(Layout.AOS, 1000, 4)
    soa = TrafficModel(Layout.SOA, 1000, 4)
    # paper: site struct is 320 B of which 288 B is gauge field
    assert aos.bytes_per_site_rw == 2 * 320
    assert soa.bytes_per_site_rw == 2 * 288
    # SoA removes exactly the padding traffic -> higher AI
    assert soa.arithmetic_intensity > aos.arithmetic_intensity
    assert soa.arithmetic_intensity == pytest.approx(864 / 576)


def test_site_sizes_match_paper():
    # §3.1: L=32 -> A is 320 MiB fp32
    shape = layouts.LatticeShape(32)
    assert shape.n_sites * 320 == 320 * 1024**2


@pytest.mark.parametrize("placement", ["sharded", "host_scatter", "replicated"])
def test_engine_placements(placement):
    cfg = EngineConfig(L=4, placement=placement, iterations=2, warmups=0, tile=128)
    r = SU3Engine(cfg).run()
    assert r.verified
    assert r.gflops > 0


@pytest.mark.parametrize(
    "layout,variant",
    [(Layout.SOA, "pallas"), (Layout.AOSOA, "pallas"),
     (Layout.SOA, "versionX"), (Layout.AOS, "version_gemm"),
     (Layout.SOA, "version0"), (Layout.AOS, "version3")],
)
def test_engine_layout_variant_matrix(layout, variant):
    cfg = EngineConfig(L=4, layout=layout, variant=variant, iterations=1, warmups=0, tile=128)
    r = SU3Engine(cfg).run()
    assert r.verified, (layout, variant)


def test_engine_bfloat16():
    cfg = EngineConfig(L=4, dtype="bfloat16", iterations=1, warmups=0, tile=128)
    assert SU3Engine(cfg).run().verified

"""Mamba2/xLSTM recurrence parity and MoE dispatch vs dense oracle."""
import dataclasses

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import common, mamba2, moe, xlstm


def _hybrid_cfg():
    return ModelConfig(name="h", family="hybrid", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=4, d_ff=128, vocab_size=97, dtype="float32",
                       ssm_state=16, ssm_heads=4, ssm_expand=2)


@hypothesis.settings(deadline=None, max_examples=10)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([4, 8, 16]))
def test_ssd_chunked_vs_sequential(seed, chunk):
    k = jax.random.PRNGKey(seed)
    b, s, h, p, n = 2, 16, 2, 4, 8
    x = jax.random.normal(k, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (b, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (h,)))
    b_in = jax.random.normal(jax.random.fold_in(k, 3), (b, s, n))
    c_in = jax.random.normal(jax.random.fold_in(k, 4), (b, s, n))
    y, hf = mamba2.ssd_chunked(x, dt, a, b_in, c_in, chunk=chunk)
    y_ref, hf_ref = mamba2.ssd_ref(x, dt, a, b_in, c_in)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hf_ref), rtol=1e-3, atol=1e-4)


def test_mamba2_prefill_decode_parity():
    cfg = _hybrid_cfg()
    params = common.init_params(mamba2.spec(cfg), jax.random.PRNGKey(5))
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 16, 64))
    y_full, _ = mamba2.apply(params, x, cfg, chunk=8)
    st_ = mamba2.init_state(cfg, 2)
    outs = []
    for t in range(16):
        o, st_ = mamba2.apply(params, x[:, t : t + 1], cfg, state=st_)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(y_full), rtol=1e-3, atol=1e-4
    )


def test_mamba2_chunked_prefill_with_state():
    """prefill in two halves with carried state == one-shot prefill."""
    cfg = _hybrid_cfg()
    params = common.init_params(mamba2.spec(cfg), jax.random.PRNGKey(5))
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 16, 64))
    y_full, _ = mamba2.apply(params, x, cfg, chunk=8)
    st_ = mamba2.init_state(cfg, 2)
    y1, st_ = mamba2.apply(params, x[:, :8], cfg, state=st_, chunk=4)
    y2, st_ = mamba2.apply(params, x[:, 8:], cfg, state=st_, chunk=4)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), rtol=1e-3, atol=1e-4
    )


@pytest.mark.parametrize("kind", ["mlstm", "slstm"])
def test_xlstm_parity(kind):
    cfg = ModelConfig(name="x", family="ssm", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, d_ff=0, vocab_size=97, dtype="float32",
                      ssm_expand=2, ssm_conv=4)
    specf = xlstm.mlstm_spec if kind == "mlstm" else xlstm.slstm_spec
    applyf = xlstm.mlstm_apply if kind == "mlstm" else xlstm.slstm_apply
    statef = xlstm.mlstm_init_state if kind == "mlstm" else xlstm.slstm_init_state
    params = common.init_params(specf(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 64))
    y_full, _ = applyf(params, x, cfg)
    st_ = statef(cfg, 2)
    outs = []
    for t in range(12):
        o, st_ = applyf(params, x[:, t : t + 1], cfg, state=st_)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(y_full), rtol=1e-3, atol=2e-4
    )
    assert np.all(np.isfinite(np.asarray(y_full)))


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def _moe_cfg(**kw):
    base = dict(name="m", family="moe", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=97, dtype="float32",
                n_experts=8, experts_per_token=2, n_shared_experts=1,
                d_ff_expert=32, capacity_factor=8.0)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("aux_free", [False, True])
def test_moe_matches_dense_oracle(aux_free):
    cfg = _moe_cfg(router_aux_free=aux_free)
    params = common.init_params(moe.spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    out, aux = moe.apply(params, x, cfg)
    expected = moe.moe_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-4, atol=1e-5)
    assert np.isfinite(float(aux))


def test_moe_chunked_matches_unchunked():
    cfg = _moe_cfg()
    params = common.init_params(moe.spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 64))
    out_c, _ = moe.apply(params, x, cfg, token_chunk=16)
    out_u, _ = moe.apply(params, x, cfg, token_chunk=10**9)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_u), rtol=1e-5, atol=1e-6)


def test_moe_capacity_drops_tokens():
    """At capacity_factor -> 0 every routed token is dropped; only the
    shared-expert path remains."""
    cfg = _moe_cfg(capacity_factor=1e-9, n_shared_experts=0)
    params = common.init_params(moe.spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    out, _ = moe.apply(params, x, cfg)
    # capacity 1 per expert: at most E tokens survive per group
    assert float(jnp.mean(jnp.abs(out))) < float(jnp.mean(jnp.abs(moe.moe_ref(params, x, cfg))))


@hypothesis.settings(deadline=None, max_examples=10)
@hypothesis.given(seed=st.integers(0, 2**31 - 1))
def test_moe_dispatch_weight_conservation(seed):
    """Each surviving token's combine weights sum to <= 1 (normalized)."""
    cfg = _moe_cfg()
    params = common.init_params(moe.spec(cfg), jax.random.PRNGKey(seed % 1000))
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 8, 64))
    w, idx, _ = moe._route(params, x, cfg)
    s = np.asarray(jnp.sum(w, -1))
    assert np.all(s <= 1.0 + 1e-5)
    assert np.all(s >= 0.99)  # normalized

"""Pallas flash-attention TPU kernel vs oracle: shape/dtype/block sweeps
(interpret mode on CPU) + VMEM budget check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.roofline import TPU_V5E
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_tpu, vmem_bytes


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (4, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_tpu_vs_ref(hq, hkv, causal):
    b, s, d = 2, 64, 32
    k0 = jax.random.PRNGKey(hq * 7 + hkv + int(causal))
    q = jax.random.normal(k0, (b, s, hq, d))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (b, s, hkv, d))
    out = flash_attention_tpu(q, k, v, causal=causal, block_q=16, block_k=32,
                              interpret=True)
    expected = kref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(16, 16), (32, 64), (64, 32)])
def test_flash_tpu_block_sweep(block_q, block_k):
    b, s, h, d = 1, 128, 4, 16
    k0 = jax.random.PRNGKey(block_q + block_k)
    q = jax.random.normal(k0, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (b, s, h, d))
    out = flash_attention_tpu(q, k, v, block_q=block_q, block_k=block_k,
                              interpret=True)
    expected = kref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)


def test_flash_tpu_bf16():
    b, s, h, d = 2, 64, 4, 32
    k0 = jax.random.PRNGKey(0)
    q = jax.random.normal(k0, (b, s, h, d)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(k0, 1), (b, s, h, d)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(k0, 2), (b, s, h, d)).astype(jnp.bfloat16)
    out = flash_attention_tpu(q, k, v, block_q=16, block_k=32, interpret=True)
    expected = kref.flash_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(expected),
                               rtol=5e-2, atol=5e-2)


def test_flash_tpu_vmem_budget():
    # prefill_32k config: per grid step working set must fit VMEM
    assert vmem_bytes(block_q=256, block_k=256, skv=32768, d=128, g=6) < TPU_V5E.vmem_bytes * 8
    assert vmem_bytes(block_q=256, block_k=256, skv=4096, d=128, g=4) < TPU_V5E.vmem_bytes

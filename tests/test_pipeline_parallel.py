"""GPipe pipeline parallelism: exactness vs the sequential stack (values
and gradients) on a multi-host-device subprocess mesh."""
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_forward, sequential_reference
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("pipe",))
S, M, mb, d = 4, 6, 2, 16
key = jax.random.PRNGKey(0)
params = {
    "w": jax.random.normal(key, (S, d, d)) * 0.3,
    "b": jax.random.normal(jax.random.fold_in(key, 1), (S, d)) * 0.1,
}
x = jax.random.normal(jax.random.fold_in(key, 2), (M, mb, d))

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

out_pipe = pipeline_forward(params, x, stage_fn, mesh=mesh)
out_seq = sequential_reference(params, x, stage_fn)
err = float(jnp.max(jnp.abs(out_pipe - out_seq)))
assert err < 1e-5, f"forward mismatch {err}"

# gradients: GPipe backward via autodiff of the schedule
def loss_pipe(p):
    return jnp.sum(pipeline_forward(p, x, stage_fn, mesh=mesh) ** 2)

def loss_seq(p):
    return jnp.sum(sequential_reference(p, x, stage_fn) ** 2)

g_pipe = jax.grad(loss_pipe)(params)
g_seq = jax.grad(loss_seq)(params)
gerr = max(float(jnp.max(jnp.abs(a - b))) for a, b in
           zip(jax.tree.leaves(g_pipe), jax.tree.leaves(g_seq)))
assert gerr < 1e-4, f"grad mismatch {gerr}"
print("PIPELINE_OK", err, gerr)
"""


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        env=env, timeout=300, cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PIPELINE_OK" in out.stdout
